//! Batched data pipeline with a background prefetch thread.
//!
//! The coordinator's training loop consumes batches from here; generation
//! (procedural images) runs on a worker thread so the PJRT execute path is
//! never stalled on data (L3 perf target: coordinator overhead < 10% of
//! step time — see docs/ARCHITECTURE.md §Experiments).

use super::synthcifar;
use crate::nn::tensor::Tensor;
use std::sync::mpsc;
use std::thread;

/// One training batch.
pub struct Batch {
    /// NCHW f32 images.
    pub images: Tensor,
    /// Labels, len = batch size.
    pub labels: Vec<usize>,
    /// Global step/batch index this batch was generated for.
    pub index: u64,
}

/// Configuration for the loader.
#[derive(Clone, Copy, Debug)]
pub struct LoaderCfg {
    pub seed: u64,
    pub batch_size: usize,
    /// How many batches to buffer ahead.
    pub prefetch: usize,
    /// Dataset size: indices are drawn modulo this (epoch wrap-around),
    /// shuffled per epoch by an affine permutation.
    pub dataset_size: u64,
}

impl Default for LoaderCfg {
    fn default() -> Self {
        LoaderCfg {
            seed: synthcifar::TRAIN_SEED,
            batch_size: 64,
            prefetch: 4,
            dataset_size: 50_000,
        }
    }
}

/// Streaming loader: spawns a generator thread, yields batches in order.
pub struct Loader {
    rx: mpsc::Receiver<Batch>,
    _handle: thread::JoinHandle<()>,
}

/// Affine "shuffle": maps position `i` within an epoch to a dataset index
/// via `(a*i + b) mod n` with `a` coprime to `n` — a cheap deterministic
/// permutation that differs every epoch.
fn permuted_index(epoch: u64, pos: u64, n: u64) -> u64 {
    // Odd multiplier is coprime to any power-of-two-free n as long as
    // gcd(a, n) == 1; pick from a fixed table of large primes.
    const PRIMES: [u64; 8] = [
        1_000_003, 1_000_033, 1_000_037, 1_000_039, 1_000_081, 1_000_099,
        1_000_117, 1_000_121,
    ];
    let a = PRIMES[(epoch % 8) as usize] % n;
    let a = if gcd(a, n) == 1 { a } else { 1 };
    let b = epoch.wrapping_mul(0x9E3779B9) % n;
    (a.wrapping_mul(pos) + b) % n
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Loader {
    pub fn new(cfg: LoaderCfg) -> Loader {
        let (tx, rx) = mpsc::sync_channel(cfg.prefetch);
        let handle = thread::spawn(move || {
            let per_epoch = cfg.dataset_size / cfg.batch_size as u64;
            let mut batch_index = 0u64;
            loop {
                let epoch = batch_index / per_epoch.max(1);
                let pos_in_epoch = batch_index % per_epoch.max(1);
                let start = pos_in_epoch * cfg.batch_size as u64;
                let mut data = Vec::with_capacity(
                    cfg.batch_size * synthcifar::CHANNELS * synthcifar::IMAGE_HW * synthcifar::IMAGE_HW,
                );
                let mut labels = Vec::with_capacity(cfg.batch_size);
                for b in 0..cfg.batch_size as u64 {
                    let idx = permuted_index(epoch, start + b, cfg.dataset_size);
                    let ex = synthcifar::generate(cfg.seed, idx);
                    data.extend_from_slice(&ex.image);
                    labels.push(ex.label);
                }
                let images = Tensor::from_vec(
                    &[
                        cfg.batch_size,
                        synthcifar::CHANNELS,
                        synthcifar::IMAGE_HW,
                        synthcifar::IMAGE_HW,
                    ],
                    data,
                );
                let batch = Batch { images, labels, index: batch_index };
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
                batch_index += 1;
            }
        });
        Loader { rx, _handle: handle }
    }

    /// Next batch (blocks on the prefetch channel).
    pub fn next(&self) -> Batch {
        self.rx.recv().expect("loader thread died")
    }
}

/// Fixed evaluation set, generated eagerly (no thread).
pub fn eval_set(num_batches: usize, batch_size: usize) -> Vec<(Tensor, Vec<usize>)> {
    (0..num_batches)
        .map(|b| {
            synthcifar::generate_batch(
                synthcifar::TEST_SEED,
                (b * batch_size) as u64,
                batch_size,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loader_yields_correct_shapes() {
        let loader = Loader::new(LoaderCfg {
            batch_size: 8,
            prefetch: 2,
            dataset_size: 64,
            ..Default::default()
        });
        let b = loader.next();
        assert_eq!(b.images.dims, vec![8, 3, 32, 32]);
        assert_eq!(b.labels.len(), 8);
        assert_eq!(b.index, 0);
        let b2 = loader.next();
        assert_eq!(b2.index, 1);
    }

    #[test]
    fn epochs_reshuffle() {
        // dataset of exactly one batch: epoch 0 and epoch 1 see the same
        // examples but (generally) in a different order / offset.
        let loader = Loader::new(LoaderCfg {
            batch_size: 16,
            prefetch: 2,
            dataset_size: 16,
            ..Default::default()
        });
        let e0 = loader.next();
        let e1 = loader.next();
        let mut s0 = e0.labels.clone();
        let mut s1 = e1.labels.clone();
        assert_ne!(e0.labels, e1.labels, "expected epoch reshuffle");
        s0.sort();
        s1.sort();
        assert_eq!(s0, s1, "same multiset across epochs");
    }

    #[test]
    fn permutation_is_bijective() {
        let n = 1000u64;
        for epoch in 0..3 {
            let mut seen = vec![false; n as usize];
            for pos in 0..n {
                let idx = permuted_index(epoch, pos, n) as usize;
                assert!(!seen[idx], "collision at epoch {epoch} pos {pos}");
                seen[idx] = true;
            }
        }
    }

    #[test]
    fn eval_set_deterministic() {
        let a = eval_set(2, 4);
        let b = eval_set(2, 4);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].0.data, b[0].0.data);
        assert_eq!(a[1].1, b[1].1);
    }
}
