//! Synthetic CIFAR-10-like dataset — the environment substitution for
//! CIFAR10 (see docs/ARCHITECTURE.md §Experiments: no dataset download is possible here).
//!
//! Ten classes of procedurally generated 32×32×3 images. Each class is
//! defined by a deterministic template mixing: (a) a class-specific 2-D
//! sinusoidal texture (frequency/phase/orientation), (b) a class-specific
//! geometric mask (disk/stripe/checker of varying size), and (c) a class
//! colour balance. Samples draw the template through a random affine jitter
//! (shift/flip), amplitude scaling, plus i.i.d. pixel noise — enough
//! intra-class variance that a linear model cannot solve it while a small
//! convnet reaches high accuracy in a few hundred steps, and enough texture
//! that convolution-path quantization noise measurably moves accuracy
//! (the property the paper's Tables 1–2 depend on).
//!
//! Everything is deterministic in (seed, index): train and eval splits are
//! reproducible across rust (this module) and any other consumer.

use crate::nn::tensor::Tensor;
use crate::wino::error::Prng;

pub const NUM_CLASSES: usize = 10;
pub const IMAGE_HW: usize = 32;
pub const CHANNELS: usize = 3;

/// Deterministic per-class generation constants.
#[derive(Clone, Copy, Debug)]
struct ClassSpec {
    freq_x: f64,
    freq_y: f64,
    phase: f64,
    /// 0 = disk, 1 = stripes, 2 = checker
    shape: u8,
    shape_scale: f64,
    color: [f64; 3],
}

fn class_spec(class: usize) -> ClassSpec {
    assert!(class < NUM_CLASSES);
    let c = class as f64;
    ClassSpec {
        freq_x: 0.5 + 0.45 * c,
        freq_y: 2.8 - 0.22 * c,
        phase: 0.7 * c,
        shape: (class % 3) as u8,
        shape_scale: 5.0 + (class as f64) * 1.3,
        color: [
            0.4 + 0.06 * ((class * 3) % 7) as f64,
            0.4 + 0.06 * ((class * 5) % 7) as f64,
            0.4 + 0.06 * ((class * 2) % 7) as f64,
        ],
    }
}

/// A labelled example.
#[derive(Clone, Debug)]
pub struct Example {
    /// CHW, f32, roughly zero-mean unit-range.
    pub image: Vec<f32>,
    pub label: usize,
}

/// Generate example `index` of the split with the given base seed.
/// (seed, index) fully determines the output.
pub fn generate(seed: u64, index: u64) -> Example {
    let label = (index % NUM_CLASSES as u64) as usize;
    let spec = class_spec(label);
    let mut rng = Prng::new(
        seed ^ index.wrapping_mul(0xD1B54A32D192ED03) ^ 0x94D049BB133111EB,
    );
    // Random affine jitter.
    let dx = rng.uniform(4.0);
    let dy = rng.uniform(4.0);
    let flip = rng.next_u64() & 1 == 1;
    let amp = 0.7 + 0.3 * ((rng.next_u64() >> 40) as f64 / (1u64 << 24) as f64);
    let noise_level = 0.12;

    let hw = IMAGE_HW;
    let mut image = vec![0.0f32; CHANNELS * hw * hw];
    for y in 0..hw {
        for x in 0..hw {
            let xs = if flip { (hw - 1 - x) as f64 } else { x as f64 };
            let xf = (xs + dx) / hw as f64 * std::f64::consts::TAU;
            let yf = (y as f64 + dy) / hw as f64 * std::f64::consts::TAU;
            // (a) class texture
            let tex = (spec.freq_x * xf + spec.phase).sin()
                * (spec.freq_y * yf).cos();
            // (b) class geometry
            let cx = xs + dx - hw as f64 / 2.0;
            let cy = y as f64 + dy - hw as f64 / 2.0;
            let geo = match spec.shape {
                0 => {
                    // disk
                    if (cx * cx + cy * cy).sqrt() < spec.shape_scale {
                        1.0
                    } else {
                        -0.4
                    }
                }
                1 => {
                    // stripes
                    if ((cx / spec.shape_scale * 2.0).floor() as i64) % 2 == 0 {
                        0.8
                    } else {
                        -0.8
                    }
                }
                _ => {
                    // checker
                    let q = ((cx / spec.shape_scale).floor()
                        + (cy / spec.shape_scale).floor()) as i64;
                    if q % 2 == 0 {
                        0.8
                    } else {
                        -0.8
                    }
                }
            };
            let signal = amp * (0.55 * tex + 0.45 * geo);
            for ch in 0..CHANNELS {
                let v = signal * spec.color[ch] + noise_level * rng.uniform(1.0);
                image[(ch * hw + y) * hw + x] = v as f32;
            }
        }
    }
    Example { image, label }
}

/// Generate a whole batch as an NCHW tensor plus labels.
/// Indices `start..start+batch` of the (seed)-split.
pub fn generate_batch(seed: u64, start: u64, batch: usize) -> (Tensor, Vec<usize>) {
    let hw = IMAGE_HW;
    let mut data = Vec::with_capacity(batch * CHANNELS * hw * hw);
    let mut labels = Vec::with_capacity(batch);
    for b in 0..batch {
        let ex = generate(seed, start + b as u64);
        data.extend_from_slice(&ex.image);
        labels.push(ex.label);
    }
    (
        Tensor::from_vec(&[batch, CHANNELS, hw, hw], data),
        labels,
    )
}

/// Canonical split seeds, so every consumer agrees on what "train"/"test"
/// mean.
pub const TRAIN_SEED: u64 = 0x5EED_7EA1;
pub const TEST_SEED: u64 = 0x7E57_0DD5;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed_and_index() {
        let a = generate(TRAIN_SEED, 123);
        let b = generate(TRAIN_SEED, 123);
        assert_eq!(a.image, b.image);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn different_indices_differ() {
        let a = generate(TRAIN_SEED, 0);
        let b = generate(TRAIN_SEED, 10); // same label (10 % 10 == 0)
        assert_eq!(a.label, b.label);
        assert_ne!(a.image, b.image, "intra-class variance required");
    }

    #[test]
    fn train_and_test_splits_differ() {
        let a = generate(TRAIN_SEED, 5);
        let b = generate(TEST_SEED, 5);
        assert_ne!(a.image, b.image);
    }

    #[test]
    fn labels_balanced() {
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..1000u64 {
            counts[generate(TRAIN_SEED, i).label] += 1;
        }
        for c in counts {
            assert_eq!(c, 100);
        }
    }

    #[test]
    fn values_bounded() {
        for i in 0..50u64 {
            let ex = generate(TRAIN_SEED, i);
            for &v in &ex.image {
                assert!(v.is_finite() && v.abs() < 3.0, "pixel out of range: {v}");
            }
        }
    }

    #[test]
    fn batch_layout_matches_singles() {
        let (batch, labels) = generate_batch(TRAIN_SEED, 7, 4);
        assert_eq!(batch.dims, vec![4, 3, 32, 32]);
        for b in 0..4 {
            let ex = generate(TRAIN_SEED, 7 + b as u64);
            assert_eq!(labels[b], ex.label);
            let chw = 3 * 32 * 32;
            assert_eq!(&batch.data[b * chw..(b + 1) * chw], &ex.image[..]);
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean per-class images must differ pairwise by a margin — the
        // classes carry signal.
        let mean_img = |class: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; 3 * 32 * 32];
            let mut count = 0;
            for i in 0..200u64 {
                let ex = generate(TRAIN_SEED, i);
                if ex.label == class {
                    for (a, &v) in acc.iter_mut().zip(&ex.image) {
                        *a += v;
                    }
                    count += 1;
                }
            }
            acc.iter().map(|v| v / count as f32).collect()
        };
        let m0 = mean_img(0);
        let m1 = mean_img(1);
        let dist: f32 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }
}
