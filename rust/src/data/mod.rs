//! Data substrate: the synthetic CIFAR-10 substitute and the prefetching
//! batch loader feeding the training coordinator.

pub mod loader;
pub mod synthcifar;

pub use loader::{Batch, Loader, LoaderCfg};
