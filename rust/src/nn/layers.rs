//! Inference-path NN layers (pure rust, NCHW): direct conv2d, batch norm,
//! ReLU, linear, pooling. These are the building blocks of the rust-side
//! ResNet18 (`nn::resnet`) used by the serving example and the direct-conv
//! baseline of the throughput bench.

use super::tensor::Tensor;

/// 2-D convolution (correlation) parameters.
#[derive(Clone, Copy, Debug)]
pub struct Conv2dCfg {
    pub stride: usize,
    pub padding: usize,
}

impl Default for Conv2dCfg {
    fn default() -> Self {
        Conv2dCfg { stride: 1, padding: 0 }
    }
}

/// Direct conv2d: `x` [N,C,H,W], `w` [K,C,R,S] → [N,K,H',W'] with
/// `H' = (H + 2p − R)/stride + 1`.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: Option<&[f32]>, cfg: Conv2dCfg) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c, h, wd) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (k, wc, r, s) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    assert_eq!(c, wc, "channel mismatch");
    let oh = (h + 2 * cfg.padding - r) / cfg.stride + 1;
    let ow = (wd + 2 * cfg.padding - s) / cfg.stride + 1;
    let mut y = Tensor::zeros(&[n, k, oh, ow]);
    for ni in 0..n {
        for ki in 0..k {
            let b = bias.map_or(0.0, |bs| bs[ki]);
            for oi in 0..oh {
                for oj in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for ri in 0..r {
                            let ih = (oi * cfg.stride + ri) as isize - cfg.padding as isize;
                            if ih < 0 || ih >= h as isize {
                                continue;
                            }
                            for si in 0..s {
                                let iw = (oj * cfg.stride + si) as isize - cfg.padding as isize;
                                if iw < 0 || iw >= wd as isize {
                                    continue;
                                }
                                acc += x.at4(ni, ci, ih as usize, iw as usize)
                                    * w.at4(ki, ci, ri, si);
                            }
                        }
                    }
                    *y.at4_mut(ni, ki, oi, oj) = acc + b;
                }
            }
        }
    }
    y
}

/// Inference-time batch norm: `y = gamma * (x − mean)/sqrt(var + eps) + beta`
/// per channel.
pub fn batchnorm(x: &Tensor, gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32], eps: f32) -> Tensor {
    assert_eq!(x.rank(), 4);
    let c = x.dims[1];
    assert!(gamma.len() == c && beta.len() == c && mean.len() == c && var.len() == c);
    let mut y = x.clone();
    let (n, _, h, w) = (x.dims[0], c, x.dims[2], x.dims[3]);
    for ci in 0..c {
        let inv = 1.0 / (var[ci] + eps).sqrt();
        let g = gamma[ci] * inv;
        let b = beta[ci] - mean[ci] * g;
        for ni in 0..n {
            for hi in 0..h {
                for wi in 0..w {
                    let v = y.at4(ni, ci, hi, wi);
                    *y.at4_mut(ni, ci, hi, wi) = v * g + b;
                }
            }
        }
    }
    y
}

pub fn relu(x: &Tensor) -> Tensor {
    x.map(|v| v.max(0.0))
}

/// Global average pool: [N,C,H,W] → [N,C].
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (n, c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut y = Tensor::zeros(&[n, c]);
    let denom = (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0.0;
            for hi in 0..h {
                for wi in 0..w {
                    acc += x.at4(ni, ci, hi, wi);
                }
            }
            y.data[ni * c + ci] = acc / denom;
        }
    }
    y
}

/// Fully connected: `x` [N,F] × `w` [F,O] + b[O] → [N,O].
pub fn linear(x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
    assert_eq!(x.rank(), 2);
    assert_eq!(w.rank(), 2);
    let (n, f) = (x.dims[0], x.dims[1]);
    let (wf, o) = (w.dims[0], w.dims[1]);
    assert_eq!(f, wf);
    assert_eq!(bias.len(), o);
    let mut y = Tensor::zeros(&[n, o]);
    for ni in 0..n {
        for oi in 0..o {
            let mut acc = bias[oi];
            for fi in 0..f {
                acc += x.at2(ni, fi) * w.at2(fi, oi);
            }
            y.data[ni * o + oi] = acc;
        }
    }
    y
}

/// Zero-pad the spatial dims of an NCHW tensor.
pub fn pad_hw(x: &Tensor, pad: usize) -> Tensor {
    if pad == 0 {
        return x.clone();
    }
    let (n, c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut y = Tensor::zeros(&[n, c, h + 2 * pad, w + 2 * pad]);
    for ni in 0..n {
        for ci in 0..c {
            for hi in 0..h {
                for wi in 0..w {
                    *y.at4_mut(ni, ci, hi + pad, wi + pad) = x.at4(ni, ci, hi, wi);
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv2d_identity_1x1() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d(&x, &w, None, Conv2dCfg::default());
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv2d_3x3_known() {
        // All-ones 3×3 kernel over a 3×3 input of 1..9 sums everything = 45.
        let x = Tensor::from_vec(&[1, 1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let y = conv2d(&x, &w, None, Conv2dCfg::default());
        assert_eq!(y.dims, vec![1, 1, 1, 1]);
        assert_eq!(y.data, vec![45.0]);
    }

    #[test]
    fn conv2d_padding_same() {
        let x = Tensor::from_vec(&[1, 1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        let y = conv2d(&x, &w, None, Conv2dCfg { stride: 1, padding: 1 });
        // Center-tap kernel with same-padding reproduces the input.
        assert_eq!(y.dims, vec![1, 1, 3, 3]);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv2d_stride() {
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d(&x, &w, None, Conv2dCfg { stride: 2, padding: 0 });
        assert_eq!(y.dims, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn conv2d_multichannel_accumulates() {
        let x = Tensor::from_vec(&[1, 2, 1, 1], vec![3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 2, 1, 1], vec![10.0, 100.0]);
        let y = conv2d(&x, &w, None, Conv2dCfg::default());
        assert_eq!(y.data, vec![430.0]);
    }

    #[test]
    fn conv2d_bias() {
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let w = Tensor::from_vec(&[2, 1, 1, 1], vec![2.0, 3.0]);
        let y = conv2d(&x, &w, Some(&[10.0, 20.0]), Conv2dCfg::default());
        assert_eq!(y.data, vec![12.0, 23.0]);
    }

    #[test]
    fn batchnorm_normalises() {
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![4.0, 6.0]);
        let y = batchnorm(&x, &[1.0], &[0.0], &[5.0], &[1.0], 0.0);
        assert_eq!(y.data, vec![-1.0, 1.0]);
    }

    #[test]
    fn relu_clamps() {
        let x = Tensor::from_vec(&[3], vec![-1.0, 0.0, 2.0]);
        assert_eq!(relu(&x).data, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn gap_means() {
        let x = Tensor::from_vec(&[1, 2, 1, 2], vec![1.0, 3.0, 10.0, 30.0]);
        let y = global_avg_pool(&x);
        assert_eq!(y.dims, vec![1, 2]);
        assert_eq!(y.data, vec![2.0, 20.0]);
    }

    #[test]
    fn linear_known() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = linear(&x, &w, &[0.5, -0.5]);
        assert_eq!(y.data, vec![1.5, 1.5]);
    }

    #[test]
    fn pad_hw_zero_border() {
        let x = Tensor::from_vec(&[1, 1, 1, 1], vec![5.0]);
        let y = pad_hw(&x, 1);
        assert_eq!(y.dims, vec![1, 1, 3, 3]);
        assert_eq!(y.at4(0, 0, 1, 1), 5.0);
        assert_eq!(y.at4(0, 0, 0, 0), 0.0);
    }
}
