//! Winograd convolution layer over NCHW tensors — the rust serving-path
//! counterpart of the JAX/Pallas winograd-aware *training* layer, which
//! lives in `python/compile/` (`wino.py` constructs the same exact
//! matrices, `layers.py`/`model.py` build the fake-quant training graph);
//! `python/tests/test_wino_matrices.py` pins both halves to identical
//! constants.
//!
//! Tiles the padded input into N×N patches with stride m, transforms each
//! patch once, multiplies against pre-transformed weights with channel
//! accumulation in the Winograd domain, and back-transforms — i.e. the
//! standard layer-level amortisation the paper's §1 describes ("the cost of
//! transformations amortizes over multiple uses"). Supports all bases and
//! the quantized pipeline of Fig. 2.
//!
//! Execution is delegated to the batched flat-buffer
//! [`WinoEngine`](crate::engine::WinoEngine); the original per-tile
//! nested-loop evaluation is kept as
//! [`WinoConv2d::forward_reference`] — the bit-for-bit validation oracle
//! the engine parity tests run against.
//!
//! Quantized layers additionally lower an
//! [`IntWinoEngine`](crate::engine::int::IntWinoEngine) (i16 code
//! panels, integer-domain channel reduction) at
//! [`WinoConv2d::quantize`] time, and [`WinoConv2d::forward`] dispatches
//! to it — the paper's core quantized scenario is the fast path, with
//! the fake-quant float engine kept as the explicit
//! [`WinoConv2d::forward_float`] route (training semantics / baseline).

use super::layers::{pad_hw, Conv2dCfg};
use super::tensor::Tensor;
use crate::engine::int::{IntWeightBank, IntWinoEngine};
use crate::engine::layout::extract_tile;
use crate::engine::{transform_weight_bank, EngineScratch, PackedF64, WinoEngine};
use crate::quant::scheme::{QuantConfig, Quantizer};
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

/// Which execution engine a layer's [`WinoConv2d::forward`] dispatches
/// to — the fallback ladder the serving circuit breaker walks when the
/// drift monitor reports persistent budget violations
/// (`Int → Float → Direct`, re-armed back to `Int` after a quiet
/// period). Stored on the layer as an atomic so the serving drift probe
/// can flip it through a shared `&dyn BatchModel` without locks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EngineMode {
    /// Tuned quantized path: the integer-domain engine when the layer
    /// has one (the default, and what the NetPlan calibrated for).
    Int = 0,
    /// Degraded one rung: the float fake-quant Winograd engine —
    /// same transform algebra, no integer rounding on activations.
    Float = 1,
    /// Degraded fully: bypass Winograd and run direct convolution
    /// (dispatched by the *model* — see `ResNet18::conv_unit`; the
    /// layer itself serves the float engine for callers that cannot
    /// bypass, e.g. the drift probe's per-layer forward).
    Direct = 2,
}

impl EngineMode {
    /// Stable lowercase label (`"int"`/`"float"`/`"direct"`) — what the
    /// `fallback_engaged`/`fallback_cleared` trace events carry.
    pub fn as_str(self) -> &'static str {
        match self {
            EngineMode::Int => "int",
            EngineMode::Float => "float",
            EngineMode::Direct => "direct",
        }
    }

    /// Inverse of the `repr(u8)` discriminant (atomic storage decode).
    fn from_u8(v: u8) -> EngineMode {
        match v {
            1 => EngineMode::Float,
            2 => EngineMode::Direct,
            _ => EngineMode::Int,
        }
    }

    /// One rung down the fallback ladder (`Direct` is the floor).
    pub fn degraded(self) -> EngineMode {
        match self {
            EngineMode::Int => EngineMode::Float,
            EngineMode::Float | EngineMode::Direct => EngineMode::Direct,
        }
    }
}

/// Per-layer quantization state (calibrated scales), if quantization is on.
#[derive(Clone, Copy, Debug)]
pub struct LayerScales {
    pub input: Quantizer,
    pub input_t: Quantizer,
    pub weights_t: Quantizer,
    pub hadamard: Quantizer,
    pub output: Quantizer,
}

/// A Winograd conv layer: F(m×m, r×r), stride 1, `same`-style padding
/// supplied by the caller.
pub struct WinoConv2d {
    pub wf: WinoF,
    /// Pre-transformed weights, `[K][C]` of N×N mats (already through the
    /// base-change conjugation, i.e. canonical Winograd domain).
    pub wt: Vec<Vec<Mat>>,
    pub k: usize,
    pub c: usize,
    pub quant: Option<(QuantConfig, LayerScales)>,
    /// Batched float (fake-quant) execution engine lowered from `wt`
    /// (rebuilt on [`quantize`](Self::quantize)).
    engine: WinoEngine,
    /// Integer-domain batched engine — built by
    /// [`quantize_pct`](Self::quantize_pct) whenever the bit config fits
    /// the i16 code panels; when present, [`forward`](Self::forward)
    /// dispatches here (quantized serving never dequantizes weights).
    int_engine: Option<IntWinoEngine>,
    /// Shared weight-code bank injected by the serve plan cache
    /// ([`set_int_codes`](Self::set_int_codes)) before calibration.
    int_codes: Option<Arc<IntWeightBank>>,
    /// Current serving engine ([`EngineMode`] discriminant) — atomic so
    /// the drift-fallback controller can degrade/restore a layer through
    /// a shared reference while workers are serving.
    mode: AtomicU8,
}

impl WinoConv2d {
    /// Build from float weights `[K,C,r,r]`; transforms them once (via
    /// the shared [`transform_weight_bank`] lowering). Constructs a fresh
    /// transform plan — callers instantiating many layers with the same
    /// `F(m, r)`/base (a ResNet, the serve registry) should build the
    /// [`WinoF`] once and use [`with_plan`](Self::with_plan).
    pub fn new(m: usize, weights: &Tensor, base: Base) -> WinoConv2d {
        assert_eq!(weights.rank(), 4);
        let plan = WinogradPlan::new(m, weights.dims[2]);
        Self::with_plan(WinoF::new(&plan, base), weights)
    }

    /// Build from float weights and an already-lowered transform plan
    /// (shared across layers / cached by `serve::plan::PlanCache`), so the
    /// exact Toom-Cook construction and base-change conjugation are not
    /// redone per layer.
    pub fn with_plan(wf: WinoF, weights: &Tensor) -> WinoConv2d {
        assert_eq!(weights.rank(), 4);
        let r = weights.dims[2];
        assert_eq!(r, wf.r, "kernel size {r} does not match the plan's r = {}", wf.r);
        let wt = transform_weight_bank(&wf, weights);
        Self::from_transformed(wf, wt)
    }

    /// Build from an already-transformed `[K][C]` weight bank (e.g. one
    /// cached by `serve::plan::PlanCache`) — no weight transform runs at
    /// all. The engine is lowered through
    /// [`WinoEngine::from_transformed_weights`], the single serving
    /// construction path.
    pub fn from_transformed(wf: WinoF, wt: Vec<Vec<Mat>>) -> WinoConv2d {
        let k = wt.len();
        assert!(k > 0, "need at least one output filter");
        let c = wt[0].len();
        for per_c in &wt {
            assert_eq!(per_c.len(), c, "ragged filter bank");
            for m in per_c {
                assert_eq!((m.rows(), m.cols()), (wf.n, wf.n), "bank/plan tile mismatch");
            }
        }
        let engine = WinoEngine::from_transformed_weights(wf.clone(), &wt, None);
        WinoConv2d {
            wf,
            wt,
            k,
            c,
            quant: None,
            engine,
            int_engine: None,
            int_codes: None,
            mode: AtomicU8::new(EngineMode::Int as u8),
        }
    }

    /// [`from_transformed`](Self::from_transformed) with an
    /// **already-packed** engine weight bank (the
    /// `serve::plan::PlanCache` caches one per layer): the float engine
    /// is lowered through [`WinoEngine::from_packed`] and no packing
    /// runs at all — served model variants share one packed bank the way
    /// quantized variants share an i16 code bank. `packed` must be the
    /// packing of exactly this `wt` (the cache keys both by the same
    /// `(layer, plan)` identity; debug builds verify element-for-element).
    pub fn from_transformed_packed(
        wf: WinoF,
        wt: Vec<Vec<Mat>>,
        packed: Arc<PackedF64>,
    ) -> WinoConv2d {
        let k = wt.len();
        assert!(k > 0, "need at least one output filter");
        let c = wt[0].len();
        assert_eq!(
            (packed.k, packed.c, packed.nn),
            (k, c, wf.n * wf.n),
            "packed bank shape does not match the transformed bank"
        );
        for per_c in &wt {
            assert_eq!(per_c.len(), c, "ragged filter bank");
            for m in per_c {
                assert_eq!((m.rows(), m.cols()), (wf.n, wf.n), "bank/plan tile mismatch");
            }
        }
        #[cfg(debug_assertions)]
        for f in 0..packed.nn {
            let panel = packed.unpacked_panel(f);
            for (ki, per_c) in wt.iter().enumerate() {
                for (ci, mat) in per_c.iter().enumerate() {
                    debug_assert_eq!(
                        panel[ki * c + ci].to_bits(),
                        mat.data()[f].to_bits(),
                        "cached packed bank diverges from the transformed bank at \
                         (f={f}, k={ki}, c={ci})"
                    );
                }
            }
        }
        let engine = WinoEngine::from_packed(wf.clone(), packed, None);
        WinoConv2d {
            wf,
            wt,
            k,
            c,
            quant: None,
            engine,
            int_engine: None,
            int_codes: None,
            mode: AtomicU8::new(EngineMode::Int as u8),
        }
    }

    /// The batched **float** (fake-quant) execution engine. Quantized
    /// layers serve through [`int_engine`](Self::int_engine) instead; use
    /// [`forward_float`](Self::forward_float) to force this path.
    pub fn engine(&self) -> &WinoEngine {
        &self.engine
    }

    /// The integer-domain engine, present after a
    /// [`quantize`](Self::quantize) whose bit config fits the i16 code
    /// panels (see [`IntWinoEngine::supports`]).
    pub fn int_engine(&self) -> Option<&IntWinoEngine> {
        self.int_engine.as_ref()
    }

    /// Current serving [`EngineMode`] (the fallback ladder rung).
    pub fn mode(&self) -> EngineMode {
        EngineMode::from_u8(self.mode.load(Ordering::Relaxed))
    }

    /// Flip the serving engine — the drift-fallback controller's lever.
    /// Takes effect on the next [`forward`](Self::forward); in-flight
    /// passes finish on the engine they started with (both engines stay
    /// lowered, so the flip is allocation-free and lock-free).
    pub fn set_mode(&self, mode: EngineMode) {
        self.mode.store(mode as u8, Ordering::Relaxed);
    }

    /// Inject a shared transformed-weight **code** bank (from
    /// `serve::plan::PlanCache`) for the upcoming
    /// [`quantize_pct`](Self::quantize_pct) call: when its quantizer
    /// matches the layer's computed `weights_t` scale — guaranteed when
    /// the bank came from this layer's own float bank at the same
    /// `weight_bits` — the integer engine is lowered from the cached
    /// codes instead of requantizing, and served model variants share one
    /// bank. A mismatched bank is ignored (codes are recomputed).
    pub fn set_int_codes(&mut self, bank: Arc<IntWeightBank>) {
        assert_eq!(
            (bank.k, bank.c, bank.nn),
            (self.k, self.c, self.wf.n * self.wf.n),
            "weight-code bank shape does not match this layer"
        );
        self.int_codes = Some(bank);
    }

    /// Enable the quantized pipeline: calibrate scales on a representative
    /// input batch, then fake-quantize the stored transformed weights.
    /// Activation calibration uses the full range (`max|t|`); the tuner's
    /// percentile variant is [`quantize_pct`](Self::quantize_pct).
    pub fn quantize(&mut self, cfg: QuantConfig, calib: &Tensor, padding: usize) {
        self.quantize_pct(cfg, calib, padding, 100.0);
    }

    /// [`quantize`](Self::quantize) with percentile activation calibration:
    /// the *input* quantizer's scale comes from the `calib_pct`-th
    /// magnitude percentile of the calibration activations
    /// ([`Quantizer::calibrate_percentile`]) instead of their maximum, so a
    /// single activation outlier cannot blow up the step size for the whole
    /// layer. `calib_pct = 100` is exactly [`quantize`](Self::quantize);
    /// the transformed-input/Hadamard/output scales still come from the
    /// dry-run maxima (those ranges are post-transform aggregates, not raw
    /// activation tails).
    pub fn quantize_pct(
        &mut self,
        cfg: QuantConfig,
        calib: &Tensor,
        padding: usize,
        calib_pct: f64,
    ) {
        let wt_all: Vec<f64> = self
            .wt
            .iter()
            .flat_map(|per_c| per_c.iter().flat_map(|m| m.data().iter().copied()))
            .collect();
        let weights_t = Quantizer::calibrate(cfg.weight_bits, &wt_all);
        // Calibrate input/transformed-input/hadamard/output ranges by a dry
        // run over the calibration batch.
        let x = pad_hw(calib, padding);
        let in_all: Vec<f64> = x.data.iter().map(|&v| v as f64).collect();
        let input = Quantizer::calibrate_percentile(cfg.act_bits, &in_all, calib_pct);
        let mut xt_max = 0.0f64;
        let mut had_max = 0.0f64;
        let mut out_max = 0.0f64;
        let n = self.wf.n;
        let m = self.wf.m;
        let (bn, _, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        let tiles_h = (h.saturating_sub(n)) / m + 1;
        let tiles_w = (w.saturating_sub(n)) / m + 1;
        for ni in 0..bn.min(2) {
            for th in 0..tiles_h {
                for tw in 0..tiles_w {
                    let mut acc = Mat::zeros(n, n);
                    for ci in 0..self.c {
                        let tile = extract_tile(&x, ni, ci, th * m, tw * m, n);
                        let xt = self.wf.transform_input(&tile);
                        for i in 0..n {
                            for j in 0..n {
                                xt_max = xt_max.max(xt[(i, j)].abs());
                            }
                        }
                        let wt = &self.wt[0][ci];
                        for i in 0..n {
                            for j in 0..n {
                                acc[(i, j)] += xt[(i, j)] * wt[(i, j)];
                                had_max = had_max.max(acc[(i, j)].abs());
                            }
                        }
                    }
                    let y = self.wf.transform_output(&acc);
                    for i in 0..m {
                        for j in 0..m {
                            out_max = out_max.max(y[(i, j)].abs());
                        }
                    }
                }
            }
        }
        let mk = |bits: u32, maxabs: f64| {
            Quantizer::with_scale(
                bits,
                if maxabs == 0.0 { 1.0 } else { maxabs / Quantizer::qmax(bits) as f64 },
            )
        };
        let scales = LayerScales {
            input,
            input_t: mk(cfg.act_bits, xt_max),
            weights_t,
            hadamard: mk(cfg.hadamard_bits, had_max),
            output: mk(cfg.out_bits, out_max),
        };
        // Integer code bank: reuse an injected (plan-cache-shared) bank
        // when its quantizer is exactly this layer's weights_t; otherwise
        // quantize the bank here. Taken from the still-pristine `self.wt`
        // (requantizing baked values would give the same codes, but the
        // cached bank's quantizer is calibrated on pristine values, so
        // this keeps the two routes trivially identical).
        let int_bank = if IntWinoEngine::supports(&cfg) {
            Some(match &self.int_codes {
                Some(b) if b.weights_t == weights_t => b.clone(),
                _ => Arc::new(IntWeightBank::with_quantizer(&self.wt, weights_t)),
            })
        } else {
            None
        };
        // Bake weight quantization into the stored transforms.
        for per_c in &mut self.wt {
            for w in per_c.iter_mut() {
                *w = Mat::from_vec(w.rows(), w.cols(), weights_t.fake_all(w.data()));
            }
        }
        self.quant = Some((cfg, scales));
        // Re-lower: the float engine snapshots the (now fake-quantized)
        // weight panels and the Fig. 2 cast sites; the integer engine
        // snapshots the code bank and the same calibrated scales.
        self.engine =
            WinoEngine::from_transformed_weights(self.wf.clone(), &self.wt, self.quant);
        self.int_engine =
            int_bank.map(|b| IntWinoEngine::from_bank(self.wf.clone(), b, cfg, scales));
    }

    /// Forward pass: `x` [N,C,H,W] → [N,K,H',W'] (stride 1) — the
    /// **serving path**. Quantized layers with a lowered
    /// [`IntWinoEngine`] execute fully in the integer domain (i16 code
    /// panels, integer channel reduction, one Hadamard requant);
    /// everything else — including a layer degraded off
    /// [`EngineMode::Int`] by the drift-fallback controller — runs the
    /// float [`WinoEngine`]. (`Direct` bypass happens at the model's
    /// dispatch site; from the layer's own entry points `Direct` serves
    /// the float engine.) Allocates a fresh workspace; serving loops
    /// should prefer [`forward_with_scratch`](Self::forward_with_scratch).
    pub fn forward(&self, x: &Tensor, cfg: Conv2dCfg) -> Tensor {
        match &self.int_engine {
            Some(ie) if self.mode() == EngineMode::Int => ie.forward(x, cfg),
            _ => self.engine.forward(x, cfg),
        }
    }

    /// Forward pass reusing caller-held engine scratch buffers (see
    /// [`EngineScratch`]); output is identical to [`forward`](Self::forward).
    pub fn forward_with_scratch(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        match &self.int_engine {
            Some(ie) if self.mode() == EngineMode::Int => ie.forward_with(x, cfg, scratch),
            _ => self.engine.forward_with(x, cfg, scratch),
        }
    }

    /// Forward pass forced onto the float fake-quant [`WinoEngine`] (the
    /// dequantize-to-float route a server without the integer engine
    /// would pay) — what the engine-vs-per-tile parity tests and the
    /// `BENCH_int` baseline measure.
    pub fn forward_float(&self, x: &Tensor, cfg: Conv2dCfg) -> Tensor {
        self.engine.forward(x, cfg)
    }

    /// [`forward_float`](Self::forward_float) with caller-held scratch.
    pub fn forward_float_with_scratch(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        self.engine.forward_with(x, cfg, scratch)
    }

    /// The original per-tile nested-loop forward pass, kept as the
    /// engine's validation oracle: `engine::tests` and
    /// `tests/engine_parity.rs` assert the batched path reproduces this
    /// bit-for-bit in float and quantized modes. Use it for debugging and
    /// differential testing only — it is the slow path by design.
    pub fn forward_reference(&self, x: &Tensor, cfg: Conv2dCfg) -> Tensor {
        assert_eq!(cfg.stride, 1, "winograd layer is stride-1");
        let x = pad_hw(x, cfg.padding);
        let x = match &self.quant {
            Some((_, s)) => x.map(|v| s.input.fake(v as f64) as f32),
            None => x,
        };
        let n = self.wf.n;
        let m = self.wf.m;
        let r = self.wf.r;
        let (bn, c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        assert_eq!(c, self.c);
        let oh = h - r + 1;
        let ow = w - r + 1;
        // Tile grid covering the output: ceil-division, edge tiles clamped
        // by input zero-extension.
        let tiles_h = oh.div_ceil(m);
        let tiles_w = ow.div_ceil(m);
        let mut y = Tensor::zeros(&[bn, self.k, oh, ow]);
        for ni in 0..bn {
            // Transform all input tiles once per image (amortised across K).
            let mut xt_tiles: Vec<Vec<Mat>> =
                vec![Vec::with_capacity(tiles_h * tiles_w); c];
            for (ci, xt_c) in xt_tiles.iter_mut().enumerate() {
                for th in 0..tiles_h {
                    for tw in 0..tiles_w {
                        let tile = extract_tile(&x, ni, ci, th * m, tw * m, n);
                        let mut xt = self.wf.transform_input(&tile);
                        if let Some((_, s)) = &self.quant {
                            xt = Mat::from_vec(n, n, s.input_t.fake_all(xt.data()));
                        }
                        xt_c.push(xt);
                    }
                }
            }
            for ki in 0..self.k {
                for th in 0..tiles_h {
                    for tw in 0..tiles_w {
                        let mut acc = Mat::zeros(n, n);
                        for ci in 0..c {
                            let xt = &xt_tiles[ci][th * tiles_w + tw];
                            let wt = &self.wt[ki][ci];
                            for i in 0..n {
                                for j in 0..n {
                                    acc[(i, j)] += xt[(i, j)] * wt[(i, j)];
                                }
                            }
                        }
                        if let Some((_, s)) = &self.quant {
                            acc = Mat::from_vec(n, n, s.hadamard.fake_all(acc.data()));
                        }
                        let mut out = self.wf.transform_output(&acc);
                        if let Some((_, s)) = &self.quant {
                            out = Mat::from_vec(m, m, s.output.fake_all(out.data()));
                        }
                        for i in 0..m {
                            let oi = th * m + i;
                            if oi >= oh {
                                break;
                            }
                            for j in 0..m {
                                let oj = tw * m + j;
                                if oj >= ow {
                                    break;
                                }
                                *y.at4_mut(ni, ki, oi, oj) = out[(i, j)] as f32;
                            }
                        }
                    }
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::super::layers::conv2d;
    use super::*;
    use crate::testkit::prng_tensor;

    fn assert_tensors_close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.dims, b.dims);
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert!((x - y).abs() <= tol, "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn matches_direct_conv_no_padding() {
        // 8×8 input, F(4,3): output 6×6 needs 2×2 tiles with edge clamping.
        let x = prng_tensor(1, &[2, 3, 8, 8], 1.0);
        let w = prng_tensor(2, &[4, 3, 3, 3], 0.5);
        let direct = conv2d(&x, &w, None, Conv2dCfg::default());
        for base in [Base::Canonical, Base::Legendre] {
            let layer = WinoConv2d::new(4, &w, base);
            let y = layer.forward(&x, Conv2dCfg::default());
            assert_tensors_close(&y, &direct, 1e-4);
        }
    }

    #[test]
    fn matches_direct_conv_same_padding() {
        let x = prng_tensor(3, &[1, 2, 8, 8], 1.0);
        let w = prng_tensor(4, &[2, 2, 3, 3], 0.5);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let direct = conv2d(&x, &w, None, cfg);
        let layer = WinoConv2d::new(4, &w, Base::Legendre);
        let y = layer.forward(&x, cfg);
        assert_eq!(y.dims, vec![1, 2, 8, 8]);
        assert_tensors_close(&y, &direct, 1e-4);
    }

    #[test]
    fn non_multiple_output_size() {
        // 7×7 output (not a multiple of m=4) exercises edge-tile clamping.
        let x = prng_tensor(5, &[1, 2, 9, 9], 1.0);
        let w = prng_tensor(6, &[2, 2, 3, 3], 0.5);
        let direct = conv2d(&x, &w, None, Conv2dCfg::default());
        let layer = WinoConv2d::new(4, &w, Base::Canonical);
        let y = layer.forward(&x, Conv2dCfg::default());
        assert_eq!(y.dims, vec![1, 2, 7, 7]);
        assert_tensors_close(&y, &direct, 1e-4);
    }

    #[test]
    fn f2_variant_matches() {
        let x = prng_tensor(7, &[1, 1, 6, 6], 1.0);
        let w = prng_tensor(8, &[1, 1, 3, 3], 0.5);
        let direct = conv2d(&x, &w, None, Conv2dCfg::default());
        let layer = WinoConv2d::new(2, &w, Base::Legendre);
        assert_tensors_close(&layer.forward(&x, Conv2dCfg::default()), &direct, 1e-4);
    }

    #[test]
    fn quantized_stays_close_and_differs() {
        let x = prng_tensor(9, &[1, 4, 12, 12], 1.0);
        let w = prng_tensor(10, &[4, 4, 3, 3], 0.3);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let direct = conv2d(&x, &w, None, cfg);
        let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
        layer.quantize(QuantConfig::w8(), &x, 1);
        let y = layer.forward(&x, cfg);
        // Quantized ≠ exact but same ballpark.
        let max_direct = direct.max_abs();
        let mut max_err = 0.0f32;
        let mut identical = true;
        for (a, b) in y.data.iter().zip(&direct.data) {
            max_err = max_err.max((a - b).abs());
            if a != b {
                identical = false;
            }
        }
        assert!(!identical, "quantization must change values");
        assert!(
            max_err < 0.35 * max_direct,
            "quantized error too large: {max_err} vs signal {max_direct}"
        );
    }

    #[test]
    fn quantize_pct_100_matches_quantize() {
        let x = prng_tensor(40, &[1, 3, 10, 10], 1.0);
        let w = prng_tensor(41, &[3, 3, 3, 3], 0.4);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let mut a = WinoConv2d::new(4, &w, Base::Legendre);
        a.quantize(QuantConfig::w8(), &x, 1);
        let mut b = WinoConv2d::new(4, &w, Base::Legendre);
        b.quantize_pct(QuantConfig::w8(), &x, 1, 100.0);
        assert_eq!(a.forward(&x, cfg).data, b.forward(&x, cfg).data);
    }

    #[test]
    fn quantize_pct_shrinks_input_scale_under_outlier() {
        // One planted outlier owns the max-calibrated input scale; the
        // percentile calibration must not let it.
        let mut x = prng_tensor(42, &[1, 2, 10, 10], 0.5);
        x.data[7] = 50.0;
        let w = prng_tensor(43, &[2, 2, 3, 3], 0.4);
        let mut qmax = WinoConv2d::new(4, &w, Base::Legendre);
        qmax.quantize(QuantConfig::w8(), &x, 1);
        let mut qpct = WinoConv2d::new(4, &w, Base::Legendre);
        qpct.quantize_pct(QuantConfig::w8(), &x, 1, 99.0);
        let s_max = qmax.quant.unwrap().1.input.scale;
        let s_pct = qpct.quant.unwrap().1.input.scale;
        assert!(
            s_pct < s_max / 10.0,
            "percentile scale {s_pct} should be far below outlier-driven {s_max}"
        );
    }

    #[test]
    fn quantized_forward_dispatches_to_int_engine() {
        // After quantize(), forward() must be the integer engine's output
        // (bit-for-bit), with the fake-quant float route still reachable
        // via forward_float(); a float layer has no int engine at all.
        let x = prng_tensor(50, &[1, 3, 10, 10], 1.0);
        let w = prng_tensor(51, &[3, 3, 3, 3], 0.4);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
        assert!(layer.int_engine().is_none());
        layer.quantize(QuantConfig::w8_h9(), &x, 1);
        let ie = layer.int_engine().expect("w8_h9 fits the i16 code panels");
        assert_eq!(layer.forward(&x, cfg).data, ie.forward(&x, cfg).data);
        assert_eq!(
            layer.forward_float(&x, cfg).data,
            layer.engine().forward(&x, cfg).data
        );
        let mut scratch = EngineScratch::new();
        assert_eq!(
            layer.forward_with_scratch(&x, cfg, &mut scratch).data,
            layer.forward(&x, cfg).data
        );
        // Int and float paths are different numeric routes (the integer
        // path accumulates exactly; the fake path rounds per term and
        // detours the input cast through f32), so they agree only to a
        // few quantization steps — assert same-ballpark, not identity.
        let yi = layer.forward(&x, cfg);
        let yf = layer.forward_float(&x, cfg);
        let signal = yf.max_abs();
        let mut max_diff = 0.0f32;
        for (a, b) in yi.data.iter().zip(&yf.data) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(
            max_diff <= 0.1 * signal,
            "int vs float paths diverged: {max_diff} vs signal {signal}"
        );
        // A too-wide config falls back to the float engine.
        let mut wide = WinoConv2d::new(4, &w, Base::Legendre);
        wide.quantize(QuantConfig::uniform(18), &x, 1);
        assert!(wide.int_engine().is_none());
        assert_eq!(wide.forward(&x, cfg).data, wide.forward_float(&x, cfg).data);
    }

    #[test]
    fn engine_mode_flips_dispatch_and_restores() {
        // The fallback controller's lever: Float mode must force the
        // fake-quant float engine on a quantized layer, and restoring
        // Int must bring back the integer path bit-for-bit.
        let x = prng_tensor(60, &[1, 3, 10, 10], 1.0);
        let w = prng_tensor(61, &[3, 3, 3, 3], 0.4);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
        layer.quantize(QuantConfig::w8_h9(), &x, 1);
        assert_eq!(layer.mode(), EngineMode::Int);
        let y_int = layer.forward(&x, cfg);
        layer.set_mode(EngineMode::Float);
        assert_eq!(layer.forward(&x, cfg).data, layer.forward_float(&x, cfg).data);
        let mut scratch = EngineScratch::new();
        assert_eq!(
            layer.forward_with_scratch(&x, cfg, &mut scratch).data,
            layer.forward_float(&x, cfg).data
        );
        // Direct from the layer's own entry point also serves float.
        layer.set_mode(EngineMode::Direct);
        assert_eq!(layer.forward(&x, cfg).data, layer.forward_float(&x, cfg).data);
        layer.set_mode(EngineMode::Int);
        assert_eq!(layer.forward(&x, cfg).data, y_int.data);
        // Ladder arithmetic: Int → Float → Direct, floor at Direct.
        assert_eq!(EngineMode::Int.degraded(), EngineMode::Float);
        assert_eq!(EngineMode::Float.degraded(), EngineMode::Direct);
        assert_eq!(EngineMode::Direct.degraded(), EngineMode::Direct);
        assert_eq!(EngineMode::Float.as_str(), "float");
    }

    #[test]
    fn engine_and_reference_paths_agree() {
        // forward() (batched engine) and forward_reference() (per-tile
        // oracle) must be interchangeable — exact f32 equality.
        let x = prng_tensor(20, &[2, 3, 9, 9], 1.0);
        let w = prng_tensor(21, &[4, 3, 3, 3], 0.5);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let layer = WinoConv2d::new(4, &w, Base::Legendre);
        assert_eq!(
            layer.forward(&x, cfg).data,
            layer.forward_reference(&x, cfg).data
        );
    }

    #[test]
    fn with_plan_matches_fresh_construction() {
        // Sharing one lowered WinoF across layers (the serve/plan path)
        // must be indistinguishable from per-layer construction.
        use crate::wino::toomcook::WinogradPlan;
        use crate::wino::transform::WinoF;
        let x = prng_tensor(30, &[1, 3, 9, 9], 1.0);
        let w = prng_tensor(31, &[2, 3, 3, 3], 0.5);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let wf = WinoF::new(&WinogradPlan::new(4, 3), Base::Chebyshev);
        let shared = WinoConv2d::with_plan(wf, &w);
        let fresh = WinoConv2d::new(4, &w, Base::Chebyshev);
        assert_eq!(shared.forward(&x, cfg).data, fresh.forward(&x, cfg).data);
    }

    #[test]
    fn nine_bit_hadamard_tightens_layer_error() {
        let x = prng_tensor(11, &[1, 8, 12, 12], 1.0);
        let w = prng_tensor(12, &[8, 8, 3, 3], 0.3);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let direct = conv2d(&x, &w, None, cfg);
        let l2 = |q: QuantConfig| -> f32 {
            let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
            layer.quantize(q, &x, 1);
            let y = layer.forward(&x, cfg);
            y.data
                .iter()
                .zip(&direct.data)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt()
        };
        let e8 = l2(QuantConfig::w8());
        let e9 = l2(QuantConfig::w8_h9());
        assert!(e9 < e8, "9-bit hadamard {e9} !< 8-bit {e8}");
    }
}
