//! Pure-rust NN inference substrate: NCHW tensors, direct layers, the
//! Winograd conv layer (all bases + Fig. 2 quantization), and the ResNet18
//! serving model.

pub mod layers;
pub mod resnet;
pub mod tensor;
pub mod winolayer;

pub use resnet::{ConvMode, Params, ResNet18, ResNetCfg};
pub use tensor::Tensor;
pub use winolayer::EngineMode;
