//! Minimal NCHW f32 tensor for the pure-rust inference path.
//!
//! The rust serving pipeline (`nn::resnet` + `quant::qwino`) needs only
//! dense 4-D/2-D/1-D tensors with a handful of ops; this keeps it
//! dependency-free (no ndarray in the vendored set).

/// Dense f32 tensor, row-major over its dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "dims/data mismatch");
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// NCHW indexing for rank-4 tensors.
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (_, cc, hh, ww) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    #[inline]
    pub fn at4_mut(&mut self, n: usize, c: usize, h: usize, w: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 4);
        let (_, cc, hh, ww) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        &mut self.data[((n * cc + c) * hh + h) * ww + w]
    }

    /// 2-D indexing.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.dims[1] + j]
    }

    pub fn reshape(mut self, dims: &[usize]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims.to_vec();
        self
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            dims: self.dims.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &b| a.max(b.abs()))
    }

    /// Elementwise add (shapes must match).
    pub fn add(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.dims, rhs.dims);
        Tensor {
            dims: self.dims.clone(),
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// argmax over the last axis for each row of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let cols = self.dims[1];
        self.data
            .chunks(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_len() {
        let t = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert_eq!(t.rank(), 4);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn at4_layout_is_nchw() {
        let mut t = Tensor::zeros(&[2, 3, 4, 4]);
        *t.at4_mut(1, 2, 3, 3) = 7.0;
        // flat index = ((1*3+2)*4+3)*4+3 = 95
        assert_eq!(t.data[95], 7.0);
        assert_eq!(t.at4(1, 2, 3, 3), 7.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.dims, vec![3, 2]);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_size_panics() {
        let _ = Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.3, 2.0, -1.0, 0.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn add_and_map() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![10.0, 20.0]);
        assert_eq!(a.add(&b).data, vec![11.0, 22.0]);
        assert_eq!(a.map(|x| x * 2.0).data, vec![2.0, 4.0]);
    }

    #[test]
    fn max_abs() {
        let t = Tensor::from_vec(&[3], vec![-5.0, 2.0, 4.5]);
        assert_eq!(t.max_abs(), 5.0);
    }
}
