//! ResNet18 (CIFAR variant, width-multiplier) — pure-rust inference path.
//!
//! Mirrors the JAX training model in `python/compile/resnet.py`: a 3×3 stem
//! into four stages of two basic blocks with channel widths
//! `[64, 128, 256, 512] × width_mult`, stride 2 between stages, global
//! average pool and a linear head. Every stride-1 3×3 convolution can run
//! either direct or through the (optionally quantized) Winograd layer —
//! exactly the substitution the paper's winograd-aware training makes.
//!
//! Parameters are loaded from the flat-f32 checkpoint blob format the
//! runtime's artifact manifests describe (see `runtime::manifest`), so a
//! trained network can be served without python.
//!
//! The stride-1 3×3 Winograd convolutions execute on the batched
//! [`WinoEngine`](crate::engine::WinoEngine); one
//! [`EngineScratch`](crate::engine::EngineScratch) workspace is threaded
//! through the whole forward pass so the per-layer flat buffers are
//! allocated once per call, not once per layer.

use super::layers::{batchnorm, conv2d, global_avg_pool, linear, relu, Conv2dCfg};
use super::tensor::Tensor;
use super::winolayer::{EngineMode, WinoConv2d};
use crate::engine::{EngineScratch, TileGrid};
use crate::quant::scheme::QuantConfig;
use crate::wino::basis::Base;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;
use std::collections::HashMap;

/// How to execute the stride-1 3×3 convolutions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConvMode {
    /// Plain direct convolution (the paper's baseline column).
    Direct,
    /// Winograd F(m×m, 3×3) in `base`, optionally quantized.
    Winograd { m: usize, base: Base, quant: Option<QuantConfig> },
}

/// Model hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ResNetCfg {
    pub width_mult: f32,
    pub num_classes: usize,
    pub mode: ConvMode,
}

impl ResNetCfg {
    pub fn widths(&self) -> [usize; 4] {
        let w = |c: usize| ((c as f32 * self.width_mult).round() as usize).max(4);
        [w(64), w(128), w(256), w(512)]
    }
}

/// Named parameter collection (flat f32 tensors).
pub type Params = HashMap<String, Tensor>;

/// The single Winograd-eligibility rule: stride-1 3×3 units that are not
/// the parallel 1×1 downsample path. Consumed by the per-layer builder
/// (via [`ResNet18::wino_eligible_units`]) and the activation-capture
/// site, so the two can never disagree about which layers the tuner may
/// plan.
fn is_wino_eligible(prefix: &str, stride: usize, ksize: usize) -> bool {
    stride == 1 && ksize == 3 && !prefix.ends_with("down")
}

/// A conv+bn unit's parameter names.
fn conv_bn_names(prefix: &str) -> (String, String, String, String, String) {
    (
        format!("{prefix}.w"),
        format!("{prefix}.bn.gamma"),
        format!("{prefix}.bn.beta"),
        format!("{prefix}.bn.mean"),
        format!("{prefix}.bn.var"),
    )
}

pub struct ResNet18 {
    pub cfg: ResNetCfg,
    pub params: Params,
    /// Pre-built Winograd layers keyed by conv prefix (built lazily from
    /// params at construction when mode is Winograd).
    wino: HashMap<String, WinoConv2d>,
}

impl ResNet18 {
    /// All conv-unit prefixes of the architecture, with (stride, in, out).
    pub fn conv_units(cfg: &ResNetCfg) -> Vec<(String, usize, usize, usize)> {
        let w = cfg.widths();
        let mut units = vec![("stem".to_string(), 1, 3, w[0])];
        let mut cin = w[0];
        for (si, &cout) in w.iter().enumerate() {
            for bi in 0..2usize {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                units.push((format!("s{si}b{bi}.conv1"), stride, cin, cout));
                units.push((format!("s{si}b{bi}.conv2"), 1, cout, cout));
                if stride != 1 || cin != cout {
                    units.push((format!("s{si}b{bi}.down"), stride, cin, cout));
                }
                cin = cout;
            }
        }
        units
    }

    /// The Winograd-**eligible** conv units of a config, in network
    /// order: `(prefix, in channels, out channels)` for every stride-1
    /// 3×3 unit (strided and 1×1 downsample convs stay direct, as in
    /// ref [5]). The builder, the activation capture, the tuner's sweep,
    /// and the serve registry's NetPlan validation all consume the same
    /// `is_wino_eligible` rule, so eligibility cannot drift between
    /// them.
    pub fn wino_eligible_units(cfg: &ResNetCfg) -> Vec<(String, usize, usize)> {
        Self::conv_units(cfg)
            .into_iter()
            .filter(|(prefix, stride, _, _)| {
                let ksize = if prefix.ends_with("down") { 1 } else { 3 };
                is_wino_eligible(prefix, *stride, ksize)
            })
            .map(|(prefix, _, cin, cout)| (prefix, cin, cout))
            .collect()
    }

    /// Initialise with He-style pseudo-random params (for tests / untrained
    /// serving demos).
    pub fn init(cfg: ResNetCfg, seed: u64) -> ResNet18 {
        Self::from_params(cfg, Self::init_params(&cfg, seed))
    }

    /// He-style pseudo-random parameter collection for `cfg` — split out of
    /// [`init`](Self::init) so callers holding a shared transform plan (the
    /// serve registry) can route through
    /// [`from_params_with_plan`](Self::from_params_with_plan).
    pub fn init_params(cfg: &ResNetCfg, seed: u64) -> Params {
        use crate::wino::error::Prng;
        let mut rng = Prng::new(seed);
        let mut params: Params = HashMap::new();
        for (prefix, _stride, cin, cout) in Self::conv_units(cfg) {
            let ksize = if prefix.ends_with("down") { 1 } else { 3 };
            let fan_in = (cin * ksize * ksize) as f64;
            let std = (2.0 / fan_in).sqrt();
            let n = cout * cin * ksize * ksize;
            let w = Tensor::from_vec(
                &[cout, cin, ksize, ksize],
                (0..n).map(|_| (rng.uniform(std) * 1.73) as f32).collect(),
            );
            let (wn, g, b, m, v) = conv_bn_names(&prefix);
            params.insert(wn, w);
            params.insert(g, Tensor::from_vec(&[cout], vec![1.0; cout]));
            params.insert(b, Tensor::from_vec(&[cout], vec![0.0; cout]));
            params.insert(m, Tensor::from_vec(&[cout], vec![0.0; cout]));
            params.insert(v, Tensor::from_vec(&[cout], vec![1.0; cout]));
        }
        let w3 = cfg.widths()[3];
        let std = (2.0 / w3 as f64).sqrt();
        params.insert(
            "fc.w".into(),
            Tensor::from_vec(
                &[w3, cfg.num_classes],
                (0..w3 * cfg.num_classes)
                    .map(|_| (rng.uniform(std)) as f32)
                    .collect(),
            ),
        );
        params.insert(
            "fc.b".into(),
            Tensor::from_vec(&[cfg.num_classes], vec![0.0; cfg.num_classes]),
        );
        params
    }

    /// Build from a parameter collection (e.g. a loaded checkpoint). In
    /// Winograd mode the `F(m, 3)` transform plan is lowered **once** and
    /// shared across all stride-1 3×3 layers (it used to be rebuilt per
    /// layer); callers with a cross-model plan cache pass theirs via
    /// [`from_params_with_plan`](Self::from_params_with_plan).
    pub fn from_params(cfg: ResNetCfg, params: Params) -> ResNet18 {
        match cfg.mode {
            ConvMode::Winograd { m, base, .. } => {
                let wf = WinoF::new(&WinogradPlan::new(m, 3), base);
                Self::build(cfg, params, Some(&|_prefix: &str, w: &Tensor| {
                    WinoConv2d::with_plan(wf.clone(), w)
                }))
            }
            ConvMode::Direct => Self::build(cfg, params, None),
        }
    }

    /// Build from a parameter collection and a shared, already-lowered
    /// transform plan (the serve registry's plan-cache path). `wf` must
    /// match the mode's `(m, base)` — the per-layer engines are lowered
    /// from it without re-running the Toom-Cook construction.
    pub fn from_params_with_plan(cfg: ResNetCfg, params: Params, wf: &WinoF) -> ResNet18 {
        Self::check_plan(&cfg, wf);
        Self::build(cfg, params, Some(&|_prefix: &str, w: &Tensor| {
            WinoConv2d::with_plan(wf.clone(), w)
        }))
    }

    /// Build with a caller-supplied layer lowering `(prefix, weights) →
    /// layer` — how the serve registry routes every stride-1 3×3 layer
    /// through its transform-plan / weight-bank cache. `wf` is only used
    /// to validate the mode; the closure owns construction.
    pub fn from_params_lowered(
        cfg: ResNetCfg,
        params: Params,
        wf: &WinoF,
        lower: &dyn Fn(&str, &Tensor) -> WinoConv2d,
    ) -> ResNet18 {
        Self::check_plan(&cfg, wf);
        Self::build(cfg, params, Some(lower))
    }

    /// Build a **heterogeneous** network: the closure decides, per
    /// stride-1 3×3 conv unit, which Winograd operating point the layer
    /// runs (returning a lowered layer) or whether it stays direct
    /// (returning `None`). This is how a tuned
    /// [`NetPlan`](crate::tune::netplan::NetPlan) materializes — each
    /// layer may carry its own `(m, base, bit-width)` — generalizing the
    /// one-plan-per-net constructors above. `cfg.mode` must be a Winograd
    /// mode; its `(m, base, quant)` are the *nominal* label (reporting
    /// only), not a constraint on individual layers.
    pub fn from_params_per_layer(
        cfg: ResNetCfg,
        params: Params,
        lower: &dyn Fn(&str, &Tensor) -> Option<WinoConv2d>,
    ) -> ResNet18 {
        assert!(
            matches!(cfg.mode, ConvMode::Winograd { .. }),
            "per-layer lowering requires a Winograd mode label"
        );
        Self::build_per_layer(cfg, params, Some(lower))
    }

    fn check_plan(cfg: &ResNetCfg, wf: &WinoF) {
        match cfg.mode {
            ConvMode::Winograd { m, base, .. } => {
                assert_eq!(wf.m, m, "plan tile size mismatch");
                assert_eq!(wf.base, base, "plan base mismatch");
                assert_eq!(wf.r, 3, "ResNet18 wino layers are 3x3");
            }
            ConvMode::Direct => panic!("a transform plan requires Winograd mode"),
        }
    }

    fn build(
        cfg: ResNetCfg,
        params: Params,
        lower: Option<&dyn Fn(&str, &Tensor) -> WinoConv2d>,
    ) -> ResNet18 {
        match (cfg.mode, lower) {
            (ConvMode::Winograd { .. }, Some(lower)) => Self::build_per_layer(
                cfg,
                params,
                Some(&|prefix: &str, w: &Tensor| Some(lower(prefix, w))),
            ),
            _ => Self::build_per_layer(cfg, params, None),
        }
    }

    fn build_per_layer(
        cfg: ResNetCfg,
        params: Params,
        lower: Option<&dyn Fn(&str, &Tensor) -> Option<WinoConv2d>>,
    ) -> ResNet18 {
        let mut wino = HashMap::new();
        if let Some(lower) = lower {
            for (prefix, _cin, _cout) in Self::wino_eligible_units(&cfg) {
                let w = params
                    .get(&format!("{prefix}.w"))
                    .unwrap_or_else(|| panic!("missing weights for {prefix}"));
                if let Some(layer) = lower(&prefix, w) {
                    wino.insert(prefix.clone(), layer);
                }
            }
        }
        ResNet18 { cfg, params, wino }
    }

    /// The Winograd layer serving `prefix`, if that conv unit is lowered.
    pub fn wino_layer(&self, prefix: &str) -> Option<&WinoConv2d> {
        self.wino.get(prefix)
    }

    /// Run the network on `x` and return each Winograd-eligible layer's
    /// input activations (keyed by conv-unit prefix) — the calibration
    /// data the tuner sweeps candidates against. Captures every stride-1
    /// 3×3 unit, whether or not it is currently lowered to Winograd, so a
    /// direct-mode reference net yields the same activation set.
    pub fn capture_wino_inputs(&self, x: &Tensor) -> HashMap<String, Tensor> {
        let mut captured: HashMap<String, Tensor> = HashMap::new();
        self.forward_impl(x, Some(&mut captured), &mut EngineScratch::new());
        captured
    }

    /// Calibrate the quantized Winograd layers on a representative batch.
    pub fn calibrate_quant(&mut self, batch: &Tensor) {
        if let ConvMode::Winograd { quant: Some(qcfg), .. } = self.cfg.mode {
            self.calibrate_quant_with(batch, &|_prefix| Some((qcfg, 100.0)));
        }
    }

    /// Calibrate with a per-layer bit-width policy: the closure maps a
    /// conv-unit prefix to `(QuantConfig, activation calibration
    /// percentile)`, or `None` to leave that layer float. Each layer is
    /// calibrated on its **actual** input activations (captured by a
    /// stem-to-tail forward pass of the still-float network). The uniform
    /// [`calibrate_quant`](Self::calibrate_quant) delegates here; tuned
    /// NetPlans use it to give every layer its own operating point.
    pub fn calibrate_quant_with(
        &mut self,
        batch: &Tensor,
        policy: &dyn Fn(&str) -> Option<(QuantConfig, f64)>,
    ) {
        let captured = self.capture_wino_inputs(batch);
        for (prefix, layer) in self.wino.iter_mut() {
            if let (Some((qcfg, pct)), Some(input)) = (policy(prefix), captured.get(prefix)) {
                layer.quantize_pct(qcfg, input, 1, pct);
            }
        }
    }

    /// Winograd tiles a single item (square `input_hw`×`input_hw` image)
    /// pushes through this network's lowered layers — the serve-stats
    /// throughput unit. Walks the conv units tracking the spatial size
    /// stage by stage; each lowered layer contributes its **own** `m`'s
    /// tile grid, so heterogeneous (per-layer-tuned) networks are counted
    /// correctly.
    pub fn wino_tiles_per_item(&self, input_hw: usize) -> usize {
        self.wino_tiles_per_shape(input_hw, input_hw)
    }

    /// [`wino_tiles_per_item`](Self::wino_tiles_per_item) for an
    /// arbitrary (possibly non-square) `h`×`w` image. The stage walk uses
    /// the exact conv output arithmetic `out = (in − 1)/stride + 1`,
    /// which holds for both unit kinds that advance the spatial size here
    /// — stride-1/stride-2 3×3 `same` convs (`(in + 2 − 3)/s + 1`) and
    /// the skipped parallel 1×1-pad-0 stride-2 `down` path — so odd
    /// sizes (where `hw /= stride` would round the wrong way) and
    /// 1-pixel edge tiles are counted exactly.
    pub fn wino_tiles_per_shape(&self, input_h: usize, input_w: usize) -> usize {
        let pad = 1; // all wino units are 3×3 `same` convs
        let mut tiles = 0;
        let (mut h, mut w) = (input_h, input_w);
        for (prefix, stride, _cin, _cout) in Self::conv_units(&self.cfg) {
            if prefix.ends_with("down") {
                continue; // parallel 1×1 path; conv1 already advanced h/w
            }
            if stride == 1 {
                if let Some(layer) = self.wino.get(&prefix) {
                    let g = TileGrid::new(
                        &[1, 1, h + 2 * pad, w + 2 * pad],
                        layer.wf.m,
                        layer.wf.r,
                    );
                    tiles += g.tile_count();
                }
            }
            h = (h - 1) / stride + 1;
            w = (w - 1) / stride + 1;
        }
        tiles
    }

    fn conv_unit(
        &self,
        x: &Tensor,
        prefix: &str,
        stride: usize,
        capture: &mut Option<&mut HashMap<String, Tensor>>,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        let (wn, g, b, m, v) = conv_bn_names(prefix);
        let w = &self.params[&wn];
        let pad = if w.dims[2] == 3 { 1 } else { 0 };
        if let Some(cap) = capture.as_deref_mut() {
            // Capture every Winograd-eligible unit (stride-1 3×3), not just
            // currently-lowered ones, so a direct-mode net still yields the
            // tuner's calibration activations.
            if is_wino_eligible(prefix, stride, w.dims[2]) {
                cap.insert(prefix.to_string(), x.clone());
            }
        }
        // A layer the drift-fallback controller degraded to Direct
        // bypasses Winograd entirely — the raw weights are still in
        // `params`, so direct conv is always available as the floor of
        // the fallback ladder.
        let y = match self.wino.get(prefix) {
            Some(layer) if stride == 1 && layer.mode() != EngineMode::Direct => {
                layer.forward_with_scratch(x, Conv2dCfg { stride: 1, padding: pad }, scratch)
            }
            _ => conv2d(x, w, None, Conv2dCfg { stride, padding: pad }),
        };
        batchnorm(
            &y,
            &self.params[&g].data,
            &self.params[&b].data,
            &self.params[&m].data,
            &self.params[&v].data,
            1e-5,
        )
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        mut capture: Option<&mut HashMap<String, Tensor>>,
        sc: &mut EngineScratch,
    ) -> Tensor {
        let mut h = relu(&self.conv_unit(x, "stem", 1, &mut capture, sc));
        let widths = self.cfg.widths();
        let mut cin = widths[0];
        for (si, &cout) in widths.iter().enumerate() {
            for bi in 0..2usize {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let prefix = format!("s{si}b{bi}");
                let y1 =
                    relu(&self.conv_unit(&h, &format!("{prefix}.conv1"), stride, &mut capture, sc));
                let y2 = self.conv_unit(&y1, &format!("{prefix}.conv2"), 1, &mut capture, sc);
                let shortcut = if stride != 1 || cin != cout {
                    self.conv_unit(&h, &format!("{prefix}.down"), stride, &mut capture, sc)
                } else {
                    h.clone()
                };
                h = relu(&y2.add(&shortcut));
                cin = cout;
            }
        }
        let pooled = global_avg_pool(&h);
        linear(&pooled, &self.params["fc.w"], &self.params["fc.b"].data)
    }

    /// Forward pass: `x` [N,3,H,W] → logits [N, num_classes]. Allocates a
    /// fresh engine workspace; serving loops should prefer
    /// [`forward_with_scratch`](Self::forward_with_scratch).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_impl(x, None, &mut EngineScratch::new())
    }

    /// Forward pass reusing a caller-held engine workspace — the serve
    /// workers hold one [`EngineScratch`] each, so repeated micro-batch
    /// passes stay allocation-free on the large flat buffers. Output is
    /// identical to [`forward`](Self::forward).
    pub fn forward_with_scratch(&self, x: &Tensor, scratch: &mut EngineScratch) -> Tensor {
        self.forward_impl(x, None, scratch)
    }

    /// Top-1 accuracy on a labelled batch.
    pub fn accuracy(&self, x: &Tensor, labels: &[usize]) -> f64 {
        let logits = self.forward(x);
        let preds = logits.argmax_rows();
        let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        correct as f64 / labels.len() as f64
    }

    pub fn param_count(&self) -> usize {
        self.params.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wino::error::Prng;

    fn small_cfg(mode: ConvMode) -> ResNetCfg {
        ResNetCfg { width_mult: 0.25, num_classes: 10, mode }
    }

    fn rand_images(seed: u64, n: usize, hw: usize) -> Tensor {
        let mut rng = Prng::new(seed);
        let len = n * 3 * hw * hw;
        Tensor::from_vec(
            &[n, 3, hw, hw],
            (0..len).map(|_| rng.uniform(1.0) as f32).collect(),
        )
    }

    #[test]
    fn forward_shape() {
        let net = ResNet18::init(small_cfg(ConvMode::Direct), 1);
        let x = rand_images(2, 2, 32);
        let y = net.forward(&x);
        assert_eq!(y.dims, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn winograd_mode_matches_direct_unquantized() {
        // Float Winograd is algebraically a re-ordering — logits must agree
        // with direct conv to f32 tolerance.
        let direct = ResNet18::init(small_cfg(ConvMode::Direct), 7);
        let wino = ResNet18::from_params(
            small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None }),
            direct.params.clone(),
        );
        let x = rand_images(3, 1, 32);
        let yd = direct.forward(&x);
        let yw = wino.forward(&x);
        for (a, b) in yd.data.iter().zip(&yw.data) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn direct_mode_layers_bypass_winograd_exactly() {
        // The fallback ladder's floor: degrading every lowered layer to
        // EngineMode::Direct must reproduce the direct-conv net
        // bit-for-bit (conv_unit falls through to the same conv2d over
        // the same raw params), and restoring Int brings the Winograd
        // output back.
        use crate::nn::winolayer::EngineMode;
        let direct = ResNet18::init(small_cfg(ConvMode::Direct), 7);
        let wino = ResNet18::from_params(
            small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None }),
            direct.params.clone(),
        );
        let x = rand_images(3, 1, 32);
        let yd = direct.forward(&x);
        let yw = wino.forward(&x);
        let prefixes: Vec<String> = ResNet18::wino_eligible_units(&wino.cfg)
            .into_iter()
            .map(|(p, _, _)| p)
            .collect();
        for p in &prefixes {
            wino.wino_layer(p).unwrap().set_mode(EngineMode::Direct);
        }
        assert_eq!(wino.forward(&x).data, yd.data, "Direct mode must be bit-exact");
        for p in &prefixes {
            wino.wino_layer(p).unwrap().set_mode(EngineMode::Int);
        }
        assert_eq!(wino.forward(&x).data, yw.data, "restore must return to Winograd");
    }

    #[test]
    fn param_count_scales_with_width() {
        let small = ResNet18::init(small_cfg(ConvMode::Direct), 1);
        let big = ResNet18::init(
            ResNetCfg { width_mult: 0.5, num_classes: 10, mode: ConvMode::Direct },
            1,
        );
        assert!(big.param_count() > 3 * small.param_count());
    }

    #[test]
    fn conv_units_structure() {
        let units = ResNet18::conv_units(&small_cfg(ConvMode::Direct));
        // stem + 4 stages × 2 blocks × 2 convs + 3 downsamples = 20.
        assert_eq!(units.len(), 20);
        let downs: Vec<_> = units.iter().filter(|u| u.0.ends_with("down")).collect();
        assert_eq!(downs.len(), 3);
    }

    #[test]
    fn accuracy_on_random_labels_near_chance() {
        let net = ResNet18::init(small_cfg(ConvMode::Direct), 5);
        let x = rand_images(11, 16, 32);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let acc = net.accuracy(&x, &labels);
        assert!(acc <= 0.6, "untrained net should be near chance, got {acc}");
    }

    #[test]
    fn shared_plan_construction_matches_fresh() {
        // from_params_with_plan (serve registry path) must be
        // indistinguishable from from_params' per-net plan.
        use crate::wino::toomcook::WinogradPlan;
        use crate::wino::transform::WinoF;
        let cfg = small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None });
        let params = ResNet18::init_params(&cfg, 17);
        let fresh = ResNet18::from_params(cfg, params.clone());
        let wf = WinoF::new(&WinogradPlan::new(4, 3), Base::Legendre);
        let shared = ResNet18::from_params_with_plan(cfg, params, &wf);
        let x = rand_images(19, 1, 32);
        assert_eq!(fresh.forward(&x).data, shared.forward(&x).data);
    }

    #[test]
    fn per_layer_heterogeneous_build_runs_and_counts_tiles() {
        // Mix m=4 Legendre and m=2 canonical across layers, leave one
        // layer direct: the net must run, and tile accounting must follow
        // each layer's own grid.
        use crate::wino::toomcook::WinogradPlan;
        use crate::wino::transform::WinoF;
        let cfg = small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None });
        let params = ResNet18::init_params(&cfg, 23);
        let wf4 = WinoF::new(&WinogradPlan::new(4, 3), Base::Legendre);
        let wf2 = WinoF::new(&WinogradPlan::new(2, 3), Base::Canonical);
        let net = ResNet18::from_params_per_layer(cfg, params.clone(), &|prefix, w| {
            match prefix {
                "stem" => None, // stays direct
                p if p.starts_with("s0") => Some(WinoConv2d::with_plan(wf2.clone(), w)),
                _ => Some(WinoConv2d::with_plan(wf4.clone(), w)),
            }
        });
        assert!(net.wino_layer("stem").is_none());
        assert_eq!(net.wino_layer("s0b0.conv1").unwrap().wf.m, 2);
        assert_eq!(net.wino_layer("s1b0.conv2").unwrap().wf.m, 4);
        // Float winograd ≈ direct regardless of the per-layer mix.
        let x = rand_images(29, 1, 32);
        let yd = ResNet18::from_params(small_cfg(ConvMode::Direct), params).forward(&x);
        let yh = net.forward(&x);
        for (a, b) in yd.data.iter().zip(&yh.data) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
        // Tiles: s0 runs m=2 on 32×32 (16×16 = 256 tiles × 4 layers),
        // stem is direct (0), s1..s3 run m=4: 3·16 + 3·4 + 3·1 = 63.
        assert_eq!(net.wino_tiles_per_item(32), 4 * 256 + 63);
    }

    #[test]
    fn capture_covers_eligible_layers_even_in_direct_mode() {
        let net = ResNet18::init(small_cfg(ConvMode::Direct), 3);
        let x = rand_images(4, 2, 32);
        let captured = net.capture_wino_inputs(&x);
        // stem + s0's 4 block convs + 3 per later stage (conv1 of s1..s3
        // b0 are stride 2; downsamples are 1×1): 14 captured activations.
        assert_eq!(captured.len(), 14);
        assert!(captured.contains_key("stem"));
        assert!(captured.contains_key("s3b1.conv2"));
        assert!(!captured.contains_key("s1b0.conv1"), "stride-2 conv is not wino-eligible");
        assert!(!captured.contains_key("s1b0.down"));
        assert_eq!(captured["stem"].dims, vec![2, 3, 32, 32]);
        assert_eq!(captured["s1b1.conv1"].dims, vec![2, 8, 16, 16]);
    }

    #[test]
    fn calibrate_quant_with_per_layer_policy() {
        // Two layers get different bit policies; the rest stay float.
        let cfg = small_cfg(ConvMode::Winograd {
            m: 4,
            base: Base::Legendre,
            quant: Some(QuantConfig::w8()),
        });
        let mut net = ResNet18::init(cfg, 31);
        let x = rand_images(37, 2, 32);
        net.calibrate_quant_with(&x, &|prefix| match prefix {
            "stem" => Some((QuantConfig::w8(), 100.0)),
            "s0b0.conv1" => Some((QuantConfig::w8_h9(), 99.0)),
            _ => None,
        });
        let stem_q = net.wino_layer("stem").unwrap().quant.unwrap();
        assert_eq!(stem_q.0, QuantConfig::w8());
        let c1_q = net.wino_layer("s0b0.conv1").unwrap().quant.unwrap();
        assert_eq!(c1_q.0.hadamard_bits, 9);
        assert!(net.wino_layer("s0b0.conv2").unwrap().quant.is_none());
        assert!(net.forward(&x).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_winograd_runs_and_differs() {
        let direct = ResNet18::init(small_cfg(ConvMode::Direct), 9);
        let mut qnet = ResNet18::from_params(
            small_cfg(ConvMode::Winograd {
                m: 4,
                base: Base::Legendre,
                quant: Some(QuantConfig::w8()),
            }),
            direct.params.clone(),
        );
        let x = rand_images(13, 2, 32);
        qnet.calibrate_quant(&x);
        let yq = qnet.forward(&x);
        let yd = direct.forward(&x);
        assert_eq!(yq.dims, yd.dims);
        assert!(yq.data.iter().all(|v| v.is_finite()));
        assert!(yq.data != yd.data, "quantization must perturb logits");
    }
}
