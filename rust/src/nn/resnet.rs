//! ResNet18 (CIFAR variant, width-multiplier) — pure-rust inference path.
//!
//! Mirrors the JAX training model in `python/compile/resnet.py`: a 3×3 stem
//! into four stages of two basic blocks with channel widths
//! `[64, 128, 256, 512] × width_mult`, stride 2 between stages, global
//! average pool and a linear head. Every stride-1 3×3 convolution can run
//! either direct or through the (optionally quantized) Winograd layer —
//! exactly the substitution the paper's winograd-aware training makes.
//!
//! Parameters are loaded from the flat-f32 checkpoint blob format the
//! runtime's artifact manifests describe (see `runtime::manifest`), so a
//! trained network can be served without python.
//!
//! The stride-1 3×3 Winograd convolutions execute on the batched
//! [`WinoEngine`](crate::engine::WinoEngine); one
//! [`EngineScratch`](crate::engine::EngineScratch) workspace is threaded
//! through the whole forward pass so the per-layer flat buffers are
//! allocated once per call, not once per layer.

use super::layers::{batchnorm, conv2d, global_avg_pool, linear, relu, Conv2dCfg};
use super::tensor::Tensor;
use super::winolayer::WinoConv2d;
use crate::engine::EngineScratch;
use crate::quant::scheme::QuantConfig;
use crate::wino::basis::Base;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;
use std::collections::HashMap;

/// How to execute the stride-1 3×3 convolutions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConvMode {
    /// Plain direct convolution (the paper's baseline column).
    Direct,
    /// Winograd F(m×m, 3×3) in `base`, optionally quantized.
    Winograd { m: usize, base: Base, quant: Option<QuantConfig> },
}

/// Model hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ResNetCfg {
    pub width_mult: f32,
    pub num_classes: usize,
    pub mode: ConvMode,
}

impl ResNetCfg {
    pub fn widths(&self) -> [usize; 4] {
        let w = |c: usize| ((c as f32 * self.width_mult).round() as usize).max(4);
        [w(64), w(128), w(256), w(512)]
    }
}

/// Named parameter collection (flat f32 tensors).
pub type Params = HashMap<String, Tensor>;

/// A conv+bn unit's parameter names.
fn conv_bn_names(prefix: &str) -> (String, String, String, String, String) {
    (
        format!("{prefix}.w"),
        format!("{prefix}.bn.gamma"),
        format!("{prefix}.bn.beta"),
        format!("{prefix}.bn.mean"),
        format!("{prefix}.bn.var"),
    )
}

pub struct ResNet18 {
    pub cfg: ResNetCfg,
    pub params: Params,
    /// Pre-built Winograd layers keyed by conv prefix (built lazily from
    /// params at construction when mode is Winograd).
    wino: HashMap<String, WinoConv2d>,
}

impl ResNet18 {
    /// All conv-unit prefixes of the architecture, with (stride, in, out).
    pub fn conv_units(cfg: &ResNetCfg) -> Vec<(String, usize, usize, usize)> {
        let w = cfg.widths();
        let mut units = vec![("stem".to_string(), 1, 3, w[0])];
        let mut cin = w[0];
        for (si, &cout) in w.iter().enumerate() {
            for bi in 0..2usize {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                units.push((format!("s{si}b{bi}.conv1"), stride, cin, cout));
                units.push((format!("s{si}b{bi}.conv2"), 1, cout, cout));
                if stride != 1 || cin != cout {
                    units.push((format!("s{si}b{bi}.down"), stride, cin, cout));
                }
                cin = cout;
            }
        }
        units
    }

    /// Initialise with He-style pseudo-random params (for tests / untrained
    /// serving demos).
    pub fn init(cfg: ResNetCfg, seed: u64) -> ResNet18 {
        Self::from_params(cfg, Self::init_params(&cfg, seed))
    }

    /// He-style pseudo-random parameter collection for `cfg` — split out of
    /// [`init`](Self::init) so callers holding a shared transform plan (the
    /// serve registry) can route through
    /// [`from_params_with_plan`](Self::from_params_with_plan).
    pub fn init_params(cfg: &ResNetCfg, seed: u64) -> Params {
        use crate::wino::error::Prng;
        let mut rng = Prng::new(seed);
        let mut params: Params = HashMap::new();
        for (prefix, _stride, cin, cout) in Self::conv_units(cfg) {
            let ksize = if prefix.ends_with("down") { 1 } else { 3 };
            let fan_in = (cin * ksize * ksize) as f64;
            let std = (2.0 / fan_in).sqrt();
            let n = cout * cin * ksize * ksize;
            let w = Tensor::from_vec(
                &[cout, cin, ksize, ksize],
                (0..n).map(|_| (rng.uniform(std) * 1.73) as f32).collect(),
            );
            let (wn, g, b, m, v) = conv_bn_names(&prefix);
            params.insert(wn, w);
            params.insert(g, Tensor::from_vec(&[cout], vec![1.0; cout]));
            params.insert(b, Tensor::from_vec(&[cout], vec![0.0; cout]));
            params.insert(m, Tensor::from_vec(&[cout], vec![0.0; cout]));
            params.insert(v, Tensor::from_vec(&[cout], vec![1.0; cout]));
        }
        let w3 = cfg.widths()[3];
        let std = (2.0 / w3 as f64).sqrt();
        params.insert(
            "fc.w".into(),
            Tensor::from_vec(
                &[w3, cfg.num_classes],
                (0..w3 * cfg.num_classes)
                    .map(|_| (rng.uniform(std)) as f32)
                    .collect(),
            ),
        );
        params.insert(
            "fc.b".into(),
            Tensor::from_vec(&[cfg.num_classes], vec![0.0; cfg.num_classes]),
        );
        params
    }

    /// Build from a parameter collection (e.g. a loaded checkpoint). In
    /// Winograd mode the `F(m, 3)` transform plan is lowered **once** and
    /// shared across all stride-1 3×3 layers (it used to be rebuilt per
    /// layer); callers with a cross-model plan cache pass theirs via
    /// [`from_params_with_plan`](Self::from_params_with_plan).
    pub fn from_params(cfg: ResNetCfg, params: Params) -> ResNet18 {
        match cfg.mode {
            ConvMode::Winograd { m, base, .. } => {
                let wf = WinoF::new(&WinogradPlan::new(m, 3), base);
                Self::build(cfg, params, Some(&|_prefix: &str, w: &Tensor| {
                    WinoConv2d::with_plan(wf.clone(), w)
                }))
            }
            ConvMode::Direct => Self::build(cfg, params, None),
        }
    }

    /// Build from a parameter collection and a shared, already-lowered
    /// transform plan (the serve registry's plan-cache path). `wf` must
    /// match the mode's `(m, base)` — the per-layer engines are lowered
    /// from it without re-running the Toom-Cook construction.
    pub fn from_params_with_plan(cfg: ResNetCfg, params: Params, wf: &WinoF) -> ResNet18 {
        Self::check_plan(&cfg, wf);
        Self::build(cfg, params, Some(&|_prefix: &str, w: &Tensor| {
            WinoConv2d::with_plan(wf.clone(), w)
        }))
    }

    /// Build with a caller-supplied layer lowering `(prefix, weights) →
    /// layer` — how the serve registry routes every stride-1 3×3 layer
    /// through its transform-plan / weight-bank cache. `wf` is only used
    /// to validate the mode; the closure owns construction.
    pub fn from_params_lowered(
        cfg: ResNetCfg,
        params: Params,
        wf: &WinoF,
        lower: &dyn Fn(&str, &Tensor) -> WinoConv2d,
    ) -> ResNet18 {
        Self::check_plan(&cfg, wf);
        Self::build(cfg, params, Some(lower))
    }

    fn check_plan(cfg: &ResNetCfg, wf: &WinoF) {
        match cfg.mode {
            ConvMode::Winograd { m, base, .. } => {
                assert_eq!(wf.m, m, "plan tile size mismatch");
                assert_eq!(wf.base, base, "plan base mismatch");
                assert_eq!(wf.r, 3, "ResNet18 wino layers are 3x3");
            }
            ConvMode::Direct => panic!("a transform plan requires Winograd mode"),
        }
    }

    fn build(
        cfg: ResNetCfg,
        params: Params,
        lower: Option<&dyn Fn(&str, &Tensor) -> WinoConv2d>,
    ) -> ResNet18 {
        let mut wino = HashMap::new();
        if let (ConvMode::Winograd { .. }, Some(lower)) = (cfg.mode, lower) {
            for (prefix, stride, _cin, _cout) in Self::conv_units(&cfg) {
                if stride != 1 || prefix.ends_with("down") {
                    continue; // strided/1×1 convs stay direct (as in ref [5])
                }
                let w = params
                    .get(&format!("{prefix}.w"))
                    .unwrap_or_else(|| panic!("missing weights for {prefix}"));
                wino.insert(prefix.clone(), lower(&prefix, w));
            }
        }
        ResNet18 { cfg, params, wino }
    }

    /// Calibrate the quantized Winograd layers on a representative batch.
    pub fn calibrate_quant(&mut self, batch: &Tensor) {
        if let ConvMode::Winograd { quant: Some(qcfg), .. } = self.cfg.mode {
            // Run the network stem-to-tail, calibrating each wino layer on
            // its actual input activations.
            let mut captured: HashMap<String, Tensor> = HashMap::new();
            self.forward_impl(batch, Some(&mut captured), &mut EngineScratch::new());
            for (prefix, layer) in self.wino.iter_mut() {
                if let Some(input) = captured.get(prefix) {
                    layer.quantize(qcfg, input, 1);
                }
            }
        }
    }

    fn conv_unit(
        &self,
        x: &Tensor,
        prefix: &str,
        stride: usize,
        capture: &mut Option<&mut HashMap<String, Tensor>>,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        let (wn, g, b, m, v) = conv_bn_names(prefix);
        let w = &self.params[&wn];
        let pad = if w.dims[2] == 3 { 1 } else { 0 };
        if let Some(cap) = capture.as_deref_mut() {
            if self.wino.contains_key(prefix) {
                cap.insert(prefix.to_string(), x.clone());
            }
        }
        let y = match (&self.cfg.mode, self.wino.get(prefix)) {
            (ConvMode::Winograd { .. }, Some(layer)) if stride == 1 => {
                layer.forward_with_scratch(x, Conv2dCfg { stride: 1, padding: pad }, scratch)
            }
            _ => conv2d(x, w, None, Conv2dCfg { stride, padding: pad }),
        };
        batchnorm(
            &y,
            &self.params[&g].data,
            &self.params[&b].data,
            &self.params[&m].data,
            &self.params[&v].data,
            1e-5,
        )
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        mut capture: Option<&mut HashMap<String, Tensor>>,
        sc: &mut EngineScratch,
    ) -> Tensor {
        let mut h = relu(&self.conv_unit(x, "stem", 1, &mut capture, sc));
        let widths = self.cfg.widths();
        let mut cin = widths[0];
        for (si, &cout) in widths.iter().enumerate() {
            for bi in 0..2usize {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let prefix = format!("s{si}b{bi}");
                let y1 =
                    relu(&self.conv_unit(&h, &format!("{prefix}.conv1"), stride, &mut capture, sc));
                let y2 = self.conv_unit(&y1, &format!("{prefix}.conv2"), 1, &mut capture, sc);
                let shortcut = if stride != 1 || cin != cout {
                    self.conv_unit(&h, &format!("{prefix}.down"), stride, &mut capture, sc)
                } else {
                    h.clone()
                };
                h = relu(&y2.add(&shortcut));
                cin = cout;
            }
        }
        let pooled = global_avg_pool(&h);
        linear(&pooled, &self.params["fc.w"], &self.params["fc.b"].data)
    }

    /// Forward pass: `x` [N,3,H,W] → logits [N, num_classes]. Allocates a
    /// fresh engine workspace; serving loops should prefer
    /// [`forward_with_scratch`](Self::forward_with_scratch).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_impl(x, None, &mut EngineScratch::new())
    }

    /// Forward pass reusing a caller-held engine workspace — the serve
    /// workers hold one [`EngineScratch`] each, so repeated micro-batch
    /// passes stay allocation-free on the large flat buffers. Output is
    /// identical to [`forward`](Self::forward).
    pub fn forward_with_scratch(&self, x: &Tensor, scratch: &mut EngineScratch) -> Tensor {
        self.forward_impl(x, None, scratch)
    }

    /// Top-1 accuracy on a labelled batch.
    pub fn accuracy(&self, x: &Tensor, labels: &[usize]) -> f64 {
        let logits = self.forward(x);
        let preds = logits.argmax_rows();
        let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        correct as f64 / labels.len() as f64
    }

    pub fn param_count(&self) -> usize {
        self.params.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wino::error::Prng;

    fn small_cfg(mode: ConvMode) -> ResNetCfg {
        ResNetCfg { width_mult: 0.25, num_classes: 10, mode }
    }

    fn rand_images(seed: u64, n: usize, hw: usize) -> Tensor {
        let mut rng = Prng::new(seed);
        let len = n * 3 * hw * hw;
        Tensor::from_vec(
            &[n, 3, hw, hw],
            (0..len).map(|_| rng.uniform(1.0) as f32).collect(),
        )
    }

    #[test]
    fn forward_shape() {
        let net = ResNet18::init(small_cfg(ConvMode::Direct), 1);
        let x = rand_images(2, 2, 32);
        let y = net.forward(&x);
        assert_eq!(y.dims, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn winograd_mode_matches_direct_unquantized() {
        // Float Winograd is algebraically a re-ordering — logits must agree
        // with direct conv to f32 tolerance.
        let direct = ResNet18::init(small_cfg(ConvMode::Direct), 7);
        let wino = ResNet18::from_params(
            small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None }),
            direct.params.clone(),
        );
        let x = rand_images(3, 1, 32);
        let yd = direct.forward(&x);
        let yw = wino.forward(&x);
        for (a, b) in yd.data.iter().zip(&yw.data) {
            assert!((a - b).abs() < 2e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn param_count_scales_with_width() {
        let small = ResNet18::init(small_cfg(ConvMode::Direct), 1);
        let big = ResNet18::init(
            ResNetCfg { width_mult: 0.5, num_classes: 10, mode: ConvMode::Direct },
            1,
        );
        assert!(big.param_count() > 3 * small.param_count());
    }

    #[test]
    fn conv_units_structure() {
        let units = ResNet18::conv_units(&small_cfg(ConvMode::Direct));
        // stem + 4 stages × 2 blocks × 2 convs + 3 downsamples = 20.
        assert_eq!(units.len(), 20);
        let downs: Vec<_> = units.iter().filter(|u| u.0.ends_with("down")).collect();
        assert_eq!(downs.len(), 3);
    }

    #[test]
    fn accuracy_on_random_labels_near_chance() {
        let net = ResNet18::init(small_cfg(ConvMode::Direct), 5);
        let x = rand_images(11, 16, 32);
        let labels: Vec<usize> = (0..16).map(|i| i % 10).collect();
        let acc = net.accuracy(&x, &labels);
        assert!(acc <= 0.6, "untrained net should be near chance, got {acc}");
    }

    #[test]
    fn shared_plan_construction_matches_fresh() {
        // from_params_with_plan (serve registry path) must be
        // indistinguishable from from_params' per-net plan.
        use crate::wino::toomcook::WinogradPlan;
        use crate::wino::transform::WinoF;
        let cfg = small_cfg(ConvMode::Winograd { m: 4, base: Base::Legendre, quant: None });
        let params = ResNet18::init_params(&cfg, 17);
        let fresh = ResNet18::from_params(cfg, params.clone());
        let wf = WinoF::new(&WinogradPlan::new(4, 3), Base::Legendre);
        let shared = ResNet18::from_params_with_plan(cfg, params, &wf);
        let x = rand_images(19, 1, 32);
        assert_eq!(fresh.forward(&x).data, shared.forward(&x).data);
    }

    #[test]
    fn quantized_winograd_runs_and_differs() {
        let direct = ResNet18::init(small_cfg(ConvMode::Direct), 9);
        let mut qnet = ResNet18::from_params(
            small_cfg(ConvMode::Winograd {
                m: 4,
                base: Base::Legendre,
                quant: Some(QuantConfig::w8()),
            }),
            direct.params.clone(),
        );
        let x = rand_images(13, 2, 32);
        qnet.calibrate_quant(&x);
        let yq = qnet.forward(&x);
        let yd = direct.forward(&x);
        assert_eq!(yq.dims, yd.dims);
        assert!(yq.data.iter().all(|v| v.is_finite()));
        assert!(yq.data != yd.data, "quantization must perturb logits");
    }
}
