//! Deterministic multi-tenant serve soak harness.
//!
//! Drives the *production* scheduling policy
//! ([`Scheduler`](crate::serve::Scheduler) — the same struct the threaded
//! [`ServeQueue`](crate::serve::ServeQueue) embeds) under a **virtual
//! microsecond clock**: no threads, no `Instant`, no sleeps. Arrivals,
//! model routing, priorities, deadlines and service jitter all come from
//! one seeded [`Prng`], and every event is processed in deterministic
//! order, so the same [`SoakConfig`] always produces the same
//! [`SoakReport`] — byte-identical JSON — which is what lets
//! `tests/serve_deadline.rs` assert scheduler invariants over thousands
//! of simulated requests in milliseconds of test time.
//!
//! The simulation models the sharded server one-to-one:
//!
//! * each [`SoakModel`] is a tenant with its own [`Scheduler`] (capacity
//!   from [`admission_caps`] over the shared budget, exactly like
//!   [`with_shards`](crate::serve::with_shards)) and a pool of virtual
//!   workers;
//! * a worker's service time for a batch is the tenant cost model's
//!   prediction plus bounded seeded jitter, so latency distributions
//!   show realistic queueing/batching structure;
//! * a slice of the traffic is generated *hopeless* (deadline below the
//!   solo predicted cost) to exercise the shed path, and a slice is
//!   deadline-free to exercise FIFO degradation.
//!
//! `winoq serve --soak` runs this harness and writes the report to
//! `BENCH_serve_soak.json` (schema in the README); `scripts/ci.sh`
//! smoke-runs it and checks the totals reconcile exactly.

use std::collections::{BTreeMap, BTreeSet};

use super::chaos::{ChaosConfig, Fault};
use crate::benchkit::percentile_sorted;
use crate::obs::drift::{DriftConfig, DriftMonitor, DriftSample};
use crate::obs::json::{JsonArr, JsonObj};
use crate::obs::{TraceKind, TraceLog};
use crate::serve::queue::lane;
use crate::serve::{admission_caps, Poll, Priority, SchedItem, Scheduler, Shed};
use crate::tune::cost::TileCostModel;
use crate::wino::basis::Base;
use crate::wino::error::Prng;

/// Synthetic per-sample shadow-oracle rel-L2 the drift-enabled soak
/// attributes to every sampled span (scaled by
/// [`SoakConfig::drift_err_scale`]); the monitor's budget is this base
/// times its headroom, so calibrated traffic (`scale = 1.0`, jitter
/// < 10%) never alerts and scaled-out traffic must.
const SOAK_DRIFT_BASE_ERR: f64 = 0.002;

/// Deterministic synthetic rel-L2 for one sampled span: splitmix64 of
/// `seed ^ span` jitters the base error by < 10%, then the out-of-
/// distribution `scale` multiplies it. A pure function of the span id —
/// the simulation's [`Prng`] is never touched, so enabling drift
/// sampling cannot perturb arrivals, routing, or jitter.
fn synthetic_rel_err(seed: u64, span: u64, scale: f64) -> f64 {
    let mut z = (seed ^ span).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    SOAK_DRIFT_BASE_ERR * (1.0 + (z % 1000) as f64 / 10_000.0) * scale
}

/// One simulated tenant (model shard) of the soak run.
#[derive(Clone, Debug)]
pub struct SoakModel {
    /// Model name (report key).
    pub name: String,
    /// Admission weight — shares the budget via [`admission_caps`].
    pub weight: u64,
    /// Virtual worker count for this tenant.
    pub workers: usize,
    /// Cost model pricing this tenant's batches (prediction = service).
    pub cost: TileCostModel,
}

/// Full description of a soak run. Every field feeds the seeded
/// generator or the virtual event loop; two equal configs produce
/// byte-identical reports.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// PRNG seed for arrivals, routing, deadlines and service jitter.
    pub seed: u64,
    /// Total requests to generate across all tenants.
    pub requests: usize,
    /// Shared admission budget split across tenants by weight.
    pub budget: usize,
    /// Maximum micro-batch size.
    pub max_batch: usize,
    /// Batching window, µs (per-request deadlines can close earlier).
    pub window_us: u64,
    /// Mean inter-arrival gap, µs (gaps are uniform in `[1, 2·mean]`).
    pub mean_gap_us: u64,
    /// Base relative deadline, µs: normal requests get
    /// `deadline_us + U[0, deadline_us)` of slack.
    pub deadline_us: u64,
    /// Percent of requests generated *hopeless* (deadline below the solo
    /// predicted cost — must shed).
    pub tight_pct: u32,
    /// Percent of requests generated deadline-free (best-effort lane).
    pub no_deadline_pct: u32,
    /// Request shapes as `(h, w, tiles)` — tile weights are the caller's
    /// (the CLI derives them from the real
    /// [`tile_count_for`](crate::engine::layout::tile_count_for) grids).
    pub shapes: Vec<(usize, usize, u64)>,
    /// The tenants.
    pub models: Vec<SoakModel>,
    /// Service jitter bound as a divisor: each batch adds
    /// `U[0, predicted/div]` µs. `0` disables jitter.
    pub service_jitter_div: u64,
    /// Shadow-oracle drift sampling stride: every Nth span (offset
    /// `seed % stride`, the [`DriftMonitor`] rule) gets a synthetic
    /// drift measurement at completion. `0` disables drift entirely —
    /// the report is then byte-identical to a pre-drift run.
    pub drift_stride: u64,
    /// Multiplier on the synthetic rel-L2 — `1.0` models calibrated
    /// traffic (stays inside budget, zero alerts); large values model an
    /// out-of-distribution input sweep and must raise alerts.
    pub drift_err_scale: f64,
    /// Deterministic fault plan ([`ChaosConfig`]): injected worker
    /// panics (fail the batch, restart the virtual worker with backoff
    /// under [`ChaosConfig::restart_budget`], retire it when
    /// exhausted), per-batch latency, activation corruption (scales the
    /// synthetic drift error), and arrival-burst queue saturation.
    /// `None` runs byte-identical to a pre-chaos soak.
    pub chaos: Option<ChaosConfig>,
}

/// One generated request (pre-computed before the event loop runs).
#[derive(Clone, Copy, Debug)]
struct Arrival {
    at_us: u64,
    model: usize,
    priority: Priority,
    deadline_us: Option<u64>,
    shape: (usize, usize),
    tiles: u64,
}

/// One dispatched batch, as the invariant suite sees it.
#[derive(Clone, Copy, Debug)]
pub struct BatchTrace {
    /// Tenant index into [`SoakConfig::models`].
    pub model: usize,
    /// Virtual time the batch closed (dispatch decision).
    pub closed_us: u64,
    /// Predicted batch cost at close time, µs.
    pub predicted_us: u64,
    /// Earliest member deadline, if any member had one. The pinned
    /// invariant: `closed_us + predicted_us ≤ earliest_deadline_us`.
    pub earliest_deadline_us: Option<u64>,
    /// Batch size (≥ 1, ≤ configured `max_batch`).
    pub size: usize,
}

/// Drift-sampling outcome of a soak run (present iff
/// [`SoakConfig::drift_stride`] > 0).
#[derive(Clone, Debug)]
pub struct SoakDrift {
    /// Spans that received a shadow-oracle measurement.
    pub sampled: u64,
    /// Budget-violation alerts raised (one per violated window per
    /// layer — the [`DriftMonitor`] dedup rule).
    pub alerts: u64,
    /// The monitor's full JSON report
    /// ([`DriftMonitor::to_json`]), embedded verbatim in
    /// [`SoakReport::to_json`] under `"drift"`.
    pub report: String,
}

/// One shed decision, with the scheduler's justification.
#[derive(Clone, Copy, Debug)]
pub struct ShedTrace {
    /// Tenant index into [`SoakConfig::models`].
    pub model: usize,
    /// The request that was shed.
    pub item: SchedItem,
    /// Why (`decided_us + predicted_us > deadline_us` always holds).
    pub why: Shed,
}

/// Per-tenant outcome totals and latency percentiles.
#[derive(Clone, Debug)]
pub struct ModelSoak {
    /// Tenant name.
    pub name: String,
    /// Requests routed to this tenant.
    pub submitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests refused at admission (shard queue full).
    pub rejected: u64,
    /// Requests shed by the deadline policy.
    pub shed: u64,
    /// Requests failed terminally (poisoned batch under an injected
    /// panic, or drained after every tenant worker retired).
    pub failed: u64,
    /// Completed requests that finished past their deadline.
    pub deadline_missed: u64,
    /// Latency percentiles over completed requests, µs (0 when none).
    pub p50_us: f64,
    /// 99th percentile latency, µs.
    pub p99_us: f64,
    /// 99.9th percentile latency, µs.
    pub p999_us: f64,
    /// Completed requests per virtual second.
    pub requests_per_sec: f64,
}

/// The soak run's full result: exact accounting totals, latency
/// percentiles, per-tenant breakdown, and the raw batch/shed traces the
/// property suites walk.
#[derive(Clone, Debug)]
pub struct SoakReport {
    /// Config echo: PRNG seed.
    pub seed: u64,
    /// Config echo: generated request count.
    pub requests: u64,
    /// Config echo: maximum batch size (trace invariant bound).
    pub max_batch: usize,
    /// Virtual time the last worker went idle, µs.
    pub virtual_wall_us: u64,
    /// Requests generated (= `requests`).
    pub submitted: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Requests refused at admission.
    pub rejected: u64,
    /// Requests shed with predicted-cost justification.
    pub shed: u64,
    /// Requests failed terminally (chaos panics / retired workers).
    pub failed: u64,
    /// Supervised virtual-worker restarts over the run (0 without
    /// chaos).
    pub worker_restarts: u64,
    /// Virtual workers retired after exhausting the restart budget.
    pub workers_retired: u64,
    /// Completed requests that finished past their deadline.
    pub deadline_missed: u64,
    /// Overall completed-latency percentiles, µs (0 when none completed).
    pub p50_us: f64,
    /// 95th percentile latency, µs.
    pub p95_us: f64,
    /// 99th percentile latency, µs.
    pub p99_us: f64,
    /// 99.9th percentile latency, µs.
    pub p999_us: f64,
    /// Maximum completed latency, µs.
    pub max_us: f64,
    /// `deadline_missed / completed` (0 when nothing completed).
    pub deadline_miss_rate: f64,
    /// Per-tenant breakdown, in [`SoakConfig::models`] order.
    pub per_model: Vec<ModelSoak>,
    /// Drift-sampling summary — `Some` iff [`SoakConfig::drift_stride`]
    /// was non-zero. Serialized as a trailing `"drift"` object so
    /// drift-off reports keep their exact pre-drift bytes.
    pub drift: Option<SoakDrift>,
    /// Every dispatched batch (not serialized to JSON).
    pub batches: Vec<BatchTrace>,
    /// Every shed decision (not serialized to JSON).
    pub sheds: Vec<ShedTrace>,
}

impl SoakReport {
    /// The full-accounting invariant: every generated request is exactly
    /// one of completed / rejected / shed / failed.
    pub fn accounting_exact(&self) -> bool {
        let per_model_ok = self.per_model.iter().all(|m| {
            m.submitted == m.completed + m.rejected + m.shed + m.failed
        });
        self.submitted == self.requests
            && self.submitted == self.completed + self.rejected + self.shed + self.failed
            && per_model_ok
    }

    /// One-line human summary for the CLI.
    pub fn summary_line(&self) -> String {
        format!(
            "soak: {} submitted = {} ok + {} rejected + {} shed + {} failed | \
             {} restarts, {} retired | {} missed deadline \
             (rate {:.4}) | p50/p99/p99.9 {:.0}/{:.0}/{:.0} µs over {:.3}s virtual",
            self.submitted,
            self.completed,
            self.rejected,
            self.shed,
            self.failed,
            self.worker_restarts,
            self.workers_retired,
            self.deadline_missed,
            self.deadline_miss_rate,
            self.p50_us,
            self.p99_us,
            self.p999_us,
            self.virtual_wall_us as f64 / 1e6,
        )
    }

    /// Serialize to the `BENCH_serve_soak.json` schema (documented in the
    /// README; `scripts/ci.sh` parses the `totals` object with `sed`, so
    /// key order is load-bearing — built on [`obs::json`](crate::obs::json)
    /// like every other emitter in the tree).
    pub fn to_json(&self) -> String {
        let mut per_model = JsonArr::new();
        for m in &self.per_model {
            let lat = JsonObj::new()
                .f64("p50", m.p50_us, 3)
                .f64("p99", m.p99_us, 3)
                .f64("p999", m.p999_us, 3)
                .finish();
            per_model = per_model.item(
                &JsonObj::new()
                    .str("name", &m.name)
                    .u64("submitted", m.submitted)
                    .u64("completed", m.completed)
                    .u64("rejected", m.rejected)
                    .u64("shed", m.shed)
                    .u64("failed", m.failed)
                    .u64("deadline_missed", m.deadline_missed)
                    .raw("latency_us", &lat)
                    .f64("requests_per_sec", m.requests_per_sec, 3)
                    .finish(),
            );
        }
        let totals = JsonObj::new()
            .u64("submitted", self.submitted)
            .u64("completed", self.completed)
            .u64("rejected", self.rejected)
            .u64("shed", self.shed)
            .u64("failed", self.failed)
            .u64("worker_restarts", self.worker_restarts)
            .u64("workers_retired", self.workers_retired)
            .u64("deadline_missed", self.deadline_missed)
            .finish();
        let lat = JsonObj::new()
            .f64("p50", self.p50_us, 3)
            .f64("p95", self.p95_us, 3)
            .f64("p99", self.p99_us, 3)
            .f64("p999", self.p999_us, 3)
            .f64("max", self.max_us, 3)
            .finish();
        let mut obj = JsonObj::new()
            .str("bench", "serve_soak")
            .u64("seed", self.seed)
            .u64("requests", self.requests)
            .u64("virtual_wall_us", self.virtual_wall_us)
            .raw("totals", &totals)
            .f64("deadline_miss_rate", self.deadline_miss_rate, 6)
            .raw("latency_us", &lat)
            .raw("per_model", &per_model.finish());
        if let Some(d) = &self.drift {
            obj = obj.raw("drift", &d.report);
        }
        let mut out = obj.finish();
        out.push('\n');
        out
    }
}

/// A retired virtual worker's busy-until sentinel: never free again.
const RETIRED: u64 = u64::MAX;

/// Live per-tenant state of the event loop.
struct Tenant {
    sched: Scheduler,
    /// Per-worker busy-until timestamps (virtual µs); [`RETIRED`] marks
    /// a worker whose restart budget is exhausted.
    workers: Vec<u64>,
    /// Per-worker cumulative supervised-restart counts.
    restarts: Vec<u32>,
    lat_us: Vec<f64>,
    submitted: u64,
    rejected: u64,
    shed: u64,
    failed: u64,
    missed: u64,
}

/// Generate the full arrival trace up front (deterministic in the seed).
fn generate_arrivals(cfg: &SoakConfig, rng: &mut Prng) -> Vec<Arrival> {
    assert!(!cfg.models.is_empty(), "soak needs at least one model");
    assert!(!cfg.shapes.is_empty(), "soak needs at least one shape");
    let total_w: u64 = cfg.models.iter().map(|m| m.weight).sum::<u64>().max(1);
    let mut t = 0u64;
    let mut arrivals = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        // Saturation bursts: the gap draw still happens (so a burst
        // changes arrival *times*, never the downstream routing /
        // deadline draws), but arrivals inside a burst window land
        // back-to-back, slamming the admission caps.
        let gap = 1 + rng.next_u64() % (2 * cfg.mean_gap_us.max(1));
        let burst = cfg.chaos.as_ref().is_some_and(|c| c.burst_at(i as u64));
        t += if burst { 1 } else { gap };
        let mut pick = rng.next_u64() % total_w;
        let mut model = cfg.models.len() - 1;
        for (i, m) in cfg.models.iter().enumerate() {
            if pick < m.weight {
                model = i;
                break;
            }
            pick -= m.weight;
        }
        let (h, w, tiles) = cfg.shapes[(rng.next_u64() as usize) % cfg.shapes.len()];
        let priority = match rng.next_u64() % 10 {
            0..=1 => Priority::High,
            2..=8 => Priority::Normal,
            _ => Priority::Low,
        };
        let solo = cfg.models[model].cost.predict_us(tiles).max(1);
        let roll = (rng.next_u64() % 100) as u32;
        let deadline_us = if roll < cfg.no_deadline_pct {
            None
        } else if roll < cfg.no_deadline_pct + cfg.tight_pct {
            // Hopeless by construction: the solo predicted cost already
            // overruns this deadline, so the shed path must fire.
            Some(t + solo / 2)
        } else {
            let base = cfg.deadline_us.max(1);
            Some(t + base + rng.next_u64() % base)
        };
        arrivals.push(Arrival { at_us: t, model, priority, deadline_us, shape: (h, w), tiles });
    }
    arrivals
}

/// Run the soak simulation to completion and fold the report.
pub fn run_soak(cfg: &SoakConfig) -> SoakReport {
    run_soak_with(cfg, None)
}

/// [`run_soak`], but with every request's lifecycle recorded as trace
/// events (span = arrival index + 1). The simulation itself is
/// untouched — tracing consumes no PRNG draws — so the returned report
/// is byte-identical to the untraced run's, and the trace replays
/// byte-identically per seed. Stage timings are synthesized from the
/// batch's virtual service time with the measured 45/35/20 split of the
/// real engine (input transform / Hadamard / inverse); plan-cache
/// hit/miss is first-seen `(model, shape)`, mirroring
/// [`PlanCache`](crate::serve::PlanCache) shape-key behavior.
pub fn run_soak_traced(cfg: &SoakConfig) -> (SoakReport, TraceLog) {
    let mut log = TraceLog::new();
    let report = run_soak_with(cfg, Some(&mut log));
    (report, log)
}

/// Shared event loop behind [`run_soak`] / [`run_soak_traced`].
fn run_soak_with(cfg: &SoakConfig, mut trace: Option<&mut TraceLog>) -> SoakReport {
    let mut rng = Prng::new(cfg.seed);
    let arrivals = generate_arrivals(cfg, &mut rng);
    // Drift monitor: one "layer" per tenant, budget = base synthetic
    // error × monitor headroom (2× covers the < 10% deterministic
    // jitter with margin — calibrated traffic never alerts).
    let drift = (cfg.drift_stride > 0).then(|| {
        let mut dm = DriftMonitor::new(DriftConfig {
            stride: cfg.drift_stride,
            seed: cfg.seed,
            window_us: 1_000_000,
            windows: 8,
            headroom: 2.0,
        });
        for m in &cfg.models {
            dm.set_budget(&m.name, Some(SOAK_DRIFT_BASE_ERR));
        }
        dm
    });
    // Dispatched items are mapped back to spans by `submitted_us`:
    // arrival gaps are ≥ 1 µs, so the timestamp is globally unique.
    let mut span_by_at: BTreeMap<u64, u64> = BTreeMap::new();
    let mut seen_plans: BTreeSet<(usize, (usize, usize))> = BTreeSet::new();
    let weights: Vec<u64> = cfg.models.iter().map(|m| m.weight).collect();
    let caps = admission_caps(cfg.budget, &weights);
    let mut tenants: Vec<Tenant> = cfg
        .models
        .iter()
        .zip(&caps)
        .map(|(m, &cap)| Tenant {
            sched: Scheduler::new(cap),
            workers: vec![0u64; m.workers.max(1)],
            restarts: vec![0u32; m.workers.max(1)],
            lat_us: Vec::new(),
            submitted: 0,
            rejected: 0,
            shed: 0,
            failed: 0,
            missed: 0,
        })
        .collect();

    let mut batches: Vec<BatchTrace> = Vec::new();
    let mut sheds: Vec<ShedTrace> = Vec::new();
    // Chaos state: one global batch index (only non-empty dispatched
    // batches consume schedule slots, mirroring the threaded
    // `FaultPlan`), plus run-wide restart/retire totals.
    let chaos = cfg.chaos.as_ref().filter(|c| c.is_enabled());
    let mut batch_idx = 0u64;
    let mut worker_restarts = 0u64;
    let mut workers_retired = 0u64;
    let mut now = 0u64;
    let mut idx = 0usize;
    loop {
        // 1. Admit every arrival due by now (gaps are ≥ 1 µs, so the
        // event loop lands exactly on each arrival timestamp).
        while idx < arrivals.len() && arrivals[idx].at_us <= now {
            let a = arrivals[idx];
            let span = idx as u64 + 1;
            let tnt = &mut tenants[a.model];
            tnt.submitted += 1;
            if let Some(log) = trace.as_deref_mut() {
                log.record(
                    span,
                    a.at_us,
                    TraceKind::Submit {
                        model: cfg.models[a.model].name.clone(),
                        priority: lane(a.priority).into(),
                        // Relative SLO, like the threaded queue records.
                        deadline_us: a.deadline_us.map_or(0, |d| d - a.at_us),
                        tiles: a.tiles,
                        h: a.shape.0 as u64,
                        w: a.shape.1 as u64,
                    },
                );
            }
            let admitted = tnt
                .sched
                .submit(a.at_us, a.priority, a.deadline_us, a.tiles, a.shape)
                .is_some();
            if admitted && (trace.is_some() || drift.is_some()) {
                // Drift sampling needs the span id at completion even
                // when untraced; the map itself never feeds the report.
                span_by_at.insert(a.at_us, span);
            }
            if let Some(log) = trace.as_deref_mut() {
                if admitted {
                    let hit = !seen_plans.insert((a.model, a.shape));
                    log.record(
                        span,
                        a.at_us,
                        TraceKind::PlanCache { model: cfg.models[a.model].name.clone(), hit },
                    );
                } else {
                    log.record(span, a.at_us, TraceKind::Reject { why: "queue_full".into() });
                }
            }
            if !admitted {
                tnt.rejected += 1;
            }
            idx += 1;
        }
        // 2. Dispatch: each tenant drains onto free virtual workers. Once
        // the arrival trace is exhausted the remaining work is flushed
        // (the drain-on-close path), so the run terminates without
        // waiting out batching windows.
        let flush = idx >= arrivals.len();
        let mut wait_hints: Vec<u64> = Vec::new();
        for (mi, tnt) in tenants.iter_mut().enumerate() {
            loop {
                let Some(wi) = tnt.workers.iter().position(|&b| b <= now) else {
                    break;
                };
                let cost = &cfg.models[mi].cost;
                match tnt.sched.poll(now, cfg.max_batch, cfg.window_us, Some(cost), flush) {
                    Poll::Idle => break,
                    Poll::WaitUntil(t) => {
                        wait_hints.push(t);
                        break;
                    }
                    Poll::Dispatch { batch, shed } => {
                        for (item, why) in shed {
                            tnt.shed += 1;
                            if let Some(log) = trace.as_deref_mut() {
                                let span = span_by_at[&item.submitted_us];
                                log.record(span, why.decided_us, why.trace_event());
                            }
                            sheds.push(ShedTrace { model: mi, item, why });
                        }
                        if batch.is_empty() {
                            // Shed-only poll: go around again.
                            continue;
                        }
                        assert!(
                            batch.iter().all(|it| it.shape == batch[0].shape),
                            "scheduler dispatched a shape-mixed batch"
                        );
                        let tiles: u64 = batch.iter().map(|it| it.tiles).sum();
                        let predicted = cost.predict_us(tiles).max(1);
                        // Chaos: claim this batch's scheduled fault —
                        // only non-empty batches consume schedule slots,
                        // mirroring the threaded `FaultPlan`.
                        let fault = chaos.and_then(|c| {
                            let f = c.fault_for(batch_idx);
                            batch_idx += 1;
                            f
                        });
                        if fault == Some(Fault::Panic) {
                            // Poisoned batch: every member fails
                            // terminally; the supervisor restarts this
                            // virtual worker with backoff until its
                            // budget is spent, then retires it.
                            let c = chaos.unwrap();
                            for it in &batch {
                                tnt.failed += 1;
                                if let Some(log) = trace.as_deref_mut() {
                                    let span = span_by_at[&it.submitted_us];
                                    log.record(
                                        span,
                                        now,
                                        TraceKind::Batch {
                                            size: batch.len() as u64,
                                            predicted_us: predicted,
                                        },
                                    );
                                    log.record(
                                        span,
                                        now,
                                        TraceKind::Failed {
                                            reason: "chaos: injected worker panic".into(),
                                        },
                                    );
                                }
                            }
                            tnt.restarts[wi] += 1;
                            if tnt.restarts[wi] > c.restart_budget {
                                tnt.workers[wi] = RETIRED;
                                workers_retired += 1;
                            } else {
                                worker_restarts += 1;
                                let backoff_us = c.backoff_for(tnt.restarts[wi]);
                                tnt.workers[wi] = now + backoff_us;
                                if let Some(log) = trace.as_deref_mut() {
                                    // Span 0 is the reserved "untraced"
                                    // carrier: process-level events ride
                                    // it without touching accounting.
                                    log.record(
                                        0,
                                        now,
                                        TraceKind::WorkerRestart {
                                            worker: ((mi as u64) << 8) | wi as u64,
                                            restarts: tnt.restarts[wi] as u64,
                                            backoff_us,
                                        },
                                    );
                                }
                            }
                            continue;
                        }
                        let (fault_lat_us, corrupt_mult) = match fault {
                            Some(Fault::Latency { us }) => (us, 1.0),
                            Some(Fault::Corrupt { scale }) => (0, scale),
                            _ => (0, 1.0),
                        };
                        let jitter = if cfg.service_jitter_div == 0 {
                            0
                        } else {
                            rng.next_u64() % (predicted / cfg.service_jitter_div + 1)
                        };
                        let done = now + predicted + jitter + fault_lat_us;
                        tnt.workers[wi] = done;
                        batches.push(BatchTrace {
                            model: mi,
                            closed_us: now,
                            predicted_us: predicted,
                            earliest_deadline_us: batch
                                .iter()
                                .filter_map(|it| it.deadline_us)
                                .min(),
                            size: batch.len(),
                        });
                        // Synthesized stage split: the engine's measured
                        // cost shape (45% input transform, 35% Hadamard,
                        // remainder inverse) of the batch's service ns.
                        let total_ns = (predicted + jitter) * 1000;
                        let input_ns = total_ns * 45 / 100;
                        let had_ns = total_ns * 35 / 100;
                        let inv_ns = total_ns - input_ns - had_ns;
                        for it in &batch {
                            let span = span_by_at.get(&it.submitted_us).copied();
                            let size = batch.len() as u64;
                            if let Some(log) = trace.as_deref_mut() {
                                let span = span.unwrap();
                                log.record(
                                    span,
                                    now,
                                    TraceKind::Batch { size, predicted_us: predicted },
                                );
                                log.record(
                                    span,
                                    done,
                                    TraceKind::Stage {
                                        input_transform_ns: input_ns,
                                        hadamard_ns: had_ns,
                                        inverse_ns: inv_ns,
                                        tiles,
                                    },
                                );
                            }
                            // Shadow-oracle drift sample at completion
                            // time; alerts land between Stage and
                            // Complete in the trace stream.
                            if let (Some(dm), Some(span)) = (drift.as_ref(), span) {
                                if dm.should_sample(span) {
                                    let sample = DriftSample {
                                        layer: cfg.models[mi].name.clone(),
                                        m: 4,
                                        base: Base::Legendre,
                                        weight_bits: 8,
                                        hadamard_bits: 9,
                                        // An injected corruption fault
                                        // scales this batch's synthetic
                                        // error on top of the config's
                                        // OOD multiplier — corrupted
                                        // activations are exactly what
                                        // the shadow oracle must flag.
                                        rel_err: synthetic_rel_err(
                                            cfg.seed,
                                            span,
                                            cfg.drift_err_scale * corrupt_mult,
                                        ),
                                    };
                                    let alerts = dm.observe(span, done, &[sample]);
                                    if let Some(log) = trace.as_deref_mut() {
                                        for kind in alerts {
                                            log.record(span, done, kind);
                                        }
                                    }
                                }
                            }
                            if let Some(log) = trace.as_deref_mut() {
                                log.record(
                                    span.unwrap(),
                                    done,
                                    TraceKind::Complete {
                                        latency_us: done - it.submitted_us,
                                        batch_size: size,
                                    },
                                );
                            }
                            tnt.lat_us.push((done - it.submitted_us) as f64);
                            if it.deadline_us.is_some_and(|d| done > d) {
                                tnt.missed += 1;
                            }
                        }
                    }
                }
            }
            // A tenant whose every worker retired can never serve again:
            // drain its scheduler now (flush mode), failing batch members
            // and recording sheds, so accounting stays exact and the run
            // terminates instead of stranding admitted requests.
            if chaos.is_some()
                && tnt.workers.iter().all(|&b| b == RETIRED)
                && tnt.sched.depth() > 0
            {
                let cost = &cfg.models[mi].cost;
                loop {
                    match tnt.sched.poll(now, cfg.max_batch, cfg.window_us, Some(cost), true) {
                        Poll::Dispatch { batch, shed } => {
                            let progressed = !batch.is_empty() || !shed.is_empty();
                            for (item, why) in shed {
                                tnt.shed += 1;
                                if let Some(log) = trace.as_deref_mut() {
                                    let span = span_by_at[&item.submitted_us];
                                    log.record(span, why.decided_us, why.trace_event());
                                }
                                sheds.push(ShedTrace { model: mi, item, why });
                            }
                            for it in &batch {
                                tnt.failed += 1;
                                if let Some(log) = trace.as_deref_mut() {
                                    let span = span_by_at[&it.submitted_us];
                                    log.record(
                                        span,
                                        now,
                                        TraceKind::Failed {
                                            reason: "worker retired: restart budget exhausted"
                                                .into(),
                                        },
                                    );
                                }
                            }
                            if !progressed || tnt.sched.depth() == 0 {
                                break;
                            }
                        }
                        Poll::Idle | Poll::WaitUntil(_) => break,
                    }
                }
            }
        }
        // 3. Advance the clock to the next event: the next arrival, a
        // worker freeing up (only relevant while that tenant has pending
        // work), or a scheduler-requested re-poll time.
        let mut next = u64::MAX;
        if idx < arrivals.len() {
            next = next.min(arrivals[idx].at_us);
        }
        for &t in &wait_hints {
            if t > now {
                next = next.min(t);
            }
        }
        for tnt in &tenants {
            if tnt.sched.depth() > 0 {
                for &b in &tnt.workers {
                    if b > now && b != RETIRED {
                        next = next.min(b);
                    }
                }
            }
        }
        if next == u64::MAX {
            break;
        }
        now = next.max(now + 1);
    }

    // Fold the report.
    let wall = tenants
        .iter()
        .flat_map(|t| t.workers.iter().copied())
        .filter(|&b| b != RETIRED)
        .max()
        .unwrap_or(0)
        .max(now);
    let wall_secs = (wall as f64 / 1e6).max(1e-9);
    let pct = |sorted: &[f64], q: f64| {
        if sorted.is_empty() {
            0.0
        } else {
            percentile_sorted(sorted, q)
        }
    };
    let mut all_lat: Vec<f64> = tenants.iter().flat_map(|t| t.lat_us.iter().copied()).collect();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let per_model: Vec<ModelSoak> = cfg
        .models
        .iter()
        .zip(&mut tenants)
        .map(|(m, t)| {
            t.lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
            ModelSoak {
                name: m.name.clone(),
                submitted: t.submitted,
                completed: t.lat_us.len() as u64,
                rejected: t.rejected,
                shed: t.shed,
                failed: t.failed,
                deadline_missed: t.missed,
                p50_us: pct(&t.lat_us, 0.50),
                p99_us: pct(&t.lat_us, 0.99),
                p999_us: pct(&t.lat_us, 0.999),
                requests_per_sec: t.lat_us.len() as f64 / wall_secs,
            }
        })
        .collect();
    let completed = all_lat.len() as u64;
    let missed: u64 = per_model.iter().map(|m| m.deadline_missed).sum();
    SoakReport {
        seed: cfg.seed,
        requests: cfg.requests as u64,
        max_batch: cfg.max_batch.max(1),
        virtual_wall_us: wall,
        submitted: per_model.iter().map(|m| m.submitted).sum(),
        completed,
        rejected: per_model.iter().map(|m| m.rejected).sum(),
        shed: per_model.iter().map(|m| m.shed).sum(),
        failed: per_model.iter().map(|m| m.failed).sum(),
        worker_restarts,
        workers_retired,
        deadline_missed: missed,
        p50_us: pct(&all_lat, 0.50),
        p95_us: pct(&all_lat, 0.95),
        p99_us: pct(&all_lat, 0.99),
        p999_us: pct(&all_lat, 0.999),
        max_us: all_lat.last().copied().unwrap_or(0.0),
        deadline_miss_rate: missed as f64 / (completed.max(1)) as f64,
        per_model,
        drift: drift.as_ref().map(|dm| SoakDrift {
            sampled: dm.sampled(),
            alerts: dm.alerts(),
            report: dm.to_json(),
        }),
        batches,
        sheds,
    }
}

/// A representative two-tenant mixed-shape config — the CLI default and
/// the fixture the invariant suites perturb.
pub fn two_tenant_config(seed: u64, requests: usize) -> SoakConfig {
    SoakConfig {
        seed,
        requests,
        budget: 64,
        max_batch: 8,
        window_us: 1_000,
        mean_gap_us: 30,
        deadline_us: 20_000,
        tight_pct: 5,
        no_deadline_pct: 15,
        shapes: vec![
            (32, 32, 896),
            (24, 48, 1008),
            (48, 24, 1008),
            (16, 16, 224),
        ],
        models: vec![
            SoakModel {
                name: "model-a".into(),
                weight: 1,
                workers: 2,
                cost: TileCostModel::new(40.0, 0.02),
            },
            SoakModel {
                name: "model-b".into(),
                weight: 2,
                workers: 2,
                cost: TileCostModel::new(55.0, 0.03),
            },
        ],
        service_jitter_div: 16,
        drift_stride: 0,
        drift_err_scale: 1.0,
        chaos: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soak_accounting_is_exact_and_deterministic() {
        let cfg = two_tenant_config(0x50AB, 512);
        let a = run_soak(&cfg);
        let b = run_soak(&cfg);
        assert!(a.accounting_exact(), "{}", a.summary_line());
        assert_eq!(a.to_json(), b.to_json(), "same seed must replay byte-identically");
        assert_eq!(a.submitted, 512);
        // The hopeless slice forces sheds; the rest mostly completes.
        assert!(a.shed > 0, "tight_pct traffic must shed");
        assert!(a.completed > 400, "most traffic completes: {}", a.summary_line());
        assert!(a.p999_us >= a.p50_us && a.p50_us > 0.0);
    }

    #[test]
    fn different_seeds_differ_but_both_account() {
        let a = run_soak(&two_tenant_config(1, 256));
        let b = run_soak(&two_tenant_config(2, 256));
        assert!(a.accounting_exact() && b.accounting_exact());
        assert_ne!(a.to_json(), b.to_json(), "seeds must actually steer the trace");
    }

    #[test]
    fn batch_traces_respect_deadline_and_size_invariants() {
        let r = run_soak(&two_tenant_config(7, 1024));
        assert!(!r.batches.is_empty());
        for b in &r.batches {
            assert!(b.size >= 1 && b.size <= r.max_batch);
            if let Some(d) = b.earliest_deadline_us {
                assert!(
                    b.closed_us + b.predicted_us <= d,
                    "batch closed past its earliest member deadline: {b:?}"
                );
            }
        }
        for s in &r.sheds {
            assert!(
                s.why.decided_us + s.why.predicted_us > s.why.deadline_us,
                "shed without predicted-cost justification: {s:?}"
            );
        }
    }

    #[test]
    fn traced_soak_replays_byte_identically_and_does_not_perturb_the_run() {
        use crate::obs::TraceSink;
        let cfg = two_tenant_config(0x7ACE, 384);
        let (ra, ta) = run_soak_traced(&cfg);
        let (rb, tb) = run_soak_traced(&cfg);
        assert!(!ta.is_empty());
        assert_eq!(
            ta.to_json_lines(),
            tb.to_json_lines(),
            "same seed must replay the trace byte-identically"
        );
        assert_eq!(ra.to_json(), rb.to_json());
        // Tracing consumes no PRNG draws, so the report matches the
        // untraced run exactly.
        assert_eq!(ra.to_json(), run_soak(&cfg).to_json());
        let acc = ta.accounting();
        assert!(acc.exact, "every span must end in exactly one terminal: {acc:?}");
        assert_eq!(acc.submitted, ra.submitted);
        assert_eq!(acc.completed, ra.completed);
        assert_eq!(acc.rejected, ra.rejected);
        assert_eq!(acc.shed, ra.shed);
    }

    #[test]
    fn traced_spans_follow_the_lifecycle_grammar() {
        use crate::obs::TraceSink;
        let (r, t) = run_soak_traced(&two_tenant_config(11, 512));
        assert!(r.shed > 0, "fixture must exercise the shed path");
        let mut by_span: std::collections::BTreeMap<u64, Vec<&'static str>> =
            std::collections::BTreeMap::new();
        for ev in t.events() {
            let name = match ev.kind {
                TraceKind::Submit { .. } => "submit",
                TraceKind::Reject { .. } => "reject",
                TraceKind::Shed { .. } => "shed",
                TraceKind::Batch { .. } => "batch",
                TraceKind::PlanCache { .. } => "plan_cache",
                TraceKind::Stage { tiles, .. } => {
                    assert!(tiles > 0, "stage event without tiles");
                    "stage"
                }
                TraceKind::Complete { .. } => "complete",
                TraceKind::Failed { .. } => "failed",
                // Non-terminal advisories; the fixture has drift and
                // chaos off, so seeing any here is itself a bug.
                TraceKind::DriftAlert { .. } => "drift_alert",
                TraceKind::WorkerRestart { .. } => "worker_restart",
                TraceKind::FallbackEngaged { .. } => "fallback_engaged",
                TraceKind::FallbackCleared { .. } => "fallback_cleared",
            };
            by_span.entry(ev.span).or_default().push(name);
        }
        assert_eq!(by_span.len() as u64, r.submitted);
        for (span, kinds) in &by_span {
            let ok = matches!(
                kinds.as_slice(),
                ["submit", "reject"]
                    | ["submit", "plan_cache", "shed"]
                    | ["submit", "plan_cache", "batch", "stage", "complete"]
            );
            assert!(ok, "span {span} has out-of-grammar event sequence {kinds:?}");
        }
    }

    #[test]
    fn every_seed_accounts_every_span_exactly_once() {
        use crate::obs::TraceSink;
        crate::testkit::forall(
            0x50AB7,
            6,
            |rng: &mut Prng| rng.next_u64() % 10_000,
            |&seed| {
                let (r, t) = run_soak_traced(&two_tenant_config(seed, 160));
                let acc = t.accounting();
                acc.exact
                    && acc.submitted == r.requests
                    && acc.completed == r.completed
                    && acc.rejected == r.rejected
                    && acc.shed == r.shed
            },
        );
    }

    /// Drift-off runs must not change a single byte of the report —
    /// enabling the subsystem is opt-in per config.
    #[test]
    fn drift_off_report_has_no_drift_object() {
        let j = run_soak(&two_tenant_config(3, 128)).to_json();
        assert!(!j.contains("\"drift\""), "{j}");
    }

    /// Drift-sampled, traced soak reruns are byte-identical (report and
    /// trace), sampling consumes zero PRNG draws (the scheduling outcome
    /// matches the drift-off run exactly), and calibrated traffic raises
    /// zero alerts.
    #[test]
    fn drift_sampled_soak_replays_byte_identically_and_stays_calibrated() {
        use crate::obs::TraceSink;
        let mut cfg = two_tenant_config(0xD21F7, 384);
        cfg.drift_stride = 8;
        let (ra, ta) = run_soak_traced(&cfg);
        let (rb, tb) = run_soak_traced(&cfg);
        assert_eq!(ra.to_json(), rb.to_json(), "drift-sampled rerun must be byte-identical");
        assert_eq!(ta.to_json_lines(), tb.to_json_lines());
        let d = ra.drift.as_ref().expect("drift enabled");
        assert!(d.sampled > 0, "stride 8 over 384 spans must sample");
        assert_eq!(d.alerts, 0, "calibrated traffic must stay inside budget: {}", d.report);
        assert!(ra.to_json().contains("\"drift\": {"), "{}", ra.to_json());
        // Zero PRNG draws: scrubbing the drift object out of the report
        // leaves exactly the drift-off run's bytes.
        let mut off = cfg.clone();
        off.drift_stride = 0;
        let base = run_soak(&off);
        assert_eq!(
            (base.completed, base.shed, base.rejected, base.virtual_wall_us),
            (ra.completed, ra.shed, ra.rejected, ra.virtual_wall_us),
            "drift sampling must not perturb the simulation"
        );
    }

    /// Out-of-distribution traffic (synthetic error scaled far past the
    /// budget) must alert on every tenant, the alerts must appear in the
    /// trace as non-terminal `drift_alert` events, and accounting must
    /// stay exact with them interleaved.
    #[test]
    fn out_of_distribution_errors_raise_alerts_in_trace_and_report() {
        use crate::obs::TraceSink;
        let mut cfg = two_tenant_config(0x00D, 512);
        cfg.drift_stride = 4;
        cfg.drift_err_scale = 100.0;
        let (r, t) = run_soak_traced(&cfg);
        let d = r.drift.as_ref().expect("drift enabled");
        assert!(d.alerts > 0, "100x errors must breach the budget: {}", d.report);
        for m in &cfg.models {
            assert!(
                d.report.contains(&format!("\"layer\": \"{}\"", m.name)),
                "per-tenant drift entry missing in {}",
                d.report
            );
        }
        let n_alerts = t.to_json_lines().matches("\"event\": \"drift_alert\"").count() as u64;
        assert_eq!(n_alerts, d.alerts, "every alert must be traced exactly once");
        let acc = t.accounting();
        assert!(acc.exact, "alerts are non-terminal; accounting must stay exact: {acc:?}");
    }

    #[test]
    fn json_schema_is_stable() {
        let j = run_soak(&two_tenant_config(3, 128)).to_json();
        for key in [
            "\"bench\": \"serve_soak\"",
            "\"totals\": {\"submitted\": ",
            ", \"completed\": ",
            ", \"rejected\": ",
            ", \"shed\": ",
            ", \"failed\": ",
            ", \"worker_restarts\": ",
            ", \"workers_retired\": ",
            ", \"deadline_missed\": ",
            "\"deadline_miss_rate\": ",
            "\"p999\": ",
            "\"per_model\": [{\"name\": \"model-a\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    /// A chaos config for the fixture: panics on every 17th batch (seed
    /// 7 offsets the schedule), latency on every 5th, bursts every 50
    /// arrivals.
    fn chaotic_config(seed: u64, requests: usize) -> SoakConfig {
        let mut cfg = two_tenant_config(seed, requests);
        cfg.chaos = Some(ChaosConfig {
            seed: 7,
            panic_every: 17,
            latency_every: 5,
            latency_us: 2_000,
            burst_every: 50,
            burst_len: 8,
            // Deep enough that a long fixture run never retires a
            // worker — retirement has its own dedicated test.
            restart_budget: 100,
            ..ChaosConfig::default()
        });
        cfg
    }

    #[test]
    fn chaos_soak_accounts_exactly_and_replays_byte_identically() {
        let cfg = chaotic_config(0xC405, 768);
        let a = run_soak(&cfg);
        let b = run_soak(&cfg);
        assert!(a.accounting_exact(), "{}", a.summary_line());
        assert_eq!(a.to_json(), b.to_json(), "chaos must replay byte-identically per seed");
        assert!(a.failed > 0, "injected panics must fail batches: {}", a.summary_line());
        assert!(
            a.worker_restarts >= 3,
            "the run must survive at least 3 panics via restarts: {}",
            a.summary_line()
        );
        assert_eq!(a.workers_retired, 0, "sparse panics never exhaust the deep fixture budget");
        assert!(a.completed > 0, "the fleet keeps serving between faults");
        // A different chaos seed shifts which batches fail.
        let mut other = cfg.clone();
        other.chaos.as_mut().unwrap().seed = 8;
        assert_ne!(run_soak(&other).to_json(), a.to_json());
    }

    #[test]
    fn chaos_off_is_byte_identical_to_a_disabled_plan() {
        // `Some(ChaosConfig::default())` schedules nothing — the report
        // must be the pre-chaos bytes, same as `None`.
        let mut cfg = two_tenant_config(0xC0FF, 256);
        let off = run_soak(&cfg).to_json();
        cfg.chaos = Some(ChaosConfig::default());
        assert_eq!(run_soak(&cfg).to_json(), off);
    }

    #[test]
    fn chaos_failed_spans_follow_the_lifecycle_grammar() {
        use crate::obs::TraceSink;
        let cfg = chaotic_config(0xFA11, 768);
        let (r, t) = run_soak_traced(&cfg);
        assert!(r.failed > 0, "{}", r.summary_line());
        let acc = t.accounting();
        assert!(acc.exact, "failed is terminal; accounting must stay exact: {acc:?}");
        assert_eq!(acc.failed, r.failed);
        assert_eq!(acc.submitted, r.submitted);
        // Failed spans carry submit → plan_cache → batch → failed, and
        // worker restarts ride span 0 without touching accounting.
        let mut failed_spans = 0u64;
        let mut by_span: std::collections::BTreeMap<u64, Vec<&'static str>> =
            std::collections::BTreeMap::new();
        for ev in t.events() {
            let name = match ev.kind {
                TraceKind::Submit { .. } => "submit",
                TraceKind::Reject { .. } => "reject",
                TraceKind::Shed { .. } => "shed",
                TraceKind::Batch { .. } => "batch",
                TraceKind::PlanCache { .. } => "plan_cache",
                TraceKind::Stage { .. } => "stage",
                TraceKind::Complete { .. } => "complete",
                TraceKind::Failed { .. } => "failed",
                TraceKind::WorkerRestart { .. } => {
                    assert_eq!(ev.span, 0, "restarts are process-level, span-0 events");
                    continue;
                }
                other => panic!("unexpected event in a drift-off chaos run: {other:?}"),
            };
            by_span.entry(ev.span).or_default().push(name);
        }
        for (span, kinds) in &by_span {
            if *span == 0 {
                continue;
            }
            if kinds.contains(&"failed") {
                failed_spans += 1;
                assert_eq!(
                    kinds.as_slice(),
                    ["submit", "plan_cache", "batch", "failed"],
                    "span {span} has out-of-grammar failed sequence {kinds:?}"
                );
            }
        }
        assert_eq!(failed_spans, r.failed);
        let restarts = t
            .events()
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::WorkerRestart { .. }))
            .count() as u64;
        assert_eq!(restarts, r.worker_restarts, "every restart must be traced exactly once");
    }

    #[test]
    fn relentless_panics_exhaust_budgets_retire_workers_and_still_account() {
        // Every batch panics: all four virtual workers burn their
        // budgets and retire; the drain path must fail or shed every
        // remaining admitted request — nothing strands, nothing hangs.
        let mut cfg = two_tenant_config(0xDEAD, 512);
        cfg.chaos = Some(ChaosConfig {
            panic_every: 1,
            restart_budget: 3,
            ..ChaosConfig::default()
        });
        let r = run_soak(&cfg);
        assert!(r.accounting_exact(), "{}", r.summary_line());
        assert_eq!(r.completed, 0, "no batch ever survives: {}", r.summary_line());
        assert_eq!(r.workers_retired, 4, "both tenants' workers must retire");
        assert_eq!(
            r.worker_restarts, 4 * 3,
            "each worker restarts exactly its budget before retiring"
        );
        assert!(r.failed > 0);
        // Deterministic, like everything else.
        assert_eq!(r.to_json(), run_soak(&cfg).to_json());
    }

    #[test]
    fn corrupt_faults_force_drift_alerts_on_calibrated_traffic() {
        // Calibrated traffic (err scale 1.0) never alerts on its own —
        // the corrupt fault's activation scaling must push sampled
        // batches over budget.
        let mut cfg = two_tenant_config(0xC0DE, 512);
        cfg.drift_stride = 2;
        cfg.chaos = Some(ChaosConfig {
            corrupt_every: 3,
            corrupt_scale: 100.0,
            ..ChaosConfig::default()
        });
        let r = run_soak(&cfg);
        assert!(r.accounting_exact());
        let d = r.drift.as_ref().expect("drift enabled");
        assert!(d.alerts > 0, "corrupted batches must breach the budget: {}", d.report);
        // Without the corrupt faults the same traffic stays quiet.
        let mut clean = cfg.clone();
        clean.chaos = None;
        let rc = run_soak(&clean);
        assert_eq!(rc.drift.as_ref().unwrap().alerts, 0, "calibrated baseline must not alert");
    }

    #[test]
    fn bursts_saturate_admission_where_spaced_arrivals_do_not() {
        // Same seed, tiny budget: burst-compressed arrivals must reject
        // strictly more than the spaced baseline.
        let mut cfg = two_tenant_config(0xB425, 512);
        cfg.budget = 8;
        let base = run_soak(&cfg);
        cfg.chaos = Some(ChaosConfig {
            burst_every: 20,
            burst_len: 12,
            ..ChaosConfig::default()
        });
        let burst = run_soak(&cfg);
        assert!(base.accounting_exact() && burst.accounting_exact());
        assert!(
            burst.rejected > base.rejected,
            "bursts must slam admission: {} vs baseline {}",
            burst.rejected,
            base.rejected
        );
    }
}
