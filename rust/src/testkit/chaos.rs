//! Deterministic fault injection for the serving stack.
//!
//! A [`ChaosConfig`] is a *seeded schedule*, not a random process:
//! every fault site is a pure function of `(seed, index)`, so a chaos
//! run is byte-identical per seed — the property the chaos suite pins
//! and the only way "the queue survives three worker panics" is a
//! reproducible test rather than an anecdote. The same plan drives both
//! execution paths:
//!
//! * the threaded server (`winoq serve --chaos-*`): a shared
//!   [`FaultPlan`] hands each drained micro-batch its fault via the
//!   atomic batch counter ([`FaultPlan::next_fault`]);
//! * the virtual-clock soak harness
//!   ([`testkit::soak`](crate::testkit::soak)): the pure
//!   [`ChaosConfig::fault_for`] / [`ChaosConfig::burst_at`] rules are
//!   evaluated against the harness's own deterministic batch/arrival
//!   indices, so no atomic ordering can leak into the report.
//!
//! Fault kinds map one-to-one onto the recovery paths this PR builds:
//! worker panics exercise supervision (fail the batch, restart with
//! backoff, bounded budget), injected latency exercises deadline
//! shedding under slowdown, activation corruption drives the drift
//! monitor over budget (engaging the per-layer engine fallback),
//! arrival bursts exercise admission backpressure, and
//! [`flip_bits`] rots checkpoint bytes for the registry's load-time
//! validation.

use crate::nn::tensor::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// What happens to one micro-batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// The worker executing the batch panics (poisoning the batch; the
    /// supervisor fails its members and restarts the worker).
    Panic,
    /// The batch's activations are corrupted in place (multiplied by
    /// [`ChaosConfig::corrupt_scale`]) *before* inference — served
    /// outputs drift out of the calibrated range, so the shadow-oracle
    /// drift probe sees a genuine budget violation, not a simulated one.
    Corrupt {
        /// Multiplier applied to every activation of the batch.
        scale: f64,
    },
    /// The worker sleeps (or the virtual clock advances) before running
    /// the batch.
    Latency {
        /// Injected delay, microseconds.
        us: u64,
    },
}

/// Seeded fault schedule. All `*_every` knobs are modular rules on the
/// batch (or arrival) index offset by the seed; `0` disables that fault
/// kind. When several rules hit the same index, severity wins:
/// panic > corrupt > latency.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Offsets every modular rule, so two runs with different seeds hit
    /// different batch indices.
    pub seed: u64,
    /// Panic the worker on every `panic_every`-th batch.
    pub panic_every: u64,
    /// Corrupt activations on every `corrupt_every`-th batch.
    pub corrupt_every: u64,
    /// Activation multiplier for corrupt faults (OOD magnitude).
    pub corrupt_scale: f64,
    /// Inject latency on every `latency_every`-th batch.
    pub latency_every: u64,
    /// Injected delay per latency fault, microseconds.
    pub latency_us: u64,
    /// Compress arrival gaps on every `burst_every`-th arrival window.
    pub burst_every: u64,
    /// How many consecutive arrivals each burst compresses.
    pub burst_len: u64,
    /// Supervisor restart budget under this plan (soak path; the
    /// threaded server takes it from `RestartPolicy`).
    pub restart_budget: u32,
    /// Base backoff per restart, microseconds (doubled per consecutive
    /// restart, capped at 100× base).
    pub backoff_us: u64,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0,
            panic_every: 0,
            corrupt_every: 0,
            corrupt_scale: 100.0,
            latency_every: 0,
            latency_us: 1000,
            burst_every: 0,
            burst_len: 8,
            restart_budget: 5,
            backoff_us: 200,
        }
    }
}

impl ChaosConfig {
    /// True when any fault kind is scheduled.
    pub fn is_enabled(&self) -> bool {
        self.panic_every > 0
            || self.corrupt_every > 0
            || self.latency_every > 0
            || self.burst_every > 0
    }

    /// The pure schedule: fault for the `idx`-th batch, most severe
    /// rule first. This is the single source of truth both execution
    /// paths evaluate.
    pub fn fault_for(&self, idx: u64) -> Option<Fault> {
        let hits = |every: u64| every > 0 && (idx + self.seed) % every == 0;
        if hits(self.panic_every) {
            Some(Fault::Panic)
        } else if hits(self.corrupt_every) {
            Some(Fault::Corrupt { scale: self.corrupt_scale })
        } else if hits(self.latency_every) {
            Some(Fault::Latency { us: self.latency_us })
        } else {
            None
        }
    }

    /// Saturation-burst rule on *arrival* indices: true when the
    /// `idx`-th arrival falls inside a burst window (the soak generator
    /// compresses its inter-arrival gap to 1 µs, slamming the queue).
    pub fn burst_at(&self, idx: u64) -> bool {
        self.burst_every > 0 && (idx + self.seed) % self.burst_every < self.burst_len
    }

    /// Exponential backoff for the `restarts`-th consecutive restart
    /// (1-based), capped at 100× the base.
    pub fn backoff_for(&self, restarts: u32) -> u64 {
        let base = self.backoff_us.max(1);
        (base << (restarts.saturating_sub(1)).min(20)).min(base * 100)
    }
}

/// A [`ChaosConfig`] bound to a live batch counter — the threaded
/// server's view of the schedule. Workers race on `next_fault`, but the
/// *set* of faults dealt over a run is exactly the schedule's prefix;
/// only which worker draws which index varies.
pub struct FaultPlan {
    cfg: ChaosConfig,
    batches: AtomicU64,
}

impl FaultPlan {
    pub fn new(cfg: ChaosConfig) -> FaultPlan {
        FaultPlan { cfg, batches: AtomicU64::new(0) }
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// Claim the next batch index and return its scheduled fault.
    pub fn next_fault(&self) -> Option<Fault> {
        let idx = self.batches.fetch_add(1, Ordering::Relaxed);
        self.cfg.fault_for(idx)
    }

    /// Batches dealt so far.
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }
}

/// Apply a corrupt fault: scale every activation in place. Kept here so
/// the threaded worker and the soak harness share one definition of
/// "corrupted".
pub fn corrupt_tensor(t: &mut Tensor, scale: f64) {
    for v in &mut t.data {
        *v = (*v as f64 * scale) as f32;
    }
}

/// splitmix64 — the house deterministic mixer (same construction the
/// soak harness uses for synthetic errors).
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Checkpoint bit-rot: flip `flips` pseudo-randomly chosen bits of
/// `bytes`, deterministically per seed. Biased toward high bits of each
/// chosen byte so float payloads land in sign/exponent territory
/// (huge-magnitude or non-finite values rather than benign mantissa
/// noise).
pub fn flip_bits(bytes: &mut [u8], seed: u64, flips: usize) {
    if bytes.is_empty() {
        return;
    }
    for i in 0..flips {
        let r = mix(seed.wrapping_add(i as u64));
        let pos = (r % bytes.len() as u64) as usize;
        let bit = 4 + ((r >> 32) % 4) as u32; // bits 4..=7 of the byte
        bytes[pos] ^= 1u8 << bit;
    }
}

/// Targeted checkpoint rot for f32-LE blobs: overwrite `n`
/// pseudo-randomly chosen (4-byte-aligned) float slots with a NaN bit
/// pattern, deterministically per seed. Unlike [`flip_bits`], this
/// *guarantees* non-finite weights — the case the registry's load-time
/// validation must refuse.
pub fn poison_floats(bytes: &mut [u8], seed: u64, n: usize) {
    let slots = bytes.len() / 4;
    if slots == 0 {
        return;
    }
    for i in 0..n {
        let r = mix(seed.wrapping_add(0x5EED).wrapping_add(i as u64));
        let pos = (r % slots as u64) as usize * 4;
        bytes[pos..pos + 4].copy_from_slice(&f32::NAN.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_per_seed_and_offset_by_it() {
        let cfg = ChaosConfig { seed: 7, panic_every: 17, ..Default::default() };
        let a: Vec<_> = (0..64).map(|i| cfg.fault_for(i)).collect();
        let b: Vec<_> = (0..64).map(|i| cfg.fault_for(i)).collect();
        assert_eq!(a, b, "pure schedule must not vary between evaluations");
        // (idx + 7) % 17 == 0 → idx ∈ {10, 27, 44, 61}.
        let panics: Vec<u64> =
            (0..64).filter(|&i| cfg.fault_for(i) == Some(Fault::Panic)).collect();
        assert_eq!(panics, vec![10, 27, 44, 61]);
        let other = ChaosConfig { seed: 8, ..cfg };
        assert_ne!(
            (0..64).map(|i| other.fault_for(i)).collect::<Vec<_>>(),
            a,
            "a different seed must shift the schedule"
        );
    }

    #[test]
    fn severity_orders_overlapping_rules() {
        // Every 2nd batch panics, every 3rd corrupts, every 5th lags;
        // index 0 (+seed 0) hits all three → panic wins.
        let cfg = ChaosConfig {
            panic_every: 2,
            corrupt_every: 3,
            latency_every: 5,
            ..Default::default()
        };
        assert_eq!(cfg.fault_for(0), Some(Fault::Panic));
        assert_eq!(cfg.fault_for(3), Some(Fault::Corrupt { scale: 100.0 }));
        assert_eq!(cfg.fault_for(5), Some(Fault::Latency { us: 1000 }));
        assert_eq!(cfg.fault_for(7), None);
        assert!(cfg.is_enabled());
        assert!(!ChaosConfig::default().is_enabled());
    }

    #[test]
    fn fault_plan_deals_the_schedule_prefix() {
        let cfg = ChaosConfig { panic_every: 3, ..Default::default() };
        let plan = FaultPlan::new(cfg);
        let dealt: Vec<_> = (0..9).map(|_| plan.next_fault()).collect();
        let pure: Vec<_> = (0..9).map(|i| cfg.fault_for(i)).collect();
        assert_eq!(dealt, pure);
        assert_eq!(plan.batches(), 9);
    }

    #[test]
    fn bursts_cover_contiguous_arrival_windows() {
        let cfg = ChaosConfig { burst_every: 10, burst_len: 3, ..Default::default() };
        let in_burst: Vec<u64> = (0..20).filter(|&i| cfg.burst_at(i)).collect();
        assert_eq!(in_burst, vec![0, 1, 2, 10, 11, 12]);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let cfg = ChaosConfig { backoff_us: 200, ..Default::default() };
        assert_eq!(cfg.backoff_for(1), 200);
        assert_eq!(cfg.backoff_for(2), 400);
        assert_eq!(cfg.backoff_for(3), 800);
        assert_eq!(cfg.backoff_for(30), 20_000, "capped at 100× base");
    }

    #[test]
    fn corrupt_scales_in_place() {
        let mut t = Tensor::from_vec(&[1, 2, 2], vec![1.0, -0.5, 0.25, 0.0]);
        corrupt_tensor(&mut t, 100.0);
        assert_eq!(t.data, vec![100.0, -50.0, 25.0, 0.0]);
    }

    #[test]
    fn bit_rot_is_deterministic_and_hits_exponent_bits() {
        let mut a = vec![0u8; 64];
        let mut b = vec![0u8; 64];
        flip_bits(&mut a, 42, 8);
        flip_bits(&mut b, 42, 8);
        assert_eq!(a, b, "same seed, same rot");
        assert!(a.iter().any(|&v| v != 0), "flips must land");
        for &v in &a {
            assert_eq!(v & 0x0f, 0, "flips stay in the high nibble");
        }
        let mut c = vec![0u8; 64];
        flip_bits(&mut c, 43, 8);
        assert_ne!(a, c, "different seed, different rot");
    }

    #[test]
    fn poisoned_floats_are_nan_and_deterministic() {
        let clean: Vec<u8> = (0..16).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let mut a = clean.clone();
        let mut b = clean.clone();
        poison_floats(&mut a, 9, 3);
        poison_floats(&mut b, 9, 3);
        assert_eq!(a, b, "same seed, same poison");
        let nans = a
            .chunks_exact(4)
            .filter(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]).is_nan())
            .count();
        assert!(nans >= 1 && nans <= 3, "poison lands on whole float slots: {nans}");
        poison_floats(&mut [], 9, 3); // empty blob is a no-op, not a panic
    }
}
