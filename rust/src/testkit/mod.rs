//! Mini property-testing harness (proptest is not in the vendored crate
//! set): deterministic generators over a seeded PRNG plus a `forall` runner
//! that reports the failing seed/case for reproduction.

use crate::wino::error::Prng;

pub mod chaos;
pub mod soak;

/// A generator of values of `T` from the PRNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Prng) -> T;
}

impl<T, F: Fn(&mut Prng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Prng) -> T {
        self(rng)
    }
}

/// Run `prop` on `cases` generated values; panic with the case index and
/// seed on the first failure so it can be replayed.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        assert!(
            prop(&value),
            "property failed at case {case} (seed {seed}): {value:?}"
        );
    }
}

/// Uniform f64 in [lo, hi].
pub fn uniform(lo: f64, hi: f64) -> impl Fn(&mut Prng) -> f64 {
    move |rng| lo + (rng.uniform(1.0) * 0.5 + 0.5) * (hi - lo)
}

/// Uniform usize in [lo, hi].
pub fn uniform_usize(lo: usize, hi: usize) -> impl Fn(&mut Prng) -> usize {
    move |rng| lo + (rng.next_u64() as usize) % (hi - lo + 1)
}

/// Vec of f64 with the given length.
pub fn vec_f64(len: usize, scale: f64) -> impl Fn(&mut Prng) -> Vec<f64> {
    move |rng| (0..len).map(|_| rng.uniform(scale)).collect()
}

/// Seeded uniform f32 tensor in `[-scale, scale]` — the shared fixture
/// generator for the engine/serve parity suites (one definition keeps
/// seed/scale semantics identical across them).
pub fn prng_tensor(seed: u64, dims: &[usize], scale: f64) -> crate::nn::tensor::Tensor {
    let mut rng = Prng::new(seed);
    let len = dims.iter().product();
    crate::nn::tensor::Tensor::from_vec(
        dims,
        (0..len).map(|_| rng.uniform(scale) as f32).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall(1, 100, uniform(0.0, 1.0), |&x| (0.0..=1.0).contains(&x));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 100, uniform(0.0, 1.0), |&x| x < 0.5);
    }

    #[test]
    fn uniform_usize_in_range() {
        forall(3, 200, uniform_usize(2, 6), |&n| (2..=6).contains(&n));
    }

    #[test]
    fn vec_gen_length() {
        forall(4, 20, vec_f64(7, 2.0), |v| {
            v.len() == 7 && v.iter().all(|x| x.abs() <= 2.0)
        });
    }
}
