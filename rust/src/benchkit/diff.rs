//! Bench regression gating: diff two directories of `BENCH_*.json`
//! artifacts (`bench/baselines/` vs a fresh run) with per-metric-class
//! thresholds — `winoq benchdiff --baseline <dir> --current <dir>`,
//! wired into `scripts/ci.sh` as a hard gate.
//!
//! Every numeric leaf of every report is flattened to a dotted key
//! (array elements keyed by their `"name"` member when present, index
//! otherwise) and classified by name:
//!
//! * **throughput** (`*per_sec*`, `*gflops*`, `*speedup*`) — higher is
//!   better; FAIL when the current run loses more than
//!   [`THROUGHPUT_TOLERANCE`] (10%) against the baseline;
//! * **error** (`*err*`, `*rel_l2*`, `*loss*` leaves) — lower is
//!   better; FAIL on *any* increase beyond float-noise
//!   ([`ERROR_TOLERANCE`] relative). Accuracy regressions don't get a
//!   10% grace band;
//! * everything else — informational, reported but never gating.
//!
//! A bench file or gated metric present in the baseline but absent from
//! the current run is itself a failure: silently dropping a benchmark
//! must not pass the gate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::json::{JsonArr, JsonObj};
use crate::tune::json::{parse, Json};

/// Allowed relative throughput loss before the gate fails (10%).
pub const THROUGHPUT_TOLERANCE: f64 = 0.10;
/// Relative slack for error metrics — covers float formatting noise
/// only; any genuine increase fails.
pub const ERROR_TOLERANCE: f64 = 1e-9;

/// How a metric gates, decided from its flattened key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricClass {
    /// Higher is better; 10% loss tolerance.
    Throughput,
    /// Lower is better; any increase fails.
    Error,
    /// Reported, never gating.
    Info,
}

/// Classify one flattened metric key.
pub fn classify(key: &str) -> MetricClass {
    let leaf = key.rsplit('.').next().unwrap_or(key);
    if key.contains("per_sec") || key.contains("gflops") || key.contains("speedup") {
        MetricClass::Throughput
    } else if leaf.contains("err") || leaf.contains("rel_l2") || leaf.contains("loss") {
        MetricClass::Error
    } else {
        MetricClass::Info
    }
}

/// Gate outcome for one metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Gated metric within its threshold.
    Pass,
    /// Gated metric regressed (or vanished from the current run).
    Fail,
    /// Ungated metric, reported for context.
    Info,
}

/// One metric's baseline-vs-current comparison.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    /// Flattened dotted key, e.g. `"latency_us.p99"`.
    pub key: String,
    pub class: MetricClass,
    pub baseline: f64,
    /// `None` when the key vanished from the current run.
    pub current: Option<f64>,
    pub verdict: Verdict,
}

impl MetricDiff {
    /// Signed relative change, percent (`0.0` when the baseline is 0 or
    /// the metric vanished).
    pub fn delta_pct(&self) -> f64 {
        match self.current {
            Some(c) if self.baseline != 0.0 => (c - self.baseline) / self.baseline * 100.0,
            _ => 0.0,
        }
    }
}

/// All metric diffs of one bench file.
#[derive(Clone, Debug)]
pub struct FileDiff {
    /// Bench file name, e.g. `"BENCH_gemm.json"`.
    pub file: String,
    /// The current run never produced this file (always a failure).
    pub missing: bool,
    pub metrics: Vec<MetricDiff>,
}

impl FileDiff {
    /// Gated failures in this file (the missing file counts as one).
    pub fn failures(&self) -> u64 {
        self.missing as u64
            + self.metrics.iter().filter(|m| m.verdict == Verdict::Fail).count() as u64
    }
}

/// Full benchdiff result over a baseline/current directory pair.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    pub files: Vec<FileDiff>,
}

impl DiffReport {
    /// Total gating failures; the CLI exits nonzero iff this is > 0.
    pub fn failures(&self) -> u64 {
        self.files.iter().map(|f| f.failures()).sum()
    }

    /// Metrics compared across all files (gated and informational).
    pub fn compared(&self) -> u64 {
        self.files.iter().map(|f| f.metrics.len() as u64).sum()
    }

    pub fn ok(&self) -> bool {
        self.failures() == 0
    }

    /// Human table: one line per gated metric plus a per-file roll-up
    /// (informational metrics are summarized, not listed).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for f in &self.files {
            if f.missing {
                out.push_str(&format!("{}: FAIL (missing from current run)\n", f.file));
                continue;
            }
            let info = f.metrics.iter().filter(|m| m.verdict == Verdict::Info).count();
            out.push_str(&format!(
                "{}: {} gated metrics, {} informational, {} failed\n",
                f.file,
                f.metrics.len() - info,
                info,
                f.failures()
            ));
            for m in &f.metrics {
                if m.verdict == Verdict::Info {
                    continue;
                }
                let class = match m.class {
                    MetricClass::Throughput => "throughput",
                    MetricClass::Error => "error",
                    MetricClass::Info => "info",
                };
                match m.current {
                    Some(c) => out.push_str(&format!(
                        "  [{}] {} {}: {} -> {} ({:+.2}%)\n",
                        if m.verdict == Verdict::Fail { "FAIL" } else { " ok " },
                        class,
                        m.key,
                        m.baseline,
                        c,
                        m.delta_pct()
                    )),
                    None => out.push_str(&format!(
                        "  [FAIL] {} {}: {} -> missing\n",
                        class, m.key, m.baseline
                    )),
                }
            }
        }
        out.push_str(&format!(
            "benchdiff: {} metrics over {} files, {} failures\n",
            self.compared(),
            self.files.len(),
            self.failures()
        ));
        out
    }

    /// Machine-readable report (house `obs::json` style): per-file
    /// gated-metric verdicts plus the roll-up counts.
    pub fn to_json(&self) -> String {
        let mut files = JsonArr::new();
        for f in &self.files {
            let mut metrics = JsonArr::new();
            for m in &f.metrics {
                if m.verdict == Verdict::Info {
                    continue;
                }
                let mut obj = JsonObj::new()
                    .str("key", &m.key)
                    .str(
                        "class",
                        match m.class {
                            MetricClass::Throughput => "throughput",
                            MetricClass::Error => "error",
                            MetricClass::Info => "info",
                        },
                    )
                    .raw("baseline", &format_num(m.baseline));
                obj = match m.current {
                    Some(c) => obj.raw("current", &format_num(c)),
                    None => obj.raw("current", "null"),
                };
                metrics = metrics.item(
                    &obj.f64("delta_pct", m.delta_pct(), 3)
                        .bool("fail", m.verdict == Verdict::Fail)
                        .finish(),
                );
            }
            files = files.item(
                &JsonObj::new()
                    .str("file", &f.file)
                    .bool("missing", f.missing)
                    .u64("failures", f.failures())
                    .raw("gated", &metrics.finish())
                    .finish(),
            );
        }
        let mut out = JsonObj::new()
            .str("bench", "benchdiff")
            .u64("files", self.files.len() as u64)
            .u64("compared", self.compared())
            .u64("failures", self.failures())
            .bool("ok", self.ok())
            .raw("per_file", &files.finish())
            .finish();
        out.push('\n');
        out
    }
}

/// Shortest-exact f64 rendering (`Display`) — benchdiff echoes the
/// source documents' numbers rather than re-rounding them.
fn format_num(v: f64) -> String {
    if v.is_finite() {
        v.to_string()
    } else {
        "null".into()
    }
}

/// Flatten every numeric leaf of `j` into `out` under dotted keys.
/// Array elements use their `"name"` string member as the key segment
/// when they have one (the `per_model` convention), their index
/// otherwise. Non-numeric leaves (strings, bools, nulls) are skipped —
/// config echoes like `"bench": "gemm"` never gate.
pub fn flatten(prefix: &str, j: &Json, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Num(v) => {
            out.insert(prefix.to_string(), *v);
        }
        Json::Obj(members) => {
            for (k, v) in members {
                let key =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                flatten(&key, v, out);
            }
        }
        Json::Arr(items) => {
            for (i, v) in items.iter().enumerate() {
                let seg = v
                    .get("name")
                    .and_then(|n| n.as_str())
                    .map(str::to_string)
                    .unwrap_or_else(|| i.to_string());
                let key =
                    if prefix.is_empty() { seg.clone() } else { format!("{prefix}.{seg}") };
                flatten(&key, v, out);
            }
        }
        _ => {}
    }
}

/// Diff two flattened metric maps under the class thresholds. Keys only
/// in `current` are ignored (new benches don't gate); gated keys only
/// in `baseline` fail.
pub fn diff_metrics(
    baseline: &BTreeMap<String, f64>,
    current: &BTreeMap<String, f64>,
) -> Vec<MetricDiff> {
    baseline
        .iter()
        .map(|(key, &b)| {
            let class = classify(key);
            let cur = current.get(key).copied();
            let verdict = match (class, cur) {
                (MetricClass::Info, _) => Verdict::Info,
                (_, None) => Verdict::Fail,
                (MetricClass::Throughput, Some(c)) => {
                    if c < b * (1.0 - THROUGHPUT_TOLERANCE) {
                        Verdict::Fail
                    } else {
                        Verdict::Pass
                    }
                }
                (MetricClass::Error, Some(c)) => {
                    if c > b * (1.0 + ERROR_TOLERANCE) + f64::EPSILON {
                        Verdict::Fail
                    } else {
                        Verdict::Pass
                    }
                }
            };
            MetricDiff { key: key.clone(), class, baseline: b, current: cur, verdict }
        })
        .collect()
}

/// Parse one bench JSON document into its flattened metric map.
pub fn flatten_document(doc: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    flatten("", &parse(doc)?, &mut out);
    Ok(out)
}

/// Compare every `BENCH_*.json` in `baseline` against its namesake in
/// `current`. The baseline directory defines the contract: files it
/// lacks are ignored, files it has must exist (and hold their gated
/// metrics) in the current run.
pub fn diff_dirs(baseline: &Path, current: &Path) -> Result<DiffReport> {
    let mut names: Vec<String> = std::fs::read_dir(baseline)
        .with_context(|| format!("reading baseline dir {}", baseline.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();
    if names.is_empty() {
        bail!("no BENCH_*.json artifacts in baseline dir {}", baseline.display());
    }
    let mut files = Vec::new();
    for name in names {
        let base_doc = std::fs::read_to_string(baseline.join(&name))
            .with_context(|| format!("reading baseline {name}"))?;
        let base = flatten_document(&base_doc)
            .with_context(|| format!("parsing baseline {name}"))?;
        let cur_path = current.join(&name);
        if !cur_path.exists() {
            files.push(FileDiff { file: name, missing: true, metrics: Vec::new() });
            continue;
        }
        let cur_doc = std::fs::read_to_string(&cur_path)
            .with_context(|| format!("reading current {name}"))?;
        let cur =
            flatten_document(&cur_doc).with_context(|| format!("parsing current {name}"))?;
        files.push(FileDiff { file: name, missing: false, metrics: diff_metrics(&base, &cur) });
    }
    Ok(DiffReport { files })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn classes_follow_key_names() {
        assert_eq!(classify("tiles_per_sec"), MetricClass::Throughput);
        assert_eq!(classify("int.gflops"), MetricClass::Throughput);
        assert_eq!(classify("legendre.speedup"), MetricClass::Throughput);
        assert_eq!(classify("layers.stem.rel_err"), MetricClass::Error);
        assert_eq!(classify("tuned_err"), MetricClass::Error);
        assert_eq!(classify("rel_l2"), MetricClass::Error);
        assert_eq!(classify("latency_ms.p99"), MetricClass::Info);
        assert_eq!(classify("completed"), MetricClass::Info);
        // Only the leaf decides error-ness: a *container* named "errors"
        // holding a count stays informational.
        assert_eq!(classify("drift.alerts"), MetricClass::Info);
    }

    #[test]
    fn throughput_gates_at_ten_percent() {
        let base = m(&[("tiles_per_sec", 1000.0)]);
        let ok = diff_metrics(&base, &m(&[("tiles_per_sec", 901.0)]));
        assert_eq!(ok[0].verdict, Verdict::Pass);
        let fail = diff_metrics(&base, &m(&[("tiles_per_sec", 899.0)]));
        assert_eq!(fail[0].verdict, Verdict::Fail);
        assert!((fail[0].delta_pct() - -10.1).abs() < 1e-9);
        // Gains never fail.
        let gain = diff_metrics(&base, &m(&[("tiles_per_sec", 2000.0)]));
        assert_eq!(gain[0].verdict, Verdict::Pass);
    }

    #[test]
    fn any_error_increase_fails_but_noise_passes() {
        let base = m(&[("stem.rel_err", 0.002)]);
        let same = diff_metrics(&base, &m(&[("stem.rel_err", 0.002)]));
        assert_eq!(same[0].verdict, Verdict::Pass);
        let better = diff_metrics(&base, &m(&[("stem.rel_err", 0.001)]));
        assert_eq!(better[0].verdict, Verdict::Pass);
        let worse = diff_metrics(&base, &m(&[("stem.rel_err", 0.0021)]));
        assert_eq!(worse[0].verdict, Verdict::Fail, "a 5% error increase must gate");
        // Sub-noise wiggle (1 part in 10^12) is formatting, not drift.
        let noise = diff_metrics(&base, &m(&[("stem.rel_err", 0.002 * (1.0 + 1e-12))]));
        assert_eq!(noise[0].verdict, Verdict::Pass);
    }

    #[test]
    fn vanished_gated_metric_fails_vanished_info_does_not_gate() {
        let base = m(&[("tiles_per_sec", 100.0), ("completed", 10.0)]);
        let d = diff_metrics(&base, &m(&[]));
        let tps = d.iter().find(|x| x.key == "tiles_per_sec").unwrap();
        assert_eq!(tps.verdict, Verdict::Fail);
        let info = d.iter().find(|x| x.key == "completed").unwrap();
        assert_eq!(info.verdict, Verdict::Info);
    }

    #[test]
    fn flatten_handles_nesting_named_arrays_and_skips_non_numbers() {
        let doc = r#"{"bench": "serve_soak", "totals": {"completed": 5},
            "per_model": [{"name": "model-a", "shed": 1}, {"shed": 2}],
            "latency_us": {"p99": 1500.5}, "ok": true}"#;
        let flat = flatten_document(doc).unwrap();
        assert_eq!(flat.get("totals.completed"), Some(&5.0));
        assert_eq!(flat.get("per_model.model-a.shed"), Some(&1.0));
        assert_eq!(flat.get("per_model.1.shed"), Some(&2.0));
        assert_eq!(flat.get("latency_us.p99"), Some(&1500.5));
        assert!(!flat.contains_key("bench"), "strings are not metrics");
        assert!(!flat.contains_key("ok"), "bools are not metrics");
    }

    #[test]
    fn dir_diff_gates_and_reports() {
        let root = std::env::temp_dir().join(format!("winoq_benchdiff_{}", std::process::id()));
        let base_dir = root.join("baseline");
        let cur_dir = root.join("current");
        std::fs::create_dir_all(&base_dir).unwrap();
        std::fs::create_dir_all(&cur_dir).unwrap();
        let base = r#"{"bench": "gemm", "gflops": 10.0, "rel_err": 0.001}"#;
        std::fs::write(base_dir.join("BENCH_gemm.json"), base).unwrap();
        std::fs::write(base_dir.join("BENCH_tune.json"), r#"{"tiles_per_sec": 50}"#).unwrap();
        std::fs::write(base_dir.join("notes.txt"), "ignored").unwrap();
        // Current: gemm regresses on error, tune file is missing.
        let cur = r#"{"bench": "gemm", "gflops": 11.0, "rel_err": 0.002}"#;
        std::fs::write(cur_dir.join("BENCH_gemm.json"), cur).unwrap();
        let report = diff_dirs(&base_dir, &cur_dir).unwrap();
        assert_eq!(report.files.len(), 2, "only BENCH_*.json files are compared");
        assert_eq!(report.failures(), 2, "{}", report.summary());
        assert!(!report.ok());
        let j = report.to_json();
        assert!(j.contains("\"bench\": \"benchdiff\""), "{j}");
        assert!(j.contains("\"failures\": 2"), "{j}");
        assert!(j.contains("\"ok\": false"), "{j}");
        assert!(j.contains("\"key\": \"rel_err\""), "{j}");
        crate::tune::json::parse(j.trim_end()).unwrap();
        // Fix the regressions: same bytes for gemm, tune file restored.
        std::fs::write(cur_dir.join("BENCH_gemm.json"), base).unwrap();
        std::fs::write(cur_dir.join("BENCH_tune.json"), r#"{"tiles_per_sec": 49}"#).unwrap();
        let clean = diff_dirs(&base_dir, &cur_dir).unwrap();
        assert!(clean.ok(), "{}", clean.summary());
        assert!(clean.summary().contains("0 failures"));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn empty_baseline_dir_is_an_error() {
        let root =
            std::env::temp_dir().join(format!("winoq_benchdiff_empty_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let err = diff_dirs(&root, &root).unwrap_err();
        assert!(err.to_string().contains("no BENCH_"), "{err}");
        std::fs::remove_dir_all(&root).unwrap();
    }
}
