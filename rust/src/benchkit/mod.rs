//! Mini benchmarking harness (criterion is not in the vendored crate set):
//! warmup + N timed samples, median / mean / p50-p95-p99 reporting. Used
//! by the `rust/benches/*` targets (declared `harness = false`) and by the
//! latency-percentile summaries the serve bench JSON carries. The
//! [`diff`] submodule turns the emitted `BENCH_*.json` artifacts into a
//! regression gate (`winoq benchdiff`).

pub mod diff;

use std::time::Instant;

/// Nearest-rank percentile (`q` in `[0, 1]`) over an ascending-sorted
/// sample array. NaN-free inputs assumed (timings always are).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    let rank = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Timing summary over samples, in seconds. `median` is the 50th
/// percentile (kept under its historical name; `p50` in reports).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub samples: usize,
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
}

impl Summary {
    fn from_times(mut times: Vec<f64>) -> Summary {
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        Summary {
            samples: n,
            median: percentile_sorted(&times, 0.50),
            mean: times.iter().sum::<f64>() / n as f64,
            p95: percentile_sorted(&times, 0.95),
            p99: percentile_sorted(&times, 0.99),
            min: times[0],
        }
    }
}

/// Benchmark a closure: `warmup` untimed runs then `samples` timed runs.
pub fn bench<T>(warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Summary {
    assert!(samples > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    Summary::from_times(times)
}

/// Pretty-print a benchmark row: name, percentiles, throughput (per
/// `work` unit).
pub fn report(name: &str, s: &Summary, work_units: Option<(f64, &str)>) {
    let tp = work_units
        .map(|(w, unit)| format!("  {:>10.2} {unit}/s", w / s.median))
        .unwrap_or_default();
    println!(
        "{name:<44} p50 {:>9}  mean {:>9}  p95 {:>9}  p99 {:>9}{tp}",
        fmt_time(s.median),
        fmt_time(s.mean),
        fmt_time(s.p95),
        fmt_time(s.p99),
    );
}

/// Median-over-median speedup of `new` relative to `baseline` (>1 means
/// `new` is faster).
pub fn speedup(baseline: &Summary, new: &Summary) -> f64 {
    baseline.median / new.median
}

/// Pretty-print a speedup row under a pair of [`report`] rows.
pub fn report_speedup(label: &str, baseline: &Summary, new: &Summary) {
    println!("{label:<44} speedup: {:.2}x", speedup(baseline, new));
}

/// Human-readable time.
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let s = bench(2, 10, || 1 + 1);
        assert_eq!(s.samples, 10);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&v, 0.50), 50.0);
        assert_eq!(percentile_sorted(&v, 0.95), 95.0);
        assert_eq!(percentile_sorted(&v, 0.99), 99.0);
        assert_eq!(percentile_sorted(&v, 1.0), 100.0);
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
        let s = Summary::from_times(v);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.p95, 95.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
        assert!(fmt_time(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn summary_ordering() {
        let s = Summary::from_times(vec![3.0, 1.0, 2.0, 5.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_ratio() {
        let slow = Summary::from_times(vec![4.0]);
        let fast = Summary::from_times(vec![1.0]);
        assert!((speedup(&slow, &fast) - 4.0).abs() < 1e-12);
    }
}
