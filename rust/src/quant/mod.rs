//! Quantization substrate: symmetric linear quantizers, the staged
//! quantized-Winograd pipeline of the paper's Fig. 2 (fake-quant training
//! semantics and true-integer deployment semantics), and bit-width
//! configuration.

pub mod qwino;
pub mod scheme;

pub use qwino::{QWino, StageScales};
pub use scheme::{QuantConfig, Quantizer};
