//! The quantized Winograd pipeline of the paper's Fig. 2: symmetric
//! quantization casts "before and after all transformations", with a
//! configurable bit width for the Hadamard-product stage.
//!
//! Two interchangeable evaluations are provided:
//!
//! * [`QWino::forward_fake`] — fake-quantized floating point, matching the
//!   training-graph semantics (what the JAX model in `python/compile/`
//!   computes);
//! * [`QWino::forward_int`] — true integer arithmetic: int8/int9 codes with
//!   widened integer accumulation, the deployed inference path. It is a
//!   one-tile wrapper over [`QWino::forward_int_batch`], which runs the
//!   integer Hadamard stage over the engine's flat `[N²][T]` code panels
//!   ([`engine::hadamard_requant_i32`](crate::engine::hadamard_requant_i32))
//!   so many tiles share one pass.
//!
//! A property test asserts the two agree to the dequantization scale — the
//! guarantee that lets the coordinator serve with the integer path while
//! training with the fake path.
//!
//! [`QWino::forward_int_batch_mc`] extends the integer path to
//! multi-channel tiles (i64-exact channel accumulation before one
//! Hadamard requantization) — the scalar oracle the batched
//! [`IntWinoEngine`](crate::engine::int::IntWinoEngine) is pinned
//! against bit-for-bit.

use super::scheme::{QuantConfig, Quantizer};
use crate::engine::hadamard_requant_i32;
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;

/// A quantized Winograd tile convolver for `F(m×m, r×r)` in a given base.
///
/// `mat_bits = Some(b)` additionally quantizes the transform matrices
/// themselves to `b` bits (per-matrix symmetric scale) — this is the
/// deployed int8 configuration and the site where the polynomial base
/// matters: the canonical F(4,3) transforms mix entries of very different
/// magnitude (1/24 … 5.25), so an 8-bit per-matrix scale starves the small
/// ones, while the Legendre-base matrices are more uniform. Without matrix
/// quantization the canonical and Legendre pipelines are *bit-identical*
/// (the base change cancels algebraically before any cast differs) — see
/// the `pipelines_identical_without_matrix_quant` test.
#[derive(Clone)]
pub struct QWino {
    pub wf: WinoF,
    pub cfg: QuantConfig,
    pub mat_bits: Option<u32>,
}

/// Calibration stats for the staged pipeline: scales for each cast site.
#[derive(Clone, Copy, Debug)]
pub struct StageScales {
    pub input: Quantizer,
    pub weights: Quantizer,
    pub input_t: Quantizer,
    pub weights_t: Quantizer,
    pub hadamard: Quantizer,
    pub output: Quantizer,
}

impl QWino {
    /// Float transform matrices (fake-quant on values only).
    pub fn new(m: usize, r: usize, base: Base, cfg: QuantConfig) -> QWino {
        let plan = WinogradPlan::new(m, r);
        Self::with_plan(WinoF::new(&plan, base), cfg)
    }

    /// Build from an already-lowered transform plan (shared across layers
    /// or served models, e.g. by `serve::plan::PlanCache`) instead of
    /// re-running the exact Toom-Cook construction per instance.
    pub fn with_plan(wf: WinoF, cfg: QuantConfig) -> QWino {
        QWino { wf, cfg, mat_bits: None }
    }

    /// Deployed configuration: transform matrices quantized to `mat_bits`
    /// bits — the paper's static int8 setting.
    pub fn new_quantized_mats(
        m: usize,
        r: usize,
        base: Base,
        cfg: QuantConfig,
        mat_bits: u32,
    ) -> QWino {
        let plan = WinogradPlan::new(m, r);
        let mut wf = WinoF::new(&plan, base);
        let qm = |m: &Mat| -> Mat {
            let q = Quantizer::calibrate(mat_bits, m.data());
            fake_mat(m, &q)
        };
        wf.a_p = qm(&wf.a_p);
        wf.g_p = qm(&wf.g_p);
        wf.bt_p = qm(&wf.bt_p);
        // P⁻¹ / P⁻ᵀ participate in the same integer pipeline.
        wf.p_inv = qm(&wf.p_inv);
        wf.p_inv_t = qm(&wf.p_inv_t);
        QWino { wf, cfg, mat_bits: Some(mat_bits) }
    }

    /// Calibrate every stage's quantizer on a batch of representative
    /// tiles/weights (the serving-side analogue of the learned scales the
    /// winograd-aware training produces).
    pub fn calibrate(&self, xs: &[Mat], ws: &[Mat]) -> StageScales {
        let collect = |mats: &[Mat]| -> Vec<f64> {
            mats.iter().flat_map(|m| m.data().iter().copied()).collect()
        };
        let x_all = collect(xs);
        let w_all = collect(ws);
        let xt_all: Vec<f64> = xs
            .iter()
            .flat_map(|x| self.wf.transform_input(x).data().to_vec())
            .collect();
        let wt_all: Vec<f64> = ws
            .iter()
            .flat_map(|w| self.wf.transform_weights(w).data().to_vec())
            .collect();
        // Hadamard range: elementwise products of the transformed pairs.
        let mut had_all = Vec::new();
        let mut out_all = Vec::new();
        for (x, w) in xs.iter().zip(ws) {
            let xt = self.wf.transform_input(x);
            let wt = self.wf.transform_weights(w);
            let mut had = Mat::zeros(self.wf.n, self.wf.n);
            for i in 0..self.wf.n {
                for j in 0..self.wf.n {
                    had[(i, j)] = xt[(i, j)] * wt[(i, j)];
                }
            }
            had_all.extend_from_slice(had.data());
            out_all.extend_from_slice(self.wf.transform_output(&had).data());
        }
        StageScales {
            input: Quantizer::calibrate(self.cfg.act_bits, &x_all),
            weights: Quantizer::calibrate(self.cfg.weight_bits, &w_all),
            input_t: Quantizer::calibrate(self.cfg.act_bits, &xt_all),
            weights_t: Quantizer::calibrate(self.cfg.weight_bits, &wt_all),
            hadamard: Quantizer::calibrate(self.cfg.hadamard_bits, &had_all),
            output: Quantizer::calibrate(self.cfg.out_bits, &out_all),
        }
    }

    /// Fake-quantized tile correlation (training semantics, Fig. 2): casts
    /// before and after every transform stage.
    pub fn forward_fake(&self, x: &Mat, w: &Mat, s: &StageScales) -> Mat {
        let n = self.wf.n;
        let qx = fake_mat(x, &s.input);
        let qw = fake_mat(w, &s.weights);
        let xt = fake_mat(&self.wf.transform_input(&qx), &s.input_t);
        let wt = fake_mat(&self.wf.transform_weights(&qw), &s.weights_t);
        let mut had = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                had[(i, j)] = xt[(i, j)] * wt[(i, j)];
            }
        }
        let had_q = fake_mat(&had, &s.hadamard);
        fake_mat(&self.wf.transform_output(&had_q), &s.output)
    }

    /// True-integer tile correlation: the transformed input and weights are
    /// int codes; the Hadamard product is an integer multiply requantized to
    /// `hadamard_bits`; accumulation through the output transform happens in
    /// f64 on dequantized codes (the output transform's constants are
    /// rationals — a deployment would fold them into fixed-point, which is
    /// an exact rescaling and does not change the values being tested).
    ///
    /// One-tile convenience over [`forward_int_batch`](Self::forward_int_batch).
    pub fn forward_int(&self, x: &Mat, w: &Mat, s: &StageScales) -> Mat {
        self.forward_int_batch(std::slice::from_ref(x), w, s)
            .pop()
            .expect("one tile in, one tile out")
    }

    /// True-integer correlation of a *batch* of tiles against one filter,
    /// staged over the engine's flat code panels:
    ///
    /// 1. quantize every transformed tile into one `[N²][T]` i32 panel
    ///    (and the transformed filter into `[N²]` codes) — identical
    ///    rounding decisions to [`forward_fake`](Self::forward_fake);
    /// 2. run the integer Hadamard + requantization for all tiles in one
    ///    [`hadamard_requant_i32`] pass (i64-widened products, rescaled by
    ///    the product of the operand scales — an integer-preserving
    ///    rescale);
    /// 3. dequantize and back-transform each tile, with the final output
    ///    cast.
    pub fn forward_int_batch(&self, xs: &[Mat], w: &Mat, s: &StageScales) -> Vec<Mat> {
        let n = self.wf.n;
        let nn = n * n;
        let t_total = xs.len();
        let qw = fake_mat(w, &s.weights);
        let wt_codes = quant_mat(&self.wf.transform_weights(&qw), &s.weights_t);
        // Stage 1: per-tile input transform into the [N²][T] code panel.
        let mut xt_codes = vec![0i32; nn * t_total];
        for (t, x) in xs.iter().enumerate() {
            let qx = fake_mat(x, &s.input);
            let codes = quant_mat(&self.wf.transform_input(&qx), &s.input_t);
            for f in 0..nn {
                xt_codes[f * t_total + t] = codes[f];
            }
        }
        // Stage 2: integer Hadamard over the whole panel.
        let prod_scale = s.input_t.scale * s.weights_t.scale;
        let mut had_codes = vec![0i32; nn * t_total];
        hadamard_requant_i32(&xt_codes, &wt_codes, prod_scale, &s.hadamard, &mut had_codes);
        // Stage 3: dequantize, back-transform, final cast — per tile.
        let mut had = Mat::zeros(n, n);
        (0..t_total)
            .map(|t| {
                for f in 0..nn {
                    had[(f / n, f % n)] = s.hadamard.dequantize(had_codes[f * t_total + t]);
                }
                fake_mat(&self.wf.transform_output(&had), &s.output)
            })
            .collect()
    }

    /// True-integer correlation of a batch of **multi-channel** tiles
    /// against one filter's transformed-weight bank — the scalar,
    /// tile-at-a-time oracle the batched integer engine
    /// ([`engine::int::IntWinoEngine`](crate::engine::int::IntWinoEngine))
    /// is pinned against bit-for-bit (`rust/tests/int_parity.rs`).
    ///
    /// `xs[t][c]` are `N×N` input tiles (tile `t`, channel `c`); `wt[c]`
    /// are the **transformed** (`N×N`) weights for one output filter —
    /// already through whatever weight-side casts the layer bakes (a
    /// [`WinoConv2d`](crate::nn::winolayer::WinoConv2d) bakes only the
    /// `weights_t` cast, so its float bank is fed here unchanged). Per
    /// tile: each channel's transformed-input codes multiply the weight
    /// codes and accumulate in **i64** (exact, so channel order cannot
    /// matter), then one Hadamard requantization per frequency point,
    /// then dequantize → back-transform → output cast.
    ///
    /// With `C = 1` and `wt = [transform_weights(fake(w))]` this is
    /// exactly [`forward_int_batch`](Self::forward_int_batch) — pinned by
    /// the `mc_oracle_degenerates_to_single_channel` test.
    pub fn forward_int_batch_mc(
        &self,
        xs: &[Vec<Mat>],
        wt: &[Mat],
        s: &StageScales,
    ) -> Vec<Mat> {
        let n = self.wf.n;
        let nn = n * n;
        let c = wt.len();
        assert!(c > 0, "need at least one channel");
        let wt_codes: Vec<Vec<i32>> =
            wt.iter().map(|w| quant_mat(w, &s.weights_t)).collect();
        let prod_scale = s.input_t.scale * s.weights_t.scale;
        let mut had = Mat::zeros(n, n);
        xs.iter()
            .map(|tiles| {
                assert_eq!(tiles.len(), c, "tile/filter channel mismatch");
                let mut acc = vec![0i64; nn];
                for (ci, x) in tiles.iter().enumerate() {
                    let qx = fake_mat(x, &s.input);
                    let codes = quant_mat(&self.wf.transform_input(&qx), &s.input_t);
                    for f in 0..nn {
                        acc[f] += codes[f] as i64 * wt_codes[ci][f] as i64;
                    }
                }
                for f in 0..nn {
                    let code = s.hadamard.quantize(acc[f] as f64 * prod_scale);
                    had[(f / n, f % n)] = s.hadamard.dequantize(code);
                }
                fake_mat(&self.wf.transform_output(&had), &s.output)
            })
            .collect()
    }

    /// Measure end-to-end error vs the f64 direct-convolution oracle over
    /// random tiles (experiment M1's quantized variant).
    pub fn measure_error(&self, trials: usize, seed: u64) -> f64 {
        use crate::wino::conv::direct_correlate_2d;
        use crate::wino::error::Prng;
        let mut rng = Prng::new(seed);
        // Calibrate on a separate batch.
        let cal_x: Vec<Mat> = (0..32).map(|_| rng.mat(self.wf.n, self.wf.n, 1.0)).collect();
        let cal_w: Vec<Mat> = (0..32).map(|_| rng.mat(self.wf.r, self.wf.r, 0.5)).collect();
        let scales = self.calibrate(&cal_x, &cal_w);
        let mut sum_rel = 0.0;
        for _ in 0..trials {
            let x = rng.mat(self.wf.n, self.wf.n, 1.0);
            let w = rng.mat(self.wf.r, self.wf.r, 0.5);
            let oracle = direct_correlate_2d(&x, &w);
            let got = self.forward_fake(&x, &w, &scales);
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..self.wf.m {
                for j in 0..self.wf.m {
                    let d = got[(i, j)] - oracle[(i, j)];
                    num += d * d;
                    den += oracle[(i, j)] * oracle[(i, j)];
                }
            }
            sum_rel += (num / den.max(1e-300)).sqrt();
        }
        sum_rel / trials as f64
    }
}

fn fake_mat(m: &Mat, q: &Quantizer) -> Mat {
    Mat::from_vec(m.rows(), m.cols(), q.fake_all(m.data()))
}

fn quant_mat(m: &Mat, q: &Quantizer) -> Vec<i32> {
    q.quantize_all(m.data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wino::error::Prng;

    fn setup(base: Base, cfg: QuantConfig) -> (QWino, StageScales, Vec<Mat>, Vec<Mat>) {
        let qw = QWino::new(4, 3, base, cfg);
        let mut rng = Prng::new(99);
        let xs: Vec<Mat> = (0..16).map(|_| rng.mat(6, 6, 1.0)).collect();
        let ws: Vec<Mat> = (0..16).map(|_| rng.mat(3, 3, 0.5)).collect();
        let s = qw.calibrate(&xs, &ws);
        (qw, s, xs, ws)
    }

    #[test]
    fn int_and_fake_paths_agree() {
        // The deployed integer pipeline must match the training-semantics
        // fake-quant pipeline to within one final-stage quantization step
        // (identical rounding decisions at every cast site).
        for base in [Base::Canonical, Base::Legendre] {
            let (qw, s, xs, ws) = setup(base, QuantConfig::w8());
            for (x, w) in xs.iter().zip(&ws) {
                let yf = qw.forward_fake(x, w, &s);
                let yi = qw.forward_int(x, w, &s);
                for i in 0..4 {
                    for j in 0..4 {
                        let d = (yf[(i, j)] - yi[(i, j)]).abs();
                        assert!(
                            d <= s.output.scale + 1e-9,
                            "{base:?} ({i},{j}): fake {} int {}",
                            yf[(i, j)],
                            yi[(i, j)]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn int_batch_matches_per_tile_int_path() {
        // The flat-panel batched integer pipeline must reproduce the
        // tile-at-a-time results exactly (same codes, same requant).
        let (qw, s, xs, ws) = setup(Base::Legendre, QuantConfig::w8_h9());
        let w = &ws[0];
        let batched = qw.forward_int_batch(&xs, w, &s);
        assert_eq!(batched.len(), xs.len());
        for (x, yb) in xs.iter().zip(&batched) {
            let y1 = qw.forward_int(x, w, &s);
            assert_eq!(y1.data(), yb.data(), "batched ≠ per-tile integer path");
        }
    }

    #[test]
    fn mc_oracle_degenerates_to_single_channel() {
        // C = 1 with the transformed fake-quantized weights must be
        // exactly the classic single-channel integer batch path.
        for cfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
            let (qw, s, xs, ws) = setup(Base::Chebyshev, cfg);
            let w = &ws[0];
            let wt = qw.wf.transform_weights(&fake_mat(w, &s.weights));
            let mc_xs: Vec<Vec<Mat>> = xs.iter().map(|x| vec![x.clone()]).collect();
            let got = qw.forward_int_batch_mc(&mc_xs, std::slice::from_ref(&wt), &s);
            let want = qw.forward_int_batch(&xs, w, &s);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.data(), b.data(), "mc(C=1) ≠ forward_int_batch");
            }
        }
    }

    #[test]
    fn mc_oracle_accumulates_channels_exactly() {
        // The i64 channel accumulation is exact: summing per-channel code
        // products by hand reproduces the oracle's Hadamard codes.
        let (qw, s, xs, ws) = setup(Base::Legendre, QuantConfig::w8_h9());
        let c = 3;
        let tiles: Vec<Vec<Mat>> = xs.chunks(c).take(4).map(|ch| ch.to_vec()).collect();
        let wt: Vec<Mat> = ws[..c]
            .iter()
            .map(|w| qw.wf.transform_weights(&fake_mat(w, &s.weights)))
            .collect();
        let got = qw.forward_int_batch_mc(&tiles, &wt, &s);
        let nn = qw.wf.n * qw.wf.n;
        let ps = s.input_t.scale * s.weights_t.scale;
        for (t, tile_set) in tiles.iter().enumerate() {
            let mut acc = vec![0i64; nn];
            for (ci, x) in tile_set.iter().enumerate() {
                let codes =
                    quant_mat(&qw.wf.transform_input(&fake_mat(x, &s.input)), &s.input_t);
                let wcodes = quant_mat(&wt[ci], &s.weights_t);
                for f in 0..nn {
                    acc[f] += codes[f] as i64 * wcodes[f] as i64;
                }
            }
            let mut had = Mat::zeros(qw.wf.n, qw.wf.n);
            for f in 0..nn {
                had[(f / qw.wf.n, f % qw.wf.n)] =
                    s.hadamard.dequantize(s.hadamard.quantize(acc[f] as f64 * ps));
            }
            let want = fake_mat(&qw.wf.transform_output(&had), &s.output);
            assert_eq!(got[t].data(), want.data(), "tile {t}");
        }
    }

    #[test]
    fn with_plan_matches_fresh_construction() {
        // A shared lowered plan (the serve plan-cache path) must produce
        // a pipeline indistinguishable from per-instance construction.
        let plan = WinogradPlan::new(4, 3);
        let shared = QWino::with_plan(WinoF::new(&plan, Base::Legendre), QuantConfig::w8());
        let fresh = QWino::new(4, 3, Base::Legendre, QuantConfig::w8());
        assert_eq!(shared.measure_error(50, 5), fresh.measure_error(50, 5));
    }

    #[test]
    fn quantized_error_is_meaningful() {
        // 8-bit quantization must produce visible (but bounded) error.
        let qw = QWino::new(4, 3, Base::Canonical, QuantConfig::w8());
        let err = qw.measure_error(100, 1);
        assert!(err > 1e-4, "8-bit error suspiciously small: {err}");
        assert!(err < 0.5, "8-bit error suspiciously large: {err}");
    }

    #[test]
    fn pipelines_identical_without_matrix_quant() {
        // With float transform matrices the base change cancels exactly:
        // every cast site sees identical values, so canonical and Legendre
        // produce the same error. This pins down that the paper's benefit
        // must come from the representation of the transforms themselves
        // (quantized matrices / trainable flex matrices), not the casts.
        let can = QWino::new(4, 3, Base::Canonical, QuantConfig::w8());
        let leg = QWino::new(4, 3, Base::Legendre, QuantConfig::w8());
        let e_can = can.measure_error(200, 17);
        let e_leg = leg.measure_error(200, 17);
        assert!(
            (e_can - e_leg).abs() < 1e-12,
            "expected identical pipelines: {e_can} vs {e_leg}"
        );
    }

    #[test]
    fn legendre_beats_canonical_with_quantized_matrices() {
        // The paper's headline mechanism at tile level: with the transform
        // matrices themselves held in 8 bits (the deployed static int8
        // configuration), the Legendre-base pipeline accumulates less error
        // than canonical for F(4,3).
        let can =
            QWino::new_quantized_mats(4, 3, Base::Canonical, QuantConfig::w8(), 8);
        let leg =
            QWino::new_quantized_mats(4, 3, Base::Legendre, QuantConfig::w8(), 8);
        let e_can = can.measure_error(400, 17);
        let e_leg = leg.measure_error(400, 17);
        assert!(
            e_leg < e_can,
            "legendre {e_leg} !< canonical {e_can} at 8 bits (quantized mats)"
        );
    }

    #[test]
    fn nine_bit_hadamard_reduces_error() {
        // Paper §5: widening only the Hadamard stage to 9 bits recovers
        // accuracy — the tile-level error must drop for both bases.
        for base in [Base::Canonical, Base::Legendre] {
            let w8 = QWino::new(4, 3, base, QuantConfig::w8());
            let w9 = QWino::new(4, 3, base, QuantConfig::w8_h9());
            let e8 = w8.measure_error(400, 23);
            let e9 = w9.measure_error(400, 23);
            assert!(
                e9 < e8,
                "{base:?}: 9-bit hadamard {e9} !< 8-bit {e8}"
            );
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut prev = f64::INFINITY;
        for bits in [4u32, 6, 8, 10, 12] {
            let qw = QWino::new(4, 3, Base::Legendre, QuantConfig::uniform(bits));
            let e = qw.measure_error(150, 31);
            assert!(e < prev, "error did not fall at {bits} bits: {e} !< {prev}");
            prev = e;
        }
    }

    #[test]
    fn f23_less_sensitive_than_f43() {
        // Smaller tiles are better conditioned — matching ref [5]'s finding
        // that F2 quantizes well while F4/F6 degrade.
        let f2 = QWino::new(2, 3, Base::Canonical, QuantConfig::w8());
        let f4 = QWino::new(4, 3, Base::Canonical, QuantConfig::w8());
        let e2 = f2.measure_error(300, 41);
        let e4 = f4.measure_error(300, 41);
        assert!(e2 < e4, "F(2,3) err {e2} !< F(4,3) err {e4}");
    }

    #[test]
    fn calibration_covers_ranges() {
        let (_, s, xs, _) = setup(Base::Canonical, QuantConfig::w8());
        let max_in = xs
            .iter()
            .flat_map(|m| m.data())
            .fold(0.0f64, |a, &b| a.max(b.abs()));
        // max|x| must map to exactly qmax.
        assert_eq!(s.input.quantize(max_in), 127);
        assert_eq!(s.hadamard.bits, 8);
        let (_, s9, _, _) = setup(Base::Canonical, QuantConfig::w8_h9());
        assert_eq!(s9.hadamard.bits, 9);
    }
}
