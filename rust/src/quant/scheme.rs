//! Symmetric linear quantization — the scheme of the paper's §4.2 (and of
//! Fernandez-Marques et al. 2020, its ref [5]).
//!
//! A tensor `t` is mapped to integers via a per-tensor scale
//! `s = max|t| / qmax` with `qmax = 2^{bits−1} − 1`, i.e. `q = round(t/s)`
//! clamped to `[−qmax, qmax]`. Symmetric (no zero-point) because the
//! Winograd domain is sign-symmetric. The paper's two operating points are
//! `bits = 8` everywhere and `bits = 9` for the Hadamard product stage.

/// A symmetric quantizer for a fixed bit width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    pub bits: u32,
    /// Scale: real value = `q * scale`.
    pub scale: f64,
}

impl Quantizer {
    /// Largest representable magnitude for `bits`-bit symmetric signed
    /// quantization: `2^{bits−1} − 1` (127 for 8 bits, 255 for 9 bits).
    pub fn qmax(bits: u32) -> i32 {
        assert!((2..=24).contains(&bits), "unsupported bit width {bits}");
        (1i32 << (bits - 1)) - 1
    }

    /// Calibrate a quantizer from data: scale = max|t| / qmax.
    /// Degenerate all-zero tensors get scale 1 (every value quantizes to 0).
    pub fn calibrate(bits: u32, data: &[f64]) -> Quantizer {
        let maxabs = data.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let scale = if maxabs == 0.0 {
            1.0
        } else {
            maxabs / Self::qmax(bits) as f64
        };
        Quantizer { bits, scale }
    }

    /// Calibrate from a magnitude percentile instead of the maximum:
    /// `scale = percentile(|t|, pct) / qmax` with nearest-rank percentiles
    /// (the same estimator as [`crate::benchkit::percentile_sorted`],
    /// which this reuses). `pct` is in percent; `pct = 100` reproduces
    /// [`calibrate`](Self::calibrate) exactly. Lower percentiles trade a
    /// little clipping of outliers for a finer step on the bulk of the
    /// distribution — the `winoq tune --calib-pct` activation-calibration
    /// knob, after the robust-calibration observation of
    /// Fernandez-Marques et al. 2020.
    ///
    /// Two guard rails: `pct >= 100` short-circuits to
    /// [`calibrate`](Self::calibrate) (no clone/sort on the default
    /// path, NaN-tolerant like before), and a percentile that lands on
    /// `0.0` — easy with post-ReLU activations, where half the tensor
    /// plus the zero padding is exactly zero — falls back to the max
    /// instead of pinning a meaningless `scale = 1`.
    pub fn calibrate_percentile(bits: u32, data: &[f64], pct: f64) -> Quantizer {
        assert!(
            pct > 0.0 && pct <= 100.0,
            "calibration percentile must be in (0, 100], got {pct}"
        );
        if pct >= 100.0 {
            return Self::calibrate(bits, data);
        }
        // NaNs are dropped (the max-calibration fold ignores them too),
        // so the sort is total and panic-free.
        let mut mags: Vec<f64> = data.iter().map(|v| v.abs()).filter(|v| !v.is_nan()).collect();
        mags.sort_by(|a, b| a.total_cmp(b));
        let ref_mag = if mags.is_empty() {
            0.0
        } else {
            crate::benchkit::percentile_sorted(&mags, pct / 100.0)
        };
        if ref_mag == 0.0 {
            // The pct-th magnitude is zero (sparse/ReLU data): the
            // percentile carries no range information, so degrade to
            // max-calibration rather than a garbage unit scale.
            return Self::calibrate(bits, data);
        }
        Quantizer { bits, scale: ref_mag / Self::qmax(bits) as f64 }
    }

    /// Calibrate from f32 data.
    pub fn calibrate_f32(bits: u32, data: &[f32]) -> Quantizer {
        let maxabs = data.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
        let scale = if maxabs == 0.0 {
            1.0
        } else {
            maxabs / Self::qmax(bits) as f64
        };
        Quantizer { bits, scale }
    }

    /// With an explicit scale (e.g. a trained/EMA scale).
    pub fn with_scale(bits: u32, scale: f64) -> Quantizer {
        assert!(scale > 0.0, "non-positive scale");
        Quantizer { bits, scale }
    }

    /// Quantize one value to its integer code (round-to-nearest-even like
    /// the JAX side's `jnp.round`; ties in practice never matter here).
    pub fn quantize(&self, x: f64) -> i32 {
        let qmax = Self::qmax(self.bits);
        let q = (x / self.scale).round();
        (q as i32).clamp(-qmax, qmax)
    }

    /// Quantize with a saturation flag for numeric-health telemetry:
    /// the value is **bit-identical** to [`quantize`](Self::quantize)
    /// (same divide, round, cast, clamp), and the flag reports whether
    /// the rounded code fell outside `[−qmax, qmax]` — i.e. the clamp
    /// actually clipped. NaN inputs quantize to 0 and do not count as
    /// saturated (matching the cast semantics of the value path).
    #[inline]
    pub fn quantize_sat(&self, x: f64) -> (i32, bool) {
        let qmax = Self::qmax(self.bits);
        let q = (x / self.scale).round();
        let sat = q > qmax as f64 || q < -(qmax as f64);
        ((q as i32).clamp(-qmax, qmax), sat)
    }

    /// Integer code back to real.
    pub fn dequantize(&self, q: i32) -> f64 {
        q as f64 * self.scale
    }

    /// Fake-quantize: quantize-then-dequantize — the operation inserted
    /// throughout the winograd-aware training graph (Fig. 2's casts).
    pub fn fake(&self, x: f64) -> f64 {
        self.dequantize(self.quantize(x))
    }

    /// Quantize a slice to integer codes.
    pub fn quantize_all(&self, xs: &[f64]) -> Vec<i32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Fake-quantize a slice.
    pub fn fake_all(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.fake(x)).collect()
    }

    /// The worst-case absolute rounding error of this quantizer (half a
    /// step), ignoring clipping.
    pub fn step_error(&self) -> f64 {
        self.scale * 0.5
    }

    /// Precompute the fused requantization epilogue for an integer
    /// accumulator: [`Requant::apply`]`(acc)` is **bit-identical** to
    /// `self.quantize(acc as f64 * prod_scale)` — the same multiply,
    /// divide, round and clamp in the same order — with the per-call
    /// `qmax` bit-range assert and the step load hoisted out of the hot
    /// loop. This is the epilogue the tiled integer panel GEMM
    /// ([`engine::gemm`](crate::engine::gemm)) applies per register tile.
    ///
    /// Deliberately **not** folded into a single multiplier
    /// `prod_scale / scale`: that quotient would round once when formed
    /// and again when applied, and the double rounding flips codes next
    /// to ties — `requant_is_bit_identical_to_quantize` would catch it.
    pub fn requant(&self, prod_scale: f64) -> Requant {
        Requant { prod_scale, scale: self.scale, qmax: Self::qmax(self.bits) }
    }
}

/// Hoisted requantization state (see [`Quantizer::requant`]): the
/// accumulator→real scale, the quantizer step and the precomputed clamp
/// bound, applied branch-light per output element.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Requant {
    prod_scale: f64,
    scale: f64,
    qmax: i32,
}

impl Requant {
    /// Requantize one integer accumulator: exactly
    /// `quantize(acc as f64 * prod_scale)` — saturating at `±qmax`,
    /// never wrapping.
    #[inline]
    pub fn apply(&self, acc: i64) -> i32 {
        let q = ((acc as f64 * self.prod_scale) / self.scale).round();
        (q as i32).clamp(-self.qmax, self.qmax)
    }

    /// [`apply`](Self::apply) with a saturation flag: the value takes
    /// the exact same multiply/divide/round/cast/clamp path (the
    /// bit-parity invariant is untouched), and the flag reports whether
    /// the clamp clipped — the per-layer requant-clipping signal of the
    /// numeric-health telemetry.
    #[inline]
    pub fn apply_sat(&self, acc: i64) -> (i32, bool) {
        let q = ((acc as f64 * self.prod_scale) / self.scale).round();
        let sat = q > self.qmax as f64 || q < -(self.qmax as f64);
        ((q as i32).clamp(-self.qmax, self.qmax), sat)
    }
}

/// Bit-width configuration of the quantized Winograd pipeline — which stage
/// uses how many bits. The paper's two configurations are
/// `QuantConfig::w8()` (all-8-bit) and `QuantConfig::w8_h9()` (8-bit with a
/// 9-bit Hadamard product).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    /// Bits for activations entering the layer and transformed inputs.
    pub act_bits: u32,
    /// Bits for weights and transformed weights.
    pub weight_bits: u32,
    /// Bits for the Hadamard-product operands/result (the paper's knob:
    /// 8 → ~0.5% accuracy loss, 9 → parity with direct convolution).
    pub hadamard_bits: u32,
    /// Bits for the post-transform output.
    pub out_bits: u32,
}

impl QuantConfig {
    /// Everything at 8 bits (paper Table 1 row "8 bits").
    pub fn w8() -> QuantConfig {
        QuantConfig { act_bits: 8, weight_bits: 8, hadamard_bits: 8, out_bits: 8 }
    }

    /// 8 bits with 9-bit Hadamard (paper Table 1 row "8b + 9b").
    pub fn w8_h9() -> QuantConfig {
        QuantConfig { act_bits: 8, weight_bits: 8, hadamard_bits: 9, out_bits: 8 }
    }

    /// Uniform width helper for sweeps.
    pub fn uniform(bits: u32) -> QuantConfig {
        QuantConfig { act_bits: bits, weight_bits: bits, hadamard_bits: bits, out_bits: bits }
    }

    /// Parse a CLI-style config name: the paper's two operating points
    /// (`w8`, `w8_h9`/`w8h9`) plus `uN` for a uniform N-bit sweep point.
    pub fn from_name(s: &str) -> Option<QuantConfig> {
        match s {
            "w8" => Some(Self::w8()),
            "w8_h9" | "w8h9" => Some(Self::w8_h9()),
            _ => s
                .strip_prefix('u')
                .and_then(|b| b.parse::<u32>().ok())
                .filter(|b| (2..=24).contains(b))
                .map(Self::uniform),
        }
    }

    pub fn label(&self) -> String {
        if self.act_bits == self.weight_bits
            && self.act_bits == self.out_bits
        {
            if self.hadamard_bits == self.act_bits {
                format!("{} bits", self.act_bits)
            } else {
                format!("{}b + {}b", self.act_bits, self.hadamard_bits)
            }
        } else {
            format!(
                "a{}w{}h{}o{}",
                self.act_bits, self.weight_bits, self.hadamard_bits, self.out_bits
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(Quantizer::qmax(8), 127);
        assert_eq!(Quantizer::qmax(9), 255);
        assert_eq!(Quantizer::qmax(2), 1);
        assert_eq!(Quantizer::qmax(16), 32767);
    }

    #[test]
    #[should_panic]
    fn qmax_rejects_1bit() {
        let _ = Quantizer::qmax(1);
    }

    #[test]
    fn calibrate_maps_extremes_exactly() {
        let data = [-3.0, 1.0, 2.5, 3.0];
        let q = Quantizer::calibrate(8, &data);
        assert_eq!(q.quantize(3.0), 127);
        assert_eq!(q.quantize(-3.0), -127);
        assert!((q.dequantize(q.quantize(3.0)) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn calibrate_percentile_100_matches_max() {
        let data: Vec<f64> = (0..57).map(|i| (i as f64) * 0.31 - 8.0).collect();
        let a = Quantizer::calibrate(8, &data);
        let b = Quantizer::calibrate_percentile(8, &data, 100.0);
        assert_eq!(a, b, "pct=100 must reproduce max-calibration exactly");
    }

    #[test]
    fn calibrate_percentile_ignores_outlier() {
        // 99 well-behaved values plus one huge outlier: max-calibration
        // inflates the scale 100x, the 99th percentile does not.
        let mut data: Vec<f64> = (1..=99).map(|i| i as f64 / 99.0).collect();
        data.push(100.0);
        let q_max = Quantizer::calibrate(8, &data);
        let q_pct = Quantizer::calibrate_percentile(8, &data, 99.0);
        assert!((q_max.scale - 100.0 / 127.0).abs() < 1e-12);
        assert!((q_pct.scale - 1.0 / 127.0).abs() < 1e-12);
        // The outlier clips to qmax instead of owning the range.
        assert_eq!(q_pct.quantize(100.0), 127);
    }

    #[test]
    fn calibrate_percentile_zero_and_empty() {
        let q = Quantizer::calibrate_percentile(8, &[0.0, 0.0], 95.0);
        assert_eq!(q.scale, 1.0);
        let q = Quantizer::calibrate_percentile(8, &[], 95.0);
        assert_eq!(q.scale, 1.0);
    }

    #[test]
    fn calibrate_percentile_sparse_data_falls_back_to_max() {
        // Post-ReLU-like data: 60% exact zeros. A 50th percentile lands
        // on 0.0 — the quantizer must degrade to max-calibration, not a
        // meaningless unit scale.
        let mut data = vec![0.0f64; 60];
        data.extend((1..=40).map(|i| i as f64 / 40.0));
        let q = Quantizer::calibrate_percentile(8, &data, 50.0);
        assert_eq!(q, Quantizer::calibrate(8, &data));
        assert!((q.scale - 1.0 / 127.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn calibrate_percentile_rejects_zero_pct() {
        let _ = Quantizer::calibrate_percentile(8, &[1.0], 0.0);
    }

    #[test]
    fn calibrate_zero_tensor() {
        let q = Quantizer::calibrate(8, &[0.0, 0.0]);
        assert_eq!(q.quantize(0.0), 0);
        assert_eq!(q.fake(0.0), 0.0);
    }

    #[test]
    fn quantize_clamps_out_of_range() {
        let q = Quantizer::with_scale(8, 1.0);
        assert_eq!(q.quantize(1e9), 127);
        assert_eq!(q.quantize(-1e9), -127);
    }

    #[test]
    fn fake_error_bounded_by_half_step() {
        let data: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.013 - 6.0).collect();
        let q = Quantizer::calibrate(8, &data);
        for &x in &data {
            assert!((q.fake(x) - x).abs() <= q.step_error() + 1e-12);
        }
    }

    #[test]
    fn nine_bits_halves_the_step() {
        let data = [-1.0, 1.0];
        let q8 = Quantizer::calibrate(8, &data);
        let q9 = Quantizer::calibrate(9, &data);
        let ratio = q8.step_error() / q9.step_error();
        assert!((ratio - 255.0 / 127.0).abs() < 1e-12);
    }

    #[test]
    fn fake_is_idempotent() {
        let q = Quantizer::with_scale(8, 0.037);
        for i in -127..=127 {
            let x = q.dequantize(i);
            assert_eq!(q.fake(x), x);
        }
    }

    #[test]
    fn config_labels() {
        assert_eq!(QuantConfig::w8().label(), "8 bits");
        assert_eq!(QuantConfig::w8_h9().label(), "8b + 9b");
        assert_eq!(QuantConfig::uniform(6).label(), "6 bits");
    }

    #[test]
    fn config_from_name() {
        assert_eq!(QuantConfig::from_name("w8"), Some(QuantConfig::w8()));
        assert_eq!(QuantConfig::from_name("w8_h9"), Some(QuantConfig::w8_h9()));
        assert_eq!(QuantConfig::from_name("w8h9"), Some(QuantConfig::w8_h9()));
        assert_eq!(QuantConfig::from_name("u6"), Some(QuantConfig::uniform(6)));
        assert_eq!(QuantConfig::from_name("u1"), None);
        assert_eq!(QuantConfig::from_name("none"), None);
        assert_eq!(QuantConfig::from_name("w9"), None);
    }

    #[test]
    fn requant_is_bit_identical_to_quantize() {
        // The hoisted epilogue must reproduce quantize(acc · prod_scale)
        // for every code path: interior values, exact ties, clamp on
        // both sides, huge/tiny scales. Deterministic sweep plus a
        // seeded random sweep over several orders of magnitude.
        use crate::wino::error::Prng;
        let cases = [
            (9u32, 3.7e-4, 1.9e-4),
            (8, 1.0, 1.0),
            (8, 1e-9, 1e6),
            (16, 2.5e-2, 5.0e-7),
        ];
        for &(bits, scale, ps) in &cases {
            let hq = Quantizer::with_scale(bits, scale);
            let rq = hq.requant(ps);
            for acc in (-2000i64..=2000).chain([i64::MIN / 4, i64::MAX / 4]) {
                assert_eq!(
                    rq.apply(acc),
                    hq.quantize(acc as f64 * ps),
                    "bits={bits} scale={scale} ps={ps} acc={acc}"
                );
            }
        }
        let mut rng = Prng::new(0xEE);
        for _ in 0..4000 {
            let bits = 2 + (rng.next_u64() % 15) as u32;
            let hq = Quantizer::with_scale(bits, 10f64.powf(rng.uniform(6.0)));
            let ps = 10f64.powf(rng.uniform(6.0));
            let rq = hq.requant(ps);
            let acc = rng.next_u64() as i64 >> (rng.next_u64() % 40);
            assert_eq!(rq.apply(acc), hq.quantize(acc as f64 * ps));
        }
        // A tie case the folded-multiplier shortcut would get wrong is
        // hard to construct deterministically across platforms, but the
        // exact-ops invariant above subsumes it: apply() *is* quantize()
        // on the same f64 intermediate.
    }

    #[test]
    fn sat_variants_match_values_and_flag_only_real_clips() {
        use crate::wino::error::Prng;
        let q = Quantizer::with_scale(8, 1.0);
        assert_eq!(q.quantize_sat(126.4), (126, false));
        assert_eq!(q.quantize_sat(127.4), (127, false), "rounds inside range");
        assert_eq!(q.quantize_sat(127.6), (127, true), "rounds past qmax");
        assert_eq!(q.quantize_sat(-1e9), (-127, true));
        assert_eq!(q.quantize_sat(f64::NAN), (0, false), "NaN is not a clip");
        let rq = q.requant(1.0);
        assert_eq!(rq.apply_sat(127), (127, false));
        assert_eq!(rq.apply_sat(128), (127, true));
        assert_eq!(rq.apply_sat(-4000), (-127, true));
        // Values always agree with the unflagged paths, and the flag is
        // exactly "the unclamped rounded code left [-qmax, qmax]".
        let mut rng = Prng::new(0xA7);
        for _ in 0..4000 {
            let bits = 2 + (rng.next_u64() % 15) as u32;
            let hq = Quantizer::with_scale(bits, 10f64.powf(rng.uniform(4.0)));
            let x = rng.uniform(1.0) * 10f64.powf(rng.uniform(5.0));
            let (code, sat) = hq.quantize_sat(x);
            assert_eq!(code, hq.quantize(x));
            let unclamped = (x / hq.scale).round();
            assert_eq!(sat, unclamped.abs() > Quantizer::qmax(bits) as f64);
            let ps = 10f64.powf(rng.uniform(4.0));
            let rq = hq.requant(ps);
            let acc = rng.next_u64() as i64 >> (rng.next_u64() % 40);
            let (rc, _) = rq.apply_sat(acc);
            assert_eq!(rc, rq.apply(acc));
        }
    }

    #[test]
    fn quantize_all_matches_scalar() {
        let q = Quantizer::with_scale(8, 0.1);
        let xs = [0.04, 0.06, -0.14, 12.7];
        let all = q.quantize_all(&xs);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(all[i], q.quantize(x));
        }
    }
}
