//! The training coordinator: drives the AOT'd train/eval steps over the
//! prefetching data pipeline, applies the LR schedule, evaluates
//! periodically, checkpoints, and streams metrics.
//!
//! This is L3 of the stack: python never runs here — the `Artifact` holds
//! the compiled step functions, and everything else (data, batching,
//! scheduling, metrics, checkpoints) is rust.

use super::schedule::Schedule;
use crate::data::{loader, synthcifar, Loader, LoaderCfg};
use crate::obs::trainlog::{MetricLog, StepRecord, Timer};
use crate::runtime::{Artifact, TrainState};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: u64,
    pub schedule: Schedule,
    /// Evaluate every `eval_every` steps (and at the end). 0 = end only.
    pub eval_every: u64,
    /// Number of eval batches (of `manifest.eval_batch` examples).
    pub eval_batches: usize,
    /// Console log cadence; 0 = silent.
    pub log_every: u64,
    /// Optional checkpoint path (written at the end).
    pub checkpoint: Option<PathBuf>,
    /// Dataset size fed to the loader (epoch length).
    pub dataset_size: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 200,
            schedule: Schedule::WarmupCosine {
                lr: 0.05,
                warmup: 20,
                total: 200,
                final_frac: 0.05,
            },
            eval_every: 0,
            eval_batches: 5,
            log_every: 20,
            checkpoint: None,
            dataset_size: 4096,
        }
    }
}

/// Result of a training run.
pub struct TrainOutcome {
    pub log: MetricLog,
    pub final_eval_acc: f64,
    pub final_eval_loss: f32,
    pub state: TrainState,
}

/// Run the full training loop for one artifact.
pub fn train(artifact: &Artifact, dir: &Path, cfg: &TrainCfg) -> Result<TrainOutcome> {
    let mut state = artifact.init_state(dir)?;
    train_from(artifact, &mut state, cfg).map(|(log, acc, loss)| TrainOutcome {
        log,
        final_eval_acc: acc,
        final_eval_loss: loss,
        state,
    })
}

/// Train from an existing state (resume / warm start).
pub fn train_from(
    artifact: &Artifact,
    state: &mut TrainState,
    cfg: &TrainCfg,
) -> Result<(MetricLog, f64, f32)> {
    let m = &artifact.manifest;
    let loader = Loader::new(LoaderCfg {
        seed: synthcifar::TRAIN_SEED,
        batch_size: m.train_batch,
        prefetch: 4,
        dataset_size: cfg.dataset_size,
    });
    let mut log = MetricLog::new();
    for _ in 0..cfg.steps {
        let step_timer = Timer::start();
        let batch = loader.next();
        let labels: Vec<i32> = batch.labels.iter().map(|&l| l as i32).collect();
        let lr = cfg.schedule.at(state.step);
        let stats = artifact
            .train_step(state, &batch.images.data, &labels, lr)
            .with_context(|| format!("train step {}", state.step))?;
        log.push(StepRecord {
            step: state.step,
            loss: stats.loss,
            acc: stats.acc,
            lr,
            seconds: step_timer.seconds(),
        });
        if cfg.log_every > 0 && state.step % cfg.log_every == 0 {
            eprintln!(
                "[{}] step {:>5}  loss {:.4}  acc {:.3}  lr {:.4}  ({:.0} ms/step)",
                artifact.tag,
                state.step,
                log.recent_loss(cfg.log_every as usize),
                log.recent_acc(cfg.log_every as usize),
                lr,
                log.recent_step_time(cfg.log_every as usize) * 1e3,
            );
        }
        if cfg.eval_every > 0 && state.step % cfg.eval_every == 0 {
            let (eloss, eacc) = evaluate(artifact, state, cfg.eval_batches)?;
            log.push_eval(state.step, eloss, eacc);
            if cfg.log_every > 0 {
                eprintln!(
                    "[{}] eval @ {:>5}: loss {:.4} acc {:.4}",
                    artifact.tag, state.step, eloss, eacc
                );
            }
        }
    }
    let (eloss, eacc) = evaluate(artifact, state, cfg.eval_batches)?;
    log.push_eval(state.step, eloss, eacc);
    if let Some(path) = &cfg.checkpoint {
        let bytes = artifact.state_to_bytes(state)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, bytes)
            .with_context(|| format!("writing checkpoint {path:?}"))?;
    }
    Ok((log, eacc, eloss))
}

/// Evaluate on the held-out split: returns (mean loss, accuracy).
pub fn evaluate(
    artifact: &Artifact,
    state: &TrainState,
    num_batches: usize,
) -> Result<(f32, f64)> {
    let m = &artifact.manifest;
    let batches = loader::eval_set(num_batches, m.eval_batch);
    let mut total_correct = 0i64;
    let mut total = 0usize;
    let mut loss_sum = 0f64;
    for (images, labels) in &batches {
        let labels_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
        let (loss, correct) = artifact.eval_step(state, &images.data, &labels_i32)?;
        loss_sum += loss as f64;
        total_correct += correct as i64;
        total += labels.len();
    }
    Ok((
        (loss_sum / num_batches as f64) as f32,
        total_correct as f64 / total as f64,
    ))
}
