//! Experiment definitions — the cells of the paper's Tables 1 and 2 mapped
//! to artifact tags, plus the table-rendering used by the benches and the
//! `winoq tables` CLI command.
//!
//! Absolute accuracies differ from the paper (synthetic workload, short
//! schedule — see docs/ARCHITECTURE.md §Experiments); what must reproduce is the *ordering*:
//! canonical-static worst, Legendre improving each column, flex > static,
//! and the 9-bit Hadamard row closing the gap to direct.

use super::schedule::Schedule;
use super::trainer::{self, TrainCfg};
use crate::runtime::Artifact;
use anyhow::Result;
use std::fmt::Write as _;
use std::path::Path;

/// One table cell: display column name + artifact tag.
#[derive(Clone, Debug)]
pub struct Cell {
    pub column: &'static str,
    pub tag: &'static str,
}

/// Paper Table 1 (width 0.5): rows {8 bits, 8b + 9b} × columns
/// {direct, Static, Flex, L-static, L-flex}.
pub fn table1() -> Vec<(&'static str, Vec<Cell>)> {
    vec![
        (
            "8 bits",
            vec![
                Cell { column: "direct", tag: "t1-direct-8b-w0.5" },
                Cell { column: "Static", tag: "t1-static-8b-w0.5" },
                Cell { column: "Flex", tag: "t1-flex-8b-w0.5" },
                Cell { column: "L - static", tag: "t1-L-static-8b-w0.5" },
                Cell { column: "L - flex", tag: "t1-L-flex-8b-w0.5" },
            ],
        ),
        (
            "8b + 9b",
            vec![
                Cell { column: "Static", tag: "t1-static-8bh9-w0.5" },
                Cell { column: "Flex", tag: "t1-flex-8bh9-w0.5" },
                Cell { column: "L - static", tag: "t1-L-static-8bh9-w0.5" },
                Cell { column: "L - flex", tag: "t1-L-flex-8bh9-w0.5" },
            ],
        ),
    ]
}

/// Width-0.25 replica of Table 1 — same variant grid, smaller model.
/// Used when WINOQ_T1_WIDTH=0.25 (single-core testbeds where the width-0.5
/// train graphs take ~10 min each to compile under xla_extension 0.5.1).
pub fn table1_w025() -> Vec<(&'static str, Vec<Cell>)> {
    vec![
        (
            "8 bits",
            vec![
                Cell { column: "direct", tag: "t2-direct-8b-w0.25" },
                Cell { column: "Static", tag: "t2-static-8b-w0.25" },
                Cell { column: "Flex", tag: "t2-flex-8b-w0.25" },
                Cell { column: "L - static", tag: "t2-L-static-8b-w0.25" },
                Cell { column: "L - flex", tag: "t2-L-flex-8b-w0.25" },
            ],
        ),
        (
            "8b + 9b",
            vec![
                Cell { column: "Static", tag: "t2-static-8bh9-w0.25" },
                Cell { column: "Flex", tag: "t2-flex-8bh9-w0.25" },
                Cell { column: "L - static", tag: "t2-L-static-8bh9-w0.25" },
                Cell { column: "L - flex", tag: "t2-L-flex-8bh9-w0.25" },
            ],
        ),
    ]
}

/// Paper Table 2 (8-bit): rows {width 0.25, width 0.5} × same columns.
/// The 0.5 row reuses the Table 1 artifacts.
pub fn table2() -> Vec<(&'static str, Vec<Cell>)> {
    vec![
        (
            "0.25",
            vec![
                Cell { column: "direct", tag: "t2-direct-8b-w0.25" },
                Cell { column: "Static", tag: "t2-static-8b-w0.25" },
                Cell { column: "Flex", tag: "t2-flex-8b-w0.25" },
                Cell { column: "L - static", tag: "t2-L-static-8b-w0.25" },
                Cell { column: "L - flex", tag: "t2-L-flex-8b-w0.25" },
            ],
        ),
        (
            "0.5",
            vec![
                Cell { column: "direct", tag: "t1-direct-8b-w0.5" },
                Cell { column: "Static", tag: "t1-static-8b-w0.5" },
                Cell { column: "Flex", tag: "t1-flex-8b-w0.5" },
                Cell { column: "L - static", tag: "t1-L-static-8b-w0.5" },
                Cell { column: "L - flex", tag: "t1-L-flex-8b-w0.5" },
            ],
        ),
    ]
}

/// Paper-reported values for side-by-side display.
pub fn paper_table1() -> Vec<(&'static str, Vec<(&'static str, f64)>)> {
    vec![
        (
            "8 bits",
            vec![
                ("direct", 0.923),
                ("Static", 0.772),
                ("Flex", 0.911),
                ("L - static", 0.850),
                ("L - flex", 0.918),
            ],
        ),
        (
            "8b + 9b",
            vec![
                ("Static", 0.782),
                ("Flex", 0.915),
                ("L - static", 0.894),
                ("L - flex", 0.923),
            ],
        ),
    ]
}

/// Train one cell's artifact and return final eval accuracy.
pub fn run_cell(dir: &Path, tag: &str, cfg: &TrainCfg) -> Result<f64> {
    let artifact = Artifact::load(dir, tag)?;
    let outcome = trainer::train(&artifact, dir, cfg)?;
    Ok(outcome.final_eval_acc)
}

/// Cached variant: HLO compilation dominates cell cost (minutes per cell on
/// xla_extension 0.5.1), so table benches memoise results per (tag, steps)
/// in `out/table_cache.csv`. Delete the file (or a line) to re-train a cell.
pub fn run_cell_cached(dir: &Path, tag: &str, cfg: &TrainCfg) -> Result<f64> {
    let cache_path = Path::new("out/table_cache.csv");
    let key = format!("{tag},{}", cfg.steps);
    if let Ok(text) = std::fs::read_to_string(cache_path) {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix(&format!("{key},")) {
                if let Ok(acc) = rest.parse::<f64>() {
                    eprintln!("  {tag}: cached ({:.2}%)", acc * 100.0);
                    return Ok(acc);
                }
            }
        }
    }
    let acc = run_cell(dir, tag, cfg)?;
    if let Some(parent) = cache_path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    use std::io::Write as _;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(cache_path)
    {
        let _ = writeln!(f, "{key},{acc}");
    }
    Ok(acc)
}

/// Training configuration used for table regeneration: short schedule,
/// scaled from the paper's 200-epoch runs (see docs/ARCHITECTURE.md §Experiments).
pub fn table_train_cfg(steps: u64) -> TrainCfg {
    TrainCfg {
        steps,
        schedule: Schedule::WarmupCosine {
            lr: 0.08,
            warmup: steps / 10,
            total: steps,
            final_frac: 0.02,
        },
        eval_every: 0,
        eval_batches: 5,
        log_every: 0,
        checkpoint: None,
        dataset_size: 4096,
    }
}

/// Render a measured table next to the paper's numbers.
pub fn render_table(
    title: &str,
    rows: &[(&'static str, Vec<(String, f64)>)],
    paper: Option<&[(&'static str, Vec<(&'static str, f64)>)]>,
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    for (row_label, cells) in rows {
        let _ = write!(out, "{row_label:>8} |");
        for (col, acc) in cells {
            let _ = write!(out, " {col}: {:5.1}% |", acc * 100.0);
        }
        let _ = writeln!(out);
        if let Some(paper_rows) = paper {
            if let Some((_, pcells)) = paper_rows.iter().find(|(l, _)| l == row_label) {
                let _ = write!(out, "{:>8} |", "(paper)");
                for (col, acc) in pcells {
                    let _ = write!(out, " {col}: {:5.1}% |", acc * 100.0);
                }
                let _ = writeln!(out);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_nine_cells() {
        let t = table1();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].1.len(), 5);
        assert_eq!(t[1].1.len(), 4); // no direct row for 8b+9b (paper: "-")
    }

    #[test]
    fn table2_reuses_t1_for_width_half() {
        let t = table2();
        assert!(t[1].1.iter().all(|c| c.tag.starts_with("t1-")));
        assert!(t[0].1.iter().all(|c| c.tag.contains("w0.25")));
    }

    #[test]
    fn tags_are_unique_within_rows() {
        for (_, cells) in table1().iter().chain(table2().iter()) {
            let mut tags: Vec<&str> = cells.iter().map(|c| c.tag).collect();
            tags.sort();
            tags.dedup();
            assert_eq!(tags.len(), cells.len());
        }
    }

    #[test]
    fn paper_values_match_abstract() {
        let p = paper_table1();
        // Abstract: direct 92.3%, L-flex 8b 91.8% (0.5% loss), 8b+9b 92.3%.
        assert_eq!(p[0].1[0], ("direct", 0.923));
        assert_eq!(p[0].1[4], ("L - flex", 0.918));
        assert_eq!(p[1].1[3], ("L - flex", 0.923));
    }

    #[test]
    fn render_table_contains_cells() {
        let rows = vec![("8 bits", vec![("direct".to_string(), 0.5)])];
        let s = render_table("T", &rows, Some(&paper_table1()));
        assert!(s.contains("direct:  50.0%"));
        assert!(s.contains("(paper)"));
    }

    #[test]
    fn train_cfg_scales_warmup() {
        let cfg = table_train_cfg(100);
        assert_eq!(cfg.steps, 100);
        match cfg.schedule {
            Schedule::WarmupCosine { warmup, total, .. } => {
                assert_eq!(warmup, 10);
                assert_eq!(total, 100);
            }
            _ => panic!("wrong schedule"),
        }
    }
}
