//! Learning-rate schedules for the training coordinator.

/// A learning-rate schedule over global steps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup to `lr` over `warmup` steps, then cosine decay to
    /// `final_frac * lr` at `total` steps — the shape used by ref [5].
    WarmupCosine { lr: f32, warmup: u64, total: u64, final_frac: f32 },
    /// Piecewise: multiply by `gamma` at each milestone.
    StepDecay { lr: f32, gamma: f32, milestones: [u64; 3] },
}

impl Schedule {
    pub fn at(&self, step: u64) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupCosine { lr, warmup, total, final_frac } => {
                if warmup > 0 && step < warmup {
                    return lr * (step as f32 + 1.0) / warmup as f32;
                }
                let total = total.max(warmup + 1);
                let t = ((step - warmup) as f32 / (total - warmup) as f32).min(1.0);
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                let lo = lr * final_frac;
                lo + (lr - lo) * cos
            }
            Schedule::StepDecay { lr, gamma, milestones } => {
                let k = milestones.iter().filter(|&&m| step >= m).count() as i32;
                lr * gamma.powi(k)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::WarmupCosine { lr: 1.0, warmup: 10, total: 100, final_frac: 0.0 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_final() {
        let s = Schedule::WarmupCosine { lr: 1.0, warmup: 0, total: 100, final_frac: 0.1 };
        assert!((s.at(0) - 1.0).abs() < 1e-5);
        let mid = s.at(50);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.at(100) - 0.1).abs() < 1e-5);
        assert!((s.at(1000) - 0.1).abs() < 1e-5, "clamps past total");
    }

    #[test]
    fn cosine_is_monotone_after_warmup() {
        let s = Schedule::WarmupCosine { lr: 0.5, warmup: 5, total: 200, final_frac: 0.01 };
        let mut prev = f32::INFINITY;
        for step in 5..200 {
            let v = s.at(step);
            assert!(v <= prev + 1e-7, "not monotone at {step}");
            prev = v;
        }
    }

    #[test]
    fn step_decay_milestones() {
        let s = Schedule::StepDecay { lr: 1.0, gamma: 0.1, milestones: [10, 20, 30] };
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(10) - 0.1).abs() < 1e-7);
        assert!((s.at(25) - 0.01).abs() < 1e-8);
        assert!((s.at(35) - 0.001).abs() < 1e-9);
    }
}
