//! L3 coordinator: training orchestration, LR schedules, experiment
//! definitions for the paper's tables.

pub mod experiments;
pub mod schedule;
pub mod trainer;

pub use schedule::Schedule;
pub use trainer::{evaluate, train, TrainCfg, TrainOutcome};
