//! winoq CLI — the L3 leader entrypoint.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use winoq::cli::{Args, HELP};
use winoq::config::{Config, RunConfig};
use winoq::coordinator::experiments::{self, table_train_cfg};
use winoq::coordinator::schedule::Schedule;
use winoq::coordinator::trainer::{self, TrainCfg};
use winoq::quant::{QWino, QuantConfig};
use winoq::runtime::{self, Artifact};
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::error as werror;
use winoq::wino::toomcook::WinogradPlan;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{HELP}");
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "tables" => cmd_tables(&args),
        "list" => cmd_list(&args),
        "gen-matrices" => cmd_gen_matrices(&args),
        "error-analysis" => cmd_error_analysis(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.flag("--artifacts-dir")
        .map(PathBuf::from)
        .unwrap_or_else(runtime::artifacts_dir)
}

fn cmd_train(args: &Args) -> Result<()> {
    let (tag, dir, cfg, metrics_csv) = if let Some(path) = args.flag("--config") {
        let file = Config::load(Path::new(path))?;
        let run = RunConfig::from_config(&file)?;
        (run.tag, run.artifacts_dir, run.train, run.metrics_csv)
    } else {
        let tag = args
            .flag("--artifact")
            .context("--artifact <tag> (or --config FILE) is required")?
            .to_string();
        let steps = args.flag_u64("--steps", 200)?;
        let cfg = TrainCfg {
            steps,
            schedule: Schedule::WarmupCosine {
                lr: args.flag_f32("--lr", 0.05)?,
                warmup: steps / 10,
                total: steps,
                final_frac: 0.05,
            },
            eval_every: args.flag_u64("--eval-every", 0)?,
            eval_batches: args.flag_u64("--eval-batches", 5)? as usize,
            log_every: 20,
            checkpoint: args.flag("--checkpoint").map(PathBuf::from),
            dataset_size: args.flag_u64("--dataset-size", 4096)?,
        };
        (
            tag,
            artifacts_dir(args),
            cfg,
            args.flag("--metrics-csv").map(PathBuf::from),
        )
    };
    eprintln!("loading artifact {tag} from {dir:?} (compiling HLO)…");
    let artifact = Artifact::load(&dir, &tag)?;
    let outcome = trainer::train(&artifact, &dir, &cfg)?;
    if let Some(csv) = metrics_csv {
        outcome.log.write_csv(&csv)?;
        eprintln!("metrics written to {csv:?}");
    }
    println!(
        "{tag}: final eval accuracy {:.2}% (loss {:.4}) after {} steps",
        outcome.final_eval_acc * 100.0,
        outcome.final_eval_loss,
        cfg.steps
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let tag = args.flag("--artifact").context("--artifact <tag> required")?;
    let dir = artifacts_dir(args);
    let artifact = Artifact::load(&dir, tag)?;
    let state = match args.flag("--checkpoint") {
        Some(path) => {
            let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
            artifact.state_from_bytes(&bytes)?
        }
        None => artifact.init_state(&dir)?,
    };
    let batches = args.flag_u64("--eval-batches", 5)? as usize;
    let (loss, acc) = trainer::evaluate(&artifact, &state, batches)?;
    println!("{tag}: eval accuracy {:.2}% (loss {loss:.4})", acc * 100.0);
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let steps = args.flag_u64("--table-steps", 150)?;
    let cfg = table_train_cfg(steps);
    let tables: [(&str, Vec<_>, Option<Vec<_>>); 2] = [
        (
            "Table 1: ResNet18 x0.5, Winograd F4 (synthetic CIFAR substitute)",
            experiments::table1(),
            Some(experiments::paper_table1()),
        ),
        (
            "Table 2: width 0.25 / 0.5, 8-bit",
            experiments::table2(),
            None,
        ),
    ];
    for (title, table, paper) in tables {
        let mut rows = Vec::new();
        for (row_label, cells) in &table {
            let mut out_cells = Vec::new();
            for cell in cells {
                eprintln!("training {} ({} steps)…", cell.tag, steps);
                let acc = experiments::run_cell(&dir, cell.tag, &cfg)?;
                out_cells.push((cell.column.to_string(), acc));
            }
            rows.push((*row_label, out_cells));
        }
        print!(
            "{}",
            experiments::render_table(title, &rows, paper.as_deref())
        );
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let tags = runtime::list_artifacts(&dir)?;
    if tags.is_empty() {
        bail!("no artifacts in {dir:?} — run `make artifacts` first");
    }
    for t in tags {
        println!("{t}");
    }
    Ok(())
}

fn cmd_gen_matrices(args: &Args) -> Result<()> {
    let m = args.flag_u64("--m", 4)? as usize;
    let r = args.flag_u64("--r", 3)? as usize;
    let base_name = args.flag_or("--base", "legendre");
    let base = Base::from_name(base_name)
        .with_context(|| format!("unknown base {base_name:?}"))?;
    let plan = WinogradPlan::new(m, r);
    println!("F({m}x{m}, {r}x{r}), N = {}", plan.n);
    println!(
        "general mults/output (2-D): {:.4}  (direct: {})",
        plan.mults_per_output_2d(),
        r * r
    );
    println!("\nA (N x m):\n{:?}", plan.a);
    println!("G (N x r):\n{:?}", plan.g);
    println!("Bᵀ (N x N):\n{:?}", plan.bt);
    let bc = BaseChange::new(base, plan.n);
    println!("base = {}:\nPᵀ:\n{:?}", base.name(), bc.p.transpose());
    println!("P⁻ᵀ:\n{:?}", bc.p_inv.transpose());
    println!(
        "P non-zeros: {} (off-diagonal: {})",
        bc.p.nnz(),
        bc.nnz_offdiag()
    );
    Ok(())
}

fn cmd_error_analysis(args: &Args) -> Result<()> {
    let trials = args.flag_u64("--trials", 300)? as usize;
    let bits = args.flag_u64("--bits", 8)? as u32;
    println!("fp32 pipeline error vs f64 direct oracle ({trials} trials):");
    println!(
        "{:>7} {:>12} {:>12} {:>12}",
        "tile", "canonical", "legendre", "chebyshev"
    );
    for m in [2usize, 4, 6] {
        let mut row = format!("{:>7}", format!("F({m},3)"));
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let e = werror::measure_tile_error(m, 3, base, trials, 42);
            row += &format!(" {:>12.3e}", e.mean_rel_l2);
        }
        println!("{row}");
    }
    println!("\n{bits}-bit quantized pipeline (matrices quantized, Fig. 2 casts):");
    println!(
        "{:>7} {:>12} {:>12} {:>12}",
        "tile", "canonical", "legendre", "chebyshev"
    );
    for m in [2usize, 4, 6] {
        let mut row = format!("{:>7}", format!("F({m},3)"));
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let q = QWino::new_quantized_mats(m, 3, base, QuantConfig::uniform(bits), bits);
            row += &format!(" {:>12.4}", q.measure_error(trials, 42));
        }
        println!("{row}");
    }
    println!("\ncondition numbers κ₂ of the transforms (canonical → legendre):");
    for m in [2usize, 4, 6] {
        let c = werror::condition_numbers(m, 3, Base::Canonical);
        let l = werror::condition_numbers(m, 3, Base::Legendre);
        println!(
            "F({m},3): Bᵀ {:9.2} → {:9.2} | G {:7.2} → {:7.2} | A {:7.2} → {:7.2}",
            c.kappa_bt, l.kappa_bt, c.kappa_g, l.kappa_g, c.kappa_a, l.kappa_a
        );
    }
    Ok(())
}

fn cmd_serve_demo(_args: &Args) -> Result<()> {
    use winoq::data::synthcifar;
    use winoq::nn::{ConvMode, ResNet18, ResNetCfg};
    // Pure-rust int8 winograd inference on the synthetic eval split.
    let cfg = ResNetCfg {
        width_mult: 0.25,
        num_classes: 10,
        mode: ConvMode::Winograd {
            m: 4,
            base: Base::Legendre,
            quant: Some(QuantConfig::w8()),
        },
    };
    let mut net = ResNet18::init(cfg, 7);
    let (calib, _) = synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, 8);
    net.calibrate_quant(&calib);
    let (images, labels) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, 16);
    let t = std::time::Instant::now();
    let acc = net.accuracy(&images, &labels);
    println!(
        "int8 L-winograd ResNet18x0.25 (untrained weights): {} images in {:.1} ms, accuracy {:.1}% (chance 10%)",
        labels.len(),
        t.elapsed().as_secs_f64() * 1e3,
        acc * 100.0
    );
    println!("(train a checkpoint via `winoq train --checkpoint …`, then `winoq eval`)");
    Ok(())
}
