//! winoq CLI — the L3 leader entrypoint.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use winoq::cli::{self, Args};
use winoq::config::{Config, RunConfig};
use winoq::coordinator::experiments::{self, table_train_cfg};
use winoq::coordinator::schedule::Schedule;
use winoq::coordinator::trainer::{self, TrainCfg};
use winoq::quant::{QWino, QuantConfig};
use winoq::runtime::{self, Artifact};
use winoq::wino::basis::{Base, BaseChange};
use winoq::wino::error as werror;
use winoq::wino::toomcook::WinogradPlan;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{}", cli::help());
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has_switch("--help") {
        print!("{}", cli::help());
        return;
    }
    // Global escape hatch: pin every panel-GEMM dispatch to the scalar
    // micro-kernels before any command touches the engine (equivalent to
    // WINOQ_NO_SIMD=1; `scripts/ci.sh` runs the parity suite both ways).
    if args.has_switch("--no-simd") {
        winoq::engine::gemm::set_simd_enabled(false);
    }
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "tables" => cmd_tables(&args),
        "list" => cmd_list(&args),
        "gen-matrices" => cmd_gen_matrices(&args),
        "error-analysis" => cmd_error_analysis(&args),
        "serve" => cmd_serve(&args),
        "tune" => cmd_tune(&args),
        "bench" => cmd_bench(&args),
        "benchdiff" => cmd_benchdiff(&args),
        "serve-demo" => {
            eprintln!("serve-demo was retired; use `winoq serve --synthetic` (see `winoq help`)");
            std::process::exit(2);
        }
        "help" | "--help" | "-h" => {
            print!("{}", cli::help());
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", cli::help());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.flag("--artifacts-dir")
        .map(PathBuf::from)
        .unwrap_or_else(runtime::artifacts_dir)
}

fn cmd_train(args: &Args) -> Result<()> {
    let (tag, dir, cfg, metrics_csv) = if let Some(path) = args.flag("--config") {
        let file = Config::load(Path::new(path))?;
        let run = RunConfig::from_config(&file)?;
        (run.tag, run.artifacts_dir, run.train, run.metrics_csv)
    } else {
        let tag = args
            .flag("--artifact")
            .context("--artifact <tag> (or --config FILE) is required")?
            .to_string();
        let steps = args.flag_u64("--steps", 200)?;
        let cfg = TrainCfg {
            steps,
            schedule: Schedule::WarmupCosine {
                lr: args.flag_f32("--lr", 0.05)?,
                warmup: steps / 10,
                total: steps,
                final_frac: 0.05,
            },
            eval_every: args.flag_u64("--eval-every", 0)?,
            eval_batches: args.flag_u64("--eval-batches", 5)? as usize,
            log_every: 20,
            checkpoint: args.flag("--checkpoint").map(PathBuf::from),
            dataset_size: args.flag_u64("--dataset-size", 4096)?,
        };
        (
            tag,
            artifacts_dir(args),
            cfg,
            args.flag("--metrics-csv").map(PathBuf::from),
        )
    };
    eprintln!("loading artifact {tag} from {dir:?} (compiling HLO)…");
    let artifact = Artifact::load(&dir, &tag)?;
    let outcome = trainer::train(&artifact, &dir, &cfg)?;
    if let Some(csv) = metrics_csv {
        outcome.log.write_csv(&csv)?;
        eprintln!("metrics written to {csv:?}");
    }
    println!(
        "{tag}: final eval accuracy {:.2}% (loss {:.4}) after {} steps",
        outcome.final_eval_acc * 100.0,
        outcome.final_eval_loss,
        cfg.steps
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let tag = args.flag("--artifact").context("--artifact <tag> required")?;
    let dir = artifacts_dir(args);
    let artifact = Artifact::load(&dir, tag)?;
    let state = match args.flag("--checkpoint") {
        Some(path) => {
            let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
            artifact.state_from_bytes(&bytes)?
        }
        None => artifact.init_state(&dir)?,
    };
    let batches = args.flag_u64("--eval-batches", 5)? as usize;
    let (loss, acc) = trainer::evaluate(&artifact, &state, batches)?;
    println!("{tag}: eval accuracy {:.2}% (loss {loss:.4})", acc * 100.0);
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let steps = args.flag_u64("--table-steps", 150)?;
    let cfg = table_train_cfg(steps);
    let tables: [(&str, Vec<_>, Option<Vec<_>>); 2] = [
        (
            "Table 1: ResNet18 x0.5, Winograd F4 (synthetic CIFAR substitute)",
            experiments::table1(),
            Some(experiments::paper_table1()),
        ),
        (
            "Table 2: width 0.25 / 0.5, 8-bit",
            experiments::table2(),
            None,
        ),
    ];
    for (title, table, paper) in tables {
        let mut rows = Vec::new();
        for (row_label, cells) in &table {
            let mut out_cells = Vec::new();
            for cell in cells {
                eprintln!("training {} ({} steps)…", cell.tag, steps);
                let acc = experiments::run_cell(&dir, cell.tag, &cfg)?;
                out_cells.push((cell.column.to_string(), acc));
            }
            rows.push((*row_label, out_cells));
        }
        print!(
            "{}",
            experiments::render_table(title, &rows, paper.as_deref())
        );
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let tags = runtime::list_artifacts(&dir)?;
    if tags.is_empty() {
        bail!("no artifacts in {dir:?} — run `make artifacts` first");
    }
    for t in tags {
        println!("{t}");
    }
    Ok(())
}

fn cmd_gen_matrices(args: &Args) -> Result<()> {
    let m = args.flag_u64("--m", 4)? as usize;
    let r = args.flag_u64("--r", 3)? as usize;
    let base_name = args.flag_or("--base", "legendre");
    let base = Base::from_name(base_name)
        .with_context(|| format!("unknown base {base_name:?} (valid: {})", Base::names()))?;
    let plan = WinogradPlan::new(m, r);
    println!("F({m}x{m}, {r}x{r}), N = {}", plan.n);
    println!(
        "general mults/output (2-D): {:.4}  (direct: {})",
        plan.mults_per_output_2d(),
        r * r
    );
    println!("\nA (N x m):\n{:?}", plan.a);
    println!("G (N x r):\n{:?}", plan.g);
    println!("Bᵀ (N x N):\n{:?}", plan.bt);
    let bc = BaseChange::new(base, plan.n);
    println!("base = {}:\nPᵀ:\n{:?}", base.name(), bc.p.transpose());
    println!("P⁻ᵀ:\n{:?}", bc.p_inv.transpose());
    println!(
        "P non-zeros: {} (off-diagonal: {})",
        bc.p.nnz(),
        bc.nnz_offdiag()
    );
    Ok(())
}

fn cmd_error_analysis(args: &Args) -> Result<()> {
    let trials = args.flag_u64("--trials", 300)? as usize;
    let bits = args.flag_u64("--bits", 8)? as u32;
    println!("fp32 pipeline error vs f64 direct oracle ({trials} trials):");
    println!(
        "{:>7} {:>12} {:>12} {:>12}",
        "tile", "canonical", "legendre", "chebyshev"
    );
    for m in [2usize, 4, 6] {
        let mut row = format!("{:>7}", format!("F({m},3)"));
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let e = werror::measure_tile_error(m, 3, base, trials, 42);
            row += &format!(" {:>12.3e}", e.mean_rel_l2);
        }
        println!("{row}");
    }
    println!("\n{bits}-bit quantized pipeline (matrices quantized, Fig. 2 casts):");
    println!(
        "{:>7} {:>12} {:>12} {:>12}",
        "tile", "canonical", "legendre", "chebyshev"
    );
    for m in [2usize, 4, 6] {
        let mut row = format!("{:>7}", format!("F({m},3)"));
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let q = QWino::new_quantized_mats(m, 3, base, QuantConfig::uniform(bits), bits);
            row += &format!(" {:>12.4}", q.measure_error(trials, 42));
        }
        println!("{row}");
    }
    println!("\ncondition numbers κ₂ of the transforms (canonical → legendre):");
    for m in [2usize, 4, 6] {
        let c = werror::condition_numbers(m, 3, Base::Canonical);
        let l = werror::condition_numbers(m, 3, Base::Legendre);
        println!(
            "F({m},3): Bᵀ {:9.2} → {:9.2} | G {:7.2} → {:7.2} | A {:7.2} → {:7.2}",
            c.kappa_bt, l.kappa_bt, c.kappa_g, l.kappa_g, c.kappa_a, l.kappa_a
        );
    }
    Ok(())
}

/// Parse the shared `--chaos-*` flag family into a fault schedule.
/// Returns `None` when no fault kind is scheduled (chaos off), so both
/// serve paths stay byte-identical to their pre-chaos behaviour unless
/// a rule is explicitly armed.
fn chaos_from_args(args: &Args) -> Result<Option<winoq::testkit::chaos::ChaosConfig>> {
    use winoq::testkit::chaos::ChaosConfig;
    let d = ChaosConfig::default();
    let cfg = ChaosConfig {
        seed: args.flag_u64("--chaos-seed", d.seed)?,
        panic_every: args.flag_u64("--chaos-panic-every", 0)?,
        corrupt_every: args.flag_u64("--chaos-corrupt-every", 0)?,
        corrupt_scale: args.flag_f64("--chaos-corrupt-scale", d.corrupt_scale)?,
        latency_every: args.flag_u64("--chaos-latency-every", 0)?,
        latency_us: args.flag_u64("--chaos-latency-us", d.latency_us)?,
        burst_every: args.flag_u64("--chaos-burst-every", 0)?,
        burst_len: args.flag_u64("--chaos-burst-len", d.burst_len)?,
        ..d
    };
    Ok(cfg.is_enabled().then_some(cfg))
}

/// `winoq serve`: the micro-batching inference server with the built-in
/// synthetic closed-loop client (the only frontend in this vendored
/// build — there is no socket listener; embedders drive
/// `serve::ServeQueue` directly).
fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::Arc;
    use winoq::data::synthcifar;
    use winoq::nn::{ConvMode, ResNet18, ResNetCfg, Tensor};
    use winoq::obs::drift::{DriftConfig, DriftMonitor};
    use winoq::obs::{MetricsRegistry, TraceSink, Tracer};
    use winoq::serve::{
        run_closed_loop, run_closed_loop_resilient, BatchModel, FallbackConfig,
        FallbackController, ModelRegistry, Resilience, ServeConfig, ServeStats,
    };
    use winoq::testkit::chaos::FaultPlan;

    if args.has_switch("--soak") {
        return cmd_serve_soak(args);
    }
    if !args.has_switch("--synthetic") {
        bail!(
            "no network frontend exists in this vendored build; run the built-in \
             closed-loop client with `winoq serve --synthetic`, or the deterministic \
             soak simulation with `winoq serve --soak` (see `winoq help`)"
        );
    }
    let requests = args.flag_u64("--requests", 256)? as usize;
    let concurrency = args.flag_u64("--concurrency", 16)? as usize;
    // Zero is never meaningful for these; clamp instead of panicking in
    // the queue's capacity assert.
    let serve_cfg = ServeConfig {
        max_batch: (args.flag_u64("--max-batch", 8)? as usize).max(1),
        batch_window_us: args.flag_u64("--batch-window-us", 2000)?,
        queue_cap: (args.flag_u64("--queue-cap", 256)? as usize).max(1),
        workers: (args.flag_u64("--workers", 1)? as usize).max(1),
        cost: None,
    };
    let m = args.flag_u64("--m", 4)? as usize;
    let base_name = args.flag_or("--base", "legendre");
    let base = Base::from_name(base_name)
        .with_context(|| format!("unknown base {base_name:?} (valid: {})", Base::names()))?;
    let quant = match args.flag_or("--quant", "w8") {
        "none" => None,
        q => Some(
            QuantConfig::from_name(q)
                .with_context(|| format!("unknown quant config {q:?} (w8|w8_h9|uN|none)"))?,
        ),
    };
    let mode = ConvMode::Winograd { m, base, quant };
    let name = args.flag_or("--model", "resnet18-synthetic");

    let mut registry = ModelRegistry::new();
    let mut loaded_plan: Option<winoq::tune::NetPlan> = None;
    let served = if let Some(plan_path) = args.flag("--plan") {
        // The NetPlan pins the whole operating point (width, per-layer
        // m/base/bits, calibration); a conflicting flag would be silently
        // ignored — reject it instead.
        let pinned_by_plan =
            ["--artifact", "--checkpoint", "--quant", "--m", "--base", "--width-mult"];
        for conflicting in pinned_by_plan {
            if args.flag(conflicting).is_some() {
                bail!(
                    "{conflicting} conflicts with --plan: the NetPlan already pins the \
                     model and its per-layer operating points"
                );
            }
        }
        let plan = winoq::tune::NetPlan::load(Path::new(plan_path))?;
        eprintln!(
            "loaded NetPlan v{} from {plan_path}: {} tuned layer(s), width x{:.2}",
            plan.version,
            plan.layers.len(),
            plan.width_mult
        );
        let served = registry.register_netplan(name, &plan)?;
        loaded_plan = Some(plan);
        served
    } else if let Some(tag) = args.flag("--artifact") {
        registry.register_checkpoint(
            name,
            &artifacts_dir(args),
            tag,
            args.flag("--checkpoint").map(Path::new),
            mode,
            8,
        )?
    } else {
        let cfg = ResNetCfg {
            width_mult: args.flag_f32("--width-mult", 0.5)?,
            num_classes: 10,
            mode,
        };
        registry.register_synthetic(name, cfg, 32, 7, 8)?
    };
    // Heterogeneous NetPlan models report their nominal (modal) mode.
    let mode_str = if args.flag("--plan").is_some() {
        format!("netplan, nominal {}", mode_label(&served.net.cfg.mode))
    } else {
        mode_label(&mode)
    };
    let (plan_counters, bank_counters) = registry.plans().counters();
    let int_counters = registry.plans().int_counters();
    let packed_counters = registry.plans().packed_counters();
    eprintln!(
        "model {name:?}: width x{:.2}, {} | {} wino tiles/request | plan cache: {} plans \
         ({} hits / {} misses), {} weight banks ({} hits / {} misses), \
         {} int code banks ({} hits / {} misses), {} packed banks \
         ({} hits / {} packs)",
        served.net.cfg.width_mult,
        mode_str,
        served.tiles_per_item(),
        registry.plans().plan_count(),
        plan_counters.hits,
        plan_counters.misses,
        registry.plans().bank_count(),
        bank_counters.hits,
        bank_counters.misses,
        registry.plans().int_bank_count(),
        int_counters.hits,
        int_counters.misses,
        registry.plans().packed_bank_count(),
        packed_counters.hits,
        packed_counters.misses,
    );

    // Request pool: distinct synthetic images, round-robined by clients.
    let pool_n = concurrency.clamp(8, 64);
    let (batch, _) = synthcifar::generate_batch(synthcifar::TEST_SEED, 0, pool_n);
    let item = 3 * 32 * 32;
    let mut inputs: Vec<Tensor> = (0..pool_n)
        .map(|i| {
            Tensor::from_vec(&[3, 32, 32], batch.data[i * item..(i + 1) * item].to_vec())
        })
        .collect();

    // Shadow-oracle drift monitoring: budgets come from the NetPlan's
    // tuned anchors (v2), or — for synthetic/artifact models with no
    // plan — from a one-shot calibration probe over an in-distribution
    // pool input. The probe runs BEFORE any --input-scale distortion so
    // scaled traffic is judged against the calibrated operating point.
    let drift = if args.flag("--drift-json").is_some() {
        let dcfg = DriftConfig {
            stride: args.flag_u64("--drift-stride", 16)?,
            ..DriftConfig::default()
        };
        let dm = match &loaded_plan {
            Some(plan) => {
                let dm = DriftMonitor::from_netplan(dcfg, plan);
                if dm.report_only() {
                    eprintln!(
                        "drift: NetPlan carries no tuned error anchors (v1 artifact?); \
                         monitoring degrades to report-only"
                    );
                }
                dm
            }
            None => {
                // Budget anchor per layer = max rel-L2 over a few pool
                // probes, so same-distribution traffic sits well under
                // anchor × headroom while OOD traffic still clears it.
                let mut dm = DriftMonitor::new(dcfg);
                let mut anchors: std::collections::BTreeMap<String, f64> =
                    std::collections::BTreeMap::new();
                for input in inputs.iter().take(4) {
                    for s in served.drift_probe(input) {
                        let a = anchors.entry(s.layer).or_insert(0.0);
                        *a = a.max(s.rel_err);
                    }
                }
                for (layer, err) in &anchors {
                    dm.set_budget(layer, Some(*err));
                }
                eprintln!(
                    "drift: self-calibrated {} layer budget(s) from pool probes",
                    anchors.len()
                );
                dm
            }
        };
        Some(dm)
    } else {
        None
    };

    // Out-of-distribution knob: scale every pooled input. With quantized
    // layers this drives activations past their calibrated ranges and the
    // shadow oracle's rel-L2 through the tuned budget.
    let input_scale = args.flag_f64("--input-scale", 1.0)?;
    if input_scale != 1.0 {
        for t in &mut inputs {
            for v in &mut t.data {
                *v *= input_scale as f32;
            }
        }
        eprintln!("input pool scaled by {input_scale} (out-of-distribution exercise)");
    }

    eprintln!(
        "closed loop: {requests} requests, {concurrency} clients | max_batch {}, \
         window {} µs, queue cap {}, {} worker(s)",
        serve_cfg.max_batch, serve_cfg.batch_window_us, serve_cfg.queue_cap, serve_cfg.workers
    );
    let tracer = args.flag("--trace-json").map(|_| Arc::new(Tracer::default()));
    let stats = ServeStats::new();

    // Resilience posture: supervised workers with the default bounded
    // restart budget, an optional seeded fault plan (`--chaos-*`), and —
    // whenever the drift monitor runs — the per-layer circuit breaker
    // that walks degraded layers down the int → float → direct ladder.
    let chaos_plan = chaos_from_args(args)?.map(|c| {
        eprintln!(
            "chaos armed: seed {} | panic/{} corrupt/{} (x{}) latency/{} ({} µs) burst/{} ({})",
            c.seed,
            c.panic_every,
            c.corrupt_every,
            c.corrupt_scale,
            c.latency_every,
            c.latency_us,
            c.burst_every,
            c.burst_len
        );
        Arc::new(FaultPlan::new(c))
    });
    let fallback = drift.as_ref().map(|_| {
        let fcfg = FallbackConfig {
            alerts_to_degrade: args.flag_u64("--fallback-alerts", 2)?.max(1) as u32,
            quiet_to_restore: args.flag_u64("--fallback-quiet", 16)?.max(1) as u32,
        };
        Ok::<_, anyhow::Error>(Arc::new(FallbackController::new(fcfg)))
    });
    let fallback = match fallback {
        Some(f) => Some(f?),
        None => None,
    };
    let res = Resilience { chaos: chaos_plan, fallback: fallback.clone(), ..Resilience::default() };

    let report = run_closed_loop_resilient(
        served.as_ref(),
        &serve_cfg,
        &stats,
        &inputs,
        requests,
        concurrency,
        tracer.clone(),
        drift.as_ref(),
        &res,
    );
    println!("{}", report.summary_line());
    if let Some(fb) = &fallback {
        if fb.degraded() > 0 {
            eprintln!(
                "fallback: {} layer(s) still degraded at shutdown (serving off the \
                 float/direct ladder)",
                fb.degraded()
            );
        }
    }
    // Failed requests were *answered* (typed error, exact accounting) —
    // they only fail the run when chaos wasn't deliberately armed.
    let answered = report.completed + report.failed;
    if report.failed > 0 && res.chaos.is_none() {
        bail!("{} request(s) failed without injected faults", report.failed);
    }
    if answered as usize != requests {
        bail!("served {answered} of {requests} requests ({} failed)", report.failed);
    }

    // Drift report: the windowed per-layer rel-L2 series, budgets, and
    // alert counts — written unconditionally so CI can assert both the
    // calibrated (zero alerts) and OOD (≥1 alert) directions.
    if let Some(path) = args.flag("--drift-json") {
        let dm = drift.as_ref().expect("monitor exists when --drift-json is set");
        println!(
            "drift: {} span(s) shadow-sampled, {} alert(s){}",
            dm.sampled(),
            dm.alerts(),
            if dm.report_only() { " [report-only]" } else { "" }
        );
        std::fs::write(path, dm.to_json() + "\n")
            .with_context(|| format!("writing {path}"))?;
        eprintln!("drift report written to {path}");
    }

    // Request tracing: drain every span's lifecycle as JSON lines, after
    // checking the accounting invariant (every submitted span ended in
    // exactly one of complete/reject/shed/failed).
    if let Some(path) = args.flag("--trace-json") {
        let tracer = tracer.as_ref().expect("tracer exists when --trace-json is set");
        let acc = tracer.accounting();
        if !acc.exact {
            bail!(
                "trace accounting does not reconcile: {} submitted vs {} + {} + {} + {}",
                acc.submitted,
                acc.completed,
                acc.rejected,
                acc.shed,
                acc.failed
            );
        }
        if tracer.dropped() > 0 {
            eprintln!(
                "warning: {} trace events dropped at capacity ({} terminal — \
                 accounting reconciled against the drop counter)",
                tracer.dropped(),
                tracer.dropped_terminal()
            );
        }
        std::fs::write(path, tracer.to_json_lines())
            .with_context(|| format!("writing {path}"))?;
        eprintln!(
            "trace JSON lines written to {path} ({} spans: {} completed, {} rejected, \
             {} shed, {} failed)",
            acc.submitted, acc.completed, acc.rejected, acc.shed, acc.failed
        );
    }

    // Metrics registry: one snapshot of the whole stack — request
    // outcomes and latency histogram, engine stage totals, plan-cache
    // counters, and per-layer numeric-health saturation counters.
    if let Some(path) = args.flag("--metrics-json") {
        let reg = MetricsRegistry::new();
        stats.export_metrics(&reg);
        registry.plans().export_metrics(&reg);
        winoq::engine::pool::export_metrics(&reg);
        if let Some(dm) = &drift {
            dm.export_metrics(&reg);
        }
        for (prefix, _cin, _cout) in ResNet18::wino_eligible_units(&served.net.cfg) {
            let Some(engine) = served.net.wino_layer(&prefix).and_then(|la| la.int_engine())
            else {
                continue;
            };
            let h = engine.health();
            for (stage, n) in [
                ("input_sat", h.input_sat),
                ("input_t_sat", h.input_t_sat),
                ("hadamard_sat", h.hadamard_sat),
                ("output_sat", h.output_sat),
            ] {
                reg.inc(&format!("health.{prefix}.{stage}"), n);
            }
        }
        std::fs::write(path, reg.snapshot_json_lines())
            .with_context(|| format!("writing {path}"))?;
        eprintln!("metrics snapshot written to {path} ({} metrics)", reg.len());
    }

    if let Some(path) = args.flag("--stats-json") {
        // Re-read the counters at dump time: the plan cache is only
        // touched at registration, but a future in-session registration
        // flow should not silently report stale telemetry.
        let (pc, bc) = registry.plans().counters();
        let ic = registry.plans().int_counters();
        let pk = registry.plans().packed_counters();
        std::fs::write(path, report.to_json_with_plan_cache(pc, bc, ic, pk) + "\n")
            .with_context(|| format!("writing {path}"))?;
        eprintln!("stats JSON written to {path}");
    }

    // Bench mode: rerun the identical closed loop forced to max_batch 1
    // and report the micro-batching payoff (acceptance bar: ≥ 2× tiles/s).
    if let Some(path) = args.flag("--bench-json") {
        eprintln!("baseline run (max_batch 1)…");
        let base_cfg = ServeConfig { max_batch: 1, ..serve_cfg };
        let baseline = run_closed_loop(served.as_ref(), &base_cfg, &inputs, requests, concurrency);
        println!("batch=1  {}", baseline.summary_line());
        let ratio = if baseline.tiles_per_sec > 0.0 {
            report.tiles_per_sec / baseline.tiles_per_sec
        } else {
            0.0
        };
        println!(
            "micro-batching payoff: {ratio:.2}x tiles/s at max_batch {} vs 1 {}",
            serve_cfg.max_batch,
            if ratio >= 2.0 { "(PASS ≥2x)" } else { "(below 2x bar)" }
        );
        let json = format!(
            concat!(
                "{{\"bench\": \"serve\", \"model\": \"{}\", \"mode\": \"{}\", ",
                "\"requests\": {}, \"concurrency\": {}, \"max_batch\": {}, ",
                "\"batch_window_us\": {}, \"workers\": {}, ",
                "\"tiles_per_sec_ratio_vs_batch1\": {:.3}, ",
                "\"run\": {}, \"baseline_batch1\": {}}}"
            ),
            json_escape(name),
            json_escape(&mode_str),
            requests,
            concurrency,
            serve_cfg.max_batch,
            serve_cfg.batch_window_us,
            serve_cfg.workers,
            ratio,
            report.to_json(),
            baseline.to_json(),
        );
        std::fs::write(path, json + "\n").with_context(|| format!("writing {path}"))?;
        eprintln!("bench JSON written to {path}");
    }

    // Integer-engine bench: time the true-integer path against the
    // dequantize-to-float path on a representative quantized layer at
    // the served operating point (m/base/quant), and emit BENCH_int.json
    // (the same emitter `cargo bench --bench conv_throughput` runs on
    // the bigger acceptance shape).
    if let Some(path) = args.flag("--int-bench-json") {
        use winoq::nn::layers::Conv2dCfg;
        use winoq::nn::winolayer::WinoConv2d;
        use winoq::testkit::prng_tensor;
        let Some(q) = quant else {
            bail!("--int-bench-json requires a quantized mode (--quant w8|w8_h9|uN)");
        };
        let c = 32;
        let x = prng_tensor(0xB1, &[4, c, 32, 32], 1.0);
        let w = prng_tensor(0xB2, &[c, c, 3, 3], 0.25);
        let mut layer = WinoConv2d::new(m, &w, base);
        layer.quantize(q, &x, 1);
        if layer.int_engine().is_none() {
            bail!(
                "--int-bench-json: quant config {} exceeds the i16 code range, \
                 no integer engine to bench",
                q.label()
            );
        }
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let (json, ratio) =
            winoq::engine::int::int_vs_float_bench_json(&layer, &x, conv, 1, 3);
        println!(
            "int engine vs dequantize-to-float (C=K={c}, 32x32, batch 4, {}): \
             {ratio:.2}x tiles/s {}",
            q.label(),
            if ratio >= 2.0 { "(PASS ≥2x)" } else { "(below 2x bar)" }
        );
        std::fs::write(path, json + "\n").with_context(|| format!("writing {path}"))?;
        eprintln!("int bench JSON written to {path}");
    }
    Ok(())
}

/// `winoq bench`: in-binary micro-benchmarks that CI can run without a
/// `cargo bench` recompile. Two suites: the register-tiled panel GEMM vs
/// its naive oracles (float and integer) at a ResNet18-shaped layer,
/// written as `BENCH_gemm.json` — the same emitter `cargo bench --bench
/// conv_throughput` runs
/// ([`gemm_bench_json`](winoq::engine::gemm::gemm_bench_json)), which
/// also asserts tiled/naive bit-parity on the measured buffers — and the
/// numeric-health saturation report (`--health-json`).
fn cmd_bench(args: &Args) -> Result<()> {
    // Numeric-health suite: run the integer engine over calibration-range
    // and adversarial (2× calibration) inputs at representative operating
    // points, and report the saturation/clip counters per
    // (layer, base, m, quant) — the telemetry `scripts/ci.sh` gates on
    // (the w8_h9 profile must show Hadamard-stage saturation).
    if let Some(path) = args.flag("--health-json") {
        let json = winoq::engine::int::numeric_health_json();
        std::fs::write(path, json + "\n").with_context(|| format!("writing {path}"))?;
        eprintln!("numeric-health JSON written to {path}");
    }
    let Some(path) = args.flag("--gemm-json") else {
        if args.flag("--health-json").is_some() {
            return Ok(());
        }
        bail!(
            "nothing to bench: pass --gemm-json <path> and/or --health-json <path> \
             (see `winoq help`)"
        );
    };
    let m = args.flag_u64("--m", 4)? as usize;
    if !(1..=8).contains(&m) {
        bail!("--m {m} is outside the supported tile range 1..=8");
    }
    // ResNet18 acceptance shape: C = K = 64, 32×32 images, batch 8 →
    // T = 8 · ⌈32/m⌉² tiles per pass, N² = (m + 2)² frequencies.
    let (c, k, hw, batch) = (64, 64, 32usize, 8);
    let t_total = batch * hw.div_ceil(m) * hw.div_ceil(m);
    let nn = (m + 2) * (m + 2);
    eprintln!(
        "panel GEMM bench: C={c} K={k} T={t_total} N²={nn} (m={m}), tiled vs naive \
         [kernels: float={} int={}]…",
        winoq::engine::gemm::Kernel::detect_f64().name(),
        winoq::engine::gemm::Kernel::detect_i16().name(),
    );
    let (json, float_ratio, int_ratio) =
        winoq::engine::gemm::gemm_bench_json(c, k, t_total, nn, 1, 5);
    println!(
        "float: {float_ratio:.2}x tiles/s tiled vs naive {}",
        if float_ratio >= 1.5 { "(PASS ≥1.5x)" } else { "(below 1.5x bar)" }
    );
    println!(
        "int:   {int_ratio:.2}x tiles/s tiled vs naive {}",
        if int_ratio >= 1.5 { "(PASS ≥1.5x)" } else { "(below 1.5x bar)" }
    );
    std::fs::write(path, json + "\n").with_context(|| format!("writing {path}"))?;
    eprintln!("gemm bench JSON written to {path}");
    Ok(())
}

/// `winoq benchdiff`: compare the current run's `BENCH_*.json` artifacts
/// against a committed baseline directory and exit nonzero on any gated
/// regression (throughput down >10%, or any error metric up at all).
/// This is the CI gate `scripts/ci.sh` runs against `bench/baselines/`.
fn cmd_benchdiff(args: &Args) -> Result<()> {
    use winoq::benchkit::diff::diff_dirs;

    let baseline = args.flag_or("--baseline", "bench/baselines");
    let current = args.flag_or("--current", ".");
    let report = diff_dirs(Path::new(baseline), Path::new(current))?;
    print!("{}", report.summary());
    if let Some(path) = args.flag("--out") {
        std::fs::write(path, report.to_json() + "\n")
            .with_context(|| format!("writing {path}"))?;
        eprintln!("benchdiff JSON written to {path}");
    }
    if !report.ok() {
        bail!(
            "benchdiff: {} regression(s) in {} gated metric(s) vs {baseline}",
            report.failures(),
            report.compared()
        );
    }
    println!("benchdiff: {} gated metric(s) within thresholds vs {baseline}", report.compared());
    Ok(())
}

/// `winoq serve --soak`: the deterministic multi-model stress/soak
/// simulation — seeded arrivals over N weighted model shards, per-request
/// deadlines and priorities, shed/miss accounting, all on a virtual
/// clock (milliseconds of real time for millions of virtual µs). Writes
/// the `BENCH_serve_soak.json` report `scripts/ci.sh` validates.
fn cmd_serve_soak(args: &Args) -> Result<()> {
    use winoq::engine::layout::tile_count_for;
    use winoq::obs::TraceSink;
    use winoq::testkit::soak::{run_soak, run_soak_traced, SoakConfig, SoakModel};
    use winoq::tune::cost::TileCostModel;

    let requests = (args.flag_u64("--requests", 256)? as usize).max(1);
    let models = (args.flag_u64("--models", 2)? as usize).clamp(1, 16);
    let deadline_us = args.flag_u64("--deadline-us", 20_000)?.max(1);
    let seed = args.flag_u64("--seed", 0x50AB)?;
    // Mixed request geometries, including non-square and transposed
    // shapes; tile weights come from the real F(4,3) grid over a
    // 14-layer stride-1 stack (the ResNet18 wino-layer count).
    let shapes: Vec<(usize, usize, u64)> = [(32, 32), (24, 48), (48, 24), (16, 16)]
        .iter()
        .map(|&(h, w)| (h, w, (tile_count_for(&[1, 3, h, w], 1, 4, 3) * 14) as u64))
        .collect();
    let workers = (args.flag_u64("--workers", 2)? as usize).max(1);
    let tenants: Vec<SoakModel> = (0..models)
        .map(|i| SoakModel {
            name: format!("model-{i}"),
            weight: i as u64 + 1,
            workers,
            cost: TileCostModel::new(40.0 + 15.0 * i as f64, 0.02 + 0.01 * i as f64),
        })
        .collect();
    let cfg = SoakConfig {
        seed,
        requests,
        budget: (args.flag_u64("--queue-cap", 64)? as usize).max(1),
        max_batch: (args.flag_u64("--max-batch", 8)? as usize).max(1),
        window_us: args.flag_u64("--batch-window-us", 1000)?,
        mean_gap_us: 30,
        deadline_us,
        tight_pct: 5,
        no_deadline_pct: 15,
        shapes,
        models: tenants,
        service_jitter_div: 16,
        drift_stride: args.flag_u64("--drift-stride", 0)?,
        drift_err_scale: args.flag_f64("--drift-scale", 1.0)?,
        chaos: chaos_from_args(args)?,
    };
    if let Some(c) = &cfg.chaos {
        eprintln!(
            "chaos armed: seed {} | panic/{} corrupt/{} (x{}) latency/{} ({} µs) \
             burst/{} ({}) | restart budget {}",
            c.seed,
            c.panic_every,
            c.corrupt_every,
            c.corrupt_scale,
            c.latency_every,
            c.latency_us,
            c.burst_every,
            c.burst_len,
            c.restart_budget
        );
    }
    let trace_path = args.flag("--trace-json");
    let (report, trace) = if trace_path.is_some() {
        let (r, t) = run_soak_traced(&cfg);
        (r, Some(t))
    } else {
        (run_soak(&cfg), None)
    };
    println!("{}", report.summary_line());
    for m in &report.per_model {
        println!(
            "  {}: {} ok / {} rejected / {} shed / {} failed, p99 {:.0} µs, {:.0} req/s",
            m.name, m.completed, m.rejected, m.shed, m.failed, m.p99_us, m.requests_per_sec
        );
    }
    if let Some(d) = &report.drift {
        println!("  drift: {} span(s) shadow-sampled, {} alert(s)", d.sampled, d.alerts);
    }
    if !report.accounting_exact() {
        bail!(
            "soak accounting does not reconcile: {} submitted vs {} + {} + {} + {}",
            report.submitted,
            report.completed,
            report.rejected,
            report.shed,
            report.failed
        );
    }
    let path = args.flag_or("--soak-json", "BENCH_serve_soak.json");
    std::fs::write(path, report.to_json()).with_context(|| format!("writing {path}"))?;
    eprintln!("soak report written to {path}");
    if let (Some(tp), Some(trace)) = (trace_path, trace) {
        let acc = trace.accounting();
        if !acc.exact
            || acc.submitted != report.submitted
            || acc.completed != report.completed
            || acc.rejected != report.rejected
            || acc.shed != report.shed
            || acc.failed != report.failed
        {
            bail!(
                "soak trace accounting does not reconcile with the report: \
                 trace {acc:?} vs report {}/{}/{}/{}/{}",
                report.submitted,
                report.completed,
                report.rejected,
                report.shed,
                report.failed
            );
        }
        std::fs::write(tp, trace.to_json_lines()).with_context(|| format!("writing {tp}"))?;
        eprintln!(
            "soak trace JSON lines written to {tp} ({} spans, {} events)",
            acc.submitted,
            trace.len()
        );
    }
    Ok(())
}

/// `winoq tune`: per-layer base/tile/bit-width autotuning over a
/// synthetic ResNet18, emitting a deployable NetPlan JSON artifact (for
/// `winoq serve --plan`) and the `BENCH_tune.json` report.
fn cmd_tune(args: &Args) -> Result<()> {
    use winoq::tune::{self, grid, Objective, TuneConfig};

    if !args.has_switch("--synthetic") {
        bail!(
            "only the synthetic model source exists offline; run \
             `winoq tune --synthetic` (see `winoq help`)"
        );
    }
    let objective_name = args.flag_or("--objective", "balanced");
    let objective = Objective::from_name(objective_name).with_context(|| {
        format!(
            "unknown objective {objective_name:?} (valid: {})",
            Objective::names()
        )
    })?;
    let grid_name = args.flag_or("--grid", "full");
    let grid = grid::grid_from_name(grid_name)
        .with_context(|| format!("unknown grid {grid_name:?} (valid: {})", grid::grid_names()))?;
    let max_err = match args.flag("--max-err") {
        None => None,
        Some(_) => Some(args.flag_f64("--max-err", 0.0)?),
    };
    let cfg = TuneConfig {
        width_mult: args.flag_f32("--width-mult", 0.25)?,
        calib_batch: args.flag_u64("--calib-batch", 4)? as usize,
        calib_pct: args.flag_f64("--calib-pct", 100.0)?,
        max_err,
        objective,
        grid,
        max_layers: args.flag_u64("--layers", 0)? as usize,
        verbose: args.has_switch("--verbose"),
        ..TuneConfig::default()
    };
    eprintln!(
        "tuning resnet18-synthetic x{:.2}: {} candidates/layer, objective {}, \
         calib pct {} over batch {}…",
        cfg.width_mult,
        cfg.grid.len(),
        cfg.objective.name(),
        cfg.calib_pct,
        cfg.calib_batch
    );
    let outcome = tune::tune_synthetic(&cfg)?;

    println!(
        "{:<12} {:>4} {:>4} {:>4}  {:<24} {:>11} {:>11} {:>8}",
        "layer", "C", "K", "HW", "winner", "err", "base err", "speed"
    );
    for lr in &outcome.layers {
        let w = lr.winner_result();
        let b = lr.baseline_result();
        let speed = if b.measure.outputs_per_sec > 0.0 {
            w.measure.outputs_per_sec / b.measure.outputs_per_sec
        } else {
            0.0
        };
        println!(
            "{:<12} {:>4} {:>4} {:>4}  {:<24} {:>11.3e} {:>11.3e} {:>7.2}x",
            lr.prefix,
            lr.c,
            lr.k,
            lr.hw,
            w.cand.label(),
            w.measure.err,
            b.measure.err,
            speed,
        );
    }
    println!(
        "tuned vs uniform (end to end, {} layers changed): logit err {:.3e} vs {:.3e}, \
         {:.1} vs {:.1} uniform-equivalent tiles/s ({:.2}x)",
        outcome.changed_layers,
        outcome.tuned.logit_rel_l2,
        outcome.uniform.logit_rel_l2,
        outcome.tuned.eq_tiles_per_sec,
        outcome.uniform.eq_tiles_per_sec,
        if outcome.uniform.eq_tiles_per_sec > 0.0 {
            outcome.tuned.eq_tiles_per_sec / outcome.uniform.eq_tiles_per_sec
        } else {
            0.0
        },
    );

    let plan_path = args.flag_or("--plan-out", "netplan.json");
    outcome.plan.save(Path::new(plan_path))?;
    eprintln!(
        "NetPlan written to {plan_path} (serve it: `winoq serve --synthetic --plan {plan_path}`)"
    );
    let bench_path = args.flag_or("--out", "BENCH_tune.json");
    std::fs::write(bench_path, tune::bench_json(&cfg, &outcome))
        .with_context(|| format!("writing {bench_path}"))?;
    eprintln!("bench JSON written to {bench_path}");
    Ok(())
}

// Minimal JSON string escaping for interpolated values (the rest of the
// emitted JSON is static keys and numbers) — the tune subsystem's
// reader/escaper, aliased so serve's writer and tune's reader cannot drift.
use winoq::tune::json::escape as json_escape;

fn mode_label(mode: &winoq::nn::ConvMode) -> String {
    match *mode {
        winoq::nn::ConvMode::Direct => "direct".to_string(),
        winoq::nn::ConvMode::Winograd { m, base, quant } => format!(
            "F({m},3) {} {}",
            base.name(),
            quant.map_or("float".to_string(), |q| q.label())
        ),
    }
}
