//! Register-tiled, cache-blocked panel GEMM — the shared micro-kernel
//! layer under both execution engines' Hadamard stage.
//!
//! Every forward pass ([`WinoEngine`](super::WinoEngine) and
//! [`IntWinoEngine`](super::int::IntWinoEngine) alike) bottoms out in the
//! per-frequency `[K,C] × [C,T]` panel multiplies. The original stage-2
//! loops walked unpacked panels one scalar row at a time, re-reading each
//! input row `K` times from cache and re-writing each output row `C`
//! times; the integer path additionally paid a `Quantizer::quantize` call
//! (with its per-element `qmax` range assert) for every output code. This
//! module restructures that work the BLIS way:
//!
//! * **`MR`×`NR` register tiles** — the micro-kernels keep an
//!   `MR × NR` block of accumulators in registers across the whole
//!   channel reduction, so each output element is written exactly once
//!   and each packed operand element is loaded once per `MR`/`NR` reuse.
//! * **Packed operand panels** — weights are repacked **once at lowering
//!   time** into `[N²][⌈K/MR⌉][C][MR]` ([`Packed`]), so the micro-kernel
//!   reads them unit-stride; input panels are streamed through a
//!   `C`×[`NC`]-blocked packing buffer ([`pack_x_block`], layout
//!   `[⌈NC/NR⌉][C][NR]`) owned by the caller's
//!   [`EngineScratch`](super::scratch::EngineScratch).
//! * **Fused requantize epilogue** (integer path) — the `prod_scale`
//!   multiply, the divide-by-step, the round and the clamp are hoisted
//!   out of `Quantizer::quantize` into
//!   [`Requant`](crate::quant::scheme::Requant), applied per register
//!   tile: no per-element function call, no per-element range assert.
//! * **Two-dimensional parallelism** — work splits over
//!   `(frequency × T-blocks)` instead of frequency only
//!   ([`parallel::par_for_states`] on the persistent
//!   [`pool`](super::pool)), so a small-`N²` layer with a wide tile
//!   axis no longer leaves workers idle. [`grid_items`] is the one
//!   definition of that split; [`workers_for`] clamps the thread count
//!   to it so packing-buffer leases can never under-split the grid.
//! * **Explicit SIMD inner kernels behind runtime detection**
//!   ([`Kernel`]) — the register-tile reduction has `std::arch`
//!   implementations for AVX2 (`_mm256_madd_epi16` channel-pair lanes
//!   for i16, `mul`+`add` f64 lanes) and NEON (`vmull_s16` widening
//!   lanes, `f64x2` lanes), selected per dispatch by
//!   `is_x86_feature_detected!` / `is_aarch64_feature_detected!` with
//!   the scalar kernels as the always-available fallback, and a kill
//!   switch (`--no-simd` / `WINOQ_NO_SIMD`) that forces scalar.
//!
//! **Bit-parity is a hard constraint**, not a tolerance: the float tiled
//! path must equal [`panel_mul_f64_naive`] bit-for-bit and the integer
//! tiled path must equal
//! [`panel_mul_requant_i16_naive`](super::int::panel_mul_requant_i16_naive)
//! exactly (`rust/tests/gemm_property.rs` pins both over randomized
//! ragged shapes). That constraint shapes the SIMD policy too — see
//! [`Kernel`] for the float-parity rules (un-reassociated `mul`+`add`
//! lanes are bit-exact and serve-path eligible; FMA lanes are not and
//! carry a documented tolerance). Two further design decisions follow:
//!
//! * **No channel (KC) blocking in the float kernel.** Splitting the
//!   channel reduction into partial sums would reassociate the f64
//!   accumulation chain (`((0 + p₀) + p₁) + …` per `(k, t)`) and change
//!   low bits. The micro-kernel therefore runs the **full** `C` reduction
//!   per register tile — i.e. `KC = C`. The hosted layer shapes keep
//!   `C ≤ 512`, so one `[C][MR]` weight micro-panel plus one `[C][NR]`
//!   input micro-panel is at most ~48 KB — L2-resident, which is what KC
//!   blocking buys anyway. The integer kernel's i64 accumulation is
//!   exact, so blocking *couldn't* perturb it, but it shares the same
//!   loop structure for simplicity.
//! * **The epilogue keeps `quantize`'s exact operation sequence**
//!   (`(acc·prod_scale) / scale`, round, clamp). Folding the two scale
//!   factors into one multiplier would introduce a second rounding (of
//!   `prod_scale / scale` itself) and flip codes near ties — see
//!   [`Quantizer::requant`](crate::quant::scheme::Quantizer::requant).
//!
//! Ragged edges (`K % MR ≠ 0`, `T % NR ≠ 0`) are handled by zero-padding
//! the *packed* operands: padded lanes are computed and discarded at
//! store time, so the hot loop has no tail branches. Padding cannot
//! perturb real outputs — each `(k, t)` accumulator chain is independent.

use std::time::Instant;

use super::parallel;
use crate::benchkit;
use crate::quant::scheme::{Quantizer, Requant};
use crate::wino::error::Prng;

/// Register-tile rows (output filters per micro-kernel). With `NR = 8`,
/// an `MR × NR` f64 accumulator block is 8 four-wide vector registers —
/// the classic auto-vectorizable shape on AVX2-class hardware, and small
/// enough to stay in registers on NEON too.
pub const MR: usize = 4;

/// Register-tile columns (tiles per micro-kernel). See [`MR`].
pub const NR: usize = 8;

/// `T`-axis cache-block width: one packed `[C][NC]` input block stays
/// resident while every `K` row-block streams over it. Must be a
/// multiple of [`NR`] so only the final block has a ragged tail.
pub const NC: usize = 256;

const _: () = assert!(NC % NR == 0, "NC must be a multiple of NR");

/// The 2-D `(frequency × T-block)` split rule: number of work items one
/// panel dispatch fans out over. **The one definition** — the kernels
/// iterate exactly `grid_items(nn, t_total)` items (`item / n_tb` is
/// the frequency, `item % n_tb` the T-block) and [`workers_for`] clamps
/// the worker count to it, so a packing-buffer lease sized off
/// `workers_for` can never under-split the grid the kernels actually
/// walk, however ragged `T` is against [`NC`].
#[inline]
pub fn grid_items(nn: usize, t_total: usize) -> usize {
    nn * t_total.div_ceil(NC)
}

/// Worker count for one panel-GEMM dispatch: the thread pool clamped to
/// the [`grid_items`] work grid (and floored at 1 so zero-tile shapes
/// still get a packing-buffer lease).
pub fn workers_for(nn: usize, t_total: usize) -> usize {
    parallel::num_threads().min(grid_items(nn, t_total)).max(1)
}

/// Global SIMD kill switch backing store: `true` disables every SIMD
/// kernel. Seeded from `WINOQ_NO_SIMD` on first query; the CLI's
/// `--no-simd` flag writes it via [`set_simd_enabled`].
fn simd_disabled_flag() -> &'static std::sync::atomic::AtomicBool {
    static FLAG: std::sync::OnceLock<std::sync::atomic::AtomicBool> =
        std::sync::OnceLock::new();
    FLAG.get_or_init(|| {
        let off = std::env::var_os("WINOQ_NO_SIMD").is_some_and(|v| v != "0");
        std::sync::atomic::AtomicBool::new(off)
    })
}

/// True unless SIMD kernels are disabled (`WINOQ_NO_SIMD` env var, or
/// the CLI `--no-simd` escape hatch via [`set_simd_enabled`]). When
/// false, [`Kernel::detect_f64`] / [`Kernel::detect_i16`] always report
/// [`Kernel::Scalar`].
pub fn simd_enabled() -> bool {
    !simd_disabled_flag().load(std::sync::atomic::Ordering::Relaxed)
}

/// Flip the SIMD kill switch at runtime (the CLI calls this with
/// `false` when `--no-simd` is passed).
pub fn set_simd_enabled(on: bool) {
    simd_disabled_flag().store(!on, std::sync::atomic::Ordering::Relaxed);
}

/// Which inner micro-kernel a panel dispatch runs. Selected once per
/// dispatch by runtime feature detection ([`Kernel::detect_f64`] /
/// [`Kernel::detect_i16`]); every variant computes the identical
/// register-tile reduction, they differ only in lane width and (for the
/// FMA variants) rounding:
///
/// | kernel     | arch      | int i16            | float f64            |
/// |------------|-----------|--------------------|----------------------|
/// | `Scalar`   | any       | exact (oracle)     | bit-exact (oracle)   |
/// | `Avx2`     | x86-64    | exact (`madd`)     | bit-exact (mul+add)  |
/// | `Avx2Fma`  | x86-64    | —                  | tolerance (fused)    |
/// | `Neon`     | aarch64   | exact (`vmull`)    | bit-exact (mul+add)  |
/// | `NeonFma`  | aarch64   | —                  | tolerance (fused)    |
///
/// **Float-parity policy.** The serve path only ever auto-selects
/// kernels whose accumulation chain is *un-reassociated*: one product
/// rounding plus one add rounding per channel step, per `(k, t)` lane —
/// exactly the scalar chain, so `Avx2`/`Neon` f64 results are
/// bit-identical to [`panel_mul_f64_naive`] and parity holds. The FMA
/// variants fuse the multiply-add into a single rounding, which breaks
/// the bitwise chain; they are **never** auto-selected (detection skips
/// them) and exist for explicit opt-in benchmarking, gated by the
/// documented tolerance in `rust/tests/gemm_property.rs`
/// (`FMA_REL_TOL`). The integer kernels accumulate i16×i16 products
/// exactly (i32 pair-sums, i64 totals — integer addition reassociates
/// freely), so every int variant is bit-exact and serve-eligible.
///
/// **Integer operand precondition.** The AVX2 `madd` pair-sum is exact
/// for any codes in `-32767..=32767`; the single unreachable corner is
/// all four pair operands equal to `i16::MIN` (pair-sum `2^31`, one
/// past `i32::MAX`). Quantized code banks are symmetric (`±(2^{b−1}−1)`
/// — [`Quantizer`] clamps to `±qmax`), so `i16::MIN` never occurs on
/// the serve path; the property suite generates in quantizer ranges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar register tiles — always available, the fallback
    /// every other variant must match.
    Scalar,
    /// AVX2: i16 via `_mm256_madd_epi16` channel pairs widened to i64;
    /// f64 via separate `_mm256_mul_pd` + `_mm256_add_pd` (bit-exact).
    Avx2,
    /// AVX2 + FMA f64 (`_mm256_fmadd_pd`): fused rounding, tolerance
    /// only, never auto-selected.
    Avx2Fma,
    /// NEON: i16 via `vmull_s16` widening lanes accumulated in i64;
    /// f64 via separate `vmulq_f64` + `vaddq_f64` (bit-exact).
    Neon,
    /// NEON fused f64 (`vfmaq_f64`): tolerance only, never
    /// auto-selected.
    NeonFma,
}

impl Kernel {
    /// Stable lowercase name — emitted in `BENCH_gemm.json` (the CI
    /// detected-feature gate greps it) and the bench summary line.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Avx2Fma => "avx2_fma",
            Kernel::Neon => "neon",
            Kernel::NeonFma => "neon_fma",
        }
    }

    /// True when the kernel's f64 accumulation is bit-identical to the
    /// scalar chain (everything except the fused variants).
    pub fn f64_bit_exact(self) -> bool {
        !matches!(self, Kernel::Avx2Fma | Kernel::NeonFma)
    }

    /// Runtime-detected kernel for the f64 panels: the widest
    /// *bit-exact* variant this machine supports, or `Scalar` when SIMD
    /// is disabled or undetected. FMA variants are intentionally never
    /// returned (see the float-parity policy above).
    pub fn detect_f64() -> Kernel {
        if !simd_enabled() {
            return Kernel::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Runtime-detected kernel for the i16 panels (every variant is
    /// exact, so this is simply the widest supported one), or `Scalar`
    /// when SIMD is disabled or undetected.
    pub fn detect_i16() -> Kernel {
        if !simd_enabled() {
            return Kernel::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Kernel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Kernel::Neon;
            }
        }
        Kernel::Scalar
    }

    /// Every f64 kernel variant runnable on this machine right now
    /// (ignoring the kill switch) — the forall parity suite iterates
    /// this so CI exercises whatever the host supports.
    pub fn available_f64() -> Vec<Kernel> {
        #[allow(unused_mut)]
        let mut v = vec![Kernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                v.push(Kernel::Avx2);
                if is_x86_feature_detected!("fma") {
                    v.push(Kernel::Avx2Fma);
                }
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                v.push(Kernel::Neon);
                v.push(Kernel::NeonFma);
            }
        }
        v
    }

    /// Every i16 kernel variant runnable on this machine right now
    /// (ignoring the kill switch).
    pub fn available_i16() -> Vec<Kernel> {
        #[allow(unused_mut)]
        let mut v = vec![Kernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                v.push(Kernel::Avx2);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                v.push(Kernel::Neon);
            }
        }
        v
    }
}

/// Geometry of one panel multiply: input channels, output filters and
/// frequency points (`N²`); the tile count `T` is inferred from the
/// panel lengths. Shared by the float and integer raw-slice entries
/// (re-exported as `engine::int::PanelDims` for the integer oracles).
#[derive(Clone, Copy, Debug)]
pub struct PanelDims {
    pub c: usize,
    pub k: usize,
    pub nn: usize,
}

/// A weight bank repacked for the micro-kernel: layout
/// `[N²][⌈K/MR⌉][C][MR]`, i.e. for one frequency point and one
/// `MR`-row block, the `MR` weights of each channel are contiguous.
/// Ragged `K` tails are zero-padded so the kernel never branches on row
/// count. Packed once at lowering time (engine construction /
/// [`IntWeightBank`](super::int::IntWeightBank) quantization) and shared
/// across served model variants via
/// [`PlanCache`](crate::serve::plan::PlanCache).
pub struct Packed<T> {
    /// Frequency points `N²`.
    pub nn: usize,
    /// Output filters (unpadded).
    pub k: usize,
    /// Input channels.
    pub c: usize,
    data: Vec<T>,
}

/// Packed float weight panels (the [`WinoEngine`](super::WinoEngine) bank).
pub type PackedF64 = Packed<f64>;

/// Packed i16 weight-code panels (the
/// [`IntWeightBank`](super::int::IntWeightBank) codes).
pub type PackedI16 = Packed<i16>;

impl<T: Copy> Packed<T> {
    /// Number of `MR`-row blocks covering `k` rows.
    #[inline]
    pub fn row_blocks(&self) -> usize {
        self.k.div_ceil(MR)
    }

    /// Repack a `[N²] × [K] × [C]` weight bank (supplied through the
    /// `at(f, k, c)` accessor so float mats and flat code slices share
    /// one packer) into the micro-kernel layout. `zero` pads ragged `K`
    /// tails.
    pub fn pack(
        nn: usize,
        k: usize,
        c: usize,
        zero: T,
        at: impl Fn(usize, usize, usize) -> T,
    ) -> Packed<T> {
        assert!(nn > 0 && k > 0 && c > 0, "degenerate panel shape");
        let kb = k.div_ceil(MR);
        let mut data = vec![zero; nn * kb * c * MR];
        for f in 0..nn {
            for b in 0..kb {
                let base = (f * kb + b) * c * MR;
                for ci in 0..c {
                    for i in 0..MR {
                        let ki = b * MR + i;
                        if ki < k {
                            data[base + ci * MR + i] = at(f, ki, ci);
                        }
                    }
                }
            }
        }
        Packed { nn, k, c, data }
    }

    /// The packed `[⌈K/MR⌉][C][MR]` panel for frequency point `f`.
    #[inline]
    pub fn panel(&self, f: usize) -> &[T] {
        let len = self.row_blocks() * self.c * MR;
        &self.data[f * len..][..len]
    }

    /// Reconstruct the row-major `[K][C]` panel for frequency `f` — the
    /// pre-packing layout, for tests and introspection (the packed form
    /// is the only one stored).
    pub fn unpacked_panel(&self, f: usize) -> Vec<T> {
        let pan = self.panel(f);
        let mut out = Vec::with_capacity(self.k * self.c);
        for ki in 0..self.k {
            let (b, i) = (ki / MR, ki % MR);
            for ci in 0..self.c {
                out.push(pan[(b * self.c + ci) * MR + i]);
            }
        }
        out
    }

    /// Packed element count (pad included) — memory-accounting helper.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Never empty by construction ([`pack`](Self::pack) rejects
    /// degenerate shapes); present for `len`/`is_empty` symmetry.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Pack one `(f, T-block)` slice of a `[C][N²][T]` input panel into
/// `buf`, layout `[⌈block.len()/NR⌉][C][NR]`: for each `NR`-wide column
/// block, the `C` channel rows are contiguous, unit-stride for the
/// micro-kernel. Ragged column tails are zero-padded **explicitly** (no
/// blanket memset of a buffer whose every real lane is about to be
/// overwritten — only the `cols < NR` tail lanes of the final column
/// block pay a fill). The buffer only grows (capacity and stale length
/// retained across calls — it lives in
/// [`EngineScratch`](super::scratch::EngineScratch)); the kernels read
/// exactly the `⌈block.len()/NR⌉ · C · NR` elements written here.
pub fn pack_x_block<T: Copy + Default>(
    xt: &[T],
    nn: usize,
    c: usize,
    t_total: usize,
    f: usize,
    block: std::ops::Range<usize>,
    buf: &mut Vec<T>,
) {
    let (tb, te) = (block.start, block.end);
    let njb = (te - tb).div_ceil(NR);
    let need = njb * c * NR;
    if buf.len() < need {
        buf.resize(need, T::default());
    }
    for jb in 0..njb {
        let t0 = tb + jb * NR;
        let cols = (te - t0).min(NR);
        for ci in 0..c {
            let src = &xt[(ci * nn + f) * t_total + t0..][..cols];
            let dst = &mut buf[(jb * c + ci) * NR..][..NR];
            dst[..cols].copy_from_slice(src);
            for pad in &mut dst[cols..] {
                *pad = T::default();
            }
        }
    }
}

/// Raw output cursor handed to the 2-D parallel loop. Each `(f, T-block)`
/// work item writes only rows `(f, k, tb..te)` of the `[N²][K][T]` output
/// — ranges that partition the buffer — so concurrent writers never
/// alias.
struct OutPtr<T>(*mut T);

// SAFETY: the pointer is only dereferenced through disjoint
// `(f, k, column-range)` row slices (one work item per `(f, T-block)`,
// see `panel_gemm_f64` / `panel_gemm_requant_i16`), and the pointee
// outlives the dispatch (pool dispatches block until every participant
// leaves the closure).
unsafe impl<T: Send> Send for OutPtr<T> {}
unsafe impl<T: Send> Sync for OutPtr<T> {}

/// Scalar `MR × NR` f64 micro-kernel: the full-`C` register-tile
/// reduction, one mul rounding + one add rounding per channel step per
/// lane. `acc` must be zeroed on entry. This is the chain every SIMD
/// variant is judged against.
#[inline]
fn mk_f64_scalar(a: &[f64], bx: &[f64], c: usize, acc: &mut [[f64; NR]; MR]) {
    for ci in 0..c {
        let av = &a[ci * MR..][..MR];
        let bv = &bx[ci * NR..][..NR];
        for (ai, av) in av.iter().enumerate() {
            for (bj, bv) in bv.iter().enumerate() {
                acc[ai][bj] += av * bv;
            }
        }
    }
}

/// Scalar `MR × NR` i16 micro-kernel: i16×i16→i32 products accumulated
/// exactly in i64. `acc` must be zeroed on entry.
#[inline]
fn mk_i16_scalar(a: &[i16], bx: &[i16], c: usize, acc: &mut [[i64; NR]; MR]) {
    for ci in 0..c {
        let av = &a[ci * MR..][..MR];
        let bv = &bx[ci * NR..][..NR];
        for (ai, &av) in av.iter().enumerate() {
            let aw = av as i32;
            for (bj, &bv) in bv.iter().enumerate() {
                acc[ai][bj] += (aw * bv as i32) as i64;
            }
        }
    }
}

/// AVX2 micro-kernels. All are `unsafe` because they require the
/// caller to have *verified* the feature at runtime
/// ([`Kernel::detect_f64`] / [`Kernel::available_f64`] do); operand
/// slices are the same `[C][MR]` / `[C][NR]` packed panels the scalar
/// kernels read, so bounds are structural, not checked per element.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// f64 via separate `_mm256_mul_pd` + `_mm256_add_pd`. Each
    /// accumulator lane performs exactly the scalar sequence (product
    /// rounded, then sum rounded, per channel step), so the result is
    /// **bit-identical** to [`super::mk_f64_scalar`] — no
    /// reassociation, lanes are independent `(k, t)` chains.
    ///
    /// # Safety
    /// Caller must have verified `avx2` is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mk_f64_avx2(
        a: &[f64],
        bx: &[f64],
        c: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut acc_v = [[_mm256_setzero_pd(); 2]; MR];
        for ci in 0..c {
            let bp = bx.as_ptr().add(ci * NR);
            let b_lo = _mm256_loadu_pd(bp);
            let b_hi = _mm256_loadu_pd(bp.add(4));
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let va = _mm256_set1_pd(*ap.add(i));
                row[0] = _mm256_add_pd(row[0], _mm256_mul_pd(va, b_lo));
                row[1] = _mm256_add_pd(row[1], _mm256_mul_pd(va, b_hi));
            }
        }
        for (i, row) in acc_v.iter().enumerate() {
            _mm256_storeu_pd(acc[i].as_mut_ptr(), row[0]);
            _mm256_storeu_pd(acc[i].as_mut_ptr().add(4), row[1]);
        }
    }

    /// f64 via `_mm256_fmadd_pd`: the fused multiply-add rounds once
    /// per channel step instead of twice, so low bits differ from the
    /// scalar chain — tolerance-gated, never auto-selected.
    ///
    /// # Safety
    /// Caller must have verified `avx2` **and** `fma` are available.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mk_f64_avx2_fma(
        a: &[f64],
        bx: &[f64],
        c: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut acc_v = [[_mm256_setzero_pd(); 2]; MR];
        for ci in 0..c {
            let bp = bx.as_ptr().add(ci * NR);
            let b_lo = _mm256_loadu_pd(bp);
            let b_hi = _mm256_loadu_pd(bp.add(4));
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let va = _mm256_set1_pd(*ap.add(i));
                row[0] = _mm256_fmadd_pd(va, b_lo, row[0]);
                row[1] = _mm256_fmadd_pd(va, b_hi, row[1]);
            }
        }
        for (i, row) in acc_v.iter().enumerate() {
            _mm256_storeu_pd(acc[i].as_mut_ptr(), row[0]);
            _mm256_storeu_pd(acc[i].as_mut_ptr().add(4), row[1]);
        }
    }

    /// i16 via `_mm256_madd_epi16` channel pairs — the LANCE-shaped
    /// lane plan: the multiply stays in 16-bit precision inside the
    /// kernel and only widens on accumulate.
    ///
    /// Per channel pair `(ci, ci+1)`:
    /// * `vb` interleaves the two packed `[NR]` channel rows
    ///   (`_mm_unpacklo/hi_epi16`), so each i32 lane holds the pair
    ///   `(x[ci][t], x[ci+1][t])` for one column `t`;
    /// * `va` broadcasts the weight pair `(a[ci][i], a[ci+1][i])` into
    ///   every lane;
    /// * `madd` yields the 8 exact i32 pair-sums
    ///   `a₀·x₀ + a₁·x₁` (exact for codes ≥ `-32767`, see the operand
    ///   precondition on [`Kernel`]);
    /// * the pair-sums widen to i64 and accumulate — integer addition
    ///   reassociates freely, so the final totals are **bit-identical**
    ///   to [`super::mk_i16_scalar`].
    ///
    /// Odd `C` pairs the last channel with zeros.
    ///
    /// # Safety
    /// Caller must have verified `avx2` is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mk_i16_avx2(
        a: &[i16],
        bx: &[i16],
        c: usize,
        acc: &mut [[i64; NR]; MR],
    ) {
        let mut acc_v = [[_mm256_setzero_si256(); 2]; MR];
        let zero = _mm_setzero_si128();
        let mut ci = 0;
        while ci < c {
            let pair = ci + 1 < c;
            let b0 = _mm_loadu_si128(bx.as_ptr().add(ci * NR) as *const __m128i);
            let b1 = if pair {
                _mm_loadu_si128(bx.as_ptr().add((ci + 1) * NR) as *const __m128i)
            } else {
                zero
            };
            let lo = _mm_unpacklo_epi16(b0, b1);
            let hi = _mm_unpackhi_epi16(b0, b1);
            let vb = _mm256_set_m128i(hi, lo);
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let a0 = *ap.add(i) as u16 as u32;
                let a1 = if pair { *ap.add(MR + i) as u16 as u32 } else { 0 };
                let va = _mm256_set1_epi32(((a1 << 16) | a0) as i32);
                let prod = _mm256_madd_epi16(va, vb);
                let w_lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(prod));
                let w_hi =
                    _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(prod));
                row[0] = _mm256_add_epi64(row[0], w_lo);
                row[1] = _mm256_add_epi64(row[1], w_hi);
            }
            ci += 2;
        }
        for (i, row) in acc_v.iter().enumerate() {
            _mm256_storeu_si256(acc[i].as_mut_ptr() as *mut __m256i, row[0]);
            _mm256_storeu_si256(acc[i].as_mut_ptr().add(4) as *mut __m256i, row[1]);
        }
    }
}

/// NEON micro-kernels — same contracts as the AVX2 set: `mul`+`add`
/// f64 lanes are bit-exact, `vfmaq_f64` is tolerance-only, the i16
/// kernel widens `vmull_s16` products into exact i64 totals.
#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// f64 via separate `vmulq_f64` + `vaddq_f64` — bit-identical to
    /// [`super::mk_f64_scalar`] (independent lanes, two roundings per
    /// channel step).
    ///
    /// # Safety
    /// Caller must have verified `neon` is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn mk_f64_neon(
        a: &[f64],
        bx: &[f64],
        c: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut acc_v = [[vdupq_n_f64(0.0); 4]; MR];
        for ci in 0..c {
            let bp = bx.as_ptr().add(ci * NR);
            let b = [
                vld1q_f64(bp),
                vld1q_f64(bp.add(2)),
                vld1q_f64(bp.add(4)),
                vld1q_f64(bp.add(6)),
            ];
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let va = vdupq_n_f64(*ap.add(i));
                for (j, acc_j) in row.iter_mut().enumerate() {
                    *acc_j = vaddq_f64(*acc_j, vmulq_f64(va, b[j]));
                }
            }
        }
        for (i, row) in acc_v.iter().enumerate() {
            for (j, acc_j) in row.iter().enumerate() {
                vst1q_f64(acc[i].as_mut_ptr().add(2 * j), *acc_j);
            }
        }
    }

    /// f64 via `vfmaq_f64`: fused rounding — tolerance-gated, never
    /// auto-selected.
    ///
    /// # Safety
    /// Caller must have verified `neon` is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn mk_f64_neon_fma(
        a: &[f64],
        bx: &[f64],
        c: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut acc_v = [[vdupq_n_f64(0.0); 4]; MR];
        for ci in 0..c {
            let bp = bx.as_ptr().add(ci * NR);
            let b = [
                vld1q_f64(bp),
                vld1q_f64(bp.add(2)),
                vld1q_f64(bp.add(4)),
                vld1q_f64(bp.add(6)),
            ];
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let va = vdupq_n_f64(*ap.add(i));
                for (j, acc_j) in row.iter_mut().enumerate() {
                    *acc_j = vfmaq_f64(*acc_j, va, b[j]);
                }
            }
        }
        for (i, row) in acc_v.iter().enumerate() {
            for (j, acc_j) in row.iter().enumerate() {
                vst1q_f64(acc[i].as_mut_ptr().add(2 * j), *acc_j);
            }
        }
    }

    /// i16 via `vmull_s16` widening multiplies (i16×i16→i32, exact)
    /// accumulated into i64 lanes with `vaddw_s32` — bit-identical to
    /// [`super::mk_i16_scalar`].
    ///
    /// # Safety
    /// Caller must have verified `neon` is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn mk_i16_neon(
        a: &[i16],
        bx: &[i16],
        c: usize,
        acc: &mut [[i64; NR]; MR],
    ) {
        let mut acc_v = [[vdupq_n_s64(0); 4]; MR];
        for ci in 0..c {
            let bp = bx.as_ptr().add(ci * NR);
            let b_lo = vld1_s16(bp);
            let b_hi = vld1_s16(bp.add(4));
            let ap = a.as_ptr().add(ci * MR);
            for (i, row) in acc_v.iter_mut().enumerate() {
                let va = vdup_n_s16(*ap.add(i));
                let p_lo = vmull_s16(va, b_lo);
                let p_hi = vmull_s16(va, b_hi);
                row[0] = vaddw_s32(row[0], vget_low_s32(p_lo));
                row[1] = vaddw_s32(row[1], vget_high_s32(p_lo));
                row[2] = vaddw_s32(row[2], vget_low_s32(p_hi));
                row[3] = vaddw_s32(row[3], vget_high_s32(p_hi));
            }
        }
        for (i, row) in acc_v.iter().enumerate() {
            for (j, acc_j) in row.iter().enumerate() {
                vst1q_s64(acc[i].as_mut_ptr().add(2 * j), *acc_j);
            }
        }
    }
}

/// Run the selected f64 micro-kernel (foreign-arch or undetected
/// variants fall back to scalar — selection already guaranteed the
/// feature exists for the native arms).
#[inline]
fn run_mk_f64(kernel: Kernel, a: &[f64], bx: &[f64], c: usize, acc: &mut [[f64; NR]; MR]) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: selection verified the feature (see Kernel docs).
        Kernel::Avx2 => unsafe { x86::mk_f64_avx2(a, bx, c, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above, including `fma`.
        Kernel::Avx2Fma => unsafe { x86::mk_f64_avx2_fma(a, bx, c, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: selection verified the feature (see Kernel docs).
        Kernel::Neon => unsafe { arm::mk_f64_neon(a, bx, c, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        Kernel::NeonFma => unsafe { arm::mk_f64_neon_fma(a, bx, c, acc) },
        _ => mk_f64_scalar(a, bx, c, acc),
    }
}

/// Run the selected i16 micro-kernel (FMA variants are float-only and
/// fall back to scalar, as do foreign-arch variants).
#[inline]
fn run_mk_i16(kernel: Kernel, a: &[i16], bx: &[i16], c: usize, acc: &mut [[i64; NR]; MR]) {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: selection verified the feature (see Kernel docs).
        Kernel::Avx2 => unsafe { x86::mk_i16_avx2(a, bx, c, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: selection verified the feature (see Kernel docs).
        Kernel::Neon => unsafe { arm::mk_i16_neon(a, bx, c, acc) },
        _ => mk_i16_scalar(a, bx, c, acc),
    }
}

/// Float per-frequency panel multiply over packed weights — stage 2 of
/// [`WinoEngine::execute_into`](super::WinoEngine::execute_into).
///
/// `xt` is `[C][N²][T]`, `had` is `[N²][K][T]`; every `had` element is
/// written exactly once (no pre-zeroing needed). When `fake` is set
/// (Fig. 2 quantized pipeline), the Hadamard cast is applied at store
/// time — elementwise on the fully-accumulated sums, the same values the
/// naive path casts after its accumulation loop. `packs` supplies one
/// input packing buffer per worker (at least one; see
/// [`parallel::par_for_states`]).
///
/// Bit-for-bit equal to [`panel_mul_f64_naive`]: each `(k, f, t)`
/// accumulator runs the identical `c = 0..C` fused chain, register-tiled
/// but never reassociated. Dispatches to the runtime-detected
/// **bit-exact** micro-kernel ([`Kernel::detect_f64`] — scalar, or
/// un-reassociated AVX2/NEON lanes; never FMA).
pub fn panel_gemm_f64(
    pw: &PackedF64,
    xt: &[f64],
    t_total: usize,
    fake: Option<&Quantizer>,
    had: &mut [f64],
    packs: &mut [Vec<f64>],
) {
    panel_gemm_f64_with(Kernel::detect_f64(), pw, xt, t_total, fake, had, packs);
}

/// [`panel_gemm_f64`] with an explicit micro-kernel — the forall parity
/// suite drives every [`Kernel::available_f64`] variant through this;
/// production paths go through the auto-detecting wrapper.
pub fn panel_gemm_f64_with(
    kernel: Kernel,
    pw: &PackedF64,
    xt: &[f64],
    t_total: usize,
    fake: Option<&Quantizer>,
    had: &mut [f64],
    packs: &mut [Vec<f64>],
) {
    let (nn, k, c) = (pw.nn, pw.k, pw.c);
    assert_eq!(xt.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    let n_tb = t_total.div_ceil(NC);
    let out = OutPtr(had.as_mut_ptr());
    parallel::par_for_states(grid_items(nn, t_total), packs, |item, buf| {
        let f = item / n_tb;
        let tb = (item % n_tb) * NC;
        let te = (tb + NC).min(t_total);
        pack_x_block(xt, nn, c, t_total, f, tb..te, buf);
        let wpan = pw.panel(f);
        let njb = (te - tb).div_ceil(NR);
        for b in 0..k.div_ceil(MR) {
            let a = &wpan[b * c * MR..][..c * MR];
            let rows = (k - b * MR).min(MR);
            for jb in 0..njb {
                let bx = &buf[jb * c * NR..][..c * NR];
                let mut acc = [[0.0f64; NR]; MR];
                run_mk_f64(kernel, a, bx, c, &mut acc);
                let t0 = tb + jb * NR;
                let cols = (te - t0).min(NR);
                for (i, acc_row) in acc.iter().enumerate().take(rows) {
                    // SAFETY: rows `(f, b·MR + i, t0..t0+cols)` are
                    // disjoint across work items and across `i`; `had`
                    // outlives the parallel scope and is not otherwise
                    // touched while it runs.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add((f * k + b * MR + i) * t_total + t0),
                            cols,
                        )
                    };
                    match fake {
                        Some(q) => {
                            for (dst, &v) in row.iter_mut().zip(acc_row) {
                                *dst = q.fake(v);
                            }
                        }
                        None => row.copy_from_slice(&acc_row[..cols]),
                    }
                }
            }
        }
    });
}

/// Integer per-frequency panel multiply with the fused requantize
/// epilogue — stage 2 of
/// [`IntWinoEngine::execute_into`](super::int::IntWinoEngine::execute_into).
///
/// `xt_codes` is `[C][N²][T]` i16, `had_codes` is `[N²][K][T]` i32.
/// Products are widened i16×i16→i32 and accumulated in i64 register
/// tiles (exact for any hosted `C`, so register tiling cannot perturb
/// the result); each finished accumulator is requantized through `rq`
/// ([`Quantizer::requant`]) — bit-identical to
/// `hq.quantize(acc as f64 * prod_scale)` by construction.
pub fn panel_gemm_requant_i16(
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
) {
    let sat = std::sync::atomic::AtomicU64::new(0);
    panel_gemm_requant_i16_counted(pw, xt_codes, t_total, rq, had_codes, packs, &sat);
}

/// [`panel_gemm_requant_i16_counted`] with an explicit micro-kernel —
/// the forall parity suite drives every [`Kernel::available_i16`]
/// variant through this; every int variant is bit-exact, so production
/// paths auto-detect.
pub fn panel_gemm_requant_i16_with(
    kernel: Kernel,
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
) {
    let sat = std::sync::atomic::AtomicU64::new(0);
    panel_gemm_requant_i16_counted_with(
        kernel, pw, xt_codes, t_total, rq, had_codes, packs, &sat,
    );
}

/// [`panel_gemm_requant_i16`] with numeric-health accounting: `sat`
/// accumulates how many output codes the requant epilogue clamped
/// (via [`Requant::apply_sat`] — value path bit-identical to
/// [`Requant::apply`]). Each `(f, T-block)` work item counts locally
/// and folds in with **one** relaxed `fetch_add`, so the counter costs
/// one add per output element plus one atomic per work item — invisible
/// next to the `C`-deep reduction it rides on.
pub fn panel_gemm_requant_i16_counted(
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
    sat: &std::sync::atomic::AtomicU64,
) {
    panel_gemm_requant_i16_counted_with(
        Kernel::detect_i16(),
        pw,
        xt_codes,
        t_total,
        rq,
        had_codes,
        packs,
        sat,
    );
}

/// [`panel_gemm_requant_i16_counted`] with an explicit micro-kernel.
#[allow(clippy::too_many_arguments)]
pub fn panel_gemm_requant_i16_counted_with(
    kernel: Kernel,
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
    sat: &std::sync::atomic::AtomicU64,
) {
    let (nn, k, c) = (pw.nn, pw.k, pw.c);
    assert_eq!(xt_codes.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had_codes.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    let n_tb = t_total.div_ceil(NC);
    let out = OutPtr(had_codes.as_mut_ptr());
    parallel::par_for_states(grid_items(nn, t_total), packs, |item, buf| {
        let f = item / n_tb;
        let tb = (item % n_tb) * NC;
        let te = (tb + NC).min(t_total);
        pack_x_block(xt_codes, nn, c, t_total, f, tb..te, buf);
        let wpan = pw.panel(f);
        let njb = (te - tb).div_ceil(NR);
        let mut local_sat = 0u64;
        for b in 0..k.div_ceil(MR) {
            let a = &wpan[b * c * MR..][..c * MR];
            let rows = (k - b * MR).min(MR);
            for jb in 0..njb {
                let bx = &buf[jb * c * NR..][..c * NR];
                let mut acc = [[0i64; NR]; MR];
                run_mk_i16(kernel, a, bx, c, &mut acc);
                let t0 = tb + jb * NR;
                let cols = (te - t0).min(NR);
                for (i, acc_row) in acc.iter().enumerate().take(rows) {
                    // SAFETY: see `panel_gemm_f64` — same disjoint
                    // `(f, row, column-range)` partition.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add((f * k + b * MR + i) * t_total + t0),
                            cols,
                        )
                    };
                    for (dst, &v) in row.iter_mut().zip(acc_row) {
                        let (code, clipped) = rq.apply_sat(v);
                        *dst = code;
                        local_sat += u64::from(clipped);
                    }
                }
            }
        }
        if local_sat > 0 {
            sat.fetch_add(local_sat, std::sync::atomic::Ordering::Relaxed);
        }
    });
}

/// `T`-dimension block size of the retired in-engine float loop, kept in
/// the oracle so [`panel_mul_f64_naive`] is the literal old stage-2 path.
const NAIVE_T_BLOCK: usize = 512;

/// The pre-tiling float stage-2 loop, verbatim — the oracle the property
/// suite pins [`panel_gemm_f64`] against bit-for-bit, and the baseline
/// `BENCH_gemm.json` times. `wt_panels` is the unpacked `[N²][K][C]`
/// flat bank; `had` is fully overwritten (the old engine zero-filled it
/// at `prepare` time and accumulated with `+=`; this oracle owns the
/// zeroing so callers treat both paths identically). Parallel over
/// frequency points exactly like the old stage 2, so the bench ratio
/// isolates the tiling/packing win, not a threading difference (set
/// `WINOQ_THREADS=1` to force both serial).
pub fn panel_mul_f64_naive(
    wt_panels: &[f64],
    dims: PanelDims,
    xt: &[f64],
    t_total: usize,
    fake: Option<&Quantizer>,
    had: &mut [f64],
) {
    let PanelDims { c, k, nn } = dims;
    assert_eq!(wt_panels.len(), nn * k * c, "wt panel not [N²][K][C]");
    assert_eq!(xt.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    parallel::par_chunks_mut(had, k * t_total, |f, panel| {
        panel.fill(0.0);
        let wpan = &wt_panels[f * k * c..][..k * c];
        let mut tb = 0;
        while tb < t_total {
            let te = (tb + NAIVE_T_BLOCK).min(t_total);
            for ki in 0..k {
                let row = &mut panel[ki * t_total..][..t_total];
                for ci in 0..c {
                    let wkc = wpan[ki * c + ci];
                    let xrow = &xt[(ci * nn + f) * t_total..][..t_total];
                    for t in tb..te {
                        row[t] += wkc * xrow[t];
                    }
                }
            }
            tb = te;
        }
        if let Some(s) = fake {
            for v in panel.iter_mut() {
                *v = s.fake(*v);
            }
        }
    });
}

/// Time the tiled kernels against their naive oracles on one synthetic
/// shape, returning `(BENCH_gemm JSON, float ratio, int ratio)` where
/// each ratio is tiled-over-naive tiles/sec. Shared by
/// `benches/conv_throughput.rs` and `winoq bench --gemm-json`; the run
/// also *asserts* bit-parity on the measured buffers, so an emitted JSON
/// doubles as a parity witness.
pub fn gemm_bench_json(
    c: usize,
    k: usize,
    t_total: usize,
    nn: usize,
    warmup: usize,
    samples: usize,
) -> (String, f64, f64) {
    let mut rng = Prng::new(0x6E77);
    let wt: Vec<f64> = (0..nn * k * c).map(|_| rng.uniform(0.5)).collect();
    let xt: Vec<f64> = (0..c * nn * t_total).map(|_| rng.uniform(1.0)).collect();
    let pw = Packed::pack(nn, k, c, 0.0f64, |f, ki, ci| wt[(f * k + ki) * c + ci]);
    let samples = samples.max(1);
    let workers = workers_for(nn, t_total);
    let mut packs: Vec<Vec<f64>> = vec![Vec::new(); workers];

    let mut had_tiled = vec![0.0f64; nn * k * t_total];
    let s_f_tiled = benchkit::bench(warmup, samples, || {
        panel_gemm_f64(&pw, &xt, t_total, None, &mut had_tiled, &mut packs)
    });
    let dims = PanelDims { c, k, nn };
    let mut had_naive = vec![0.0f64; nn * k * t_total];
    let s_f_naive = benchkit::bench(warmup, samples, || {
        panel_mul_f64_naive(&wt, dims, &xt, t_total, None, &mut had_naive)
    });
    for (i, (a, b)) in had_tiled.iter().zip(&had_naive).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "float gemm parity broke at {i}");
    }

    let wt_i: Vec<i16> = (0..nn * k * c)
        .map(|_| (rng.next_u64() % 255) as i16 - 127)
        .collect();
    let xt_i: Vec<i16> = (0..c * nn * t_total)
        .map(|_| (rng.next_u64() % 511) as i16 - 255)
        .collect();
    let pwi = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt_i[(f * k + ki) * c + ci]);
    let hq = Quantizer::with_scale(9, 3.1e-4);
    let prod_scale = 1.7e-4;
    let rq = hq.requant(prod_scale);
    let mut ipacks: Vec<Vec<i16>> = vec![Vec::new(); workers];
    let mut ihad_tiled = vec![0i32; nn * k * t_total];
    let s_i_tiled = benchkit::bench(warmup, samples, || {
        panel_gemm_requant_i16(&pwi, &xt_i, t_total, &rq, &mut ihad_tiled, &mut ipacks)
    });
    let mut ihad_naive = vec![0i32; nn * k * t_total];
    let s_i_naive = benchkit::bench(warmup, samples, || {
        super::int::panel_mul_requant_i16_naive(
            &xt_i,
            &wt_i,
            dims,
            prod_scale,
            &hq,
            &mut ihad_naive,
        )
    });
    assert_eq!(ihad_tiled, ihad_naive, "int gemm parity broke");

    let tps = |median: f64| t_total as f64 / median.max(1e-12);
    let (ftt, ftn) = (tps(s_f_tiled.median), tps(s_f_naive.median));
    let (itt, itn) = (tps(s_i_tiled.median), tps(s_i_naive.median));
    let fr = if ftn > 0.0 { ftt / ftn } else { 0.0 };
    let ir = if itn > 0.0 { itt / itn } else { 0.0 };
    let json = format!(
        concat!(
            "{{\"bench\": \"gemm\", \"mr\": {}, \"nr\": {}, \"nc\": {}, ",
            "\"shape\": {{\"c\": {}, \"k\": {}, \"t\": {}, \"nn\": {}}}, ",
            "\"threads\": {}, ",
            "\"kernel\": {{\"float\": \"{}\", \"int\": \"{}\", ",
            "\"simd_enabled\": {}}}, ",
            "\"float\": {{\"tiled_seconds\": {:e}, \"naive_seconds\": {:e}, ",
            "\"tiled_tiles_per_sec\": {:.1}, \"naive_tiles_per_sec\": {:.1}, ",
            "\"ratio_tiled_vs_naive\": {:.3}}}, ",
            "\"int\": {{\"tiled_seconds\": {:e}, \"naive_seconds\": {:e}, ",
            "\"tiled_tiles_per_sec\": {:.1}, \"naive_tiles_per_sec\": {:.1}, ",
            "\"ratio_tiled_vs_naive\": {:.3}}}}}"
        ),
        MR,
        NR,
        NC,
        c,
        k,
        t_total,
        nn,
        parallel::num_threads(),
        Kernel::detect_f64().name(),
        Kernel::detect_i16().name(),
        simd_enabled(),
        s_f_tiled.median,
        s_f_naive.median,
        ftt,
        ftn,
        fr,
        s_i_tiled.median,
        s_i_naive.median,
        itt,
        itn,
        ir,
    );
    (json, fr, ir)
}

/// Cumulative per-stage wall time of an engine pass, nanoseconds:
/// `[input-transform, hadamard/GEMM, inverse]`. Accumulated into
/// [`EngineScratch`](super::scratch::EngineScratch) by both engines so
/// serving workers and benches can report **which** stage moved.
pub type StageNs = [u64; 3];

/// Elapsed nanoseconds since `t0`, saturating into the `u64` the stage
/// counters use.
pub(super) fn ns_since(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_and_unpack_roundtrip() {
        // 2 freqs, K=5 (ragged over MR=4), C=3.
        let (nn, k, c) = (2, 5, 3);
        let src: Vec<f64> = (0..nn * k * c).map(|i| i as f64 + 1.0).collect();
        let p = Packed::pack(nn, k, c, 0.0, |f, ki, ci| src[(f * k + ki) * c + ci]);
        assert_eq!(p.row_blocks(), 2);
        assert_eq!(p.len(), nn * 2 * c * MR);
        for f in 0..nn {
            // Unpacked reconstruction matches the source panel exactly.
            assert_eq!(p.unpacked_panel(f), src[f * k * c..][..k * c].to_vec());
            // Padded lanes (rows 5..8 of block 1) are zero.
            let pan = p.panel(f);
            for ci in 0..c {
                for i in 1..MR {
                    assert_eq!(pan[(c + ci) * MR + i], 0.0, "pad lane must be zero");
                }
            }
        }
    }

    #[test]
    fn pack_x_block_pads_ragged_columns_even_in_dirty_buffers() {
        let (nn, c, t) = (2, 3, 11);
        let xt: Vec<f64> = (0..c * nn * t).map(|i| i as f64).collect();
        // A reused buffer full of garbage (and longer than needed) must
        // produce the identical packing: real lanes overwritten, pad
        // lanes of the ragged tail explicitly zeroed, excess untouched.
        let mut buf = vec![999.25; c * NR + 7];
        // Block [8, 11): 3 real columns, 5 padded.
        pack_x_block(&xt, nn, c, t, 1, 8..11, &mut buf);
        assert!(buf.len() >= c * NR);
        for ci in 0..c {
            for j in 0..NR {
                let want = if j < 3 { xt[(ci * nn + 1) * t + 8 + j] } else { 0.0 };
                assert_eq!(buf[ci * NR + j], want, "({ci},{j})");
            }
        }
        // A fresh buffer grows to exactly the needed length.
        let mut fresh = Vec::new();
        pack_x_block(&xt, nn, c, t, 1, 8..11, &mut fresh);
        assert_eq!(fresh.len(), c * NR);
        assert_eq!(fresh[..], buf[..c * NR]);
    }

    #[test]
    fn tiled_float_matches_naive_bitwise_ragged() {
        // K and T both ragged, C=1 edge, multi-T-block widths.
        let mut rng = Prng::new(7);
        for &(c, k, t, nn) in &[
            (1usize, 1usize, 1usize, 4usize),
            (3, 5, 13, 4),
            (2, 9, NR + 1, 1),
            (5, 4, NC + 3, 2),
        ] {
            let wt: Vec<f64> = (0..nn * k * c).map(|_| rng.uniform(1.0)).collect();
            let xt: Vec<f64> = (0..c * nn * t).map(|_| rng.uniform(1.0)).collect();
            let pw = Packed::pack(nn, k, c, 0.0, |f, ki, ci| wt[(f * k + ki) * c + ci]);
            for fake in [None, Some(Quantizer::with_scale(9, 0.037))] {
                let mut tiled = vec![f64::NAN; nn * k * t];
                let mut packs = vec![Vec::new(); 3];
                panel_gemm_f64(&pw, &xt, t, fake.as_ref(), &mut tiled, &mut packs);
                let mut naive = vec![0.0; nn * k * t];
                panel_mul_f64_naive(
                    &wt,
                    PanelDims { c, k, nn },
                    &xt,
                    t,
                    fake.as_ref(),
                    &mut naive,
                );
                for (i, (a, b)) in tiled.iter().zip(&naive).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "(c={c},k={k},t={t},nn={nn}) idx {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bench_emitter_reports_both_ratios_and_valid_json() {
        let (json, fr, ir) = gemm_bench_json(4, 5, 37, 4, 0, 1);
        assert!(json.contains("\"bench\": \"gemm\""), "{json}");
        assert!(fr > 0.0 && ir > 0.0, "degenerate ratios: {fr} {ir}");
        let doc = crate::tune::json::parse(&json).unwrap();
        for path in ["float", "int"] {
            let section = doc.get(path).unwrap();
            assert!(section.get("ratio_tiled_vs_naive").is_some(), "{json}");
            assert!(section.get("tiled_tiles_per_sec").is_some(), "{json}");
        }
        // The detected-kernel line the CI gate requires: stable names
        // plus the kill-switch state.
        let kern = doc.get("kernel").unwrap();
        for path in ["float", "int"] {
            let name = kern.get(path).unwrap().as_str().unwrap();
            assert!(
                ["scalar", "avx2", "neon"].contains(&name),
                "unexpected auto-selected kernel {name:?} in {json}"
            );
        }
        assert!(kern.get("simd_enabled").is_some(), "{json}");
    }

    #[test]
    fn counted_kernel_matches_and_counts_exact_clips() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let mut rng = Prng::new(0x5A7);
        let (c, k, t, nn) = (3usize, 5usize, 13usize, 4usize);
        let wt: Vec<i16> =
            (0..nn * k * c).map(|_| (rng.next_u64() % 255) as i16 - 127).collect();
        let xt: Vec<i16> =
            (0..c * nn * t).map(|_| (rng.next_u64() % 511) as i16 - 255).collect();
        let pw = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt[(f * k + ki) * c + ci]);
        // A coarse requant scale so a good fraction of accumulators clip.
        let hq = Quantizer::with_scale(8, 1.0);
        let rq = hq.requant(0.01);
        let mut plain = vec![0i32; nn * k * t];
        panel_gemm_requant_i16(&pw, &xt, t, &rq, &mut plain, &mut [Vec::new()]);
        let sat = AtomicU64::new(0);
        let mut counted = vec![0i32; nn * k * t];
        panel_gemm_requant_i16_counted(
            &pw,
            &xt,
            t,
            &rq,
            &mut counted,
            &mut [Vec::new()],
            &sat,
        );
        assert_eq!(plain, counted, "counting must not perturb output codes");
        // Oracle count straight from a scalar re-accumulation.
        let mut want = 0u64;
        for f in 0..nn {
            for ki in 0..k {
                for ti in 0..t {
                    let mut acc = 0i64;
                    for ci in 0..c {
                        let a = wt[(f * k + ki) * c + ci] as i32;
                        let b = xt[(ci * nn + f) * t + ti] as i32;
                        acc += (a * b) as i64;
                    }
                    want += u64::from(rq.apply_sat(acc).1);
                }
            }
        }
        assert!(want > 0, "fixture must actually clip");
        assert_eq!(sat.load(Ordering::Relaxed), want);
    }

    #[test]
    fn zero_tiles_is_a_no_op() {
        let pw = Packed::pack(1, 1, 1, 0.0, |_, _, _| 1.0);
        let mut had: Vec<f64> = Vec::new();
        panel_gemm_f64(&pw, &[], 0, None, &mut had, &mut [Vec::new()]);
        let pwi = Packed::pack(1, 1, 1, 0i16, |_, _, _| 1);
        let rq = Quantizer::with_scale(8, 1.0).requant(1.0);
        let mut ihad: Vec<i32> = Vec::new();
        panel_gemm_requant_i16(&pwi, &[], 0, &rq, &mut ihad, &mut [Vec::new()]);
    }

    #[test]
    fn grid_items_matches_the_loop_the_kernels_walk() {
        // The split rule must equal a literal count of the (f, T-block)
        // pairs the dispatch iterates — including the former off-by-one
        // shapes: T exactly NC, NC±1, and zero tiles.
        for &(nn, t) in &[
            (1usize, 0usize),
            (1, 1),
            (4, NC - 1),
            (4, NC),
            (4, NC + 1),
            (9, 2 * NC),
            (16, 2 * NC + 7),
        ] {
            let mut walked = 0usize;
            for _f in 0..nn {
                let mut t0 = 0;
                while t0 < t {
                    walked += 1;
                    t0 += NC;
                }
            }
            assert_eq!(grid_items(nn, t), walked, "(nn={nn}, t={t})");
            // workers_for never exceeds the grid (a lease per worker
            // must map onto at least one item) and never hits zero.
            let w = workers_for(nn, t);
            assert!(w >= 1, "(nn={nn}, t={t})");
            assert!(w <= grid_items(nn, t).max(1), "(nn={nn}, t={t})");
        }
    }

    #[test]
    fn every_available_kernel_matches_the_oracles_on_a_ragged_shape() {
        // Quick in-crate smoke over whatever this host can run; the
        // forall suite in tests/gemm_property.rs drives the full shape
        // grid. Int must be bitwise, float bitwise for non-FMA variants.
        let mut rng = Prng::new(0xA11);
        let (c, k, t, nn) = (5usize, 7usize, NR + 3, 4usize);
        let wt: Vec<f64> = (0..nn * k * c).map(|_| rng.uniform(1.0)).collect();
        let xt: Vec<f64> = (0..c * nn * t).map(|_| rng.uniform(1.0)).collect();
        let pw = Packed::pack(nn, k, c, 0.0, |f, ki, ci| wt[(f * k + ki) * c + ci]);
        let mut naive = vec![0.0; nn * k * t];
        panel_mul_f64_naive(&wt, PanelDims { c, k, nn }, &xt, t, None, &mut naive);
        for kern in Kernel::available_f64() {
            let mut got = vec![f64::NAN; nn * k * t];
            panel_gemm_f64_with(kern, &pw, &xt, t, None, &mut got, &mut [Vec::new()]);
            for (i, (a, b)) in got.iter().zip(&naive).enumerate() {
                if kern.f64_bit_exact() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} idx {i}", kern.name());
                } else {
                    let rel = (a - b).abs() / b.abs().max(1e-300);
                    assert!(rel < 1e-12, "{} idx {i}: {a} vs {b}", kern.name());
                }
            }
        }
        let wi: Vec<i16> =
            (0..nn * k * c).map(|_| (rng.next_u64() % 255) as i16 - 127).collect();
        let xi: Vec<i16> =
            (0..c * nn * t).map(|_| (rng.next_u64() % 511) as i16 - 255).collect();
        let pwi = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wi[(f * k + ki) * c + ci]);
        let rq = Quantizer::with_scale(8, 1.0).requant(0.05);
        let mut want = vec![0i32; nn * k * t];
        panel_gemm_requant_i16_with(
            Kernel::Scalar,
            &pwi,
            &xi,
            t,
            &rq,
            &mut want,
            &mut [Vec::new()],
        );
        for kern in Kernel::available_i16() {
            let mut got = vec![0i32; nn * k * t];
            panel_gemm_requant_i16_with(
                kern,
                &pwi,
                &xi,
                t,
                &rq,
                &mut got,
                &mut [Vec::new()],
            );
            assert_eq!(got, want, "int kernel {} must be bit-exact", kern.name());
        }
    }

    #[test]
    fn detection_never_returns_fma_and_kill_switch_forces_scalar() {
        // Auto-detection must honor the float-parity policy: whatever it
        // picks for f64 is bit-exact, and FMA variants are opt-in only.
        assert!(Kernel::detect_f64().f64_bit_exact());
        // The kill switch pins both paths to scalar; restore after (the
        // other tests tolerate either state — every auto-selectable
        // kernel is exact).
        let was = simd_enabled();
        set_simd_enabled(false);
        assert_eq!(Kernel::detect_f64(), Kernel::Scalar);
        assert_eq!(Kernel::detect_i16(), Kernel::Scalar);
        assert!(!simd_enabled());
        set_simd_enabled(was);
    }
}
