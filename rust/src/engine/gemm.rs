//! Register-tiled, cache-blocked panel GEMM — the shared micro-kernel
//! layer under both execution engines' Hadamard stage.
//!
//! Every forward pass ([`WinoEngine`](super::WinoEngine) and
//! [`IntWinoEngine`](super::int::IntWinoEngine) alike) bottoms out in the
//! per-frequency `[K,C] × [C,T]` panel multiplies. The original stage-2
//! loops walked unpacked panels one scalar row at a time, re-reading each
//! input row `K` times from cache and re-writing each output row `C`
//! times; the integer path additionally paid a `Quantizer::quantize` call
//! (with its per-element `qmax` range assert) for every output code. This
//! module restructures that work the BLIS way without leaving portable
//! Rust (no intrinsics — the kernels are shaped so the compiler
//! auto-vectorizes them):
//!
//! * **`MR`×`NR` register tiles** — the micro-kernels keep an
//!   `MR × NR` block of accumulators in registers across the whole
//!   channel reduction, so each output element is written exactly once
//!   and each packed operand element is loaded once per `MR`/`NR` reuse.
//! * **Packed operand panels** — weights are repacked **once at lowering
//!   time** into `[N²][⌈K/MR⌉][C][MR]` ([`Packed`]), so the micro-kernel
//!   reads them unit-stride; input panels are streamed through a
//!   `C`×[`NC`]-blocked packing buffer ([`pack_x_block`], layout
//!   `[⌈NC/NR⌉][C][NR]`) owned by the caller's
//!   [`EngineScratch`](super::scratch::EngineScratch).
//! * **Fused requantize epilogue** (integer path) — the `prod_scale`
//!   multiply, the divide-by-step, the round and the clamp are hoisted
//!   out of `Quantizer::quantize` into
//!   [`Requant`](crate::quant::scheme::Requant), applied per register
//!   tile: no per-element function call, no per-element range assert.
//! * **Two-dimensional parallelism** — work splits over
//!   `(frequency × T-blocks)` instead of frequency only
//!   ([`parallel::par_for_states`]), so a small-`N²` layer with a wide
//!   tile axis no longer leaves workers idle.
//!
//! **Bit-parity is a hard constraint**, not a tolerance: the float tiled
//! path must equal [`panel_mul_f64_naive`] bit-for-bit and the integer
//! tiled path must equal
//! [`panel_mul_requant_i16_naive`](super::int::panel_mul_requant_i16_naive)
//! exactly (`rust/tests/gemm_property.rs` pins both over randomized
//! ragged shapes). Two design decisions follow from it:
//!
//! * **No channel (KC) blocking in the float kernel.** Splitting the
//!   channel reduction into partial sums would reassociate the f64
//!   accumulation chain (`((0 + p₀) + p₁) + …` per `(k, t)`) and change
//!   low bits. The micro-kernel therefore runs the **full** `C` reduction
//!   per register tile — i.e. `KC = C`. The hosted layer shapes keep
//!   `C ≤ 512`, so one `[C][MR]` weight micro-panel plus one `[C][NR]`
//!   input micro-panel is at most ~48 KB — L2-resident, which is what KC
//!   blocking buys anyway. The integer kernel's i64 accumulation is
//!   exact, so blocking *couldn't* perturb it, but it shares the same
//!   loop structure for simplicity.
//! * **The epilogue keeps `quantize`'s exact operation sequence**
//!   (`(acc·prod_scale) / scale`, round, clamp). Folding the two scale
//!   factors into one multiplier would introduce a second rounding (of
//!   `prod_scale / scale` itself) and flip codes near ties — see
//!   [`Quantizer::requant`](crate::quant::scheme::Quantizer::requant).
//!
//! Ragged edges (`K % MR ≠ 0`, `T % NR ≠ 0`) are handled by zero-padding
//! the *packed* operands: padded lanes are computed and discarded at
//! store time, so the hot loop has no tail branches. Padding cannot
//! perturb real outputs — each `(k, t)` accumulator chain is independent.

use std::time::Instant;

use super::parallel;
use crate::benchkit;
use crate::quant::scheme::{Quantizer, Requant};
use crate::wino::error::Prng;

/// Register-tile rows (output filters per micro-kernel). With `NR = 8`,
/// an `MR × NR` f64 accumulator block is 8 four-wide vector registers —
/// the classic auto-vectorizable shape on AVX2-class hardware, and small
/// enough to stay in registers on NEON too.
pub const MR: usize = 4;

/// Register-tile columns (tiles per micro-kernel). See [`MR`].
pub const NR: usize = 8;

/// `T`-axis cache-block width: one packed `[C][NC]` input block stays
/// resident while every `K` row-block streams over it. Must be a
/// multiple of [`NR`] so only the final block has a ragged tail.
pub const NC: usize = 256;

const _: () = assert!(NC % NR == 0, "NC must be a multiple of NR");

/// Worker count for one panel-GEMM dispatch: the thread pool clamped to
/// the `(frequency × T-block)` item grid the kernels split over. The
/// one definition callers size their packing-buffer leases with — keep
/// it in lockstep with the `nn * t_total.div_ceil(NC)` grid inside
/// [`panel_gemm_f64`] / [`panel_gemm_requant_i16`].
pub fn workers_for(nn: usize, t_total: usize) -> usize {
    parallel::num_threads().min(nn * t_total.div_ceil(NC)).max(1)
}

/// Geometry of one panel multiply: input channels, output filters and
/// frequency points (`N²`); the tile count `T` is inferred from the
/// panel lengths. Shared by the float and integer raw-slice entries
/// (re-exported as `engine::int::PanelDims` for the integer oracles).
#[derive(Clone, Copy, Debug)]
pub struct PanelDims {
    pub c: usize,
    pub k: usize,
    pub nn: usize,
}

/// A weight bank repacked for the micro-kernel: layout
/// `[N²][⌈K/MR⌉][C][MR]`, i.e. for one frequency point and one
/// `MR`-row block, the `MR` weights of each channel are contiguous.
/// Ragged `K` tails are zero-padded so the kernel never branches on row
/// count. Packed once at lowering time (engine construction /
/// [`IntWeightBank`](super::int::IntWeightBank) quantization) and shared
/// across served model variants via
/// [`PlanCache`](crate::serve::plan::PlanCache).
pub struct Packed<T> {
    /// Frequency points `N²`.
    pub nn: usize,
    /// Output filters (unpadded).
    pub k: usize,
    /// Input channels.
    pub c: usize,
    data: Vec<T>,
}

/// Packed float weight panels (the [`WinoEngine`](super::WinoEngine) bank).
pub type PackedF64 = Packed<f64>;

/// Packed i16 weight-code panels (the
/// [`IntWeightBank`](super::int::IntWeightBank) codes).
pub type PackedI16 = Packed<i16>;

impl<T: Copy> Packed<T> {
    /// Number of `MR`-row blocks covering `k` rows.
    #[inline]
    pub fn row_blocks(&self) -> usize {
        self.k.div_ceil(MR)
    }

    /// Repack a `[N²] × [K] × [C]` weight bank (supplied through the
    /// `at(f, k, c)` accessor so float mats and flat code slices share
    /// one packer) into the micro-kernel layout. `zero` pads ragged `K`
    /// tails.
    pub fn pack(
        nn: usize,
        k: usize,
        c: usize,
        zero: T,
        at: impl Fn(usize, usize, usize) -> T,
    ) -> Packed<T> {
        assert!(nn > 0 && k > 0 && c > 0, "degenerate panel shape");
        let kb = k.div_ceil(MR);
        let mut data = vec![zero; nn * kb * c * MR];
        for f in 0..nn {
            for b in 0..kb {
                let base = (f * kb + b) * c * MR;
                for ci in 0..c {
                    for i in 0..MR {
                        let ki = b * MR + i;
                        if ki < k {
                            data[base + ci * MR + i] = at(f, ki, ci);
                        }
                    }
                }
            }
        }
        Packed { nn, k, c, data }
    }

    /// The packed `[⌈K/MR⌉][C][MR]` panel for frequency point `f`.
    #[inline]
    pub fn panel(&self, f: usize) -> &[T] {
        let len = self.row_blocks() * self.c * MR;
        &self.data[f * len..][..len]
    }

    /// Reconstruct the row-major `[K][C]` panel for frequency `f` — the
    /// pre-packing layout, for tests and introspection (the packed form
    /// is the only one stored).
    pub fn unpacked_panel(&self, f: usize) -> Vec<T> {
        let pan = self.panel(f);
        let mut out = Vec::with_capacity(self.k * self.c);
        for ki in 0..self.k {
            let (b, i) = (ki / MR, ki % MR);
            for ci in 0..self.c {
                out.push(pan[(b * self.c + ci) * MR + i]);
            }
        }
        out
    }

    /// Packed element count (pad included) — memory-accounting helper.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Never empty by construction ([`pack`](Self::pack) rejects
    /// degenerate shapes); present for `len`/`is_empty` symmetry.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Pack one `(f, T-block)` slice of a `[C][N²][T]` input panel into
/// `buf`, layout `[⌈block.len()/NR⌉][C][NR]`: for each `NR`-wide column
/// block, the `C` channel rows are contiguous, unit-stride for the
/// micro-kernel. Ragged column tails are zero-padded **explicitly** (no
/// blanket memset of a buffer whose every real lane is about to be
/// overwritten — only the `cols < NR` tail lanes of the final column
/// block pay a fill). The buffer only grows (capacity and stale length
/// retained across calls — it lives in
/// [`EngineScratch`](super::scratch::EngineScratch)); the kernels read
/// exactly the `⌈block.len()/NR⌉ · C · NR` elements written here.
pub fn pack_x_block<T: Copy + Default>(
    xt: &[T],
    nn: usize,
    c: usize,
    t_total: usize,
    f: usize,
    block: std::ops::Range<usize>,
    buf: &mut Vec<T>,
) {
    let (tb, te) = (block.start, block.end);
    let njb = (te - tb).div_ceil(NR);
    let need = njb * c * NR;
    if buf.len() < need {
        buf.resize(need, T::default());
    }
    for jb in 0..njb {
        let t0 = tb + jb * NR;
        let cols = (te - t0).min(NR);
        for ci in 0..c {
            let src = &xt[(ci * nn + f) * t_total + t0..][..cols];
            let dst = &mut buf[(jb * c + ci) * NR..][..NR];
            dst[..cols].copy_from_slice(src);
            for pad in &mut dst[cols..] {
                *pad = T::default();
            }
        }
    }
}

/// Raw output cursor handed to the 2-D parallel loop. Each `(f, T-block)`
/// work item writes only rows `(f, k, tb..te)` of the `[N²][K][T]` output
/// — ranges that partition the buffer — so concurrent writers never
/// alias.
struct OutPtr<T>(*mut T);

// SAFETY: the pointer is only dereferenced through disjoint
// `(f, k, column-range)` row slices (one work item per `(f, T-block)`,
// see `panel_gemm_f64` / `panel_gemm_requant_i16`), and the pointee
// outlives the scoped threads that use it.
unsafe impl<T: Send> Send for OutPtr<T> {}
unsafe impl<T: Send> Sync for OutPtr<T> {}

/// Float per-frequency panel multiply over packed weights — stage 2 of
/// [`WinoEngine::execute_into`](super::WinoEngine::execute_into).
///
/// `xt` is `[C][N²][T]`, `had` is `[N²][K][T]`; every `had` element is
/// written exactly once (no pre-zeroing needed). When `fake` is set
/// (Fig. 2 quantized pipeline), the Hadamard cast is applied at store
/// time — elementwise on the fully-accumulated sums, the same values the
/// naive path casts after its accumulation loop. `packs` supplies one
/// input packing buffer per worker (at least one; see
/// [`parallel::par_for_states`]).
///
/// Bit-for-bit equal to [`panel_mul_f64_naive`]: each `(k, f, t)`
/// accumulator runs the identical `c = 0..C` fused chain, register-tiled
/// but never reassociated.
pub fn panel_gemm_f64(
    pw: &PackedF64,
    xt: &[f64],
    t_total: usize,
    fake: Option<&Quantizer>,
    had: &mut [f64],
    packs: &mut [Vec<f64>],
) {
    let (nn, k, c) = (pw.nn, pw.k, pw.c);
    assert_eq!(xt.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    let n_tb = t_total.div_ceil(NC);
    let out = OutPtr(had.as_mut_ptr());
    parallel::par_for_states(nn * n_tb, packs, |item, buf| {
        let f = item / n_tb;
        let tb = (item % n_tb) * NC;
        let te = (tb + NC).min(t_total);
        pack_x_block(xt, nn, c, t_total, f, tb..te, buf);
        let wpan = pw.panel(f);
        let njb = (te - tb).div_ceil(NR);
        for b in 0..k.div_ceil(MR) {
            let a = &wpan[b * c * MR..][..c * MR];
            let rows = (k - b * MR).min(MR);
            for jb in 0..njb {
                let bx = &buf[jb * c * NR..][..c * NR];
                let mut acc = [[0.0f64; NR]; MR];
                for ci in 0..c {
                    let av = &a[ci * MR..][..MR];
                    let bv = &bx[ci * NR..][..NR];
                    for (ai, av) in av.iter().enumerate() {
                        for (bj, bv) in bv.iter().enumerate() {
                            acc[ai][bj] += av * bv;
                        }
                    }
                }
                let t0 = tb + jb * NR;
                let cols = (te - t0).min(NR);
                for (i, acc_row) in acc.iter().enumerate().take(rows) {
                    // SAFETY: rows `(f, b·MR + i, t0..t0+cols)` are
                    // disjoint across work items and across `i`; `had`
                    // outlives the parallel scope and is not otherwise
                    // touched while it runs.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add((f * k + b * MR + i) * t_total + t0),
                            cols,
                        )
                    };
                    match fake {
                        Some(q) => {
                            for (dst, &v) in row.iter_mut().zip(acc_row) {
                                *dst = q.fake(v);
                            }
                        }
                        None => row.copy_from_slice(&acc_row[..cols]),
                    }
                }
            }
        }
    });
}

/// Integer per-frequency panel multiply with the fused requantize
/// epilogue — stage 2 of
/// [`IntWinoEngine::execute_into`](super::int::IntWinoEngine::execute_into).
///
/// `xt_codes` is `[C][N²][T]` i16, `had_codes` is `[N²][K][T]` i32.
/// Products are widened i16×i16→i32 and accumulated in i64 register
/// tiles (exact for any hosted `C`, so register tiling cannot perturb
/// the result); each finished accumulator is requantized through `rq`
/// ([`Quantizer::requant`]) — bit-identical to
/// `hq.quantize(acc as f64 * prod_scale)` by construction.
pub fn panel_gemm_requant_i16(
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
) {
    let sat = std::sync::atomic::AtomicU64::new(0);
    panel_gemm_requant_i16_counted(pw, xt_codes, t_total, rq, had_codes, packs, &sat);
}

/// [`panel_gemm_requant_i16`] with numeric-health accounting: `sat`
/// accumulates how many output codes the requant epilogue clamped
/// (via [`Requant::apply_sat`] — value path bit-identical to
/// [`Requant::apply`]). Each `(f, T-block)` work item counts locally
/// and folds in with **one** relaxed `fetch_add`, so the counter costs
/// one add per output element plus one atomic per work item — invisible
/// next to the `C`-deep reduction it rides on.
pub fn panel_gemm_requant_i16_counted(
    pw: &PackedI16,
    xt_codes: &[i16],
    t_total: usize,
    rq: &Requant,
    had_codes: &mut [i32],
    packs: &mut [Vec<i16>],
    sat: &std::sync::atomic::AtomicU64,
) {
    let (nn, k, c) = (pw.nn, pw.k, pw.c);
    assert_eq!(xt_codes.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had_codes.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    let n_tb = t_total.div_ceil(NC);
    let out = OutPtr(had_codes.as_mut_ptr());
    parallel::par_for_states(nn * n_tb, packs, |item, buf| {
        let f = item / n_tb;
        let tb = (item % n_tb) * NC;
        let te = (tb + NC).min(t_total);
        pack_x_block(xt_codes, nn, c, t_total, f, tb..te, buf);
        let wpan = pw.panel(f);
        let njb = (te - tb).div_ceil(NR);
        let mut local_sat = 0u64;
        for b in 0..k.div_ceil(MR) {
            let a = &wpan[b * c * MR..][..c * MR];
            let rows = (k - b * MR).min(MR);
            for jb in 0..njb {
                let bx = &buf[jb * c * NR..][..c * NR];
                let mut acc = [[0i64; NR]; MR];
                for ci in 0..c {
                    let av = &a[ci * MR..][..MR];
                    let bv = &bx[ci * NR..][..NR];
                    for (ai, &av) in av.iter().enumerate() {
                        let aw = av as i32;
                        for (bj, &bv) in bv.iter().enumerate() {
                            acc[ai][bj] += (aw * bv as i32) as i64;
                        }
                    }
                }
                let t0 = tb + jb * NR;
                let cols = (te - t0).min(NR);
                for (i, acc_row) in acc.iter().enumerate().take(rows) {
                    // SAFETY: see `panel_gemm_f64` — same disjoint
                    // `(f, row, column-range)` partition.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(
                            out.0.add((f * k + b * MR + i) * t_total + t0),
                            cols,
                        )
                    };
                    for (dst, &v) in row.iter_mut().zip(acc_row) {
                        let (code, clipped) = rq.apply_sat(v);
                        *dst = code;
                        local_sat += u64::from(clipped);
                    }
                }
            }
        }
        if local_sat > 0 {
            sat.fetch_add(local_sat, std::sync::atomic::Ordering::Relaxed);
        }
    });
}

/// `T`-dimension block size of the retired in-engine float loop, kept in
/// the oracle so [`panel_mul_f64_naive`] is the literal old stage-2 path.
const NAIVE_T_BLOCK: usize = 512;

/// The pre-tiling float stage-2 loop, verbatim — the oracle the property
/// suite pins [`panel_gemm_f64`] against bit-for-bit, and the baseline
/// `BENCH_gemm.json` times. `wt_panels` is the unpacked `[N²][K][C]`
/// flat bank; `had` is fully overwritten (the old engine zero-filled it
/// at `prepare` time and accumulated with `+=`; this oracle owns the
/// zeroing so callers treat both paths identically). Parallel over
/// frequency points exactly like the old stage 2, so the bench ratio
/// isolates the tiling/packing win, not a threading difference (set
/// `WINOQ_THREADS=1` to force both serial).
pub fn panel_mul_f64_naive(
    wt_panels: &[f64],
    dims: PanelDims,
    xt: &[f64],
    t_total: usize,
    fake: Option<&Quantizer>,
    had: &mut [f64],
) {
    let PanelDims { c, k, nn } = dims;
    assert_eq!(wt_panels.len(), nn * k * c, "wt panel not [N²][K][C]");
    assert_eq!(xt.len(), c * nn * t_total, "xt panel not [C][N²][T]");
    assert_eq!(had.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    parallel::par_chunks_mut(had, k * t_total, |f, panel| {
        panel.fill(0.0);
        let wpan = &wt_panels[f * k * c..][..k * c];
        let mut tb = 0;
        while tb < t_total {
            let te = (tb + NAIVE_T_BLOCK).min(t_total);
            for ki in 0..k {
                let row = &mut panel[ki * t_total..][..t_total];
                for ci in 0..c {
                    let wkc = wpan[ki * c + ci];
                    let xrow = &xt[(ci * nn + f) * t_total..][..t_total];
                    for t in tb..te {
                        row[t] += wkc * xrow[t];
                    }
                }
            }
            tb = te;
        }
        if let Some(s) = fake {
            for v in panel.iter_mut() {
                *v = s.fake(*v);
            }
        }
    });
}

/// Time the tiled kernels against their naive oracles on one synthetic
/// shape, returning `(BENCH_gemm JSON, float ratio, int ratio)` where
/// each ratio is tiled-over-naive tiles/sec. Shared by
/// `benches/conv_throughput.rs` and `winoq bench --gemm-json`; the run
/// also *asserts* bit-parity on the measured buffers, so an emitted JSON
/// doubles as a parity witness.
pub fn gemm_bench_json(
    c: usize,
    k: usize,
    t_total: usize,
    nn: usize,
    warmup: usize,
    samples: usize,
) -> (String, f64, f64) {
    let mut rng = Prng::new(0x6E77);
    let wt: Vec<f64> = (0..nn * k * c).map(|_| rng.uniform(0.5)).collect();
    let xt: Vec<f64> = (0..c * nn * t_total).map(|_| rng.uniform(1.0)).collect();
    let pw = Packed::pack(nn, k, c, 0.0f64, |f, ki, ci| wt[(f * k + ki) * c + ci]);
    let samples = samples.max(1);
    let workers = workers_for(nn, t_total);
    let mut packs: Vec<Vec<f64>> = vec![Vec::new(); workers];

    let mut had_tiled = vec![0.0f64; nn * k * t_total];
    let s_f_tiled = benchkit::bench(warmup, samples, || {
        panel_gemm_f64(&pw, &xt, t_total, None, &mut had_tiled, &mut packs)
    });
    let dims = PanelDims { c, k, nn };
    let mut had_naive = vec![0.0f64; nn * k * t_total];
    let s_f_naive = benchkit::bench(warmup, samples, || {
        panel_mul_f64_naive(&wt, dims, &xt, t_total, None, &mut had_naive)
    });
    for (i, (a, b)) in had_tiled.iter().zip(&had_naive).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "float gemm parity broke at {i}");
    }

    let wt_i: Vec<i16> = (0..nn * k * c)
        .map(|_| (rng.next_u64() % 255) as i16 - 127)
        .collect();
    let xt_i: Vec<i16> = (0..c * nn * t_total)
        .map(|_| (rng.next_u64() % 511) as i16 - 255)
        .collect();
    let pwi = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt_i[(f * k + ki) * c + ci]);
    let hq = Quantizer::with_scale(9, 3.1e-4);
    let prod_scale = 1.7e-4;
    let rq = hq.requant(prod_scale);
    let mut ipacks: Vec<Vec<i16>> = vec![Vec::new(); workers];
    let mut ihad_tiled = vec![0i32; nn * k * t_total];
    let s_i_tiled = benchkit::bench(warmup, samples, || {
        panel_gemm_requant_i16(&pwi, &xt_i, t_total, &rq, &mut ihad_tiled, &mut ipacks)
    });
    let mut ihad_naive = vec![0i32; nn * k * t_total];
    let s_i_naive = benchkit::bench(warmup, samples, || {
        super::int::panel_mul_requant_i16_naive(
            &xt_i,
            &wt_i,
            dims,
            prod_scale,
            &hq,
            &mut ihad_naive,
        )
    });
    assert_eq!(ihad_tiled, ihad_naive, "int gemm parity broke");

    let tps = |median: f64| t_total as f64 / median.max(1e-12);
    let (ftt, ftn) = (tps(s_f_tiled.median), tps(s_f_naive.median));
    let (itt, itn) = (tps(s_i_tiled.median), tps(s_i_naive.median));
    let fr = if ftn > 0.0 { ftt / ftn } else { 0.0 };
    let ir = if itn > 0.0 { itt / itn } else { 0.0 };
    let json = format!(
        concat!(
            "{{\"bench\": \"gemm\", \"mr\": {}, \"nr\": {}, \"nc\": {}, ",
            "\"shape\": {{\"c\": {}, \"k\": {}, \"t\": {}, \"nn\": {}}}, ",
            "\"threads\": {}, ",
            "\"float\": {{\"tiled_seconds\": {:e}, \"naive_seconds\": {:e}, ",
            "\"tiled_tiles_per_sec\": {:.1}, \"naive_tiles_per_sec\": {:.1}, ",
            "\"ratio_tiled_vs_naive\": {:.3}}}, ",
            "\"int\": {{\"tiled_seconds\": {:e}, \"naive_seconds\": {:e}, ",
            "\"tiled_tiles_per_sec\": {:.1}, \"naive_tiles_per_sec\": {:.1}, ",
            "\"ratio_tiled_vs_naive\": {:.3}}}}}"
        ),
        MR,
        NR,
        NC,
        c,
        k,
        t_total,
        nn,
        parallel::num_threads(),
        s_f_tiled.median,
        s_f_naive.median,
        ftt,
        ftn,
        fr,
        s_i_tiled.median,
        s_i_naive.median,
        itt,
        itn,
        ir,
    );
    (json, fr, ir)
}

/// Cumulative per-stage wall time of an engine pass, nanoseconds:
/// `[input-transform, hadamard/GEMM, inverse]`. Accumulated into
/// [`EngineScratch`](super::scratch::EngineScratch) by both engines so
/// serving workers and benches can report **which** stage moved.
pub type StageNs = [u64; 3];

/// Elapsed nanoseconds since `t0`, saturating into the `u64` the stage
/// counters use.
pub(super) fn ns_since(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_and_unpack_roundtrip() {
        // 2 freqs, K=5 (ragged over MR=4), C=3.
        let (nn, k, c) = (2, 5, 3);
        let src: Vec<f64> = (0..nn * k * c).map(|i| i as f64 + 1.0).collect();
        let p = Packed::pack(nn, k, c, 0.0, |f, ki, ci| src[(f * k + ki) * c + ci]);
        assert_eq!(p.row_blocks(), 2);
        assert_eq!(p.len(), nn * 2 * c * MR);
        for f in 0..nn {
            // Unpacked reconstruction matches the source panel exactly.
            assert_eq!(p.unpacked_panel(f), src[f * k * c..][..k * c].to_vec());
            // Padded lanes (rows 5..8 of block 1) are zero.
            let pan = p.panel(f);
            for ci in 0..c {
                for i in 1..MR {
                    assert_eq!(pan[(c + ci) * MR + i], 0.0, "pad lane must be zero");
                }
            }
        }
    }

    #[test]
    fn pack_x_block_pads_ragged_columns_even_in_dirty_buffers() {
        let (nn, c, t) = (2, 3, 11);
        let xt: Vec<f64> = (0..c * nn * t).map(|i| i as f64).collect();
        // A reused buffer full of garbage (and longer than needed) must
        // produce the identical packing: real lanes overwritten, pad
        // lanes of the ragged tail explicitly zeroed, excess untouched.
        let mut buf = vec![999.25; c * NR + 7];
        // Block [8, 11): 3 real columns, 5 padded.
        pack_x_block(&xt, nn, c, t, 1, 8..11, &mut buf);
        assert!(buf.len() >= c * NR);
        for ci in 0..c {
            for j in 0..NR {
                let want = if j < 3 { xt[(ci * nn + 1) * t + 8 + j] } else { 0.0 };
                assert_eq!(buf[ci * NR + j], want, "({ci},{j})");
            }
        }
        // A fresh buffer grows to exactly the needed length.
        let mut fresh = Vec::new();
        pack_x_block(&xt, nn, c, t, 1, 8..11, &mut fresh);
        assert_eq!(fresh.len(), c * NR);
        assert_eq!(fresh[..], buf[..c * NR]);
    }

    #[test]
    fn tiled_float_matches_naive_bitwise_ragged() {
        // K and T both ragged, C=1 edge, multi-T-block widths.
        let mut rng = Prng::new(7);
        for &(c, k, t, nn) in &[
            (1usize, 1usize, 1usize, 4usize),
            (3, 5, 13, 4),
            (2, 9, NR + 1, 1),
            (5, 4, NC + 3, 2),
        ] {
            let wt: Vec<f64> = (0..nn * k * c).map(|_| rng.uniform(1.0)).collect();
            let xt: Vec<f64> = (0..c * nn * t).map(|_| rng.uniform(1.0)).collect();
            let pw = Packed::pack(nn, k, c, 0.0, |f, ki, ci| wt[(f * k + ki) * c + ci]);
            for fake in [None, Some(Quantizer::with_scale(9, 0.037))] {
                let mut tiled = vec![f64::NAN; nn * k * t];
                let mut packs = vec![Vec::new(); 3];
                panel_gemm_f64(&pw, &xt, t, fake.as_ref(), &mut tiled, &mut packs);
                let mut naive = vec![0.0; nn * k * t];
                panel_mul_f64_naive(
                    &wt,
                    PanelDims { c, k, nn },
                    &xt,
                    t,
                    fake.as_ref(),
                    &mut naive,
                );
                for (i, (a, b)) in tiled.iter().zip(&naive).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "(c={c},k={k},t={t},nn={nn}) idx {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bench_emitter_reports_both_ratios_and_valid_json() {
        let (json, fr, ir) = gemm_bench_json(4, 5, 37, 4, 0, 1);
        assert!(json.contains("\"bench\": \"gemm\""), "{json}");
        assert!(fr > 0.0 && ir > 0.0, "degenerate ratios: {fr} {ir}");
        let doc = crate::tune::json::parse(&json).unwrap();
        for path in ["float", "int"] {
            let section = doc.get(path).unwrap();
            assert!(section.get("ratio_tiled_vs_naive").is_some(), "{json}");
            assert!(section.get("tiled_tiles_per_sec").is_some(), "{json}");
        }
    }

    #[test]
    fn counted_kernel_matches_and_counts_exact_clips() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let mut rng = Prng::new(0x5A7);
        let (c, k, t, nn) = (3usize, 5usize, 13usize, 4usize);
        let wt: Vec<i16> =
            (0..nn * k * c).map(|_| (rng.next_u64() % 255) as i16 - 127).collect();
        let xt: Vec<i16> =
            (0..c * nn * t).map(|_| (rng.next_u64() % 511) as i16 - 255).collect();
        let pw = Packed::pack(nn, k, c, 0i16, |f, ki, ci| wt[(f * k + ki) * c + ci]);
        // A coarse requant scale so a good fraction of accumulators clip.
        let hq = Quantizer::with_scale(8, 1.0);
        let rq = hq.requant(0.01);
        let mut plain = vec![0i32; nn * k * t];
        panel_gemm_requant_i16(&pw, &xt, t, &rq, &mut plain, &mut [Vec::new()]);
        let sat = AtomicU64::new(0);
        let mut counted = vec![0i32; nn * k * t];
        panel_gemm_requant_i16_counted(
            &pw,
            &xt,
            t,
            &rq,
            &mut counted,
            &mut [Vec::new()],
            &sat,
        );
        assert_eq!(plain, counted, "counting must not perturb output codes");
        // Oracle count straight from a scalar re-accumulation.
        let mut want = 0u64;
        for f in 0..nn {
            for ki in 0..k {
                for ti in 0..t {
                    let mut acc = 0i64;
                    for ci in 0..c {
                        let a = wt[(f * k + ki) * c + ci] as i32;
                        let b = xt[(ci * nn + f) * t + ti] as i32;
                        acc += (a * b) as i64;
                    }
                    want += u64::from(rq.apply_sat(acc).1);
                }
            }
        }
        assert!(want > 0, "fixture must actually clip");
        assert_eq!(sat.load(Ordering::Relaxed), want);
    }

    #[test]
    fn zero_tiles_is_a_no_op() {
        let pw = Packed::pack(1, 1, 1, 0.0, |_, _, _| 1.0);
        let mut had: Vec<f64> = Vec::new();
        panel_gemm_f64(&pw, &[], 0, None, &mut had, &mut [Vec::new()]);
        let pwi = Packed::pack(1, 1, 1, 0i16, |_, _, _| 1);
        let rq = Quantizer::with_scale(8, 1.0).requant(1.0);
        let mut ihad: Vec<i32> = Vec::new();
        panel_gemm_requant_i16(&pwi, &[], 0, &rq, &mut ihad, &mut [Vec::new()]);
    }
}
