//! Persistent work-stealing worker pool — the fix for the
//! per-dispatch spawn tax.
//!
//! [`parallel`](super::parallel)'s primitives used to spawn `W − 1`
//! scoped threads on **every** dispatch, so a serve-path micro-batch
//! paid thread creation + teardown per engine stage — three times per
//! batch — which masks the Winograd multiplication win exactly where
//! the paper claims it (the Hadamard panel GEMM). This module owns a
//! process-wide set of **parked** worker threads created once
//! ([`global`]); a dispatch is now a condvar wake, not `W − 1`
//! `clone(2)` calls.
//!
//! # Execution model
//!
//! A dispatch ([`WorkerPool::dispatch`]) publishes one job: a
//! lifetime-erased `Fn(item, slot)` plus a shared atomic item counter.
//! Participants — the **calling thread always included** — claim items
//! one at a time from the counter, which is work stealing in its
//! simplest honest form: a fast participant drains more of the range, a
//! slow one is never waited on mid-range. Each participant holds a
//! distinct **slot** in `0..max_slots` for the whole job (the caller is
//! always slot 0), which is what
//! [`par_for_states`](super::parallel::par_for_states) leases per-worker
//! packing buffers against: slot exclusivity makes `&mut states[slot]`
//! race-free even though item claiming is dynamic.
//!
//! The caller pre-claims item 0 before waking the pool, so it always
//! participates (pinned by the `parallel` tests), then joins the shared
//! counter. When the counter drains, the caller unlists the job and
//! blocks until every pool participant has left the closure — only then
//! does `dispatch` return, which is the safety contract that lets the
//! job borrow the caller's stack (`f`, the data pointers inside it)
//! without lifetimes.
//!
//! # Panic safety
//!
//! Every item runs under `catch_unwind`. The first payload is stored on
//! the job and **re-raised on the calling thread** after all
//! participants finish, so a panicking kernel looks exactly like it did
//! under scoped spawning (the caller unwinds, tests can `should_panic`)
//! while the pool threads survive to serve the next dispatch. Shutdown
//! ([`Drop`]) parks no ghosts: it flags, wakes everyone, and joins.
//!
//! Concurrency across dispatches: multiple serve workers can dispatch
//! simultaneously — jobs queue side by side and idle pool threads pick
//! whichever has unclaimed slots and items, so one worker's batch does
//! not serialize another's.
//!
//! # Self-healing
//!
//! Pool threads normally never die (`run_items` catches every item
//! panic), but a thread lost anyway — an injected exit via
//! [`request_worker_exit`], or anything that unwinds outside the item
//! closure — must not shrink the pool for the rest of the process.
//! [`WorkerPool::replenish`] reaps finished threads and respawns back to
//! the construction-time target, counting respawns in the
//! `pool.respawned` metric ([`export_metrics`]). Every [`dispatch`]
//! cheaply checks the live count and replenishes first when short, and
//! the serve supervisor calls the module-level [`replenish`] after each
//! worker restart — so a panic that quenched pool threads is healed
//! before the next batch needs them. A shrunken (even empty) pool never
//! deadlocks a dispatch regardless: the caller always participates and
//! drains unclaimed items itself.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Raw mutable pointer wrapper the dispatch closures use to smuggle a
/// slice base across threads; safety rests on the caller's disjointness
/// argument (distinct items / distinct slots never alias).
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// One published dispatch: the erased closure, the stealing counter,
/// slot allocation, and completion/panic state.
struct Job {
    /// Borrow of the dispatching caller's closure, transmuted to
    /// `'static`. Sound because `dispatch` does not return until
    /// `active == 0` and the job is unlisted — no participant can
    /// touch `run` after the real borrow ends.
    run: &'static (dyn Fn(usize, usize) + Sync),
    n_items: usize,
    /// Next unclaimed item — the work-stealing counter. Starts at 1;
    /// the caller pre-claims item 0.
    next_item: AtomicUsize,
    /// Next unclaimed slot; pool workers claim (under the pool lock)
    /// from 1 upward, the caller is slot 0.
    next_slot: AtomicUsize,
    max_slots: usize,
    /// Pool participants currently inside the closure (caller excluded).
    active: AtomicUsize,
    /// Latched on first panic so other participants stop claiming.
    panicked: AtomicBool,
    /// First panic payload, re-raised by the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Job {
    /// Can a fresh pool worker still contribute? (Checked under the
    /// pool lock, which makes check-then-claim atomic.)
    fn claimable(&self) -> bool {
        !self.panicked.load(Ordering::Relaxed)
            && self.next_slot.load(Ordering::Relaxed) < self.max_slots
            && self.next_item.load(Ordering::Relaxed) < self.n_items
    }
}

/// Claim items off `job`'s counter and run them as `slot` until the
/// counter drains (or a panic latches). `first` is a pre-claimed item.
fn run_items(job: &Job, slot: usize, mut first: Option<usize>) {
    loop {
        let i = match first.take() {
            Some(i) => i,
            None => {
                if job.panicked.load(Ordering::Relaxed) {
                    break;
                }
                let i = job.next_item.fetch_add(1, Ordering::Relaxed);
                if i >= job.n_items {
                    break;
                }
                i
            }
        };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| (job.run)(i, slot))) {
            job.panicked.store(true, Ordering::Relaxed);
            let mut p = job.panic.lock().unwrap();
            if p.is_none() {
                *p = Some(payload);
            }
        }
    }
}

struct PoolState {
    jobs: Vec<Arc<Job>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for claimable jobs (or shutdown).
    work_cv: Condvar,
    /// Callers park here waiting for their job's `active` to hit 0.
    done_cv: Condvar,
    /// Worker threads currently running `worker_main` (guard-decremented
    /// on every exit path) — the cheap "are we short?" signal
    /// [`WorkerPool::dispatch`] gates replenishment on.
    alive: AtomicUsize,
    /// Fault-injection hook: pending requests for a worker thread to
    /// exit ([`WorkerPool::request_worker_exit`]). Each parked or
    /// between-jobs worker consumes at most one and returns.
    exit_requests: AtomicUsize,
}

/// Process-lifetime count of pool threads respawned by
/// [`WorkerPool::replenish`] — exported as `pool.respawned`.
static RESPAWNED: AtomicU64 = AtomicU64::new(0);

/// A set of parked worker threads that repeatedly join published jobs,
/// replenished back to its construction-time target whenever threads
/// are lost. Create one explicitly for tests; production code shares
/// [`global`].
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Construction-time thread count [`replenish`](Self::replenish)
    /// restores toward.
    target: usize,
    /// Monotonic name counter so respawned threads get fresh names.
    next_name: AtomicUsize,
}

impl WorkerPool {
    /// Spawn `threads` parked workers (0 is valid: every dispatch then
    /// runs entirely on the caller, which is also the serial-machine
    /// configuration).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { jobs: Vec::new(), shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            alive: AtomicUsize::new(0),
            exit_requests: AtomicUsize::new(0),
        });
        let pool = WorkerPool {
            shared,
            handles: Mutex::new(Vec::with_capacity(threads)),
            target: threads,
            next_name: AtomicUsize::new(0),
        };
        {
            let mut handles = pool.handles.lock().unwrap();
            for _ in 0..threads {
                let h = pool.spawn_worker();
                handles.push(h);
            }
        }
        pool
    }

    /// Spawn one worker thread, pre-registering it as alive (so a
    /// concurrent dispatch's shortness check never double-counts a gap
    /// that is already being filled).
    fn spawn_worker(&self) -> JoinHandle<()> {
        let shared = self.shared.clone();
        let i = self.next_name.fetch_add(1, Ordering::Relaxed);
        self.shared.alive.fetch_add(1, Ordering::Relaxed);
        std::thread::Builder::new()
            .name(format!("winoq-pool-{i}"))
            .spawn(move || worker_main(&shared))
            .expect("spawn pool worker")
    }

    /// The pool's target worker-thread count (the caller adds one more
    /// participant on top at dispatch time).
    pub fn threads(&self) -> usize {
        self.target
    }

    /// Worker threads currently live (≤ [`threads`](Self::threads)
    /// until [`replenish`](Self::replenish) heals a loss).
    pub fn alive(&self) -> usize {
        self.shared.alive.load(Ordering::Relaxed)
    }

    /// Ask `n` worker threads to exit (fault injection for the chaos
    /// suite — production threads never exit on their own). Each parked
    /// or between-jobs worker consumes one request and returns; the
    /// next [`dispatch`](Self::dispatch) or explicit
    /// [`replenish`](Self::replenish) respawns replacements.
    pub fn request_worker_exit(&self, n: usize) {
        self.shared.exit_requests.fetch_add(n, Ordering::Relaxed);
        // Take the pool lock before waking so a worker between its
        // exit check and `wait` cannot miss the notification.
        let _st = self.shared.state.lock().unwrap();
        self.shared.work_cv.notify_all();
    }

    /// Reap finished worker threads and respawn back to the target
    /// count; returns how many were respawned (also added to the
    /// `pool.respawned` metric). Idempotent and cheap when nothing
    /// died.
    pub fn replenish(&self) -> usize {
        let mut handles = self.handles.lock().unwrap();
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let _ = handles.remove(i).join();
            } else {
                i += 1;
            }
        }
        let mut spawned = 0;
        while handles.len() < self.target {
            let h = self.spawn_worker();
            handles.push(h);
            spawned += 1;
        }
        if spawned > 0 {
            RESPAWNED.fetch_add(spawned as u64, Ordering::Relaxed);
        }
        spawned
    }

    /// Run `f(item, slot)` for every `item in 0..n_items` across at
    /// most `max_workers` participants (caller + pool workers), each
    /// holding a distinct `slot in 0..max_workers` for the whole
    /// dispatch. Items are claimed dynamically off a shared counter;
    /// slots are exclusive. Blocks until every item has run and every
    /// participant has left `f`; re-raises the first panic.
    pub fn dispatch<F>(&self, n_items: usize, max_workers: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n_items == 0 {
            return;
        }
        let max_slots = max_workers.max(1).min(n_items);
        if max_slots <= 1 || self.target == 0 {
            for i in 0..n_items {
                f(i, 0);
            }
            return;
        }
        // Self-healing: if any worker thread died since the last
        // dispatch, respawn before publishing (one relaxed load on the
        // happy path).
        if self.shared.alive.load(Ordering::Relaxed) < self.target {
            self.replenish();
        }
        // Lifetime erasure: the wait below keeps the borrow alive for
        // every participant, see the safety note on `Job::run`.
        let local: &(dyn Fn(usize, usize) + Sync) = &f;
        let run = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, usize) + Sync),
                &'static (dyn Fn(usize, usize) + Sync),
            >(local)
        };
        let job = Arc::new(Job {
            run,
            n_items,
            next_item: AtomicUsize::new(1),
            next_slot: AtomicUsize::new(1),
            max_slots,
            active: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            panic: Mutex::new(None),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.push(job.clone());
            self.shared.work_cv.notify_all();
        }
        // The caller is slot 0 and pre-claimed item 0.
        run_items(&job, 0, Some(0));
        // Unlist (no new participants), then wait out the active ones.
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        while job.active.load(Ordering::Relaxed) > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        drop(st);
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    /// Panic-safe shutdown: flag, wake every parked worker, join all of
    /// them. Workers finish any job they are inside first (callers of
    /// in-flight dispatches are still blocked in `dispatch`, which
    /// keeps their borrows alive until the workers leave).
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.get_mut().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(shared: &PoolShared) {
    /// Decrements the pool's live count on *any* exit path (requested
    /// exit today; an unexpected unwind would also be counted, so the
    /// next dispatch heals it).
    struct AliveGuard<'a>(&'a PoolShared);
    impl Drop for AliveGuard<'_> {
        fn drop(&mut self) {
            self.0.alive.fetch_sub(1, Ordering::Relaxed);
        }
    }
    let _alive = AliveGuard(shared);
    let mut st = shared.state.lock().unwrap();
    loop {
        // Consume at most one pending exit request (fault injection).
        if shared
            .exit_requests
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
            .is_ok()
        {
            return;
        }
        let found = st.jobs.iter().find(|j| j.claimable()).cloned();
        if let Some(job) = found {
            // Check-then-claim is atomic: both happen under the lock.
            let slot = job.next_slot.fetch_add(1, Ordering::Relaxed);
            job.active.fetch_add(1, Ordering::Relaxed);
            drop(st);
            run_items(&job, slot, None);
            st = shared.state.lock().unwrap();
            job.active.fetch_sub(1, Ordering::Relaxed);
            shared.done_cv.notify_all();
            continue;
        }
        if st.shutdown {
            return;
        }
        st = shared.work_cv.wait(st).unwrap();
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool every [`parallel`](super::parallel) primitive
/// dispatches through. Created lazily with
/// [`num_threads`](super::parallel::num_threads)` − 1` workers (the
/// caller is the final participant); the serve session and the bench
/// runners call [`warm`] up front so the one-time thread creation never
/// lands inside a measured or deadline-bound dispatch.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(super::parallel::num_threads().saturating_sub(1)))
}

/// Force-create the global pool (idempotent). Called at serve-session
/// and bench start so worker threads exist before the first request.
pub fn warm() {
    let _ = global();
}

/// Replenish the global pool back to its target thread count (no-op if
/// nothing died, or if the pool was never created). The serve
/// supervisor calls this after every worker restart so a panic that
/// took pool threads with it is healed before the next batch.
pub fn replenish() -> usize {
    GLOBAL.get().map_or(0, WorkerPool::replenish)
}

/// Ask `n` global-pool threads to exit (chaos fault injection; forces
/// creation so the request has someone to land on).
pub fn request_worker_exit(n: usize) {
    global().request_worker_exit(n);
}

/// Pool threads respawned over the process lifetime (all pools).
pub fn respawned() -> u64 {
    RESPAWNED.load(Ordering::Relaxed)
}

/// Publish the pool's health counters: `pool.respawned` plus the
/// global pool's target/alive thread gauges.
pub fn export_metrics(reg: &crate::obs::MetricsRegistry) {
    reg.inc("pool.respawned", respawned());
    if let Some(pool) = GLOBAL.get() {
        reg.set_gauge("pool.threads", pool.threads() as f64);
        reg.set_gauge("pool.alive", pool.alive() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_item_runs_exactly_once() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.dispatch(257, 4, |i, _slot| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn caller_participates_and_slots_are_exclusive() {
        let pool = WorkerPool::new(3);
        let ids = Mutex::new(HashSet::new());
        // Slot exclusivity: each slot's cell is touched by exactly one
        // thread, tracked by stashing the thread id per slot.
        let slot_owner: Vec<Mutex<Option<std::thread::ThreadId>>> =
            (0..4).map(|_| Mutex::new(None)).collect();
        pool.dispatch(64, 4, |_i, slot| {
            let me = std::thread::current().id();
            ids.lock().unwrap().insert(me);
            let mut owner = slot_owner[slot].lock().unwrap();
            match *owner {
                None => *owner = Some(me),
                Some(prev) => assert_eq!(prev, me, "slot {slot} switched threads"),
            }
        });
        let ids = ids.into_inner().unwrap();
        assert!(ids.len() <= 4, "4 slots must use at most 4 threads");
        assert!(
            ids.contains(&std::thread::current().id()),
            "the calling thread must work items itself (it pre-claims item 0)"
        );
        // The caller always owns slot 0.
        assert_eq!(
            slot_owner[0].lock().unwrap().expect("slot 0 ran"),
            std::thread::current().id()
        );
    }

    #[test]
    fn pool_threads_are_reused_across_dispatches_no_churn() {
        let pool = WorkerPool::new(2);
        let me = std::thread::current().id();
        let mut helper_ids: HashSet<std::thread::ThreadId> = HashSet::new();
        for _ in 0..8 {
            let ids = Mutex::new(HashSet::new());
            pool.dispatch(512, 3, |_i, _slot| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
            for id in ids.into_inner().unwrap() {
                if id != me {
                    helper_ids.insert(id);
                }
            }
        }
        // Reuse, not churn: across 8 dispatches every non-caller
        // participant is one of the pool's 2 persistent threads.
        assert!(
            helper_ids.len() <= pool.threads(),
            "expected at most {} distinct helper threads, saw {}",
            pool.threads(),
            helper_ids.len()
        );
    }

    #[test]
    fn panic_propagates_to_caller_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.dispatch(32, 3, |i, _slot| {
                if i == 7 {
                    panic!("kernel blew up on item 7");
                }
            });
        }))
        .expect_err("dispatch must re-raise the job panic");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("item 7"), "payload must survive: {msg:?}");
        // Pool still works after a panicking job (panic-safe workers) …
        let hits = AtomicUsize::new(0);
        pool.dispatch(16, 3, |_i, _slot| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        // … and shutdown joins cleanly.
        drop(pool);
    }

    #[test]
    fn zero_items_zero_threads_and_serial_paths() {
        let pool = WorkerPool::new(0);
        pool.dispatch(0, 4, |_, _| panic!("no items expected"));
        // No pool threads: everything runs on the caller, slot 0.
        pool.dispatch(5, 4, |_, slot| assert_eq!(slot, 0));
        let pool = WorkerPool::new(2);
        pool.dispatch(0, 4, |_, _| panic!("no items expected"));
        // max_workers == 1 short-circuits to the in-place serial loop.
        pool.dispatch(5, 1, |_, slot| assert_eq!(slot, 0));
    }

    #[test]
    fn concurrent_dispatches_from_multiple_callers_complete() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let pool = pool.clone();
                let total = total.clone();
                scope.spawn(move || {
                    for _ in 0..10 {
                        pool.dispatch(100, 3, |_i, _slot| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 100);
    }

    #[test]
    fn requested_exits_are_healed_by_the_next_dispatch() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.alive(), 3);
        let before = respawned();
        pool.request_worker_exit(2);
        // The exit requests land on parked workers; wait for them to
        // actually die (bounded poll, virtue of the alive guard).
        for _ in 0..1000 {
            if pool.alive() <= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.alive(), 1, "two workers must have exited");
        // A shrunken pool still completes dispatches (the caller
        // participates and drains), and the dispatch path replenishes
        // when it sees the shortfall.
        let hits = AtomicUsize::new(0);
        pool.dispatch(64, 4, |_i, _slot| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 64);
        // `is_finished` can lag the alive-guard drop by an instant, so
        // healing may take more than one replenish — poll, bounded.
        for _ in 0..1000 {
            if pool.alive() == 3 {
                break;
            }
            pool.replenish();
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.alive(), 3, "replenish must respawn dead workers");
        assert!(
            respawned() >= before + 2,
            "pool.respawned must count the respawns (before {before}, now {})",
            respawned()
        );
        // Explicit replenish with nothing dead is a no-op.
        assert_eq!(pool.replenish(), 0);
        drop(pool);
    }

    #[test]
    fn exported_metrics_include_respawn_counter_and_thread_gauges() {
        warm();
        let reg = crate::obs::MetricsRegistry::new();
        export_metrics(&reg);
        assert!(reg.gauge("pool.threads").is_some());
        assert!(reg.gauge("pool.alive").is_some());
        // The counter is exported (other tests may bump the process-wide
        // total concurrently, so bound rather than pin it).
        assert!(reg.counter("pool.respawned") <= respawned());
    }

    #[test]
    fn global_pool_is_created_once_and_warm_is_idempotent() {
        warm();
        let a = global() as *const WorkerPool;
        warm();
        let b = global() as *const WorkerPool;
        assert_eq!(a, b, "warm/global must return the same pool");
    }
}
