//! Reusable workspace buffers for [`WinoEngine`](super::WinoEngine).
//!
//! One engine forward pass needs three large flat buffers (transformed
//! input panels, Hadamard accumulators, f64 output staging). Allocating
//! them per call would dominate small-batch latency, so callers that run
//! many forwards (the ResNet serving path, the throughput bench) hold an
//! [`EngineScratch`] and pass it to
//! [`WinoEngine::forward_with`](super::WinoEngine::forward_with); buffers
//! grow to the high-water mark of the layer shapes seen and are then
//! reused allocation-free.

/// Scratch buffers for one in-flight engine forward pass.
///
/// Holds both the float pipeline's f64 panels and the integer pipeline's
/// code panels ([`IntWinoEngine`](super::int::IntWinoEngine)); a serving
/// worker threads one scratch through heterogeneous float/int layers and
/// each buffer grows to its own high-water mark.
///
/// Not `Clone` on purpose: the point is to share one allocation across
/// calls, not to copy multi-megabyte workspaces around.
#[derive(Default)]
pub struct EngineScratch {
    /// Transformed input tiles, layout `[C][N²][T]` (channel-major panels).
    pub(super) xt: Vec<f64>,
    /// Hadamard/channel accumulators, layout `[N²][K][T]` (frequency-major).
    pub(super) had: Vec<f64>,
    /// f64 output staging, layout `[BN][K][OH][OW]` — shared by the float
    /// and integer pipelines (both back-transform into f64 before the f32
    /// cast).
    pub(super) out: Vec<f64>,
    /// Integer pipeline: transformed-input codes, layout `[C][N²][T]`.
    pub(super) xt_codes: Vec<i16>,
    /// Integer pipeline: requantized Hadamard codes, layout `[N²][K][T]`.
    pub(super) had_codes: Vec<i32>,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }

    /// Size the three buffers for a pass. Only `had` is zero-filled —
    /// it accumulates with `+=` in stage 2; `xt` and `out` have every
    /// element overwritten (stage 1 / stage 3), so they are resized
    /// without the redundant memset. Capacity is retained across calls.
    pub(super) fn prepare(&mut self, xt_len: usize, had_len: usize, out_len: usize) {
        self.xt.resize(xt_len, 0.0);
        self.had.clear();
        self.had.resize(had_len, 0.0);
        self.out.resize(out_len, 0.0);
    }

    /// Size the integer pipeline's buffers for a pass. Nothing is
    /// zero-filled: stage 1 overwrites every `xt_codes` element, the panel
    /// kernel's requantization overwrites every `had_codes` element (its
    /// i64 channel accumulation happens in a kernel-local row, not here),
    /// and stage 3 overwrites every `out` element.
    pub(super) fn prepare_int(&mut self, xt_len: usize, had_len: usize, out_len: usize) {
        self.xt_codes.resize(xt_len, 0);
        self.had_codes.resize(had_len, 0);
        self.out.resize(out_len, 0.0);
    }

    /// Total buffer capacity currently held, in **bytes**, across the
    /// float (f64) and integer (i16/i32) workspaces — a worker serving a
    /// quantized model grows the code panels while the f64 panels stay
    /// empty, and memory accounting must see both.
    pub fn capacity(&self) -> usize {
        (self.xt.capacity() + self.had.capacity() + self.out.capacity())
            * std::mem::size_of::<f64>()
            + self.xt_codes.capacity() * std::mem::size_of::<i16>()
            + self.had_codes.capacity() * std::mem::size_of::<i32>()
    }

    /// The f64 output staging buffer left by the most recent
    /// [`WinoEngine::execute_into`](super::WinoEngine::execute_into)
    /// (layout `[BN][K][OH][OW]` for that pass's [`TileGrid`](super::TileGrid)).
    pub fn output(&self) -> &[f64] {
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_int_sizes_code_buffers() {
        let mut s = EngineScratch::new();
        s.prepare_int(64, 32, 16);
        assert_eq!((s.xt_codes.len(), s.had_codes.len(), s.out.len()), (64, 32, 16));
        // Shrinking keeps capacity; the pass overwrites every element, so
        // no zeroing is required (or asserted).
        s.prepare_int(8, 4, 2);
        assert_eq!((s.xt_codes.len(), s.had_codes.len(), s.out.len()), (8, 4, 2));
        assert!(s.xt_codes.capacity() >= 64);
        // The code panels count toward the (byte) capacity accounting.
        assert!(s.capacity() >= 64 * 2 + 32 * 4 + 16 * 8);
    }

    #[test]
    fn prepare_zeroes_accumulator_and_keeps_capacity() {
        let mut s = EngineScratch::new();
        s.prepare(100, 200, 50);
        s.had[3] = 7.0;
        let cap = s.capacity();
        s.prepare(80, 150, 50);
        assert!(
            s.had.iter().all(|&v| v == 0.0),
            "the += accumulator must be zeroed between passes"
        );
        assert_eq!((s.xt.len(), s.had.len(), s.out.len()), (80, 150, 50));
        assert!(s.capacity() >= cap.min(280), "capacity should be retained");
    }
}
