//! Reusable workspace buffers for [`WinoEngine`](super::WinoEngine).
//!
//! One engine forward pass needs three large flat buffers (transformed
//! input panels, Hadamard accumulators, f64 output staging) plus the
//! panel GEMM's per-worker input packing buffers. Allocating them per
//! call would dominate small-batch latency, so callers that run many
//! forwards (the ResNet serving path, the throughput bench) hold an
//! [`EngineScratch`] and pass it to
//! [`WinoEngine::forward_with`](super::WinoEngine::forward_with); buffers
//! grow to the high-water mark of the layer shapes seen and are then
//! reused allocation-free.

use super::gemm::StageNs;

/// Scratch buffers for one in-flight engine forward pass.
///
/// Holds both the float pipeline's f64 panels and the integer pipeline's
/// code panels ([`IntWinoEngine`](super::int::IntWinoEngine)); a serving
/// worker threads one scratch through heterogeneous float/int layers and
/// each buffer grows to its own high-water mark. The scratch also
/// accumulates the per-stage wall-clock breakdown
/// ([`stage_ns`](Self::stage_ns)) of every pass run through it.
///
/// Not `Clone` on purpose: the point is to share one allocation across
/// calls, not to copy multi-megabyte workspaces around.
#[derive(Default)]
pub struct EngineScratch {
    /// Transformed input tiles, layout `[C][N²][T]` (channel-major panels).
    pub(super) xt: Vec<f64>,
    /// Hadamard/channel accumulators, layout `[N²][K][T]` (frequency-major).
    pub(super) had: Vec<f64>,
    /// f64 output staging, layout `[BN][K][OH][OW]` — shared by the float
    /// and integer pipelines (both back-transform into f64 before the f32
    /// cast).
    pub(super) out: Vec<f64>,
    /// Integer pipeline: transformed-input codes, layout `[C][N²][T]`.
    pub(super) xt_codes: Vec<i16>,
    /// Integer pipeline: requantized Hadamard codes, layout `[N²][K][T]`.
    pub(super) had_codes: Vec<i32>,
    /// Per-worker `C`×`NC` input packing buffers for the float panel
    /// GEMM (layout per buffer: `[⌈NC/NR⌉][C][NR]`, sized inside
    /// [`gemm::pack_x_block`](super::gemm::pack_x_block)). One buffer
    /// per dispatch *slot*: the pool leases buffer `slot` exclusively to
    /// whichever participant holds that slot for the whole dispatch
    /// ([`parallel::par_for_states`](super::parallel::par_for_states)),
    /// sized by [`gemm::workers_for`](super::gemm::workers_for) so the
    /// lease can never under-split the `(frequency × T-block)` grid.
    pub(super) pack_f64: Vec<Vec<f64>>,
    /// Per-worker packing buffers for the integer panel GEMM.
    pub(super) pack_i16: Vec<Vec<i16>>,
    /// Cumulative stage wall time `[input-transform, hadamard, inverse]`
    /// in nanoseconds across every pass since the last
    /// [`take_stage_ns`](Self::take_stage_ns).
    stage_ns: StageNs,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }

    /// Size the three buffers for a pass. Nothing is zero-filled: stage 1
    /// overwrites every `xt` element, the tiled panel GEMM writes every
    /// `had` element exactly once (its accumulators live in registers,
    /// not in this buffer), and stage 3 overwrites every `out` element.
    /// Capacity is retained across calls.
    pub(super) fn prepare(&mut self, xt_len: usize, had_len: usize, out_len: usize) {
        self.xt.resize(xt_len, 0.0);
        self.had.resize(had_len, 0.0);
        self.out.resize(out_len, 0.0);
    }

    /// Size the integer pipeline's buffers for a pass. Nothing is
    /// zero-filled: stage 1 overwrites every `xt_codes` element, the panel
    /// kernel's requantization overwrites every `had_codes` element (its
    /// i64 channel accumulation happens in register tiles, not here),
    /// and stage 3 overwrites every `out` element.
    pub(super) fn prepare_int(&mut self, xt_len: usize, had_len: usize, out_len: usize) {
        self.xt_codes.resize(xt_len, 0);
        self.had_codes.resize(had_len, 0);
        self.out.resize(out_len, 0.0);
    }

    /// Ensure at least `workers` float packing buffers exist (the
    /// buffers themselves are sized lazily by the GEMM's packer and keep
    /// their capacity across passes).
    pub(super) fn ensure_pack_f64(&mut self, workers: usize) {
        if self.pack_f64.len() < workers {
            self.pack_f64.resize_with(workers, Vec::new);
        }
    }

    /// Integer-path counterpart of [`ensure_pack_f64`](Self::ensure_pack_f64).
    pub(super) fn ensure_pack_i16(&mut self, workers: usize) {
        if self.pack_i16.len() < workers {
            self.pack_i16.resize_with(workers, Vec::new);
        }
    }

    /// Add one pass's stage breakdown to the cumulative counters.
    pub(super) fn add_stage_ns(&mut self, add: StageNs) {
        for (acc, v) in self.stage_ns.iter_mut().zip(add) {
            *acc = acc.saturating_add(v);
        }
    }

    /// Cumulative per-stage wall time since construction or the last
    /// [`take_stage_ns`](Self::take_stage_ns):
    /// `[input-transform, hadamard/GEMM, inverse]` nanoseconds.
    pub fn stage_ns(&self) -> StageNs {
        self.stage_ns
    }

    /// Return the cumulative stage breakdown and reset it — what a
    /// serving worker records per micro-batch.
    pub fn take_stage_ns(&mut self) -> StageNs {
        std::mem::take(&mut self.stage_ns)
    }

    /// Total buffer capacity currently held, in **bytes**, across the
    /// float (f64) and integer (i16/i32) workspaces and the per-worker
    /// packing buffers — a worker serving a quantized model grows the
    /// code panels while the f64 panels stay empty, and memory accounting
    /// must see both.
    pub fn capacity(&self) -> usize {
        let pack_f64: usize = self.pack_f64.iter().map(Vec::capacity).sum();
        let pack_i16: usize = self.pack_i16.iter().map(Vec::capacity).sum();
        (self.xt.capacity() + self.had.capacity() + self.out.capacity() + pack_f64)
            * std::mem::size_of::<f64>()
            + (self.xt_codes.capacity() + pack_i16) * std::mem::size_of::<i16>()
            + self.had_codes.capacity() * std::mem::size_of::<i32>()
    }

    /// The f64 output staging buffer left by the most recent
    /// [`WinoEngine::execute_into`](super::WinoEngine::execute_into)
    /// (layout `[BN][K][OH][OW]` for that pass's [`TileGrid`](super::TileGrid)).
    pub fn output(&self) -> &[f64] {
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_int_sizes_code_buffers() {
        let mut s = EngineScratch::new();
        s.prepare_int(64, 32, 16);
        assert_eq!((s.xt_codes.len(), s.had_codes.len(), s.out.len()), (64, 32, 16));
        // Shrinking keeps capacity; the pass overwrites every element, so
        // no zeroing is required (or asserted).
        s.prepare_int(8, 4, 2);
        assert_eq!((s.xt_codes.len(), s.had_codes.len(), s.out.len()), (8, 4, 2));
        assert!(s.xt_codes.capacity() >= 64);
        // The code panels count toward the (byte) capacity accounting.
        assert!(s.capacity() >= 64 * 2 + 32 * 4 + 16 * 8);
    }

    #[test]
    fn prepare_sizes_buffers_and_keeps_capacity() {
        let mut s = EngineScratch::new();
        s.prepare(100, 200, 50);
        let cap = s.capacity();
        s.prepare(80, 150, 50);
        assert_eq!((s.xt.len(), s.had.len(), s.out.len()), (80, 150, 50));
        assert!(s.capacity() >= cap.min(280), "capacity should be retained");
    }

    #[test]
    fn pack_buffers_grow_and_are_counted() {
        let mut s = EngineScratch::new();
        s.ensure_pack_f64(3);
        s.ensure_pack_i16(2);
        assert_eq!((s.pack_f64.len(), s.pack_i16.len()), (3, 2));
        s.ensure_pack_f64(2); // never shrinks
        assert_eq!(s.pack_f64.len(), 3);
        s.pack_f64[0].resize(128, 0.0);
        s.pack_i16[1].resize(64, 0);
        assert!(s.capacity() >= 128 * 8 + 64 * 2);
    }

    #[test]
    fn stage_counters_accumulate_and_reset() {
        let mut s = EngineScratch::new();
        s.add_stage_ns([1, 2, 3]);
        s.add_stage_ns([10, 20, 30]);
        assert_eq!(s.stage_ns(), [11, 22, 33]);
        assert_eq!(s.take_stage_ns(), [11, 22, 33]);
        assert_eq!(s.stage_ns(), [0, 0, 0]);
        // Saturating, never wrapping.
        s.add_stage_ns([u64::MAX, 0, 0]);
        s.add_stage_ns([5, 0, 0]);
        assert_eq!(s.stage_ns()[0], u64::MAX);
    }
}
