//! Reusable workspace buffers for [`WinoEngine`](super::WinoEngine).
//!
//! One engine forward pass needs three large flat buffers (transformed
//! input panels, Hadamard accumulators, f64 output staging). Allocating
//! them per call would dominate small-batch latency, so callers that run
//! many forwards (the ResNet serving path, the throughput bench) hold an
//! [`EngineScratch`] and pass it to
//! [`WinoEngine::forward_with`](super::WinoEngine::forward_with); buffers
//! grow to the high-water mark of the layer shapes seen and are then
//! reused allocation-free.

/// Scratch buffers for one in-flight engine forward pass.
///
/// Not `Clone` on purpose: the point is to share one allocation across
/// calls, not to copy multi-megabyte workspaces around.
#[derive(Default)]
pub struct EngineScratch {
    /// Transformed input tiles, layout `[C][N²][T]` (channel-major panels).
    pub(super) xt: Vec<f64>,
    /// Hadamard/channel accumulators, layout `[N²][K][T]` (frequency-major).
    pub(super) had: Vec<f64>,
    /// f64 output staging, layout `[BN][K][OH][OW]`.
    pub(super) out: Vec<f64>,
}

impl EngineScratch {
    pub fn new() -> EngineScratch {
        EngineScratch::default()
    }

    /// Size the three buffers for a pass. Only `had` is zero-filled —
    /// it accumulates with `+=` in stage 2; `xt` and `out` have every
    /// element overwritten (stage 1 / stage 3), so they are resized
    /// without the redundant memset. Capacity is retained across calls.
    pub(super) fn prepare(&mut self, xt_len: usize, had_len: usize, out_len: usize) {
        self.xt.resize(xt_len, 0.0);
        self.had.clear();
        self.had.resize(had_len, 0.0);
        self.out.resize(out_len, 0.0);
    }

    /// Total f64 capacity currently held (for memory accounting/tests).
    pub fn capacity(&self) -> usize {
        self.xt.capacity() + self.had.capacity() + self.out.capacity()
    }

    /// The f64 output staging buffer left by the most recent
    /// [`WinoEngine::execute_into`](super::WinoEngine::execute_into)
    /// (layout `[BN][K][OH][OW]` for that pass's [`TileGrid`](super::TileGrid)).
    pub fn output(&self) -> &[f64] {
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_zeroes_accumulator_and_keeps_capacity() {
        let mut s = EngineScratch::new();
        s.prepare(100, 200, 50);
        s.had[3] = 7.0;
        let cap = s.capacity();
        s.prepare(80, 150, 50);
        assert!(
            s.had.iter().all(|&v| v == 0.0),
            "the += accumulator must be zeroed between passes"
        );
        assert_eq!((s.xt.len(), s.had.len(), s.out.len()), (80, 150, 50));
        assert!(s.capacity() >= cap.min(280), "capacity should be retained");
    }
}
