//! Fully integer-domain batched Winograd execution — the deployed path
//! for the paper's quantized scenario.
//!
//! The float [`WinoEngine`](super::WinoEngine) models quantization by
//! *fake* casts: every panel stays f64 and each cast site rounds through
//! the code grid. That is the right oracle for training semantics, but a
//! deployment stores int8/int9 **codes**, and the channel reduction — the
//! only stage whose cost scales with `K·C` — runs on integers
//! (Fernandez-Marques et al. 2020; LANCE 2020). [`IntWinoEngine`] is that
//! path over the same flat-buffer geometry as the float engine:
//!
//! 1. **Quantize-on-transform** — each input tile is cast through the
//!    layer's input quantizer (f64, Fig. 2's first cast), transformed,
//!    and immediately quantized into a flat `[C][N²][T]` **i16** code
//!    panel; no f64 activation panel is ever materialized.
//! 2. **Integer channel reduction** — one `[K,C] × [C,T]` panel multiply
//!    per frequency point ([`panel_mul_requant_i16`], executed through
//!    the register-tiled kernels of [`gemm`](super::gemm) over the
//!    bank's pre-packed codes, with the inner micro-kernel auto-selected
//!    per dispatch — AVX2 `madd` / NEON `vmull` when the host supports
//!    them, scalar otherwise; every int variant is bit-exact, see
//!    [`gemm::Kernel`](super::gemm::Kernel)): i16×i16 products widened
//!    to i32, accumulated over channels in i64 register tiles (exact, so
//!    accumulation order cannot matter), then requantized once per
//!    `(k, f, t)` through the fused [`Requant`] epilogue into the
//!    Hadamard code grid — 8 or 9 bits per
//!    [`QuantConfig::hadamard_bits`], the paper's headline knob.
//! 3. **Requantize-on-inverse** — Hadamard codes are dequantized, the
//!    output transform runs in f64 (its constants are rationals; a
//!    hardware deployment folds them into fixed point, an exact
//!    rescaling), and the final output cast writes the clamped planes.
//!
//! The per-tile arithmetic is **bit-identical** to
//! [`QWino::forward_int_batch`](crate::quant::qwino::QWino::forward_int_batch)
//! (single channel) and
//! [`QWino::forward_int_batch_mc`](crate::quant::qwino::QWino::forward_int_batch_mc)
//! (multi-channel) — the scalar oracles `rust/tests/int_parity.rs` pins
//! this engine against for both paper quant configs across all bases.
//!
//! Weight codes live in an [`IntWeightBank`] (i16, stored in the
//! panel-GEMM register-tile packing), computed once per layer and shared
//! across served model variants by
//! [`PlanCache`](crate::serve::plan::PlanCache), so quantized models are
//! served without ever dequantizing their weights.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::gemm::{self, PackedI16};
use super::layout::{self, TileGrid};
use super::parallel;
use super::scratch::EngineScratch;
use crate::benchkit;
use crate::nn::layers::{pad_hw, Conv2dCfg};
use crate::nn::tensor::Tensor;
use crate::nn::winolayer::{LayerScales, WinoConv2d};
use crate::quant::scheme::{QuantConfig, Quantizer, Requant};
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::transform::WinoF;

/// Largest per-stage bit width whose codes fit the i16 panels
/// (`qmax(16) = 32767 = i16::MAX`). Wider configs (`uN`, N > 16) fall
/// back to the float fake-quant engine.
pub const MAX_CODE_BITS: u32 = 16;

/// `T`-dimension block size of the retired in-engine integer loop, kept
/// in [`panel_mul_requant_i16_naive`] so the oracle is the literal old
/// stage-2 path.
const NAIVE_T_BLOCK: usize = 1024;

/// A layer's transformed-weight **codes**, stored only in the
/// micro-kernel packing (`[N²][⌈K/MR⌉][C][MR]` i16, see
/// [`gemm`](super::gemm)) plus the quantizer that produced them.
/// Computed once from the float transformed-weight bank and shared
/// (`Arc`) across engines and served model variants — caching the bank
/// caches the packing with it, and like the float engine's bank the
/// row-major `[N²][K][C]` view is reconstructed on demand
/// ([`panel`](Self::panel)/[`codes`](Self::codes)) rather than kept as
/// a duplicate copy of possibly-megabytes of codes.
pub struct IntWeightBank {
    /// Frequency points `N²`.
    pub nn: usize,
    /// Output filters.
    pub k: usize,
    /// Input channels.
    pub c: usize,
    /// The codes in the panel-GEMM packing (the only stored form).
    packed: PackedI16,
    /// The symmetric quantizer the codes were taken with — identical (by
    /// construction: same calibration over the same float bank) to the
    /// `weights_t` scale `WinoConv2d::quantize_pct` computes.
    pub weights_t: Quantizer,
}

impl IntWeightBank {
    /// Calibrate a quantizer on a **pristine** float `[K][C]`
    /// transformed-weight bank (max-abs, matching
    /// `WinoConv2d::quantize_pct`'s `weights_t`) and quantize it into
    /// codes. Returns `None` when `weight_bits` exceeds
    /// [`MAX_CODE_BITS`]. For an already fake-quantized bank use
    /// [`with_quantizer`](Self::with_quantizer) with the layer's own
    /// `weights_t`: requantizing fake values reproduces their codes
    /// exactly (`quantize(q·s) = q`), but *re-calibrating* on them could
    /// drift the scale by an ulp.
    pub fn from_float_bank(wt: &[Vec<Mat>], weight_bits: u32) -> Option<IntWeightBank> {
        if weight_bits > MAX_CODE_BITS {
            return None;
        }
        // Same calibration as WinoConv2d::quantize_pct's weights_t —
        // scale = max|w| / qmax (1.0 for an all-zero bank) — folded
        // straight over the bank: no flattened copy of a possibly
        // multi-megabyte weight bank just to take a maximum.
        let maxabs = wt
            .iter()
            .flat_map(|per_c| per_c.iter().flat_map(|m| m.data().iter().copied()))
            .fold(0.0f64, |a, b| a.max(b.abs()));
        let scale = if maxabs == 0.0 {
            1.0
        } else {
            maxabs / Quantizer::qmax(weight_bits) as f64
        };
        Some(Self::with_quantizer(wt, Quantizer { bits: weight_bits, scale }))
    }

    /// Quantize a `[K][C]` transformed-weight bank with an explicitly
    /// supplied quantizer (a layer's already-computed `weights_t`).
    pub fn with_quantizer(wt: &[Vec<Mat>], weights_t: Quantizer) -> IntWeightBank {
        assert!(
            weights_t.bits <= MAX_CODE_BITS,
            "{}-bit weight codes do not fit the i16 panels",
            weights_t.bits
        );
        let k = wt.len();
        assert!(k > 0, "need at least one output filter");
        let c = wt[0].len();
        assert!(c > 0, "need at least one input channel");
        let nn = wt[0][0].rows() * wt[0][0].cols();
        for per_c in wt {
            assert_eq!(per_c.len(), c, "ragged filter bank");
            for mat in per_c {
                assert_eq!(mat.data().len(), nn, "bank tile size mismatch");
            }
        }
        // Quantize straight into the packed layout — each real lane is
        // quantized exactly once, pad lanes never touch the quantizer.
        let packed = PackedI16::pack(nn, k, c, 0, |f, ki, ci| {
            weights_t.quantize(wt[ki][ci].data()[f]) as i16
        });
        IntWeightBank { nn, k, c, packed, weights_t }
    }

    /// The `[K][C]` code panel for frequency point `f`, reconstructed
    /// row-major from the packed storage — for oracles and tests (the
    /// engine reads the packed form directly).
    pub fn panel(&self, f: usize) -> Vec<i16> {
        self.packed.unpacked_panel(f)
    }

    /// All codes, reconstructed in `[N²][K][C]` layout.
    pub fn codes(&self) -> Vec<i16> {
        let mut out = Vec::with_capacity(self.nn * self.k * self.c);
        for f in 0..self.nn {
            out.extend(self.packed.unpacked_panel(f));
        }
        out
    }

    /// The codes in the micro-kernel packing (what the engine executes
    /// from).
    pub fn packed(&self) -> &PackedI16 {
        &self.packed
    }
}

pub use super::gemm::PanelDims;

/// Cumulative saturation counters for one engine's quantize/clamp sites
/// — the numeric-health telemetry of the observability layer. All
/// counts are of **clamp hits**: the rounded code fell outside the
/// stage quantizer's `[−qmax, qmax]` and was clipped (the value paths
/// are bit-identical to the unflagged quantizers — see
/// [`Quantizer::quantize_sat`] / [`Requant::apply_sat`]). Counters are
/// relaxed atomics folded once per parallel work item, so the hot loops
/// pay one local `u64` add per element and one `fetch_add` per chunk.
#[derive(Default, Debug)]
pub struct EngineHealth {
    /// Stage 1: input activation cast clips (`input` fake-quant) —
    /// activations outside the calibrated input range.
    pub input_sat: AtomicU64,
    /// Stage 1: transformed-input i16 code clips (`input_t` quantize) —
    /// transformed tiles outside the calibrated transform range.
    pub input_t_sat: AtomicU64,
    /// Stage 2: fused requant epilogue clips — the 8/9-bit Hadamard
    /// clamp hit-rate numerator (the paper's headline knob).
    pub hadamard_sat: AtomicU64,
    /// Stage 3: output cast clips (`output` fake-quant), counted over
    /// every computed tile value (edge-clamped positions included).
    pub output_sat: AtomicU64,
}

/// A plain-integer copy of [`EngineHealth`] at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthSnapshot {
    pub input_sat: u64,
    pub input_t_sat: u64,
    pub hadamard_sat: u64,
    pub output_sat: u64,
}

impl HealthSnapshot {
    /// Total clips across all sites.
    pub fn total(&self) -> u64 {
        self.input_sat + self.input_t_sat + self.hadamard_sat + self.output_sat
    }
}

impl EngineHealth {
    /// Read the counters without resetting.
    pub fn snapshot(&self) -> HealthSnapshot {
        HealthSnapshot {
            input_sat: self.input_sat.load(Ordering::Relaxed),
            input_t_sat: self.input_t_sat.load(Ordering::Relaxed),
            hadamard_sat: self.hadamard_sat.load(Ordering::Relaxed),
            output_sat: self.output_sat.load(Ordering::Relaxed),
        }
    }

    /// Read and reset — what a serving worker drains per stats window.
    pub fn take(&self) -> HealthSnapshot {
        HealthSnapshot {
            input_sat: self.input_sat.swap(0, Ordering::Relaxed),
            input_t_sat: self.input_t_sat.swap(0, Ordering::Relaxed),
            hadamard_sat: self.hadamard_sat.swap(0, Ordering::Relaxed),
            output_sat: self.output_sat.swap(0, Ordering::Relaxed),
        }
    }
}

/// Per-frequency integer panel multiply with fused requantization — the
/// integer engine's stage 2, exposed standalone for the property tests.
///
/// `xt_codes` is `[C][N²][T]`, `wt_codes` is `[N²][K][C]`, `had_codes`
/// is `[N²][K][T]` (all i16/i32 row-major in the bracketed shapes; `T`
/// is inferred). For every `(f, k, t)`: the i16×i16 products over `c`
/// are widened to i32 and accumulated in i64 — exact for any `C` up to
/// 2³³ even at 16-bit codes — then the real value
/// `acc · prod_scale` (`prod_scale` = input-code scale × weight-code
/// scale) is requantized, clamping to `±qmax` (saturation, never
/// wraparound).
///
/// This raw-slice entry packs `wt_codes` and runs the register-tiled
/// kernel ([`gemm::panel_gemm_requant_i16`]) — the production path, so
/// the property suites exercise exactly what serving executes. The
/// engine itself skips the packing step: its [`IntWeightBank`] holds the
/// codes pre-packed. The pre-tiling loop survives as
/// [`panel_mul_requant_i16_naive`], the oracle both are pinned against.
pub fn panel_mul_requant_i16(
    xt_codes: &[i16],
    wt_codes: &[i16],
    dims: PanelDims,
    prod_scale: f64,
    hq: &Quantizer,
    had_codes: &mut [i32],
) {
    let PanelDims { c, k, nn } = dims;
    assert!(c > 0 && k > 0 && nn > 0, "degenerate panel shape");
    assert_eq!(xt_codes.len() % (c * nn), 0, "xt panel not [C][N²][T]");
    let t_total = xt_codes.len() / (c * nn);
    assert_eq!(wt_codes.len(), nn * k * c, "wt panel not [N²][K][C]");
    assert_eq!(had_codes.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    let packed = PackedI16::pack(nn, k, c, 0, |f, ki, ci| wt_codes[(f * k + ki) * c + ci]);
    let mut packs = vec![Vec::new(); gemm::workers_for(nn, t_total)];
    gemm::panel_gemm_requant_i16(
        &packed,
        xt_codes,
        t_total,
        &hq.requant(prod_scale),
        had_codes,
        &mut packs,
    );
}

/// The pre-tiling integer stage-2 loop, verbatim — the oracle
/// [`panel_mul_requant_i16`] and the engine are pinned against
/// (`rust/tests/gemm_property.rs`). Same contract as the tiled entry;
/// per-element requantization goes through [`Quantizer::quantize`]
/// directly.
pub fn panel_mul_requant_i16_naive(
    xt_codes: &[i16],
    wt_codes: &[i16],
    dims: PanelDims,
    prod_scale: f64,
    hq: &Quantizer,
    had_codes: &mut [i32],
) {
    let PanelDims { c, k, nn } = dims;
    assert!(c > 0 && k > 0 && nn > 0, "degenerate panel shape");
    assert_eq!(xt_codes.len() % (c * nn), 0, "xt panel not [C][N²][T]");
    let t_total = xt_codes.len() / (c * nn);
    assert_eq!(wt_codes.len(), nn * k * c, "wt panel not [N²][K][C]");
    assert_eq!(had_codes.len(), nn * k * t_total, "had panel not [N²][K][T]");
    if t_total == 0 {
        return;
    }
    parallel::par_chunks_mut(had_codes, k * t_total, |f, chunk| {
        let wpan = &wt_codes[f * k * c..][..k * c];
        // One i64 accumulator row per output filter, reused across `k`.
        let mut acc = vec![0i64; t_total];
        for ki in 0..k {
            acc.fill(0);
            let mut tb = 0;
            while tb < t_total {
                let te = (tb + NAIVE_T_BLOCK).min(t_total);
                for ci in 0..c {
                    let wkc = wpan[ki * c + ci] as i32;
                    if wkc == 0 {
                        // Zero weight codes contribute exactly nothing —
                        // skipping them is a pure speedup.
                        continue;
                    }
                    let xrow = &xt_codes[(ci * nn + f) * t_total..][..t_total];
                    for t in tb..te {
                        acc[t] += (wkc * xrow[t] as i32) as i64;
                    }
                }
                tb = te;
            }
            let row = &mut chunk[ki * t_total..][..t_total];
            for t in 0..t_total {
                row[t] = hq.quantize(acc[t] as f64 * prod_scale);
            }
        }
    });
}

/// A lowered **integer-domain** Winograd conv layer: i16 weight codes
/// plus the layer's calibrated stage quantizers, executing over flat i16
/// input-code panels. Built by
/// [`WinoConv2d::quantize_pct`](crate::nn::winolayer::WinoConv2d::quantize_pct)
/// alongside the float engine whenever the bit config fits
/// [`MAX_CODE_BITS`]; `WinoConv2d::forward*` then dispatches here, making
/// this the serving path for quantized layers.
pub struct IntWinoEngine {
    /// Float transform pipeline (plan + polynomial base) — the input and
    /// output transforms still run through it in f64.
    pub wf: WinoF,
    /// Output filters.
    pub k: usize,
    /// Input channels.
    pub c: usize,
    /// The bit-width configuration this engine honors.
    pub cfg: QuantConfig,
    /// Calibrated per-stage quantizers (Fig. 2 cast sites).
    pub scales: LayerScales,
    bank: Arc<IntWeightBank>,
    /// The fused stage-2 requantization epilogue —
    /// `hadamard.requant(prod_scale)` with
    /// `prod_scale = input_t.scale × weights_t.scale` (the exact real
    /// value of one integer Hadamard product unit) — hoisted once at
    /// lowering time.
    rq: Requant,
    /// Numeric-health saturation counters, accumulated across every
    /// pass (see [`EngineHealth`]; drain with
    /// [`take_health`](Self::take_health)).
    health: EngineHealth,
}

impl IntWinoEngine {
    /// Whether `cfg`'s activation/weight codes fit the i16 panels.
    pub fn supports(cfg: &QuantConfig) -> bool {
        cfg.act_bits <= MAX_CODE_BITS && cfg.weight_bits <= MAX_CODE_BITS
    }

    /// Build from a shared weight-code bank and calibrated layer scales.
    /// The bank's quantizer must be the layer's `weights_t` (same bits
    /// and scale) — the invariant that makes cached banks and
    /// freshly-computed ones interchangeable bit-for-bit.
    pub fn from_bank(
        wf: WinoF,
        bank: Arc<IntWeightBank>,
        cfg: QuantConfig,
        scales: LayerScales,
    ) -> IntWinoEngine {
        assert!(Self::supports(&cfg), "bit config {cfg:?} exceeds i16 code range");
        assert_eq!(bank.nn, wf.n * wf.n, "bank/plan tile size mismatch");
        assert_eq!(
            bank.weights_t, scales.weights_t,
            "weight-code bank quantizer differs from the layer's weights_t scale"
        );
        let prod_scale = scales.input_t.scale * scales.weights_t.scale;
        let rq = scales.hadamard.requant(prod_scale);
        IntWinoEngine {
            k: bank.k,
            c: bank.c,
            wf,
            cfg,
            scales,
            bank,
            rq,
            health: EngineHealth::default(),
        }
    }

    /// The shared weight-code bank (for cache-sharing assertions).
    pub fn bank(&self) -> &Arc<IntWeightBank> {
        &self.bank
    }

    /// Cumulative saturation counters since construction or the last
    /// [`take_health`](Self::take_health).
    pub fn health(&self) -> HealthSnapshot {
        self.health.snapshot()
    }

    /// Drain the saturation counters (read + reset) — the per-window
    /// numeric-health export for serving metrics.
    pub fn take_health(&self) -> HealthSnapshot {
        self.health.take()
    }

    /// Tiles one forward over `x_dims` processes (same grid as the float
    /// engine — the integer path changes arithmetic, not geometry; both
    /// delegate to [`layout::tile_count_for`]).
    pub fn tile_count_for(&self, x_dims: &[usize], padding: usize) -> usize {
        layout::tile_count_for(x_dims, padding, self.wf.m, self.wf.r)
    }

    /// Forward pass allocating a fresh workspace. Prefer
    /// [`forward_with`](Self::forward_with) in serving loops.
    pub fn forward(&self, x: &Tensor, cfg: Conv2dCfg) -> Tensor {
        let mut scratch = EngineScratch::new();
        self.forward_with(x, cfg, &mut scratch)
    }

    /// Forward pass `x` `[N,C,H,W]` → `[N,K,H',W']` (stride 1) reusing
    /// `scratch` buffers across calls.
    pub fn forward_with(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        let grid = self.execute_into(x, cfg, scratch);
        Tensor::from_vec(
            &[grid.bn, self.k, grid.oh, grid.ow],
            scratch.output().iter().map(|&v| v as f32).collect(),
        )
    }

    /// Forward pass returning the f64 output (pre-f32-cast) with its
    /// NCHW dims — what the oracle-parity tests compare bit-for-bit.
    pub fn forward_f64(&self, x: &Tensor, cfg: Conv2dCfg) -> (Vec<f64>, [usize; 4]) {
        let mut scratch = EngineScratch::new();
        let grid = self.execute_into(x, cfg, &mut scratch);
        (scratch.output().to_vec(), [grid.bn, self.k, grid.oh, grid.ow])
    }

    /// The three-stage integer pipeline: quantize-on-transform →
    /// integer panel reduction → requantize-on-inverse. Leaves the f64
    /// output in `scratch.out` (layout `[BN][K][OH][OW]`) and returns the
    /// [`TileGrid`].
    pub fn execute_into(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> TileGrid {
        assert_eq!(cfg.stride, 1, "winograd engine is stride-1");
        assert_eq!(x.rank(), 4, "NCHW input required");
        let x = pad_hw(x, cfg.padding);
        let (n, m) = (self.wf.n, self.wf.m);
        let nn = n * n;
        let grid = TileGrid::new(&x.dims, m, self.wf.r);
        assert_eq!(grid.c, self.c, "channel mismatch");
        let t_total = grid.tile_count();
        scratch.prepare_int(
            self.c * nn * t_total,
            nn * self.k * t_total,
            grid.bn * self.k * grid.oh * grid.ow,
        );
        let workers = gemm::workers_for(nn, t_total);
        scratch.ensure_pack_i16(workers);
        let EngineScratch { xt_codes, had_codes, out, pack_i16, .. } = scratch;
        let wf = &self.wf;
        let sc = &self.scales;
        let health = &self.health;

        // Stage 1 — quantize-on-transform, parallel over channels. The
        // input cast runs in f64 (the integer path's oracle is QWino's
        // f64 pipeline; no f32 detour as in the fake-quant engine), then
        // the transformed tile is quantized straight into the i16 panel.
        // Both cast sites count their clamp hits (values bit-identical
        // to the unflagged quantizers), folded per channel chunk.
        let t0 = Instant::now();
        parallel::par_chunks_mut(&mut xt_codes[..], nn * t_total, |ci, chunk| {
            let mut in_sat = 0u64;
            let mut int_sat = 0u64;
            for ni in 0..grid.bn {
                for th in 0..grid.tiles_h {
                    for tw in 0..grid.tiles_w {
                        let t = grid.tile_index(ni, th, tw);
                        let (h0, w0) = grid.tile_origin(th, tw);
                        let tile = layout::extract_tile(&x, ni, ci, h0, w0, n);
                        let faked_vals: Vec<f64> = tile
                            .data()
                            .iter()
                            .map(|&v| {
                                let (code, clipped) = sc.input.quantize_sat(v);
                                in_sat += u64::from(clipped);
                                sc.input.dequantize(code)
                            })
                            .collect();
                        let faked = Mat::from_vec(n, n, faked_vals);
                        let xt_m = wf.transform_input(&faked);
                        let d = xt_m.data();
                        for f in 0..nn {
                            let (code, clipped) = sc.input_t.quantize_sat(d[f]);
                            int_sat += u64::from(clipped);
                            chunk[f * t_total + t] = code as i16;
                        }
                    }
                }
            }
            if in_sat > 0 {
                health.input_sat.fetch_add(in_sat, Ordering::Relaxed);
            }
            if int_sat > 0 {
                health.input_t_sat.fetch_add(int_sat, Ordering::Relaxed);
            }
        });

        let t_transform = gemm::ns_since(t0);

        // Stage 2 — the integer channel reduction + fused Hadamard
        // requant, register-tiled over the bank's pre-packed codes
        // ([`gemm::panel_gemm_requant_i16`]); i64 accumulation is exact,
        // so tiling cannot perturb the codes.
        let t0 = Instant::now();
        gemm::panel_gemm_requant_i16_counted(
            &self.bank.packed,
            &xt_codes[..],
            t_total,
            &self.rq,
            &mut had_codes[..],
            &mut pack_i16[..workers],
            &health.hadamard_sat,
        );
        let t_hadamard = gemm::ns_since(t0);

        // Stage 3 — dequantize, back-transform in bulk, output cast;
        // parallel over (image, filter) planes, edge tiles clamped.
        let t0 = Instant::now();
        let had_ro: &[i32] = had_codes.as_slice();
        parallel::par_chunks_mut(&mut out[..], grid.oh * grid.ow, |plane, ochunk| {
            let ni = plane / self.k;
            let ki = plane % self.k;
            let mut acc = Mat::zeros(n, n);
            let mut out_sat = 0u64;
            for th in 0..grid.tiles_h {
                for tw in 0..grid.tiles_w {
                    let t = grid.tile_index(ni, th, tw);
                    for f in 0..nn {
                        acc[(f / n, f % n)] =
                            sc.hadamard.dequantize(had_ro[(f * self.k + ki) * t_total + t]);
                    }
                    let o = wf.transform_output(&acc);
                    let faked_out: Vec<f64> = o
                        .data()
                        .iter()
                        .map(|&v| {
                            let (code, clipped) = sc.output.quantize_sat(v);
                            out_sat += u64::from(clipped);
                            sc.output.dequantize(code)
                        })
                        .collect();
                    let o = Mat::from_vec(m, m, faked_out);
                    for i in 0..m {
                        let oi = th * m + i;
                        if oi >= grid.oh {
                            break;
                        }
                        for j in 0..m {
                            let oj = tw * m + j;
                            if oj >= grid.ow {
                                break;
                            }
                            ochunk[oi * grid.ow + oj] = o[(i, j)];
                        }
                    }
                }
            }
            if out_sat > 0 {
                health.output_sat.fetch_add(out_sat, Ordering::Relaxed);
            }
        });
        scratch.add_stage_ns([t_transform, t_hadamard, gemm::ns_since(t0)]);
        grid
    }
}

/// Time the integer path against the dequantize-to-float path (the fake
/// quant [`WinoEngine`](super::WinoEngine)) on one quantized layer and
/// one workload, returning `(BENCH_int JSON, tiles/sec ratio)`. Shared
/// by `benches/conv_throughput.rs` and `winoq serve --int-bench-json`.
///
/// The two paths compute through different numeric routes (integer vs
/// fake-quant channel accumulation), so outputs agree only to the
/// quantization step — the JSON reports the observed max |Δ| alongside a
/// step-derived bound so a degenerate run is visible in CI.
pub fn int_vs_float_bench_json(
    layer: &WinoConv2d,
    x: &Tensor,
    conv: Conv2dCfg,
    warmup: usize,
    samples: usize,
) -> (String, f64) {
    let ie = layer
        .int_engine()
        .expect("int bench requires a quantized layer with an integer engine");
    let fe = layer.engine();
    let tiles = fe.tile_count_for(&x.dims, conv.padding) as f64;
    let samples = samples.max(1);
    let mut s_int_scratch = EngineScratch::new();
    let s_int = benchkit::bench(warmup, samples, || {
        ie.forward_with(x, conv, &mut s_int_scratch)
    });
    let mut s_f_scratch = EngineScratch::new();
    let s_float = benchkit::bench(warmup, samples, || {
        fe.forward_with(x, conv, &mut s_f_scratch)
    });
    let int_tps = tiles / s_int.median.max(1e-12);
    let float_tps = tiles / s_float.median.max(1e-12);
    let ratio = if float_tps > 0.0 { int_tps / float_tps } else { 0.0 };
    let yi = ie.forward(x, conv);
    let yf = fe.forward(x, conv);
    let mut max_diff = 0.0f32;
    for (a, b) in yi.data.iter().zip(&yf.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    let out_step = ie.scales.output.scale;
    let json = format!(
        concat!(
            "{{\"bench\": \"int_engine\", \"m\": {}, \"base\": \"{}\", ",
            "\"quant\": \"{}\", \"shape\": {:?}, \"tiles\": {}, ",
            "\"int\": {{\"seconds\": {:e}, \"tiles_per_sec\": {:.1}}}, ",
            "\"float\": {{\"seconds\": {:e}, \"tiles_per_sec\": {:.1}}}, ",
            "\"tiles_per_sec_ratio_int_vs_float\": {:.3}, ",
            "\"parity_max_abs_diff\": {:e}, \"output_step\": {:e}}}"
        ),
        layer.wf.m,
        layer.wf.base.name(),
        ie.cfg.label(),
        x.dims,
        tiles as u64,
        s_int.median,
        int_tps,
        s_float.median,
        float_tps,
        ratio,
        max_diff,
        out_step,
    );
    (json, ratio)
}

/// One numeric-health fixture layer: filter 0 carries tiny constant
/// weights (`1e-3`), filter 1 large ones (`1.0`), so the dry-run
/// calibration — which ranges the Hadamard/output quantizers on
/// **filter 0 only** — produces scales that filter 1's serving-time
/// accumulators exceed by ~1000×: clipping is certain, not
/// distribution-dependent. Calibration input is a constant `0.5`
/// tensor; the adversarial input is constant `1.0` — exactly 2× the
/// calibrated input range, so **every** activation clips at the input
/// cast (and clamps back to the calibrated max, keeping the rest of the
/// pipeline well-defined).
fn health_fixture(
    base: Base,
    m: usize,
    qcfg: QuantConfig,
) -> (WinoConv2d, Tensor, Tensor) {
    let (k, c, h) = (2usize, 3usize, 10usize);
    let mut wdata = vec![1.0f32; k * c * 9];
    for v in &mut wdata[..c * 9] {
        *v = 1e-3;
    }
    let w = Tensor::from_vec(&[k, c, 3, 3], wdata);
    let x_cal = Tensor::from_vec(&[1, c, h, h], vec![0.5; c * h * h]);
    let x_adv = Tensor::from_vec(&[1, c, h, h], vec![1.0; c * h * h]);
    let mut layer = WinoConv2d::new(m, &w, base);
    layer.quantize(qcfg, &x_cal, 0);
    (layer, x_cal, x_adv)
}

/// The `winoq bench --health-json` emitter: run the near-clamp fixture
/// ([`health_fixture`]) for both paper quant configs over two layer
/// shapes and report every saturation counter per
/// `(layer, base, m, bits)` — a calibration-input baseline next to the
/// adversarial run, with the config's Hadamard clamp bound
/// (`hadamard_qmax`: 127 for `w8`, 255 for `w8_h9`) so the two clip
/// profiles are distinguishable in the emitted document. Deterministic:
/// the fixture is constant tensors and the integer pipeline is exact,
/// so counts are reproducible bit-for-bit.
pub fn numeric_health_json() -> String {
    use crate::obs::json::{JsonArr, JsonObj};
    let mut cases = JsonArr::new();
    for (lname, base, m) in
        [("conv_a", Base::Legendre, 4usize), ("conv_b", Base::Chebyshev, 2)]
    {
        for (qname, qcfg) in
            [("w8", QuantConfig::w8()), ("w8_h9", QuantConfig::w8_h9())]
        {
            let (layer, x_cal, x_adv) = health_fixture(base, m, qcfg);
            let ie = layer.int_engine().expect("8-bit configs fit the int engine");
            let conv = Conv2dCfg { stride: 1, padding: 0 };
            let tiles = ie.tile_count_for(&x_cal.dims, 0);
            let _ = ie.forward(&x_cal, conv);
            let calib = ie.take_health();
            let _ = ie.forward(&x_adv, conv);
            let adv = ie.take_health();
            cases = cases.item(
                &JsonObj::new()
                    .str("layer", lname)
                    .str("base", base.name())
                    .u64("m", m as u64)
                    .str("quant", qname)
                    .u64("hadamard_bits", qcfg.hadamard_bits as u64)
                    .u64("hadamard_qmax", Quantizer::qmax(qcfg.hadamard_bits) as u64)
                    .u64("tiles", tiles as u64)
                    .u64("calib_input_sat", calib.input_sat)
                    .u64("calib_input_t_sat", calib.input_t_sat)
                    .u64("calib_hadamard_sat", calib.hadamard_sat)
                    .u64("calib_output_sat", calib.output_sat)
                    .u64("adv_input_sat", adv.input_sat)
                    .u64("adv_input_t_sat", adv.input_t_sat)
                    .u64("adv_hadamard_sat", adv.hadamard_sat)
                    .u64("adv_output_sat", adv.output_sat)
                    .finish(),
            );
        }
    }
    JsonObj::new()
        .str("bench", "numeric_health")
        .raw("cases", &cases.finish())
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scheme::QuantConfig;
    use crate::testkit::{forall, prng_tensor};
    use crate::wino::error::Prng;

    fn quantized_layer(seed: u64, qcfg: QuantConfig, base: Base, m: usize) -> (WinoConv2d, Tensor) {
        let x = prng_tensor(seed, &[2, 3, 9, 9], 1.0);
        let w = prng_tensor(seed + 1, &[4, 3, 3, 3], 0.4);
        let mut layer = WinoConv2d::new(m, &w, base);
        layer.quantize(qcfg, &x, 1);
        (layer, x)
    }

    #[test]
    fn int_bank_codes_match_layer_weights_t() {
        // The engine's bank must carry exactly the layer's weights_t
        // quantizer, and its codes must equal requantizing the baked
        // (fake-quantized) bank — fake is code-idempotent, so the
        // pristine-bank codes and the baked-bank codes coincide.
        let (layer, _) = quantized_layer(11, QuantConfig::w8(), Base::Legendre, 4);
        let scales = layer.quant.unwrap().1;
        let bank = layer.int_engine().unwrap().bank();
        assert_eq!(bank.weights_t, scales.weights_t);
        let nn = layer.wf.n * layer.wf.n;
        for f in 0..nn {
            let panel = bank.panel(f);
            for ki in 0..layer.k {
                for ci in 0..layer.c {
                    let want = scales.weights_t.quantize(layer.wt[ki][ci].data()[f]);
                    assert_eq!(panel[ki * layer.c + ci] as i32, want, "({f},{ki},{ci})");
                }
            }
        }
    }

    #[test]
    fn int_bank_rejects_wide_codes() {
        let w = prng_tensor(5, &[2, 2, 3, 3], 0.5);
        let layer = WinoConv2d::new(4, &w, Base::Canonical);
        assert!(IntWeightBank::from_float_bank(&layer.wt, 17).is_none());
        assert!(IntWeightBank::from_float_bank(&layer.wt, 16).is_some());
        assert!(IntWinoEngine::supports(&QuantConfig::w8_h9()));
        assert!(IntWinoEngine::supports(&QuantConfig::uniform(16)));
        assert!(!IntWinoEngine::supports(&QuantConfig::uniform(17)));
    }

    #[test]
    fn panel_kernel_matches_scalar_reference() {
        // Against independent naive loops, including tie-prone scales.
        let (c, k, nn, t) = (5, 3, 4, 7);
        let mut rng = Prng::new(42);
        let xt: Vec<i16> = (0..c * nn * t)
            .map(|_| (rng.next_u64() % 511) as i16 - 255)
            .collect();
        let wt: Vec<i16> = (0..nn * k * c)
            .map(|_| (rng.next_u64() % 255) as i16 - 127)
            .collect();
        let hq = Quantizer::with_scale(9, 3.7e-4);
        let ps = 1.9e-4;
        let mut had = vec![0i32; nn * k * t];
        panel_mul_requant_i16(&xt, &wt, PanelDims { c, k, nn }, ps, &hq, &mut had);
        for f in 0..nn {
            for ki in 0..k {
                for ti in 0..t {
                    let mut acc = 0i64;
                    for ci in 0..c {
                        acc += wt[(f * k + ki) * c + ci] as i64
                            * xt[(ci * nn + f) * t + ti] as i64;
                    }
                    let want = hq.quantize(acc as f64 * ps);
                    assert_eq!(had[(f * k + ki) * t + ti], want, "({f},{ki},{ti})");
                }
            }
        }
    }

    /// One random property case for the requant properties below.
    #[derive(Debug)]
    struct RequantCase {
        xt: Vec<i16>,
        wt: Vec<i16>,
        c: usize,
        t: usize,
        prod_scale: f64,
        had_scale: f64,
    }

    fn requant_gen(hadamard_bits: u32) -> impl Fn(&mut Prng) -> RequantCase {
        move |rng: &mut Prng| {
            let c = 1 + (rng.next_u64() as usize) % 8;
            let t = 1 + (rng.next_u64() as usize) % 6;
            let code =
                |rng: &mut Prng, q: i64| ((rng.next_u64() % (2 * q as u64 + 1)) as i64 - q) as i16;
            let xt: Vec<i16> = (0..c * t).map(|_| code(rng, 255)).collect();
            let wt: Vec<i16> = (0..c).map(|_| code(rng, 127)).collect();
            // Scales spanning several orders of magnitude, biased so the
            // requant both saturates and rounds near ties.
            let prod_scale = 10f64.powf(rng.uniform(3.0) - 4.0);
            let had_scale =
                10f64.powf(rng.uniform(2.0) - 3.0) * (255.0 / Quantizer::qmax(hadamard_bits) as f64);
            RequantCase { xt, wt, c, t, prod_scale, had_scale }
        }
    }

    fn run_case(case: &RequantCase, bits: u32) -> Vec<i32> {
        let hq = Quantizer::with_scale(bits, case.had_scale);
        let mut had = vec![0i32; case.t];
        panel_mul_requant_i16(
            &case.xt,
            &case.wt,
            PanelDims { c: case.c, k: 1, nn: 1 },
            case.prod_scale,
            &hq,
            &mut had,
        );
        had
    }

    #[test]
    fn forall_requant_matches_f64_reference_within_one_ulp() {
        // The kernel's i64-accumulated real value must agree with a
        // per-term f64 reference to accumulation ulps: the integer sum is
        // exact, so the difference is bounded by the f64 reference's own
        // rounding (≪ one quantization step). Consequently the requant
        // code differs from the f64-reference code by at most 1 (a tie
        // flip), and the dequantized value by at most one step.
        forall(901, 300, requant_gen(9), |case| {
            let hq = Quantizer::with_scale(9, case.had_scale);
            let had = run_case(case, 9);
            for t in 0..case.t {
                let mut acc = 0i64;
                let mut per_term = 0.0f64;
                let mut mag = 0.0f64;
                for ci in 0..case.c {
                    let p = case.wt[ci] as i64 * case.xt[ci * case.t + t] as i64;
                    acc += p;
                    per_term += p as f64 * case.prod_scale;
                    mag += (p as f64 * case.prod_scale).abs();
                }
                let exact = acc as f64 * case.prod_scale;
                // 1-ulp-per-term bound on the f64 reference accumulation.
                if (exact - per_term).abs() > 1e-13 * mag.max(1e-300) {
                    return false;
                }
                let code = had[t];
                let ref_code = hq.quantize(per_term);
                if (code - ref_code).abs() > 1 {
                    return false;
                }
                if (hq.dequantize(code) - exact).abs()
                    > hq.scale * 0.5 + 1e-12 * exact.abs() + f64::MIN_POSITIVE
                {
                    // Within half a step unless clipped; clipping means
                    // the code sits at ±qmax.
                    if code.abs() != Quantizer::qmax(9) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn forall_nine_bit_codes_saturate_never_wrap() {
        // 9-bit Hadamard codes stay in [-255, 255] ⊂ [-256, 255] for any
        // operand codes and scales — saturation, not i32/i64 wraparound.
        forall(902, 400, requant_gen(9), |case| {
            run_case(case, 9).iter().all(|&h| (-255..=255).contains(&h))
        });
        // Worst-case magnitudes: max codes, huge prod scale, tiny
        // Hadamard scale — must pin to exactly ±qmax.
        let c = 64;
        let case = RequantCase {
            xt: vec![255; c],
            wt: vec![127; c],
            c,
            t: 1,
            prod_scale: 1e6,
            had_scale: 1e-9,
        };
        assert_eq!(run_case(&case, 9), vec![255]);
        let neg = RequantCase { wt: vec![-127; c], ..case };
        assert_eq!(run_case(&neg, 9), vec![-255]);
    }

    #[test]
    fn forall_eight_bit_codes_saturate_never_wrap() {
        forall(903, 300, requant_gen(8), |case| {
            run_case(case, 8).iter().all(|&h| (-127..=127).contains(&h))
        });
    }

    #[test]
    fn forall_hadamard_requant_i32_matches_definition_and_saturates() {
        // The single-channel i32 kernel (QWino::forward_int_batch's
        // stage 2) under the same generator: each code must equal the
        // defining formula exactly and respect the 9-bit clamp.
        use crate::engine::hadamard_requant_i32;
        forall(904, 300, requant_gen(9), |case| {
            // Reinterpret the case as an [N²][T] panel: nn = c rows.
            let hq = Quantizer::with_scale(9, case.had_scale);
            let xt: Vec<i32> = case.xt.iter().map(|&v| v as i32).collect();
            let wt: Vec<i32> = case.wt.iter().map(|&v| v as i32).collect();
            let mut had = vec![0i32; xt.len()];
            hadamard_requant_i32(&xt, &wt, case.prod_scale, &hq, &mut had);
            for f in 0..case.c {
                for t in 0..case.t {
                    let real = (xt[f * case.t + t] as i64 * wt[f] as i64) as f64
                        * case.prod_scale;
                    if had[f * case.t + t] != hq.quantize(real) {
                        return false;
                    }
                    if !(-255..=255).contains(&had[f * case.t + t]) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn int_engine_matches_naive_per_tile_pipeline() {
        // In-module mirror of the scalar integer pipeline (the
        // cross-module oracle parity lives in rust/tests/int_parity.rs).
        for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
            let (layer, x) = quantized_layer(21, qcfg, Base::Legendre, 4);
            let ie = layer.int_engine().expect("w8 fits the int engine");
            let conv = Conv2dCfg { stride: 1, padding: 1 };
            let (got, dims) = ie.forward_f64(&x, conv);
            let sc = &ie.scales;
            let padded = pad_hw(&x, 1);
            let grid = TileGrid::new(&padded.dims, 4, 3);
            let n = 6;
            let nn = 36;
            for ni in 0..grid.bn {
                for ki in 0..layer.k {
                    for th in 0..grid.tiles_h {
                        for tw in 0..grid.tiles_w {
                            // Naive per-tile integer pipeline.
                            let mut acc = vec![0i64; nn];
                            for ci in 0..layer.c {
                                let tile = layout::extract_tile(
                                    &padded, ni, ci, th * 4, tw * 4, n,
                                );
                                let faked =
                                    Mat::from_vec(n, n, sc.input.fake_all(tile.data()));
                                let xt = layer.wf.transform_input(&faked);
                                for f in 0..nn {
                                    let xc = sc.input_t.quantize(xt.data()[f]) as i64;
                                    let wc = ie.bank().panel(f)[ki * layer.c + ci] as i64;
                                    acc[f] += xc * wc;
                                }
                            }
                            let ps = sc.input_t.scale * sc.weights_t.scale;
                            let mut had = Mat::zeros(n, n);
                            for f in 0..nn {
                                let code = sc.hadamard.quantize(acc[f] as f64 * ps);
                                had[(f / n, f % n)] = sc.hadamard.dequantize(code);
                            }
                            let o = layer.wf.transform_output(&had);
                            let o = Mat::from_vec(4, 4, sc.output.fake_all(o.data()));
                            for i in 0..4 {
                                let oi = th * 4 + i;
                                if oi >= grid.oh {
                                    break;
                                }
                                for j in 0..4 {
                                    let oj = tw * 4 + j;
                                    if oj >= grid.ow {
                                        break;
                                    }
                                    let g = got[((ni * layer.k + ki) * dims[2] + oi)
                                        * dims[3]
                                        + oj];
                                    assert_eq!(
                                        g.to_bits(),
                                        o[(i, j)].to_bits(),
                                        "({ni},{ki},{oi},{oj}) [{}]",
                                        qcfg.label()
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn int_engine_scratch_reuse_and_batch_invariance() {
        let (layer, x) = quantized_layer(31, QuantConfig::w8_h9(), Base::Chebyshev, 2);
        let ie = layer.int_engine().unwrap();
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let mut scratch = EngineScratch::new();
        let fresh = ie.forward(&x, conv);
        let reused = ie.forward_with(&x, conv, &mut scratch);
        assert_eq!(fresh.data, reused.data);
        // Batch invariance: each image alone reproduces its batch rows.
        let item: usize = x.dims[1..].iter().product();
        for ni in 0..x.dims[0] {
            let mut dims = x.dims.clone();
            dims[0] = 1;
            let single = Tensor::from_vec(&dims, x.data[ni * item..(ni + 1) * item].to_vec());
            let y1 = ie.forward_with(&single, conv, &mut scratch);
            let row = y1.data.len();
            assert_eq!(&y1.data[..], &fresh.data[ni * row..(ni + 1) * row]);
        }
    }

    #[test]
    fn bench_json_emits_and_reports_ratio() {
        let (layer, x) = quantized_layer(41, QuantConfig::w8(), Base::Legendre, 4);
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let (json, ratio) = int_vs_float_bench_json(&layer, &x, conv, 0, 1);
        assert!(json.contains("\"bench\": \"int_engine\""), "{json}");
        assert!(json.contains("tiles_per_sec_ratio_int_vs_float"));
        assert!(ratio > 0.0, "degenerate ratio");
        // The emitted document is valid JSON for the in-crate reader.
        let doc = crate::tune::json::parse(&json).unwrap();
        assert!(doc.get("int").unwrap().get("tiles_per_sec").is_some());
    }

    /// The numeric-health fixture clips where the construction says it
    /// must — and *only* there on the calibration input.
    ///
    /// * Calibration pass: the input quantizer was ranged on exactly
    ///   this tensor (100th percentile), so `|x| = maxabs` round-trips
    ///   to `qmax` without exceeding it — zero input clips.
    /// * Adversarial pass (constant `2·maxabs`): every element
    ///   quantizes to `round(2·qmax) > qmax`, so the input-saturation
    ///   count equals the full panel volume `tiles · C · n²` exactly.
    /// * Filter 1's weights are 1000× the filter-0 range the Hadamard
    ///   requantizer was calibrated on, so its integer accumulators
    ///   clip with certainty in every config.
    #[test]
    fn health_counters_fire_exactly_where_the_fixture_guarantees() {
        for (base, m) in [(Base::Legendre, 4usize), (Base::Chebyshev, 2)] {
            for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
                let (layer, x_cal, x_adv) = health_fixture(base, m, qcfg);
                let ie = layer.int_engine().unwrap();
                let conv = Conv2dCfg { stride: 1, padding: 0 };
                let n = m + 2; // r = 3 throughout the fixture
                let tiles = ie.tile_count_for(&x_cal.dims, 0);

                let _ = ie.forward(&x_cal, conv);
                let calib = ie.take_health();
                assert_eq!(
                    calib.input_sat, 0,
                    "input quantizer was calibrated on this exact tensor (m={m})"
                );

                let _ = ie.forward(&x_adv, conv);
                let adv = ie.take_health();
                let panel = (tiles * 3 * n * n) as u64;
                assert_eq!(
                    adv.input_sat, panel,
                    "2x-range input must clip every panel element (m={m})"
                );
                assert!(
                    adv.hadamard_sat > 0,
                    "filter 1 is 1000x the calibrated Hadamard range \
                     (m={m}, h_bits={})",
                    qcfg.hadamard_bits
                );
            }
        }
    }

    /// `take_health` drains: a second read is all-zero, and a clean
    /// (calibration-input) pass after an adversarial one stays clean.
    #[test]
    fn take_health_drains_counters() {
        let (layer, x_cal, x_adv) = health_fixture(Base::Legendre, 4, QuantConfig::w8());
        let ie = layer.int_engine().unwrap();
        let conv = Conv2dCfg { stride: 1, padding: 0 };
        let _ = ie.forward(&x_adv, conv);
        assert!(ie.health().total() > 0);
        assert!(ie.take_health().total() > 0);
        assert_eq!(ie.take_health(), HealthSnapshot::default());
        let _ = ie.forward(&x_cal, conv);
        assert_eq!(ie.take_health().input_sat, 0);
    }

    /// The `--health-json` document parses, covers every
    /// `(layer, quant)` case, reports nonzero adversarial Hadamard
    /// saturation in all of them, and distinguishes the `w8` vs `w8_h9`
    /// clip profiles by their clamp bound (`hadamard_qmax` 127 vs 255).
    #[test]
    fn numeric_health_json_is_complete_and_parses() {
        let json = numeric_health_json();
        let doc = crate::tune::json::parse(&json).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "numeric_health");
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 4);
        let mut qmaxes = std::collections::BTreeSet::new();
        for case in cases {
            let quant = case.get("quant").unwrap().as_str().unwrap().to_string();
            let qmax = case.get("hadamard_qmax").unwrap().as_u64().unwrap();
            assert_eq!(qmax, if quant == "w8_h9" { 255 } else { 127 });
            qmaxes.insert(qmax);
            assert!(
                case.get("adv_hadamard_sat").unwrap().as_u64().unwrap() > 0,
                "case {quant} reported no Hadamard clipping"
            );
            assert_eq!(case.get("calib_input_sat").unwrap().as_u64().unwrap(), 0);
            assert!(case.get("adv_input_sat").unwrap().as_u64().unwrap() > 0);
        }
        assert_eq!(qmaxes.len(), 2, "w8 and w8_h9 profiles must differ");
    }
}
