//! Scoped-thread data parallelism for the batched engine.
//!
//! The vendored build has no crates.io access, so `rayon` itself cannot be
//! a dependency; this module provides the one primitive the engine needs —
//! a rayon-style *indexed parallel iteration over disjoint mutable chunks*
//! — on top of [`std::thread::scope`]. Every engine stage is expressed as
//! "each worker owns a contiguous run of equally-sized chunks", which is
//! exactly `rayon`'s `par_chunks_mut().enumerate()` shape, so swapping the
//! real crate in later is a one-line change per call site.
//!
//! Thread count defaults to [`std::thread::available_parallelism`] and can
//! be pinned with the `WINOQ_THREADS` environment variable (`1` forces the
//! serial path, which the parity tests use to keep failure cases
//! deterministic to debug — results are identical either way because
//! workers never share output elements).

/// Number of worker threads to use: `WINOQ_THREADS` if set and valid,
/// otherwise the machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("WINOQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Apply `f(chunk_index, chunk)` to every `chunk_len`-sized chunk of
/// `data` (the last chunk may be shorter), distributing contiguous runs
/// of chunks across up to [`num_threads`] scoped threads.
///
/// Chunks are disjoint `&mut` slices, so this is data-race-free by
/// construction; `f` must be `Sync` because all workers share it.
///
/// ```
/// let mut v = vec![0u64; 10];
/// winoq::engine::parallel::par_chunks_mut(&mut v, 3, |ci, chunk| {
///     for x in chunk.iter_mut() {
///         *x = ci as u64;
///     }
/// });
/// assert_eq!(v, [0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
/// ```
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    let workers = num_threads().min(n_chunks);
    if workers <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    // Split the chunk range into `workers` contiguous runs (first
    // `rem` runs get one extra chunk), and the data slice with it.
    let per = n_chunks / workers;
    let rem = n_chunks % workers;
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut first_chunk = 0usize;
        for w in 0..workers {
            let my_chunks = per + usize::from(w < rem);
            let my_len = (my_chunks * chunk_len).min(rest.len());
            let (mine, tail) = rest.split_at_mut(my_len);
            rest = tail;
            let base = first_chunk;
            first_chunk += my_chunks;
            let f = &f;
            scope.spawn(move || {
                for (ci, chunk) in mine.chunks_mut(chunk_len).enumerate() {
                    f(base + ci, chunk);
                }
            });
        }
    });
}

/// Run `f(i)` for every `i in 0..n` across up to [`num_threads`] scoped
/// threads, handing each worker a contiguous index range. Use when the
/// per-index work writes through interior indirection (e.g. gathering
/// into thread-owned buffers) rather than into one shared slice.
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(n);
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let per = n / workers;
    let rem = n % workers;
    std::thread::scope(|scope| {
        let mut start = 0usize;
        for w in 0..workers {
            let len = per + usize::from(w < rem);
            let range = start..start + len;
            start += len;
            let f = &f;
            scope.spawn(move || {
                for i in range {
                    f(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly_once() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 7, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_are_global() {
        let mut v = vec![0usize; 64];
        par_chunks_mut(&mut v, 4, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i / 4, "element {i}");
        }
    }

    #[test]
    fn ragged_tail_chunk() {
        let mut v = vec![0u8; 10];
        par_chunks_mut(&mut v, 4, |ci, chunk| {
            assert_eq!(chunk.len(), if ci == 2 { 2 } else { 4 });
            chunk.fill(ci as u8 + 1);
        });
        assert_eq!(v, [1, 1, 1, 1, 2, 2, 2, 2, 3, 3]);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut empty: Vec<u8> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![9u8];
        par_chunks_mut(&mut one, 4, |ci, c| {
            assert_eq!((ci, c.len()), (0, 1));
        });
    }

    #[test]
    fn par_for_counts() {
        let hits = AtomicUsize::new(0);
        par_for(137, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 137);
    }

    #[test]
    fn more_workers_than_chunks() {
        // n_chunks < threads must not spawn empty-range workers that panic.
        let mut v = vec![0u32; 3];
        par_chunks_mut(&mut v, 2, |_, chunk| chunk.fill(5));
        assert_eq!(v, [5, 5, 5]);
    }
}
