//! Data parallelism for the batched engine, on the persistent pool.
//!
//! The vendored build has no crates.io access, so `rayon` itself cannot be
//! a dependency; this module provides the primitives the engine needs —
//! rayon-style *indexed parallel iteration over disjoint mutable chunks*
//! ([`par_chunks_mut`]), plain index ranges ([`par_for`]), and index
//! ranges with one exclusive worker state each ([`par_for_states`], the
//! panel GEMM's packing-buffer lease). Every engine stage is expressed
//! as "each work item is owned by exactly one worker", which is exactly
//! `rayon`'s indexed shape, so swapping the real crate in later is a
//! one-line change per call site.
//!
//! Parallel dispatches run on the process-wide persistent
//! [`pool`](super::pool): the calling thread always participates (it
//! pre-claims the first item) and up to `W − 1` **parked pool threads**
//! are woken to steal the rest off a shared counter — a dispatch is a
//! condvar wake, not `W − 1` thread creations, which is the whole point
//! (see the pool module docs for the spawn-tax story). A dispatch with
//! `W` workers still involves at most `W` threads, the bound the tests
//! pin.
//!
//! Thread count defaults to [`std::thread::available_parallelism`] and can
//! be pinned with the `WINOQ_THREADS` environment variable (`1` forces the
//! serial in-place path, which the parity tests use to keep failure cases
//! deterministic to debug — results are identical either way because
//! workers never share output elements).

/// Number of worker threads to use: `WINOQ_THREADS` if set and valid,
/// otherwise the machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("WINOQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Apply `f(chunk_index, chunk)` to every `chunk_len`-sized chunk of
/// `data` (the last chunk may be shorter), distributing contiguous runs
/// of chunks across up to [`num_threads`] scoped threads.
///
/// Chunks are disjoint `&mut` slices, so this is data-race-free by
/// construction; `f` must be `Sync` because all workers share it.
///
/// ```
/// let mut v = vec![0u64; 10];
/// winoq::engine::parallel::par_chunks_mut(&mut v, 3, |ci, chunk| {
///     for x in chunk.iter_mut() {
///         *x = ci as u64;
///     }
/// });
/// assert_eq!(v, [0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
/// ```
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    let workers = num_threads().min(n_chunks);
    if workers <= 1 {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    // Chunks are claimed dynamically off the pool's stealing counter —
    // each chunk index exactly once — so the reconstructed `&mut`
    // sub-slices are disjoint by construction, the same guarantee the
    // old contiguous-run split gave. The worker count is clamped to the
    // chunk count and the calling thread always participates, so a
    // `W`-worker dispatch involves at most `W` threads (pinned in
    // `caller_participates_and_spawns_are_bounded`).
    let len = data.len();
    let base = super::pool::SendPtr(data.as_mut_ptr());
    super::pool::global().dispatch(n_chunks, workers, |ci, _slot| {
        let start = ci * chunk_len;
        let end = (start + chunk_len).min(len);
        // SAFETY: chunk index `ci` is claimed by exactly one
        // participant, and `[start, end)` ranges of distinct chunks
        // never overlap; `data` outlives the dispatch (it blocks).
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci, chunk);
    });
}

/// Run `f(i)` for every `i in 0..n` across up to [`num_threads`] pool
/// participants, indices claimed dynamically. Use when the per-index
/// work writes through interior indirection (e.g. gathering into
/// thread-owned buffers) rather than into one shared slice.
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(n);
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    super::pool::global().dispatch(n, workers, |i, _slot| f(i));
}

/// Run `f(i, state)` for every `i in 0..n`, handing each participant
/// **exclusive `&mut` access to one entry of `states`** for the whole
/// dispatch — the shape the panel GEMM's two-dimensional
/// `(frequency × T-block)` dispatch needs, where every worker streams
/// input panels through its own packing buffer
/// ([`EngineScratch`](super::scratch::EngineScratch) owns the buffers,
/// this primitive leases them out). At most
/// `min(num_threads(), n, states.len())` workers run. The lease is the
/// pool's **slot**: participants hold a distinct slot in `0..workers`
/// from first claim to job end (the caller is always slot 0, so the
/// serial path and the pooled path agree on which state the caller
/// uses), which makes `&mut states[slot]` race-free even though item
/// claiming is dynamic.
pub fn par_for_states<S, F>(n: usize, states: &mut [S], f: F)
where
    S: Send,
    F: Fn(usize, &mut S) + Sync,
{
    if n == 0 {
        return;
    }
    assert!(!states.is_empty(), "need at least one worker state");
    let workers = num_threads().min(n).min(states.len());
    if workers <= 1 {
        let s = &mut states[0];
        for i in 0..n {
            f(i, s);
        }
        return;
    }
    let base = super::pool::SendPtr(states.as_mut_ptr());
    super::pool::global().dispatch(n, workers, |i, slot| {
        debug_assert!(slot < workers);
        // SAFETY: `slot < workers <= states.len()`, and the pool hands
        // each participant a distinct slot held for the whole dispatch,
        // so no two threads ever touch the same state; `states` outlives
        // the dispatch (it blocks).
        let s = unsafe { &mut *base.0.add(slot) };
        f(i, s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_exactly_once() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 7, |_, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_are_global() {
        let mut v = vec![0usize; 64];
        par_chunks_mut(&mut v, 4, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i / 4, "element {i}");
        }
    }

    #[test]
    fn ragged_tail_chunk() {
        let mut v = vec![0u8; 10];
        par_chunks_mut(&mut v, 4, |ci, chunk| {
            assert_eq!(chunk.len(), if ci == 2 { 2 } else { 4 });
            chunk.fill(ci as u8 + 1);
        });
        assert_eq!(v, [1, 1, 1, 1, 2, 2, 2, 2, 3, 3]);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut empty: Vec<u8> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![9u8];
        par_chunks_mut(&mut one, 4, |ci, c| {
            assert_eq!((ci, c.len()), (0, 1));
        });
    }

    #[test]
    fn par_for_counts() {
        let hits = AtomicUsize::new(0);
        par_for(137, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 137);
    }

    #[test]
    fn more_workers_than_chunks() {
        // n_chunks < threads must not spawn empty-range workers that panic.
        let mut v = vec![0u32; 3];
        par_chunks_mut(&mut v, 2, |_, chunk| chunk.fill(5));
        assert_eq!(v, [5, 5, 5]);
    }

    #[test]
    fn caller_participates_and_spawns_are_bounded() {
        // A 3-chunk dispatch must involve at most 3 distinct threads, one
        // of which is the caller (it pre-claims the first item, so a
        // machine with a big pool never wakes workers just to idle).
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        let mut v = vec![0u8; 3];
        par_chunks_mut(&mut v, 1, |_, chunk| {
            ids.lock().unwrap().insert(std::thread::current().id());
            chunk.fill(1);
        });
        let ids = ids.into_inner().unwrap();
        assert!(ids.len() <= 3, "3 chunks must use at most 3 threads");
        assert!(
            ids.contains(&std::thread::current().id()),
            "the calling thread must work a run itself"
        );
        assert_eq!(v, [1, 1, 1]);

        let ids = Mutex::new(HashSet::new());
        par_for(3, |_| {
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        let ids = ids.into_inner().unwrap();
        assert!(ids.len() <= 3);
        assert!(ids.contains(&std::thread::current().id()));
    }

    #[test]
    fn par_for_states_visits_every_index_once_with_exclusive_state() {
        // Each worker counts into its own state; the per-state sums must
        // total n with no index visited twice (tracked via an atomic
        // bitmapish counter per index).
        let hits: Vec<AtomicUsize> = (0..137).map(|_| AtomicUsize::new(0)).collect();
        let mut states = vec![0usize; 4];
        par_for_states(137, &mut states, |i, s| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            *s += 1;
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(states.iter().sum::<usize>(), 137);
    }

    #[test]
    fn par_for_states_respects_state_count_and_serial_path() {
        // One state forces the serial path; zero items is a no-op that
        // must not touch states.
        let mut one = vec![0usize; 1];
        par_for_states(9, &mut one, |_, s| *s += 1);
        assert_eq!(one[0], 9);
        let mut none = vec![7usize; 2];
        par_for_states(0, &mut none, |_, _| panic!("no items expected"));
        assert_eq!(none, [7, 7]);
        // More states than items: workers clamp to the item count.
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        let mut many = vec![0usize; 16];
        par_for_states(2, &mut many, |_, s| {
            ids.lock().unwrap().insert(std::thread::current().id());
            *s += 1;
        });
        assert!(ids.into_inner().unwrap().len() <= 2);
        assert_eq!(many.iter().sum::<usize>(), 2);
    }
}
