//! Batched Winograd execution engine — the serving-path hot loop.
//!
//! The per-tile layer in [`nn::winolayer`](crate::nn::winolayer)
//! materialises one small matrix per tile per channel and walks it with
//! nested loops, so the Hadamard stage — the part of the pipeline the
//! paper keeps at 8/9 bits (its Fig. 2) and the only stage whose cost
//! scales with `K·C` — never becomes the GEMM-shaped kernel it is in real
//! deployments (Lavin & Gray 2016). [`WinoEngine`] restructures the same
//! arithmetic around flat buffers:
//!
//! 1. **Scatter/transform** every tile of the whole batch once into a
//!    `[C][N²][T]` workspace (`T` = batch × tile-grid size), applying the
//!    input transform (and the Fig. 2 input casts when quantized) on the
//!    way in — parallel over channels.
//! 2. **Hadamard-with-channel-accumulation** as one `[K,C] × [C,T]`
//!    panel multiply per frequency point `f ∈ N²`, run through the
//!    register-tiled, cache-blocked micro-kernels of [`gemm`] (packed
//!    weight panels, `MR×NR` register accumulators, `NC`-blocked input
//!    packing) — parallel over `(frequency × T-block)` work items. This
//!    is where the `2.25×` multiplication advantage of `F(4×4, 3×3)`
//!    lives.
//! 3. **Back-transform** each `(image, filter)` plane in bulk, clamping
//!    edge tiles — parallel over output planes.
//!
//! Accumulation order over channels is identical to the per-tile path
//! (`c = 0..C`, one fused multiply-add chain per `(k, f, t)`), so the
//! engine is **bit-for-bit equal** to
//! [`WinoConv2d::forward_reference`](crate::nn::winolayer::WinoConv2d::forward_reference)
//! in both float and quantized modes — the parity tests assert exact
//! equality, and `rust/tests/engine_parity.rs` checks the engine against
//! the direct-convolution oracle at `1e-9` in f64.
//!
//! Parallelism comes from [`parallel`] (a rayon-shaped API over the
//! persistent worker [`pool`] — a dispatch wakes parked threads instead
//! of spawning; see those modules for why rayon itself is not a
//! dependency here), and repeated calls reuse [`EngineScratch`] buffers
//! to stay allocation-free on the large workspaces.
//!
//! [`WinoEngine`] is the **float / fake-quant** pipeline (f64 panels,
//! Fig. 2 casts as quantize-dequantize round trips) — the training-graph
//! semantics and the float serving path. Its true-integer counterpart is
//! [`int::IntWinoEngine`]: i16 code panels, the channel reduction in the
//! integer domain, and a single Hadamard requantization per `(k, f, t)`
//! — the deployed path quantized layers dispatch to (see [`int`]).
//!
//! ```
//! use winoq::engine::WinoEngine;
//! use winoq::nn::layers::{conv2d, Conv2dCfg};
//! use winoq::nn::tensor::Tensor;
//! use winoq::wino::basis::Base;
//!
//! let cfg = Conv2dCfg { stride: 1, padding: 1 };
//! let x = Tensor::from_vec(&[1, 2, 8, 8], (0..128).map(|i| (i % 13) as f32 * 0.1).collect());
//! let w = Tensor::from_vec(&[3, 2, 3, 3], (0..54).map(|i| (i % 7) as f32 * 0.05).collect());
//! let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
//! let y = engine.forward(&x, cfg);
//! let oracle = conv2d(&x, &w, None, cfg);
//! assert_eq!(y.dims, oracle.dims);
//! for (a, b) in y.data.iter().zip(&oracle.data) {
//!     assert!((a - b).abs() < 1e-4, "{a} vs {b}");
//! }
//! ```

pub mod gemm;
pub mod int;
pub mod layout;
pub mod parallel;
pub mod pool;
pub mod scratch;

pub use gemm::{PackedF64, PackedI16};
pub use int::{IntWeightBank, IntWinoEngine};
pub use layout::TileGrid;
pub use scratch::EngineScratch;

use std::sync::Arc;
use std::time::Instant;

use crate::nn::layers::{pad_hw, Conv2dCfg};
use crate::nn::tensor::Tensor;
use crate::nn::winolayer::LayerScales;
use crate::quant::scheme::{QuantConfig, Quantizer};
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;

/// A lowered Winograd conv layer: pre-transformed weights stored as
/// register-tile-packed per-frequency panels ([`gemm::Packed`],
/// `[N²][⌈K/MR⌉][C][MR]`) plus the float transform pipeline, executing
/// over flat batch-wide tile buffers.
///
/// Build one with [`WinoEngine::from_weights`] (from raw `[K,C,r,r]`
/// weights), [`WinoEngine::from_transformed_weights`] (from the
/// already-transformed per-tile matrices a
/// [`WinoConv2d`](crate::nn::winolayer::WinoConv2d) holds — packs them
/// once), or [`WinoEngine::from_packed`] (from an already-packed bank
/// shared through [`PlanCache`](crate::serve::plan::PlanCache)).
pub struct WinoEngine {
    /// Float transform pipeline (plan + polynomial base).
    pub wf: WinoF,
    /// Output filters.
    pub k: usize,
    /// Input channels.
    pub c: usize,
    /// Transformed weights in the micro-kernel packing (the only stored
    /// form; [`weight_panel`](Self::weight_panel) reconstructs the
    /// row-major view). Shared (`Arc`) across served model variants.
    packed: Arc<PackedF64>,
    /// Fig. 2 quantized-pipeline state, if enabled.
    pub quant: Option<(QuantConfig, LayerScales)>,
}

/// Transform a `[K,C,r,r]` float weight tensor into the `[K][C]` bank of
/// `N×N` Winograd-domain matrices — the one lowering shared by
/// [`WinoEngine::from_weights`] and
/// [`WinoConv2d::new`](crate::nn::winolayer::WinoConv2d::new), so the
/// two construction paths cannot diverge.
pub fn transform_weight_bank(wf: &WinoF, weights: &Tensor) -> Vec<Vec<Mat>> {
    assert_eq!(weights.rank(), 4);
    let (k, c, r, s) = (
        weights.dims[0],
        weights.dims[1],
        weights.dims[2],
        weights.dims[3],
    );
    assert_eq!(r, s, "square kernels only");
    assert_eq!(r, wf.r, "kernel size mismatch with the plan");
    let mut bank = Vec::with_capacity(k);
    let mut w = Mat::zeros(r, r);
    for ki in 0..k {
        let mut per_c = Vec::with_capacity(c);
        for ci in 0..c {
            for a in 0..r {
                for b in 0..r {
                    w[(a, b)] = weights.at4(ki, ci, a, b) as f64;
                }
            }
            per_c.push(wf.transform_weights(&w));
        }
        bank.push(per_c);
    }
    bank
}

impl WinoEngine {
    /// Build from float weights `[K,C,r,r]`, transforming them once —
    /// the standalone counterpart of
    /// [`WinoConv2d::new`](crate::nn::winolayer::WinoConv2d::new).
    pub fn from_weights(m: usize, weights: &Tensor, base: Base) -> WinoEngine {
        assert_eq!(weights.rank(), 4, "weights must be [K,C,r,r]");
        let plan = WinogradPlan::new(m, weights.dims[2]);
        let wf = WinoF::new(&plan, base);
        let bank = transform_weight_bank(&wf, weights);
        Self::from_transformed_weights(wf, &bank, None)
    }

    /// Build from already-transformed `[K][C]` tile matrices (each
    /// `N×N`), e.g. the `wt` a `WinoConv2d` computed — including any
    /// fake-quantisation already baked into them. Packs the bank into
    /// the micro-kernel layout once, here.
    pub fn from_transformed_weights(
        wf: WinoF,
        wt: &[Vec<Mat>],
        quant: Option<(QuantConfig, LayerScales)>,
    ) -> WinoEngine {
        let k = wt.len();
        assert!(k > 0, "need at least one output filter");
        let c = wt[0].len();
        let nn = wf.n * wf.n;
        for per_c in wt {
            assert_eq!(per_c.len(), c, "ragged filter bank");
            for mat in per_c {
                assert_eq!((mat.rows(), mat.cols()), (wf.n, wf.n));
            }
        }
        let packed = Arc::new(PackedF64::pack(nn, k, c, 0.0, |f, ki, ci| {
            wt[ki][ci].data()[f]
        }));
        Self::from_packed(wf, packed, quant)
    }

    /// Build from an **already-packed** weight bank (the
    /// [`PlanCache`](crate::serve::plan::PlanCache) caches these per
    /// layer, so served model variants share one packing instead of
    /// repacking per registration).
    pub fn from_packed(
        wf: WinoF,
        packed: Arc<PackedF64>,
        quant: Option<(QuantConfig, LayerScales)>,
    ) -> WinoEngine {
        assert_eq!(packed.nn, wf.n * wf.n, "packed bank/plan tile size mismatch");
        WinoEngine { k: packed.k, c: packed.c, wf, packed, quant }
    }

    /// The `[K][C]` weight panel for frequency point `f` (row-major),
    /// reconstructed from the packed storage — for tests and
    /// introspection (the hot path reads the packed form directly).
    pub fn weight_panel(&self, f: usize) -> Vec<f64> {
        self.packed.unpacked_panel(f)
    }

    /// The packed weight bank (for cache-sharing assertions).
    pub fn packed_weights(&self) -> &Arc<PackedF64> {
        &self.packed
    }

    /// Forward pass allocating a fresh workspace. Prefer
    /// [`forward_with`](Self::forward_with) in serving loops.
    pub fn forward(&self, x: &Tensor, cfg: Conv2dCfg) -> Tensor {
        let mut scratch = EngineScratch::new();
        self.forward_with(x, cfg, &mut scratch)
    }

    /// Forward pass `x` `[N,C,H,W]` → `[N,K,H',W']` (stride 1) reusing
    /// `scratch` buffers across calls.
    pub fn forward_with(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> Tensor {
        let grid = self.execute_into(x, cfg, scratch);
        Tensor::from_vec(
            &[grid.bn, self.k, grid.oh, grid.ow],
            scratch.out.iter().map(|&v| v as f32).collect(),
        )
    }

    /// Forward pass returning the f64 output (pre-f32-cast) together
    /// with its NCHW dims — the precision the engine computes in
    /// internally, used by the oracle-parity tests.
    pub fn forward_f64(&self, x: &Tensor, cfg: Conv2dCfg) -> (Vec<f64>, [usize; 4]) {
        let mut scratch = EngineScratch::new();
        let grid = self.execute_into(x, cfg, &mut scratch);
        (scratch.out.clone(), [grid.bn, self.k, grid.oh, grid.ow])
    }

    /// Number of tiles one forward over `x_dims` processes — the work
    /// unit the throughput bench reports (tiles/sec); shared definition
    /// in [`layout::tile_count_for`].
    pub fn tile_count_for(&self, x_dims: &[usize], padding: usize) -> usize {
        layout::tile_count_for(x_dims, padding, self.wf.m, self.wf.r)
    }

    /// The three-stage lowered pipeline — the **panel-level entry** for
    /// pre-planned engines: runs scatter/transform → per-frequency panel
    /// multiply → back-transform, leaving the f64 output in `scratch.out`
    /// (layout `[BN][K][OH][OW]`) and returning the [`TileGrid`]. The
    /// serving path ([`serve`](crate::serve)) calls this (through
    /// [`forward_with`](Self::forward_with)) on micro-batched request
    /// panels; callers that want the f64 output without the f32 cast —
    /// parity oracles, stacked post-processing — use it directly.
    pub fn execute_into(
        &self,
        x: &Tensor,
        cfg: Conv2dCfg,
        scratch: &mut EngineScratch,
    ) -> TileGrid {
        assert_eq!(cfg.stride, 1, "winograd engine is stride-1");
        assert_eq!(x.rank(), 4, "NCHW input required");
        let x = pad_hw(x, cfg.padding);
        // Fig. 2 input cast (identical to the per-tile path: fake-quant
        // the padded activations before tiling).
        let x = match &self.quant {
            Some((_, s)) => x.map(|v| s.input.fake(v as f64) as f32),
            None => x,
        };
        let (n, m) = (self.wf.n, self.wf.m);
        let nn = n * n;
        let grid = TileGrid::new(&x.dims, m, self.wf.r);
        assert_eq!(grid.c, self.c, "channel mismatch");
        let t_total = grid.tile_count();
        scratch.prepare(
            self.c * nn * t_total,
            nn * self.k * t_total,
            grid.bn * self.k * grid.oh * grid.ow,
        );
        let workers = gemm::workers_for(nn, t_total);
        scratch.ensure_pack_f64(workers);
        let EngineScratch { xt, had, out, pack_f64, .. } = scratch;
        let wf = &self.wf;
        let quant = &self.quant;

        // Stage 1 — scatter/transform, parallel over channels. Each
        // channel owns the contiguous `[N²][T]` block `xt[c]`.
        let t0 = Instant::now();
        parallel::par_chunks_mut(&mut xt[..], nn * t_total, |ci, chunk| {
            for ni in 0..grid.bn {
                for th in 0..grid.tiles_h {
                    for tw in 0..grid.tiles_w {
                        let t = grid.tile_index(ni, th, tw);
                        let (h0, w0) = grid.tile_origin(th, tw);
                        let tile = layout::extract_tile(&x, ni, ci, h0, w0, n);
                        let xt_m = wf.transform_input(&tile);
                        let d = xt_m.data();
                        match quant {
                            Some((_, s)) => {
                                for f in 0..nn {
                                    chunk[f * t_total + t] = s.input_t.fake(d[f]);
                                }
                            }
                            None => {
                                for f in 0..nn {
                                    chunk[f * t_total + t] = d[f];
                                }
                            }
                        }
                    }
                }
            }
        });

        let t_transform = gemm::ns_since(t0);

        // Stage 2 — register-tiled per-frequency panel GEMM
        // `[K,C] × [C,T]` over the packed weight bank, parallel over
        // `(frequency × T-block)` work items ([`gemm::panel_gemm_f64`]).
        // Each `(k, f, t)` accumulator runs the identical `c = 0..C`
        // chain as the per-tile path, so parity is bit-for-bit; the
        // Fig. 2 Hadamard cast is fused into the store (elementwise on
        // the fully-accumulated sums — same values, same site).
        let t0 = Instant::now();
        let fake = quant.as_ref().map(|(_, s)| &s.hadamard);
        gemm::panel_gemm_f64(
            &self.packed,
            &xt[..],
            t_total,
            fake,
            &mut had[..],
            &mut pack_f64[..workers],
        );
        let t_hadamard = gemm::ns_since(t0);

        // Stage 3 — back-transform in bulk, parallel over `(image,
        // filter)` output planes; edge tiles write clamped.
        let t0 = Instant::now();
        let had_ro: &[f64] = had.as_slice();
        parallel::par_chunks_mut(&mut out[..], grid.oh * grid.ow, |plane, ochunk| {
            let ni = plane / self.k;
            let ki = plane % self.k;
            let mut acc = Mat::zeros(n, n);
            for th in 0..grid.tiles_h {
                for tw in 0..grid.tiles_w {
                    let t = grid.tile_index(ni, th, tw);
                    for f in 0..nn {
                        acc[(f / n, f % n)] = had_ro[(f * self.k + ki) * t_total + t];
                    }
                    let mut o = wf.transform_output(&acc);
                    if let Some((_, s)) = quant {
                        o = Mat::from_vec(m, m, s.output.fake_all(o.data()));
                    }
                    for i in 0..m {
                        let oi = th * m + i;
                        if oi >= grid.oh {
                            break;
                        }
                        for j in 0..m {
                            let oj = tw * m + j;
                            if oj >= grid.ow {
                                break;
                            }
                            ochunk[oi * grid.ow + oj] = o[(i, j)];
                        }
                    }
                }
            }
        });
        scratch.add_stage_ns([t_transform, t_hadamard, gemm::ns_since(t0)]);
        grid
    }
}

/// Batched integer Hadamard stage over flat code panels — the
/// true-integer (deployed) counterpart of stage 2 for the single-channel
/// tile pipeline in [`quant::qwino`](crate::quant::qwino).
///
/// `xt_codes` is `[N²][T]` (transformed-input codes), `wt_codes` is
/// `[N²]` (transformed-weight codes); each i32×i32 product is widened to
/// i64, rescaled by `prod_scale` (the product of the two operand scales)
/// and requantized through `hq` into `had_codes` (`[N²][T]`) — parallel
/// over frequency points for large batches.
pub fn hadamard_requant_i32(
    xt_codes: &[i32],
    wt_codes: &[i32],
    prod_scale: f64,
    hq: &Quantizer,
    had_codes: &mut [i32],
) {
    let nn = wt_codes.len();
    assert_eq!(xt_codes.len(), had_codes.len());
    assert_eq!(xt_codes.len() % nn, 0, "panel length not a multiple of N²");
    let t_total = xt_codes.len() / nn;
    if t_total == 0 {
        return;
    }
    parallel::par_chunks_mut(had_codes, t_total, |f, row| {
        let wc = wt_codes[f] as i64;
        let xrow = &xt_codes[f * t_total..][..t_total];
        for (h, &xc) in row.iter_mut().zip(xrow) {
            let real = (xc as i64 * wc) as f64 * prod_scale;
            *h = hq.quantize(real);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::winolayer::WinoConv2d;
    use crate::quant::scheme::QuantConfig;
    use crate::testkit::prng_tensor;
    use crate::wino::conv::direct_correlate_2d_multichannel;

    #[test]
    fn engine_matches_direct_oracle_at_1e9_f64() {
        // Acceptance bar: engine f64 output vs the f64 multichannel
        // direct-correlation oracle within 1e-9, per tile.
        let x = prng_tensor(21, &[2, 5, 10, 10], 1.0);
        let w = prng_tensor(22, &[3, 5, 3, 3], 0.5);
        for base in [Base::Canonical, Base::Legendre] {
            let engine = WinoEngine::from_weights(4, &w, base);
            let (y, dims) = engine.forward_f64(&x, Conv2dCfg { stride: 1, padding: 0 });
            let [bn, k, oh, ow] = dims;
            // f64 copy of the input for the oracle.
            for ni in 0..bn {
                for ki in 0..k {
                    for oi in 0..oh {
                        for oj in 0..ow {
                            let mut oracle = 0.0f64;
                            for ci in 0..5 {
                                for a in 0..3 {
                                    for b in 0..3 {
                                        oracle += w.at4(ki, ci, a, b) as f64
                                            * x.at4(ni, ci, oi + a, oj + b) as f64;
                                    }
                                }
                            }
                            let got = y[((ni * k + ki) * oh + oi) * ow + oj];
                            assert!(
                                (got - oracle).abs() < 1e-9,
                                "({ni},{ki},{oi},{oj}): {got} vs {oracle} [{base:?}]"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn engine_matches_per_tile_layer_bit_for_bit_float() {
        let x = prng_tensor(31, &[2, 4, 9, 9], 1.0);
        let w = prng_tensor(32, &[6, 4, 3, 3], 0.4);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        for base in [Base::Canonical, Base::Legendre, Base::Chebyshev] {
            let layer = WinoConv2d::new(4, &w, base);
            let reference = layer.forward_reference(&x, cfg);
            let batched = layer.engine().forward(&x, cfg);
            assert_eq!(reference.dims, batched.dims);
            for (i, (a, b)) in reference.data.iter().zip(&batched.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "idx {i}: {a} vs {b} not bit-identical [{base:?}]"
                );
            }
        }
    }

    #[test]
    fn engine_matches_per_tile_layer_bit_for_bit_quantized() {
        let x = prng_tensor(41, &[1, 4, 12, 12], 1.0);
        let w = prng_tensor(42, &[4, 4, 3, 3], 0.3);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        for qcfg in [QuantConfig::w8(), QuantConfig::w8_h9()] {
            let mut layer = WinoConv2d::new(4, &w, Base::Legendre);
            layer.quantize(qcfg, &x, 1);
            let reference = layer.forward_reference(&x, cfg);
            let batched = layer.engine().forward(&x, cfg);
            for (i, (a, b)) in reference.data.iter().zip(&batched.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "idx {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn engine_matches_multichannel_tile_oracle() {
        // Interior tile cross-check through the wino-level oracle:
        // direct_correlate_2d_multichannel on the tile's channel stack.
        let x = prng_tensor(51, &[1, 3, 6, 6], 1.0);
        let w = prng_tensor(52, &[2, 3, 3, 3], 0.5);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let (y, [_, _, oh, ow]) = engine.forward_f64(&x, Conv2dCfg::default());
        for ki in 0..2 {
            let xs: Vec<Mat> = (0..3)
                .map(|ci| layout::extract_tile(&x, 0, ci, 0, 0, 6))
                .collect();
            let ws: Vec<Mat> = (0..3)
                .map(|ci| {
                    let mut m = Mat::zeros(3, 3);
                    for a in 0..3 {
                        for b in 0..3 {
                            m[(a, b)] = w.at4(ki, ci, a, b) as f64;
                        }
                    }
                    m
                })
                .collect();
            let oracle = direct_correlate_2d_multichannel(&xs, &ws);
            for i in 0..4 {
                for j in 0..4 {
                    let got = y[(ki * oh + i) * ow + j];
                    assert!(
                        (got - oracle[(i, j)]).abs() < 1e-9,
                        "k={ki} ({i},{j}): {got} vs {}",
                        oracle[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn execute_into_exposes_f64_panels() {
        // The public panel-level entry must leave exactly the
        // forward_f64 output in the caller's scratch.
        let x = prng_tensor(91, &[1, 2, 8, 8], 1.0);
        let w = prng_tensor(92, &[2, 2, 3, 3], 0.5);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let (want, dims) = engine.forward_f64(&x, cfg);
        let mut scratch = EngineScratch::new();
        let grid = engine.execute_into(&x, cfg, &mut scratch);
        assert_eq!([grid.bn, engine.k, grid.oh, grid.ow], dims);
        assert_eq!(scratch.output(), &want[..]);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let x1 = prng_tensor(61, &[2, 3, 8, 8], 1.0);
        let x2 = prng_tensor(62, &[1, 3, 12, 12], 1.0);
        let w = prng_tensor(63, &[3, 3, 3, 3], 0.5);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let mut scratch = EngineScratch::new();
        // Big shape first, then small: reused (larger) buffers must not
        // leak stale values into the smaller pass.
        let big = engine.forward_with(&x2, cfg, &mut scratch);
        let small = engine.forward_with(&x1, cfg, &mut scratch);
        assert_eq!(big.data, engine.forward(&x2, cfg).data);
        assert_eq!(small.data, engine.forward(&x1, cfg).data);
    }

    #[test]
    fn weight_panels_match_per_tile_transforms() {
        let w = prng_tensor(71, &[2, 3, 3, 3], 0.5);
        let layer = WinoConv2d::new(4, &w, Base::Legendre);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let nn = 36;
        for f in 0..nn {
            let panel = engine.weight_panel(f);
            for ki in 0..2 {
                for ci in 0..3 {
                    assert_eq!(panel[ki * 3 + ci], layer.wt[ki][ci].data()[f]);
                }
            }
        }
    }

    #[test]
    fn hadamard_requant_matches_scalar_pipeline() {
        let hq = Quantizer::with_scale(9, 0.01);
        let xt: Vec<i32> = (0..4 * 6).map(|i| (i as i32 % 17) - 8).collect();
        let wt: Vec<i32> = vec![3, -5, 7, 11];
        let mut had = vec![0i32; xt.len()];
        hadamard_requant_i32(&xt, &wt, 2.5e-4, &hq, &mut had);
        for f in 0..4 {
            for t in 0..6 {
                let real = (xt[f * 6 + t] as i64 * wt[f] as i64) as f64 * 2.5e-4;
                assert_eq!(had[f * 6 + t], hq.quantize(real));
            }
        }
    }
}
