//! Tile-grid geometry and flat-buffer layouts for the batched engine.
//!
//! The engine lowers an NCHW activation tensor into three flat buffers
//! (all indices row-major within the bracketed shape):
//!
//! * `xt` — transformed input tiles, shape `[C][N²][T]`: for a fixed
//!   channel `c` and frequency point `f`, the `T` tile values form a
//!   contiguous row, which is the right-hand panel of the per-frequency
//!   GEMM.
//! * `had` — Hadamard/channel accumulators, shape `[N²][K][T]`: for a
//!   fixed frequency `f`, `[K][T]` is the GEMM output panel.
//! * transformed weights, shape `[N²][K][C]`: for a fixed `f`, `[K][C]`
//!   is the left-hand GEMM panel.
//!
//! `T = BN · tiles_h · tiles_w` counts tiles across the whole batch, so
//! one GEMM per frequency point covers every image; tile `t` of image
//! `ni` at grid position `(th, tw)` is `t = (ni·tiles_h + th)·tiles_w +
//! tw` (see [`TileGrid::tile_index`]).
//!
//! The integer engine ([`engine::int`](super::int)) reuses the same
//! bracketed shapes with code-typed elements:
//!
//! * `xt_codes` — transformed-input **codes**, `[C][N²][T]` i16 (2 bytes
//!   per element instead of 8 — a 4× cut in panel traffic on the hot
//!   per-frequency reduction);
//! * weight codes — `[N²][K][C]` i16
//!   ([`IntWeightBank`](super::int::IntWeightBank));
//! * `had_codes` — requantized Hadamard codes, `[N²][K][T]` i32 (the
//!   i64 channel accumulator is kernel-local, never materialized).
//!
//! Geometry ([`TileGrid`], [`extract_tile`]) is shared verbatim between
//! the two pipelines: the integer path changes arithmetic, not tiling.
//!
//! The weight operand of the per-frequency GEMM is **not** stored in the
//! row-major `[N²][K][C]` shape above: both engines keep it
//! register-tile packed as `[N²][⌈K/MR⌉][C][MR]`
//! ([`gemm::Packed`](super::gemm::Packed)), and the input panel is
//! streamed through a `[⌈NC/NR⌉][C][NR]` packing buffer per
//! `(frequency, T-block)` work item
//! ([`gemm::pack_x_block`](super::gemm::pack_x_block)) — see
//! [`gemm`](super::gemm) for the micro-kernel layouts and why the float
//! kernel never splits the `C` reduction.

use crate::nn::tensor::Tensor;
use crate::wino::matrix::Mat;

/// Geometry of one lowered layer application: padded input size, output
/// size, and the tile grid covering it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    /// Batch size.
    pub bn: usize,
    /// Input channels.
    pub c: usize,
    /// Padded input height/width.
    pub h: usize,
    pub w: usize,
    /// Output tile size `m` and input tile size `n = m + r − 1`.
    pub m: usize,
    pub n: usize,
    /// Output spatial size (`h − r + 1`, `w − r + 1`).
    pub oh: usize,
    pub ow: usize,
    /// Tile-grid extent: `ceil(oh/m) × ceil(ow/m)`; edge tiles read
    /// zero-extended input and write clamped output.
    pub tiles_h: usize,
    pub tiles_w: usize,
}

impl TileGrid {
    /// Build the grid for a padded NCHW input and an `F(m, r)` plan.
    pub fn new(padded_dims: &[usize], m: usize, r: usize) -> TileGrid {
        assert_eq!(padded_dims.len(), 4, "NCHW input required");
        let (bn, c, h, w) = (padded_dims[0], padded_dims[1], padded_dims[2], padded_dims[3]);
        assert!(h >= r && w >= r, "input {h}x{w} smaller than kernel {r}");
        let oh = h - r + 1;
        let ow = w - r + 1;
        TileGrid {
            bn,
            c,
            h,
            w,
            m,
            n: m + r - 1,
            oh,
            ow,
            tiles_h: oh.div_ceil(m),
            tiles_w: ow.div_ceil(m),
        }
    }

    /// Tiles per image.
    pub fn tiles_per_image(&self) -> usize {
        self.tiles_h * self.tiles_w
    }

    /// Total tiles across the batch (`T`, the GEMM panel width).
    pub fn tile_count(&self) -> usize {
        self.bn * self.tiles_per_image()
    }

    /// Flat tile index of image `ni`, grid row `th`, grid column `tw`.
    #[inline]
    pub fn tile_index(&self, ni: usize, th: usize, tw: usize) -> usize {
        (ni * self.tiles_h + th) * self.tiles_w + tw
    }

    /// Top-left input coordinate of tile `(th, tw)`.
    #[inline]
    pub fn tile_origin(&self, th: usize, tw: usize) -> (usize, usize) {
        (th * self.m, tw * self.m)
    }
}

/// Tiles one engine forward over an **unpadded** NCHW shape processes
/// once `padding` is applied — the throughput work unit both the float
/// and integer engines report (`tile_count_for`); one definition so the
/// two paths can never disagree about what a "tile" is.
pub fn tile_count_for(x_dims: &[usize], padding: usize, m: usize, r: usize) -> usize {
    let padded = [
        x_dims[0],
        x_dims[1],
        x_dims[2] + 2 * padding,
        x_dims[3] + 2 * padding,
    ];
    TileGrid::new(&padded, m, r).tile_count()
}

/// Extract an `n×n` input patch starting at `(h0, w0)` of image `ni`,
/// channel `ci`, zero-extended past the spatial edge — shared by the
/// batched engine's scatter stage and the per-tile reference path in
/// [`nn::winolayer`](crate::nn::winolayer).
pub fn extract_tile(
    x: &Tensor,
    ni: usize,
    ci: usize,
    h0: usize,
    w0: usize,
    n: usize,
) -> Mat {
    let (h, w) = (x.dims[2], x.dims[3]);
    let mut t = Mat::zeros(n, n);
    for i in 0..n {
        if h0 + i >= h {
            break;
        }
        for j in 0..n {
            if w0 + j >= w {
                break;
            }
            t[(i, j)] = x.at4(ni, ci, h0 + i, w0 + j) as f64;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shapes_f43() {
        // 34×34 padded input, F(4,3): 32×32 output in an 8×8 tile grid.
        let g = TileGrid::new(&[8, 64, 34, 34], 4, 3);
        assert_eq!((g.oh, g.ow), (32, 32));
        assert_eq!((g.tiles_h, g.tiles_w), (8, 8));
        assert_eq!(g.tile_count(), 8 * 64);
        assert_eq!(g.n, 6);
    }

    #[test]
    fn grid_clamps_non_multiple_output() {
        // 9×9 input, F(4,3): 7×7 output needs 2×2 tiles (last one partial).
        let g = TileGrid::new(&[1, 2, 9, 9], 4, 3);
        assert_eq!((g.oh, g.ow), (7, 7));
        assert_eq!((g.tiles_h, g.tiles_w), (2, 2));
    }

    #[test]
    fn grid_handles_non_square_and_one_pixel_edges() {
        // Non-square 11×35 padded input, F(4,3): 9×33 output. Height
        // needs ⌈9/4⌉ = 3 tile rows — the last one covers a single
        // output row — width needs ⌈33/4⌉ = 9 columns, the last one
        // covering a single output column (the 1-pixel-edge-tile case
        // the arbitrary-H×W serving path leans on).
        let g = TileGrid::new(&[1, 3, 11, 35], 4, 3);
        assert_eq!((g.oh, g.ow), (9, 33));
        assert_eq!((g.tiles_h, g.tiles_w), (3, 9));
        assert_eq!(g.oh - (g.tiles_h - 1) * g.m, 1, "last tile row is 1 px");
        assert_eq!(g.ow - (g.tiles_w - 1) * g.m, 1, "last tile col is 1 px");
        // tile_count_for applies padding to the raw dims first:
        // 9×33 + pad 1 → the same padded 11×35 grid.
        assert_eq!(tile_count_for(&[1, 3, 9, 33], 1, 4, 3), 27);
        // Transposing the image transposes the grid, nothing else.
        let t = TileGrid::new(&[1, 3, 35, 11], 4, 3);
        assert_eq!((t.tiles_h, t.tiles_w), (9, 3));
        assert_eq!(t.tile_count(), g.tile_count());
    }

    #[test]
    fn tile_index_is_batch_major() {
        let g = TileGrid::new(&[2, 1, 9, 9], 4, 3);
        assert_eq!(g.tile_index(0, 0, 0), 0);
        assert_eq!(g.tile_index(0, 1, 1), 3);
        assert_eq!(g.tile_index(1, 0, 0), 4);
        assert_eq!(g.tile_index(1, 1, 1), g.tile_count() - 1);
    }

    #[test]
    fn extract_tile_zero_extends() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let t = extract_tile(&x, 0, 0, 1, 1, 3);
        assert_eq!(t[(0, 0)], 4.0);
        for i in 0..3 {
            for j in 0..3 {
                if (i, j) != (0, 0) {
                    assert_eq!(t[(i, j)], 0.0, "({i},{j}) should be zero-extended");
                }
            }
        }
    }
}
