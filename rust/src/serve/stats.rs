//! Serving telemetry: per-request latency percentiles, queue depth,
//! batch-size and throughput accounting, dumped as JSON.
//!
//! One [`ServeStats`] is shared by all workers and clients of a serving
//! run (interior mutability; workers record one batch at a time, so the
//! single mutex is uncontended relative to engine passes). At the end of
//! a run [`ServeStats::report`] folds the raw samples into a
//! [`StatsReport`] — p50/p95/p99 latency (nearest-rank, via
//! [`benchkit::percentile_sorted`]), requests/sec and tiles/sec — whose
//! [`to_json`](StatsReport::to_json) output is what
//! `winoq serve --stats-json` writes and `scripts/ci.sh` smoke-checks.

use super::plan::CacheCounters;
use crate::benchkit;
use std::sync::Mutex;

/// Raw samples accumulated during a serving run.
#[derive(Default)]
struct StatsState {
    /// One entry per completed request: enqueue→response microseconds.
    latencies_us: Vec<u64>,
    /// One entry per engine pass: requests in that micro-batch.
    batch_sizes: Vec<usize>,
    /// Admission rejections (queue full).
    rejected: u64,
    /// Requests shed by the scheduler (predicted cost could not meet the
    /// deadline).
    shed: u64,
    /// Completed requests whose response landed after their deadline.
    deadline_missed: u64,
    /// Winograd tiles processed (batch size × tiles per item).
    tiles: u64,
    /// High-water mark of the queue depth observed at drain time.
    max_queue_depth: usize,
    /// Cumulative engine stage breakdown across all workers:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds
    /// (each worker's scratch accumulates a pass, the worker drains it
    /// here per micro-batch).
    stage_ns: [u64; 3],
}

/// Shared, thread-safe stats sink for one serving run.
#[derive(Default)]
pub struct ServeStats {
    state: Mutex<StatsState>,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one completed micro-batch: its size, the tiles it pushed
    /// through the engine, the queue depth left behind, and every
    /// member request's end-to-end latency in microseconds.
    pub fn record_batch(&self, batch_size: usize, tiles: u64, depth: usize, lat_us: &[u64]) {
        let mut st = self.state.lock().unwrap();
        st.batch_sizes.push(batch_size);
        st.tiles += tiles;
        st.max_queue_depth = st.max_queue_depth.max(depth);
        st.latencies_us.extend_from_slice(lat_us);
    }

    /// Record one admission rejection (backpressure).
    pub fn record_reject(&self) {
        self.state.lock().unwrap().rejected += 1;
    }

    /// Record one shed request (the scheduler's predicted-cost decision).
    pub fn record_shed(&self) {
        self.state.lock().unwrap().shed += 1;
    }

    /// Record `n` completed-but-late requests from one batch.
    pub fn record_deadline_miss(&self, n: u64) {
        self.state.lock().unwrap().deadline_missed += n;
    }

    /// Fold one engine-pass stage breakdown (`EngineScratch::take_stage_ns`)
    /// into the run totals.
    pub fn record_stage_ns(&self, stage_ns: [u64; 3]) {
        let mut st = self.state.lock().unwrap();
        for (acc, v) in st.stage_ns.iter_mut().zip(stage_ns) {
            *acc = acc.saturating_add(v);
        }
    }

    /// Completed-request count so far.
    pub fn completed(&self) -> u64 {
        self.state.lock().unwrap().latencies_us.len() as u64
    }

    /// Fold the samples into a report; `wall_seconds` is the run's
    /// wall-clock duration (measured by the caller around the whole
    /// closed loop, queueing included). Percentiles are
    /// [`benchkit::percentile_sorted`] (nearest-rank), the same estimator
    /// the bench harness reports.
    pub fn report(&self, wall_seconds: f64) -> StatsReport {
        let st = self.state.lock().unwrap();
        let mut lat_ms: Vec<f64> = st.latencies_us.iter().map(|&v| v as f64 / 1e3).collect();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| {
            if lat_ms.is_empty() {
                0.0
            } else {
                benchkit::percentile_sorted(&lat_ms, q)
            }
        };
        let completed = lat_ms.len() as u64;
        let batches = st.batch_sizes.len() as u64;
        let wall = wall_seconds.max(1e-9);
        StatsReport {
            submitted: completed + st.rejected + st.shed,
            completed,
            rejected: st.rejected,
            shed: st.shed,
            deadline_missed: st.deadline_missed,
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                completed as f64 / batches as f64
            },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            p999_ms: pct(0.999),
            max_ms: lat_ms.last().copied().unwrap_or(0.0),
            requests_per_sec: completed as f64 / wall,
            tiles_per_sec: st.tiles as f64 / wall,
            max_queue_depth: st.max_queue_depth,
            wall_seconds,
            stage_ns: st.stage_ns,
        }
    }
}

/// Folded summary of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct StatsReport {
    /// Every request this run accounted for: exactly
    /// `completed + rejected + shed` (the accounting invariant the
    /// deadline property suite pins).
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Requests shed by the scheduler with a predicted-cost justification.
    pub shed: u64,
    /// Completed requests that landed after their deadline.
    pub deadline_missed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// p99.9 latency — the soak harness's tail-SLO headline number.
    pub p999_ms: f64,
    pub max_ms: f64,
    pub requests_per_sec: f64,
    pub tiles_per_sec: f64,
    pub max_queue_depth: usize,
    pub wall_seconds: f64,
    /// Engine stage breakdown summed over every pass of the run:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds —
    /// the per-stage view future perf work reads to see *which* stage
    /// moved.
    pub stage_ns: [u64; 3],
}

impl StatsReport {
    /// Flat JSON object (no serde in the vendored crate set). Keys are
    /// stable — `scripts/ci.sh` greps `"completed"` out of this.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"submitted\": {}, \"completed\": {}, \"rejected\": {}, ",
                "\"shed\": {}, \"deadline_missed\": {}, \"batches\": {}, ",
                "\"mean_batch\": {:.3}, ",
                "\"latency_ms\": {{\"p50\": {:.3}, \"p95\": {:.3}, \"p99\": {:.3}, ",
                "\"p999\": {:.3}, \"max\": {:.3}}}, ",
                "\"requests_per_sec\": {:.2}, \"tiles_per_sec\": {:.1}, ",
                "\"max_queue_depth\": {}, \"wall_seconds\": {:.4}, ",
                "\"stage_ns\": {{\"input_transform\": {}, \"hadamard\": {}, ",
                "\"inverse\": {}}}}}"
            ),
            self.submitted,
            self.completed,
            self.rejected,
            self.shed,
            self.deadline_missed,
            self.batches,
            self.mean_batch,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.p999_ms,
            self.max_ms,
            self.requests_per_sec,
            self.tiles_per_sec,
            self.max_queue_depth,
            self.wall_seconds,
            self.stage_ns[0],
            self.stage_ns[1],
            self.stage_ns[2],
        )
    }

    /// [`to_json`](Self::to_json) extended with the serving registry's
    /// transform-plan cache telemetry — hits/misses for the lowered-plan,
    /// float weight-bank, i16 code-bank and register-tile-packed-bank
    /// maps ([`PlanCache::counters`](super::plan::PlanCache::counters) /
    /// [`PlanCache::int_counters`](super::plan::PlanCache::int_counters) /
    /// [`PlanCache::packed_counters`](super::plan::PlanCache::packed_counters)).
    /// Heterogeneous (NetPlan-tuned) models make this worth watching: one
    /// model may populate several `(m, base)` plan entries, a second
    /// registration should hit, not re-transform, quantized variants
    /// of one checkpoint should *share* code banks, not requantize, and
    /// `packed_banks.misses` counts the weight packings actually
    /// performed.
    pub fn to_json_with_plan_cache(
        &self,
        plans: CacheCounters,
        banks: CacheCounters,
        int_banks: CacheCounters,
        packed_banks: CacheCounters,
    ) -> String {
        let core = self.to_json();
        format!(
            concat!(
                "{}, \"plan_cache\": {{",
                "\"plans\": {{\"hits\": {}, \"misses\": {}}}, ",
                "\"banks\": {{\"hits\": {}, \"misses\": {}}}, ",
                "\"int_banks\": {{\"hits\": {}, \"misses\": {}}}, ",
                "\"packed_banks\": {{\"hits\": {}, \"misses\": {}}}}}}}"
            ),
            &core[..core.len() - 1],
            plans.hits,
            plans.misses,
            banks.hits,
            banks.misses,
            int_banks.hits,
            int_banks.misses,
            packed_banks.hits,
            packed_banks.misses,
        )
    }

    /// One-line human summary for the CLI.
    pub fn summary_line(&self) -> String {
        format!(
            "{} ok / {} rejected / {} shed ({} missed deadline) in {:.2}s | \
             {:.1} req/s, {:.0} tiles/s | \
             batch mean {:.2} over {} passes | p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
            self.completed,
            self.rejected,
            self.shed,
            self.deadline_missed,
            self.wall_seconds,
            self.requests_per_sec,
            self.tiles_per_sec,
            self.mean_batch,
            self.batches,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_folds_batches_and_latencies() {
        let s = ServeStats::new();
        s.record_batch(4, 400, 3, &[1000, 2000, 3000, 4000]);
        s.record_batch(2, 200, 7, &[5000, 6000]);
        s.record_reject();
        assert_eq!(s.completed(), 6);
        let r = s.report(2.0);
        assert_eq!(r.completed, 6);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 3.0).abs() < 1e-12);
        assert!((r.p50_ms - 3.0).abs() < 1e-9);
        assert!((r.max_ms - 6.0).abs() < 1e-9);
        assert!((r.requests_per_sec - 3.0).abs() < 1e-9);
        assert!((r.tiles_per_sec - 300.0).abs() < 1e-9);
        assert_eq!(r.max_queue_depth, 7);
        assert_eq!(r.submitted, 7, "submitted = completed + rejected + shed");
    }

    #[test]
    fn shed_and_deadline_miss_accounting() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 0, &[1000, 9000]);
        s.record_reject();
        s.record_shed();
        s.record_shed();
        s.record_deadline_miss(1);
        let r = s.report(1.0);
        assert_eq!((r.completed, r.rejected, r.shed), (2, 1, 2));
        assert_eq!(r.submitted, r.completed + r.rejected + r.shed);
        assert_eq!(r.deadline_missed, 1);
        // p99.9 of a tiny sample is the max (nearest-rank).
        assert!((r.p999_ms - 9.0).abs() < 1e-9);
        let j = r.to_json();
        assert!(j.contains("\"submitted\": 5"), "{j}");
        assert!(j.contains("\"shed\": 2"), "{j}");
        assert!(j.contains("\"deadline_missed\": 1"), "{j}");
        assert!(j.contains("\"p999\": 9.000"), "{j}");
        assert!(s.report(1.0).to_json().contains("\"p999\""));
    }

    #[test]
    fn stage_breakdown_accumulates_and_is_emitted() {
        let s = ServeStats::new();
        s.record_stage_ns([100, 2000, 30]);
        s.record_stage_ns([1, 2, 3]);
        let r = s.report(1.0);
        assert_eq!(r.stage_ns, [101, 2002, 33]);
        let j = r.to_json();
        assert!(
            j.contains(
                "\"stage_ns\": {\"input_transform\": 101, \"hadamard\": 2002, \
                 \"inverse\": 33}"
            ),
            "{j}"
        );
    }

    #[test]
    fn json_with_plan_cache_appends_counters() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json_with_plan_cache(
            CacheCounters { hits: 3, misses: 2 },
            CacheCounters { hits: 28, misses: 14 },
            CacheCounters { hits: 14, misses: 14 },
            CacheCounters { hits: 9, misses: 5 },
        );
        assert!(j.contains("\"plan_cache\""), "{j}");
        assert!(j.contains("\"plans\": {\"hits\": 3, \"misses\": 2}"), "{j}");
        assert!(j.contains("\"banks\": {\"hits\": 28, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"int_banks\": {\"hits\": 14, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"packed_banks\": {\"hits\": 9, \"misses\": 5}"), "{j}");
        // Still one well-formed object: the base keys survive and the
        // braces balance.
        assert!(j.contains("\"completed\""));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced braces in {j}"
        );
        assert!(j.ends_with("}}}"), "{j}");
    }

    #[test]
    fn json_has_stable_keys() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json();
        for key in [
            "\"submitted\"",
            "\"completed\"",
            "\"rejected\"",
            "\"shed\"",
            "\"deadline_missed\"",
            "\"batches\"",
            "\"latency_ms\"",
            "\"p99\"",
            "\"p999\"",
            "\"tiles_per_sec\"",
            "\"max_queue_depth\"",
            "\"stage_ns\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
