//! Serving telemetry: per-request latency percentiles, queue depth,
//! batch-size and throughput accounting, dumped as JSON.
//!
//! One [`ServeStats`] is shared by all workers and clients of a serving
//! run (interior mutability; workers record one batch at a time, so the
//! single mutex is uncontended relative to engine passes). Latency
//! samples fold straight into a log-bucketed
//! [`LogHistogram`](crate::obs::LogHistogram) — **fixed memory however
//! long the run**, where the pre-observability version kept one `u64`
//! per completed request and grew without bound under soak. At the end
//! of a run [`ServeStats::report`] folds the aggregates into a
//! [`StatsReport`] — p50/p95/p99/p99.9 latency (nearest-rank over the
//! histogram buckets, ≤ ~41% bucket-width relative error, exact min/max)
//! — whose [`to_json`](StatsReport::to_json) output is what
//! `winoq serve --stats-json` writes and `scripts/ci.sh` smoke-checks.
//! [`ServeStats::export_metrics`] additionally publishes the same
//! aggregates into a process-wide
//! [`MetricsRegistry`](crate::obs::MetricsRegistry) under the
//! `serve.*` / `engine.stage_ns.*` names (`winoq serve
//! --metrics-json`).

use super::plan::CacheCounters;
use crate::obs::json::JsonObj;
use crate::obs::{LogHistogram, MetricsRegistry};
use std::sync::Mutex;

/// Aggregates accumulated during a serving run. Every field is fixed
/// size — nothing here grows with request count.
#[derive(Default)]
struct StatsState {
    /// Enqueue→response latency histogram (microseconds), one sample
    /// per completed request.
    lat: LogHistogram,
    /// Engine passes executed.
    batches: u64,
    /// Admission rejections (queue full).
    rejected: u64,
    /// Requests shed by the scheduler (predicted cost could not meet the
    /// deadline).
    shed: u64,
    /// Completed requests whose response landed after their deadline.
    deadline_missed: u64,
    /// Winograd tiles processed (batch size × tiles per item).
    tiles: u64,
    /// High-water mark of the queue depth observed at drain time.
    max_queue_depth: usize,
    /// Cumulative engine stage breakdown across all workers:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds
    /// (each worker's scratch accumulates a pass, the worker drains it
    /// here per micro-batch).
    stage_ns: [u64; 3],
}

/// Shared, thread-safe stats sink for one serving run.
#[derive(Default)]
pub struct ServeStats {
    state: Mutex<StatsState>,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one completed micro-batch: its size, the tiles it pushed
    /// through the engine, the queue depth left behind, and every
    /// member request's end-to-end latency in microseconds.
    pub fn record_batch(&self, batch_size: usize, tiles: u64, depth: usize, lat_us: &[u64]) {
        let _ = batch_size; // completed = histogram count; size is lat_us.len()
        let mut st = self.state.lock().unwrap();
        st.batches += 1;
        st.tiles += tiles;
        st.max_queue_depth = st.max_queue_depth.max(depth);
        for &v in lat_us {
            st.lat.record(v);
        }
    }

    /// Record one admission rejection (backpressure).
    pub fn record_reject(&self) {
        self.state.lock().unwrap().rejected += 1;
    }

    /// Record one shed request (the scheduler's predicted-cost decision).
    pub fn record_shed(&self) {
        self.state.lock().unwrap().shed += 1;
    }

    /// Record `n` completed-but-late requests from one batch.
    pub fn record_deadline_miss(&self, n: u64) {
        self.state.lock().unwrap().deadline_missed += n;
    }

    /// Fold one engine-pass stage breakdown (`EngineScratch::take_stage_ns`)
    /// into the run totals.
    pub fn record_stage_ns(&self, stage_ns: [u64; 3]) {
        let mut st = self.state.lock().unwrap();
        for (acc, v) in st.stage_ns.iter_mut().zip(stage_ns) {
            *acc = acc.saturating_add(v);
        }
    }

    /// Completed-request count so far.
    pub fn completed(&self) -> u64 {
        self.state.lock().unwrap().lat.count()
    }

    /// Clone of the latency histogram (microseconds) accumulated so far.
    pub fn latency_histogram(&self) -> LogHistogram {
        self.state.lock().unwrap().lat.clone()
    }

    /// Publish the run's aggregates into a [`MetricsRegistry`] under the
    /// standard names (see the [`crate::obs::metrics`] naming scheme):
    /// `serve.requests.*` counters, the `serve.latency_us` histogram
    /// (merged, so repeated exports from several stats sinks fold),
    /// `serve.{batches,tiles}`, the `serve.queue_depth.max` gauge, and
    /// the three `engine.stage_ns.*` totals.
    pub fn export_metrics(&self, reg: &MetricsRegistry) {
        let st = self.state.lock().unwrap();
        reg.inc("serve.requests.submitted", st.lat.count() + st.rejected + st.shed);
        reg.inc("serve.requests.completed", st.lat.count());
        reg.inc("serve.requests.rejected", st.rejected);
        reg.inc("serve.requests.shed", st.shed);
        reg.inc("serve.requests.deadline_missed", st.deadline_missed);
        reg.inc("serve.batches", st.batches);
        reg.inc("serve.tiles", st.tiles);
        reg.set_gauge("serve.queue_depth.max", st.max_queue_depth as f64);
        reg.merge_hist("serve.latency_us", &st.lat);
        reg.inc("engine.stage_ns.input_transform", st.stage_ns[0]);
        reg.inc("engine.stage_ns.hadamard", st.stage_ns[1]);
        reg.inc("engine.stage_ns.inverse", st.stage_ns[2]);
    }

    /// Fold the aggregates into a report; `wall_seconds` is the run's
    /// wall-clock duration (measured by the caller around the whole
    /// closed loop, queueing included). Percentiles are nearest-rank
    /// over the latency histogram's log buckets — each reported value
    /// is a bucket lower bound clamped into the exact observed
    /// `[min, max]`, so `max` is exact and every percentile is within
    /// one bucket (≤ ~41% relative) of the true sample.
    pub fn report(&self, wall_seconds: f64) -> StatsReport {
        let st = self.state.lock().unwrap();
        let pct = |q: f64| st.lat.value_at_quantile(q) as f64 / 1e3;
        let completed = st.lat.count();
        let wall = wall_seconds.max(1e-9);
        StatsReport {
            submitted: completed + st.rejected + st.shed,
            completed,
            rejected: st.rejected,
            shed: st.shed,
            deadline_missed: st.deadline_missed,
            batches: st.batches,
            mean_batch: if st.batches == 0 {
                0.0
            } else {
                completed as f64 / st.batches as f64
            },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            p999_ms: pct(0.999),
            max_ms: st.lat.max().unwrap_or(0) as f64 / 1e3,
            requests_per_sec: completed as f64 / wall,
            tiles_per_sec: st.tiles as f64 / wall,
            tiles: st.tiles,
            max_queue_depth: st.max_queue_depth,
            wall_seconds,
            stage_ns: st.stage_ns,
        }
    }
}

/// Folded summary of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct StatsReport {
    /// Every request this run accounted for: exactly
    /// `completed + rejected + shed` (the accounting invariant the
    /// deadline property suite pins).
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Requests shed by the scheduler with a predicted-cost justification.
    pub shed: u64,
    /// Completed requests that landed after their deadline.
    pub deadline_missed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// p99.9 latency — the soak harness's tail-SLO headline number.
    pub p999_ms: f64,
    /// Exact maximum latency (histogram min/max tracking is exact even
    /// though percentiles are bucketed).
    pub max_ms: f64,
    pub requests_per_sec: f64,
    pub tiles_per_sec: f64,
    /// Winograd tiles processed over the whole run — the denominator of
    /// the per-tile stage costs in [`to_json`](Self::to_json).
    pub tiles: u64,
    pub max_queue_depth: usize,
    pub wall_seconds: f64,
    /// Engine stage breakdown summed over every pass of the run:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds —
    /// the per-stage view future perf work reads to see *which* stage
    /// moved.
    pub stage_ns: [u64; 3],
}

impl StatsReport {
    /// Nanoseconds per tile for stage `i` (0.0 when no tiles ran) —
    /// stage totals normalized by work done, comparable across runs of
    /// different length.
    pub fn stage_ns_per_tile(&self, i: usize) -> f64 {
        if self.tiles == 0 {
            0.0
        } else {
            self.stage_ns[i] as f64 / self.tiles as f64
        }
    }

    /// Flat JSON object built on the shared [`crate::obs::json`] writer
    /// (no serde in the vendored crate set). Keys are stable —
    /// `scripts/ci.sh` greps `"completed"` and `"stage_ns"` out of
    /// this; `stage_ns_per_tile` reports the same breakdown normalized
    /// per tile.
    pub fn to_json(&self) -> String {
        let latency = JsonObj::new()
            .f64("p50", self.p50_ms, 3)
            .f64("p95", self.p95_ms, 3)
            .f64("p99", self.p99_ms, 3)
            .f64("p999", self.p999_ms, 3)
            .f64("max", self.max_ms, 3)
            .finish();
        let stage = JsonObj::new()
            .u64("input_transform", self.stage_ns[0])
            .u64("hadamard", self.stage_ns[1])
            .u64("inverse", self.stage_ns[2])
            .finish();
        let stage_per_tile = JsonObj::new()
            .f64("input_transform", self.stage_ns_per_tile(0), 1)
            .f64("hadamard", self.stage_ns_per_tile(1), 1)
            .f64("inverse", self.stage_ns_per_tile(2), 1)
            .finish();
        JsonObj::new()
            .u64("submitted", self.submitted)
            .u64("completed", self.completed)
            .u64("rejected", self.rejected)
            .u64("shed", self.shed)
            .u64("deadline_missed", self.deadline_missed)
            .u64("batches", self.batches)
            .f64("mean_batch", self.mean_batch, 3)
            .raw("latency_ms", &latency)
            .f64("requests_per_sec", self.requests_per_sec, 2)
            .f64("tiles_per_sec", self.tiles_per_sec, 1)
            .u64("max_queue_depth", self.max_queue_depth as u64)
            .f64("wall_seconds", self.wall_seconds, 4)
            .raw("stage_ns", &stage)
            .raw("stage_ns_per_tile", &stage_per_tile)
            .finish()
    }

    /// [`to_json`](Self::to_json) extended with the serving registry's
    /// transform-plan cache telemetry — hits/misses for the lowered-plan,
    /// float weight-bank, i16 code-bank and register-tile-packed-bank
    /// maps ([`PlanCache::counters`](super::plan::PlanCache::counters) /
    /// [`PlanCache::int_counters`](super::plan::PlanCache::int_counters) /
    /// [`PlanCache::packed_counters`](super::plan::PlanCache::packed_counters)).
    /// Heterogeneous (NetPlan-tuned) models make this worth watching: one
    /// model may populate several `(m, base)` plan entries, a second
    /// registration should hit, not re-transform, quantized variants
    /// of one checkpoint should *share* code banks, not requantize, and
    /// `packed_banks.misses` counts the weight packings actually
    /// performed.
    pub fn to_json_with_plan_cache(
        &self,
        plans: CacheCounters,
        banks: CacheCounters,
        int_banks: CacheCounters,
        packed_banks: CacheCounters,
    ) -> String {
        let pair = |c: CacheCounters| {
            JsonObj::new().u64("hits", c.hits).u64("misses", c.misses).finish()
        };
        let cache = JsonObj::new()
            .raw("plans", &pair(plans))
            .raw("banks", &pair(banks))
            .raw("int_banks", &pair(int_banks))
            .raw("packed_banks", &pair(packed_banks))
            .finish();
        let core = self.to_json();
        format!("{}, \"plan_cache\": {}}}", &core[..core.len() - 1], cache)
    }

    /// One-line human summary for the CLI.
    pub fn summary_line(&self) -> String {
        format!(
            "{} ok / {} rejected / {} shed ({} missed deadline) in {:.2}s | \
             {:.1} req/s, {:.0} tiles/s | \
             batch mean {:.2} over {} passes | p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
            self.completed,
            self.rejected,
            self.shed,
            self.deadline_missed,
            self.wall_seconds,
            self.requests_per_sec,
            self.tiles_per_sec,
            self.mean_batch,
            self.batches,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_folds_batches_and_latencies() {
        let s = ServeStats::new();
        s.record_batch(4, 400, 3, &[1000, 2000, 3000, 4000]);
        s.record_batch(2, 200, 7, &[5000, 6000]);
        s.record_reject();
        assert_eq!(s.completed(), 6);
        let r = s.report(2.0);
        assert_eq!(r.completed, 6);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 3.0).abs() < 1e-12);
        // Nearest-rank p50 over 6 samples is the 3rd (3000 µs); the log
        // histogram reports its bucket's lower bound, 2048 µs.
        assert!((r.p50_ms - 2.048).abs() < 1e-9);
        // Min/max tracking is exact, bucketing notwithstanding.
        assert!((r.max_ms - 6.0).abs() < 1e-9);
        assert!((r.requests_per_sec - 3.0).abs() < 1e-9);
        assert!((r.tiles_per_sec - 300.0).abs() < 1e-9);
        assert_eq!(r.tiles, 600);
        assert_eq!(r.max_queue_depth, 7);
        assert_eq!(r.submitted, 7, "submitted = completed + rejected + shed");
    }

    /// The histogram percentiles stay within one log bucket (≤ ~41%
    /// low) of the exact nearest-rank answer, and clamp to the exact
    /// observed extremes.
    #[test]
    fn histogram_percentiles_agree_with_nearest_rank_within_bucket() {
        let s = ServeStats::new();
        // 1000 samples: 6, 12, ..., 6000 µs (exact nearest-rank p50 =
        // 3000 µs, p95 = 5700 µs, max = 6000 µs).
        let lat: Vec<u64> = (1..=1000u64).map(|i| i * 6).collect();
        s.record_batch(lat.len(), 0, 0, &lat);
        let r = s.report(1.0);
        // Bucket lower bounds: 3000 → 2048, 5700 → 4096.
        assert!((r.p50_ms - 2.048).abs() < 1e-9, "{}", r.p50_ms);
        assert!((r.p95_ms - 4.096).abs() < 1e-9, "{}", r.p95_ms);
        assert!((r.p999_ms - 4.096).abs() < 1e-9, "{}", r.p999_ms);
        assert!((r.max_ms - 6.0).abs() < 1e-9);
        for (approx, exact) in [(r.p50_ms, 3.0), (r.p95_ms, 5.7), (r.p999_ms, 6.0)] {
            assert!(approx <= exact && approx >= exact * (1.0 - 0.415), "{approx} vs {exact}");
        }
    }

    #[test]
    fn shed_and_deadline_miss_accounting() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 0, &[1000, 9000]);
        s.record_reject();
        s.record_shed();
        s.record_shed();
        s.record_deadline_miss(1);
        let r = s.report(1.0);
        assert_eq!((r.completed, r.rejected, r.shed), (2, 1, 2));
        assert_eq!(r.submitted, r.completed + r.rejected + r.shed);
        assert_eq!(r.deadline_missed, 1);
        // p99.9 of a tiny sample is the max (nearest-rank): 9000 µs,
        // whose histogram bucket starts at 8192 µs.
        assert!((r.p999_ms - 8.192).abs() < 1e-9);
        let j = r.to_json();
        assert!(j.contains("\"submitted\": 5"), "{j}");
        assert!(j.contains("\"shed\": 2"), "{j}");
        assert!(j.contains("\"deadline_missed\": 1"), "{j}");
        assert!(j.contains("\"p999\": 8.192"), "{j}");
        assert!(s.report(1.0).to_json().contains("\"p999\""));
    }

    #[test]
    fn stage_breakdown_accumulates_and_is_emitted() {
        let s = ServeStats::new();
        s.record_batch(1, 3, 0, &[1000]);
        s.record_stage_ns([100, 2000, 30]);
        s.record_stage_ns([1, 2, 3]);
        let r = s.report(1.0);
        assert_eq!(r.stage_ns, [101, 2002, 33]);
        let j = r.to_json();
        assert!(
            j.contains(
                "\"stage_ns\": {\"input_transform\": 101, \"hadamard\": 2002, \
                 \"inverse\": 33}"
            ),
            "{j}"
        );
        // Per-tile view: totals over the 3 tiles this run processed.
        assert!((r.stage_ns_per_tile(1) - 2002.0 / 3.0).abs() < 1e-9);
        assert!(
            j.contains(
                "\"stage_ns_per_tile\": {\"input_transform\": 33.7, \
                 \"hadamard\": 667.3, \"inverse\": 11.0}"
            ),
            "{j}"
        );
    }

    #[test]
    fn zero_tiles_reports_zero_per_tile_cost() {
        let s = ServeStats::new();
        s.record_stage_ns([5, 5, 5]);
        let r = s.report(1.0);
        assert_eq!(r.tiles, 0);
        assert_eq!(r.stage_ns_per_tile(0), 0.0);
        assert!(r.to_json().contains("\"stage_ns_per_tile\": {\"input_transform\": 0.0"));
    }

    #[test]
    fn json_with_plan_cache_appends_counters() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json_with_plan_cache(
            CacheCounters { hits: 3, misses: 2 },
            CacheCounters { hits: 28, misses: 14 },
            CacheCounters { hits: 14, misses: 14 },
            CacheCounters { hits: 9, misses: 5 },
        );
        assert!(j.contains("\"plan_cache\""), "{j}");
        assert!(j.contains("\"plans\": {\"hits\": 3, \"misses\": 2}"), "{j}");
        assert!(j.contains("\"banks\": {\"hits\": 28, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"int_banks\": {\"hits\": 14, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"packed_banks\": {\"hits\": 9, \"misses\": 5}"), "{j}");
        // Still one well-formed object: the base keys survive and the
        // braces balance.
        assert!(j.contains("\"completed\""));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced braces in {j}"
        );
        assert!(j.ends_with("}}}"), "{j}");
        crate::tune::json::parse(&j).unwrap();
    }

    #[test]
    fn json_has_stable_keys() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json();
        for key in [
            "\"submitted\"",
            "\"completed\"",
            "\"rejected\"",
            "\"shed\"",
            "\"deadline_missed\"",
            "\"batches\"",
            "\"latency_ms\"",
            "\"p99\"",
            "\"p999\"",
            "\"tiles_per_sec\"",
            "\"max_queue_depth\"",
            "\"stage_ns\"",
            "\"stage_ns_per_tile\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    /// `export_metrics` publishes the same aggregates the report folds.
    #[test]
    fn export_metrics_mirrors_the_report() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 4, &[1000, 9000]);
        s.record_reject();
        s.record_shed();
        s.record_stage_ns([7, 8, 9]);
        let reg = MetricsRegistry::new();
        s.export_metrics(&reg);
        assert_eq!(reg.counter("serve.requests.submitted"), 4);
        assert_eq!(reg.counter("serve.requests.completed"), 2);
        assert_eq!(reg.counter("serve.requests.rejected"), 1);
        assert_eq!(reg.counter("serve.requests.shed"), 1);
        assert_eq!(reg.counter("serve.batches"), 1);
        assert_eq!(reg.counter("serve.tiles"), 20);
        assert_eq!(reg.gauge("serve.queue_depth.max"), Some(4.0));
        assert_eq!(reg.counter("engine.stage_ns.hadamard"), 8);
        let h = reg.histogram("serve.latency_us").unwrap();
        assert_eq!((h.count(), h.max()), (2, Some(9000)));
    }
}
