//! Serving telemetry: per-request latency percentiles, queue depth,
//! batch-size and throughput accounting, dumped as JSON.
//!
//! One [`ServeStats`] is shared by all workers and clients of a serving
//! run (interior mutability; workers record one batch at a time, so the
//! single mutex is uncontended relative to engine passes). Latency
//! samples fold straight into a log-bucketed
//! [`LogHistogram`](crate::obs::LogHistogram) — **fixed memory however
//! long the run**, where the pre-observability version kept one `u64`
//! per completed request and grew without bound under soak. At the end
//! of a run [`ServeStats::report`] folds the aggregates into a
//! [`StatsReport`] — p50/p95/p99/p99.9 latency (nearest-rank over the
//! histogram buckets, ≤ ~41% bucket-width relative error, exact min/max)
//! — whose [`to_json`](StatsReport::to_json) output is what
//! `winoq serve --stats-json` writes and `scripts/ci.sh` smoke-checks.
//! [`ServeStats::export_metrics`] additionally publishes the same
//! aggregates into a process-wide
//! [`MetricsRegistry`](crate::obs::MetricsRegistry) under the
//! `serve.*` / `engine.stage_ns.*` names (`winoq serve
//! --metrics-json`).

use super::plan::CacheCounters;
use crate::obs::json::JsonObj;
use crate::obs::{LogHistogram, MetricsRegistry, TimeSeries};
use std::sync::Mutex;

/// Width of one stats time-series window (1 s of queue time). Short
/// live runs land in a single window; soak runs (virtual clock, tens of
/// seconds) rotate through many and exercise eviction.
const SERIES_WINDOW_US: u64 = 1_000_000;
/// Retained windows per stats series — older windows fold into the
/// eviction tail, so memory stays fixed however long the run.
const SERIES_WINDOWS: usize = 8;

/// Aggregates accumulated during a serving run. Every field is fixed
/// size — nothing here grows with request count.
struct StatsState {
    /// Enqueue→response latency histogram (microseconds), one sample
    /// per completed request.
    lat: LogHistogram,
    /// Engine passes executed.
    batches: u64,
    /// Admission rejections (queue full).
    rejected: u64,
    /// Requests shed by the scheduler (predicted cost could not meet the
    /// deadline).
    shed: u64,
    /// Requests failed terminally by the supervisor because their batch
    /// was poisoned by a worker panic (`ServeError::Failed`).
    failed: u64,
    /// Worker restarts performed by the supervisor (each one follows a
    /// poisoned batch and a backoff sleep).
    worker_restarts: u64,
    /// Layers currently serving via a fallback engine (float or direct)
    /// instead of their tuned quantized path — the `serve.degraded`
    /// gauge. Last-write-wins snapshot from the fallback controller.
    degraded: u64,
    /// Completed requests whose response landed after their deadline.
    deadline_missed: u64,
    /// Winograd tiles processed (batch size × tiles per item).
    tiles: u64,
    /// High-water mark of the queue depth observed at drain time.
    max_queue_depth: usize,
    /// Cumulative engine stage breakdown across all workers:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds
    /// (each worker's scratch accumulates a pass, the worker drains it
    /// here per micro-batch).
    stage_ns: [u64; 3],
    /// Queue depth left behind after each drain, bucketed into rotating
    /// one-second windows of queue time (`serve.window.queue_depth`).
    depth_series: TimeSeries,
    /// Drained micro-batch sizes per window (`serve.window.batch_size`)
    /// — the windowed view of batching efficiency under load swings.
    batch_series: TimeSeries,
    /// Per-request latency per window (`serve.window.latency_us`) — the
    /// windowed counterpart of the lifetime `lat` histogram.
    lat_series: TimeSeries,
    /// Total wall-microseconds workers spent executing batches (not
    /// parked waiting) — numerator of `worker_utilization`.
    busy_us: u64,
    /// Worker threads serving this stats sink (summed across shards
    /// when shards share a sink).
    workers: u64,
}

impl Default for StatsState {
    fn default() -> StatsState {
        StatsState {
            lat: LogHistogram::default(),
            batches: 0,
            rejected: 0,
            shed: 0,
            failed: 0,
            worker_restarts: 0,
            degraded: 0,
            deadline_missed: 0,
            tiles: 0,
            max_queue_depth: 0,
            stage_ns: [0; 3],
            depth_series: TimeSeries::new("serve.window.queue_depth", SERIES_WINDOW_US, SERIES_WINDOWS),
            batch_series: TimeSeries::new("serve.window.batch_size", SERIES_WINDOW_US, SERIES_WINDOWS),
            lat_series: TimeSeries::new("serve.window.latency_us", SERIES_WINDOW_US, SERIES_WINDOWS),
            busy_us: 0,
            workers: 0,
        }
    }
}

/// Shared, thread-safe stats sink for one serving run.
#[derive(Default)]
pub struct ServeStats {
    state: Mutex<StatsState>,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one completed micro-batch: its size, the tiles it pushed
    /// through the engine, the queue depth left behind, and every
    /// member request's end-to-end latency in microseconds. Samples land
    /// in the time-series window containing queue time 0 — callers that
    /// know the queue clock should prefer
    /// [`record_batch_at`](Self::record_batch_at).
    pub fn record_batch(&self, batch_size: usize, tiles: u64, depth: usize, lat_us: &[u64]) {
        self.record_batch_at(batch_size, tiles, depth, lat_us, 0);
    }

    /// [`record_batch`](Self::record_batch) stamped with the queue clock
    /// (`ServeQueue::now_us` — wall time live, virtual time under soak,
    /// so the windowed series rotate deterministically in soak reruns).
    /// `now_us` picks the window each depth/batch-size/latency sample
    /// falls into.
    pub fn record_batch_at(
        &self,
        batch_size: usize,
        tiles: u64,
        depth: usize,
        lat_us: &[u64],
        now_us: u64,
    ) {
        let mut st = self.state.lock().unwrap();
        st.batches += 1;
        st.tiles += tiles;
        st.max_queue_depth = st.max_queue_depth.max(depth);
        st.depth_series.record(now_us, depth as u64);
        st.batch_series.record(now_us, batch_size as u64);
        for &v in lat_us {
            st.lat.record(v);
            st.lat_series.record(now_us, v);
        }
    }

    /// Note `n` worker threads draining into this sink (additive, so
    /// shards sharing one sink account all their workers). Denominator
    /// of the report's `worker_utilization`.
    pub fn note_workers(&self, n: usize) {
        self.state.lock().unwrap().workers += n as u64;
    }

    /// Fold `us` wall-microseconds of worker busy time (time spent
    /// executing a batch rather than parked on the queue).
    pub fn record_busy_us(&self, us: u64) {
        let mut st = self.state.lock().unwrap();
        st.busy_us = st.busy_us.saturating_add(us);
    }

    /// Record one admission rejection (backpressure).
    pub fn record_reject(&self) {
        self.state.lock().unwrap().rejected += 1;
    }

    /// Record one shed request (the scheduler's predicted-cost decision).
    pub fn record_shed(&self) {
        self.state.lock().unwrap().shed += 1;
    }

    /// Record `n` requests failed terminally because their batch was
    /// poisoned by a worker panic (the supervisor's per-batch blast
    /// radius — the rest of the queue keeps serving).
    pub fn record_failed(&self, n: u64) {
        self.state.lock().unwrap().failed += n;
    }

    /// Record one supervisor worker restart.
    pub fn record_worker_restart(&self) {
        self.state.lock().unwrap().worker_restarts += 1;
    }

    /// Snapshot the number of layers currently degraded to a fallback
    /// engine (written by the fallback controller after every mode
    /// change; last write wins).
    pub fn set_degraded(&self, n: u64) {
        self.state.lock().unwrap().degraded = n;
    }

    /// Layers currently degraded to a fallback engine (the gauge's
    /// current value).
    pub fn degraded(&self) -> u64 {
        self.state.lock().unwrap().degraded
    }

    /// Failed-request count so far.
    pub fn failed(&self) -> u64 {
        self.state.lock().unwrap().failed
    }

    /// Supervisor worker-restart count so far.
    pub fn worker_restarts(&self) -> u64 {
        self.state.lock().unwrap().worker_restarts
    }

    /// Record `n` completed-but-late requests from one batch.
    pub fn record_deadline_miss(&self, n: u64) {
        self.state.lock().unwrap().deadline_missed += n;
    }

    /// Fold one engine-pass stage breakdown (`EngineScratch::take_stage_ns`)
    /// into the run totals.
    pub fn record_stage_ns(&self, stage_ns: [u64; 3]) {
        let mut st = self.state.lock().unwrap();
        for (acc, v) in st.stage_ns.iter_mut().zip(stage_ns) {
            *acc = acc.saturating_add(v);
        }
    }

    /// Completed-request count so far.
    pub fn completed(&self) -> u64 {
        self.state.lock().unwrap().lat.count()
    }

    /// Clone of the latency histogram (microseconds) accumulated so far.
    pub fn latency_histogram(&self) -> LogHistogram {
        self.state.lock().unwrap().lat.clone()
    }

    /// Publish the run's aggregates into a [`MetricsRegistry`] under the
    /// standard names (see the [`crate::obs::metrics`] naming scheme):
    /// `serve.requests.*` counters, the `serve.latency_us` histogram
    /// (merged, so repeated exports from several stats sinks fold),
    /// `serve.{batches,tiles}`, the `serve.queue_depth.max` gauge, and
    /// the three `engine.stage_ns.*` totals.
    pub fn export_metrics(&self, reg: &MetricsRegistry) {
        let st = self.state.lock().unwrap();
        reg.inc(
            "serve.requests.submitted",
            st.lat.count() + st.rejected + st.shed + st.failed,
        );
        reg.inc("serve.requests.completed", st.lat.count());
        reg.inc("serve.requests.rejected", st.rejected);
        reg.inc("serve.requests.shed", st.shed);
        reg.inc("serve.failed", st.failed);
        reg.inc("serve.worker_restarts", st.worker_restarts);
        reg.set_gauge("serve.degraded", st.degraded as f64);
        reg.inc("serve.requests.deadline_missed", st.deadline_missed);
        reg.inc("serve.batches", st.batches);
        reg.inc("serve.tiles", st.tiles);
        reg.set_gauge("serve.queue_depth.max", st.max_queue_depth as f64);
        reg.merge_hist("serve.latency_us", &st.lat);
        reg.inc("engine.stage_ns.input_transform", st.stage_ns[0]);
        reg.inc("engine.stage_ns.hadamard", st.stage_ns[1]);
        reg.inc("engine.stage_ns.inverse", st.stage_ns[2]);
        reg.set_gauge("serve.workers", st.workers as f64);
        reg.inc("serve.busy_us", st.busy_us);
        st.depth_series.export_metrics(reg);
        st.batch_series.export_metrics(reg);
        st.lat_series.export_metrics(reg);
    }

    /// Fold the aggregates into a report; `wall_seconds` is the run's
    /// wall-clock duration (measured by the caller around the whole
    /// closed loop, queueing included). Percentiles are nearest-rank
    /// over the latency histogram's log buckets — each reported value
    /// is a bucket lower bound clamped into the exact observed
    /// `[min, max]`, so `max` is exact and every percentile is within
    /// one bucket (≤ ~41% relative) of the true sample.
    pub fn report(&self, wall_seconds: f64) -> StatsReport {
        let st = self.state.lock().unwrap();
        let pct = |q: f64| st.lat.value_at_quantile(q) as f64 / 1e3;
        let completed = st.lat.count();
        let wall = wall_seconds.max(1e-9);
        let worker_utilization = if st.workers == 0 {
            0.0
        } else {
            (st.busy_us as f64 / 1e6) / (st.workers as f64 * wall)
        };
        StatsReport {
            submitted: completed + st.rejected + st.shed + st.failed,
            completed,
            rejected: st.rejected,
            shed: st.shed,
            failed: st.failed,
            worker_restarts: st.worker_restarts,
            degraded: st.degraded,
            deadline_missed: st.deadline_missed,
            batches: st.batches,
            mean_batch: if st.batches == 0 {
                0.0
            } else {
                completed as f64 / st.batches as f64
            },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            p999_ms: pct(0.999),
            max_ms: st.lat.max().unwrap_or(0) as f64 / 1e3,
            requests_per_sec: completed as f64 / wall,
            tiles_per_sec: st.tiles as f64 / wall,
            tiles: st.tiles,
            max_queue_depth: st.max_queue_depth,
            queue_depth_recent_mean: st.depth_series.merged().mean(),
            workers: st.workers,
            busy_us: st.busy_us,
            worker_utilization,
            wall_seconds,
            stage_ns: st.stage_ns,
        }
    }
}

/// Folded summary of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct StatsReport {
    /// Every request this run accounted for: exactly
    /// `completed + rejected + shed + failed` (the accounting invariant
    /// the deadline and chaos property suites pin).
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Requests shed by the scheduler with a predicted-cost justification.
    pub shed: u64,
    /// Requests failed terminally by the supervisor (poisoned batch).
    pub failed: u64,
    /// Supervisor worker restarts over the run.
    pub worker_restarts: u64,
    /// Layers serving via a fallback engine at report time.
    pub degraded: u64,
    /// Completed requests that landed after their deadline.
    pub deadline_missed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// p99.9 latency — the soak harness's tail-SLO headline number.
    pub p999_ms: f64,
    /// Exact maximum latency (histogram min/max tracking is exact even
    /// though percentiles are bucketed).
    pub max_ms: f64,
    pub requests_per_sec: f64,
    pub tiles_per_sec: f64,
    /// Winograd tiles processed over the whole run — the denominator of
    /// the per-tile stage costs in [`to_json`](Self::to_json).
    pub tiles: u64,
    pub max_queue_depth: usize,
    /// Mean drain-time queue depth over the retained time-series
    /// windows (the last ~8 s of queue time) — the recency-weighted
    /// companion of the lifetime `max_queue_depth` high-water mark.
    pub queue_depth_recent_mean: f64,
    /// Worker threads that drained into this sink.
    pub workers: u64,
    /// Total wall-microseconds those workers spent executing batches.
    pub busy_us: u64,
    /// `busy_us / (workers × wall)` — fraction of worker capacity spent
    /// executing rather than parked. Can exceed 1.0 slightly when the
    /// caller's wall clock stops before the last worker drains.
    pub worker_utilization: f64,
    pub wall_seconds: f64,
    /// Engine stage breakdown summed over every pass of the run:
    /// `[input-transform, hadamard/GEMM, inverse]` wall-nanoseconds —
    /// the per-stage view future perf work reads to see *which* stage
    /// moved.
    pub stage_ns: [u64; 3],
}

impl StatsReport {
    /// Nanoseconds per tile for stage `i` (0.0 when no tiles ran) —
    /// stage totals normalized by work done, comparable across runs of
    /// different length.
    pub fn stage_ns_per_tile(&self, i: usize) -> f64 {
        if self.tiles == 0 {
            0.0
        } else {
            self.stage_ns[i] as f64 / self.tiles as f64
        }
    }

    /// Flat JSON object built on the shared [`crate::obs::json`] writer
    /// (no serde in the vendored crate set). Keys are stable —
    /// `scripts/ci.sh` greps `"completed"` and `"stage_ns"` out of
    /// this; `stage_ns_per_tile` reports the same breakdown normalized
    /// per tile.
    pub fn to_json(&self) -> String {
        let latency = JsonObj::new()
            .f64("p50", self.p50_ms, 3)
            .f64("p95", self.p95_ms, 3)
            .f64("p99", self.p99_ms, 3)
            .f64("p999", self.p999_ms, 3)
            .f64("max", self.max_ms, 3)
            .finish();
        let stage = JsonObj::new()
            .u64("input_transform", self.stage_ns[0])
            .u64("hadamard", self.stage_ns[1])
            .u64("inverse", self.stage_ns[2])
            .finish();
        let stage_per_tile = JsonObj::new()
            .f64("input_transform", self.stage_ns_per_tile(0), 1)
            .f64("hadamard", self.stage_ns_per_tile(1), 1)
            .f64("inverse", self.stage_ns_per_tile(2), 1)
            .finish();
        JsonObj::new()
            .u64("submitted", self.submitted)
            .u64("completed", self.completed)
            .u64("rejected", self.rejected)
            .u64("shed", self.shed)
            .u64("failed", self.failed)
            .u64("deadline_missed", self.deadline_missed)
            .u64("batches", self.batches)
            .f64("mean_batch", self.mean_batch, 3)
            .raw("latency_ms", &latency)
            .f64("requests_per_sec", self.requests_per_sec, 2)
            .f64("tiles_per_sec", self.tiles_per_sec, 1)
            .u64("max_queue_depth", self.max_queue_depth as u64)
            .f64("queue_depth_recent_mean", self.queue_depth_recent_mean, 3)
            .u64("workers", self.workers)
            .u64("worker_restarts", self.worker_restarts)
            .u64("degraded", self.degraded)
            .u64("busy_us", self.busy_us)
            .f64("worker_utilization", self.worker_utilization, 4)
            .f64("wall_seconds", self.wall_seconds, 4)
            .raw("stage_ns", &stage)
            .raw("stage_ns_per_tile", &stage_per_tile)
            .finish()
    }

    /// [`to_json`](Self::to_json) extended with the serving registry's
    /// transform-plan cache telemetry — hits/misses for the lowered-plan,
    /// float weight-bank, i16 code-bank and register-tile-packed-bank
    /// maps ([`PlanCache::counters`](super::plan::PlanCache::counters) /
    /// [`PlanCache::int_counters`](super::plan::PlanCache::int_counters) /
    /// [`PlanCache::packed_counters`](super::plan::PlanCache::packed_counters)).
    /// Heterogeneous (NetPlan-tuned) models make this worth watching: one
    /// model may populate several `(m, base)` plan entries, a second
    /// registration should hit, not re-transform, quantized variants
    /// of one checkpoint should *share* code banks, not requantize, and
    /// `packed_banks.misses` counts the weight packings actually
    /// performed.
    pub fn to_json_with_plan_cache(
        &self,
        plans: CacheCounters,
        banks: CacheCounters,
        int_banks: CacheCounters,
        packed_banks: CacheCounters,
    ) -> String {
        let pair = |c: CacheCounters| {
            JsonObj::new().u64("hits", c.hits).u64("misses", c.misses).finish()
        };
        let cache = JsonObj::new()
            .raw("plans", &pair(plans))
            .raw("banks", &pair(banks))
            .raw("int_banks", &pair(int_banks))
            .raw("packed_banks", &pair(packed_banks))
            .finish();
        let core = self.to_json();
        format!("{}, \"plan_cache\": {}}}", &core[..core.len() - 1], cache)
    }

    /// One-line human summary for the CLI.
    pub fn summary_line(&self) -> String {
        format!(
            "{} ok / {} rejected / {} shed / {} failed ({} missed deadline) in {:.2}s | \
             {:.1} req/s, {:.0} tiles/s | \
             batch mean {:.2} over {} passes | p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms | \
             {} workers {:.0}% busy, {} restarts, {} degraded",
            self.completed,
            self.rejected,
            self.shed,
            self.failed,
            self.deadline_missed,
            self.wall_seconds,
            self.requests_per_sec,
            self.tiles_per_sec,
            self.mean_batch,
            self.batches,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.workers,
            self.worker_utilization * 100.0,
            self.worker_restarts,
            self.degraded,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_folds_batches_and_latencies() {
        let s = ServeStats::new();
        s.record_batch(4, 400, 3, &[1000, 2000, 3000, 4000]);
        s.record_batch(2, 200, 7, &[5000, 6000]);
        s.record_reject();
        assert_eq!(s.completed(), 6);
        let r = s.report(2.0);
        assert_eq!(r.completed, 6);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 3.0).abs() < 1e-12);
        // Nearest-rank p50 over 6 samples is the 3rd (3000 µs); the log
        // histogram reports its bucket's lower bound, 2048 µs.
        assert!((r.p50_ms - 2.048).abs() < 1e-9);
        // Min/max tracking is exact, bucketing notwithstanding.
        assert!((r.max_ms - 6.0).abs() < 1e-9);
        assert!((r.requests_per_sec - 3.0).abs() < 1e-9);
        assert!((r.tiles_per_sec - 300.0).abs() < 1e-9);
        assert_eq!(r.tiles, 600);
        assert_eq!(r.max_queue_depth, 7);
        assert_eq!(r.submitted, 7, "submitted = completed + rejected + shed");
    }

    /// The histogram percentiles stay within one log bucket (≤ ~41%
    /// low) of the exact nearest-rank answer, and clamp to the exact
    /// observed extremes.
    #[test]
    fn histogram_percentiles_agree_with_nearest_rank_within_bucket() {
        let s = ServeStats::new();
        // 1000 samples: 6, 12, ..., 6000 µs (exact nearest-rank p50 =
        // 3000 µs, p95 = 5700 µs, max = 6000 µs).
        let lat: Vec<u64> = (1..=1000u64).map(|i| i * 6).collect();
        s.record_batch(lat.len(), 0, 0, &lat);
        let r = s.report(1.0);
        // Bucket lower bounds: 3000 → 2048, 5700 → 4096.
        assert!((r.p50_ms - 2.048).abs() < 1e-9, "{}", r.p50_ms);
        assert!((r.p95_ms - 4.096).abs() < 1e-9, "{}", r.p95_ms);
        assert!((r.p999_ms - 4.096).abs() < 1e-9, "{}", r.p999_ms);
        assert!((r.max_ms - 6.0).abs() < 1e-9);
        for (approx, exact) in [(r.p50_ms, 3.0), (r.p95_ms, 5.7), (r.p999_ms, 6.0)] {
            assert!(approx <= exact && approx >= exact * (1.0 - 0.415), "{approx} vs {exact}");
        }
    }

    #[test]
    fn shed_and_deadline_miss_accounting() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 0, &[1000, 9000]);
        s.record_reject();
        s.record_shed();
        s.record_shed();
        s.record_deadline_miss(1);
        let r = s.report(1.0);
        assert_eq!((r.completed, r.rejected, r.shed), (2, 1, 2));
        assert_eq!(r.submitted, r.completed + r.rejected + r.shed);
        assert_eq!(r.deadline_missed, 1);
        // p99.9 of a tiny sample is the max (nearest-rank): 9000 µs,
        // whose histogram bucket starts at 8192 µs.
        assert!((r.p999_ms - 8.192).abs() < 1e-9);
        let j = r.to_json();
        assert!(j.contains("\"submitted\": 5"), "{j}");
        assert!(j.contains("\"shed\": 2"), "{j}");
        assert!(j.contains("\"deadline_missed\": 1"), "{j}");
        assert!(j.contains("\"p999\": 8.192"), "{j}");
        assert!(s.report(1.0).to_json().contains("\"p999\""));
    }

    #[test]
    fn stage_breakdown_accumulates_and_is_emitted() {
        let s = ServeStats::new();
        s.record_batch(1, 3, 0, &[1000]);
        s.record_stage_ns([100, 2000, 30]);
        s.record_stage_ns([1, 2, 3]);
        let r = s.report(1.0);
        assert_eq!(r.stage_ns, [101, 2002, 33]);
        let j = r.to_json();
        assert!(
            j.contains(
                "\"stage_ns\": {\"input_transform\": 101, \"hadamard\": 2002, \
                 \"inverse\": 33}"
            ),
            "{j}"
        );
        // Per-tile view: totals over the 3 tiles this run processed.
        assert!((r.stage_ns_per_tile(1) - 2002.0 / 3.0).abs() < 1e-9);
        assert!(
            j.contains(
                "\"stage_ns_per_tile\": {\"input_transform\": 33.7, \
                 \"hadamard\": 667.3, \"inverse\": 11.0}"
            ),
            "{j}"
        );
    }

    #[test]
    fn zero_tiles_reports_zero_per_tile_cost() {
        let s = ServeStats::new();
        s.record_stage_ns([5, 5, 5]);
        let r = s.report(1.0);
        assert_eq!(r.tiles, 0);
        assert_eq!(r.stage_ns_per_tile(0), 0.0);
        assert!(r.to_json().contains("\"stage_ns_per_tile\": {\"input_transform\": 0.0"));
    }

    #[test]
    fn json_with_plan_cache_appends_counters() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json_with_plan_cache(
            CacheCounters { hits: 3, misses: 2 },
            CacheCounters { hits: 28, misses: 14 },
            CacheCounters { hits: 14, misses: 14 },
            CacheCounters { hits: 9, misses: 5 },
        );
        assert!(j.contains("\"plan_cache\""), "{j}");
        assert!(j.contains("\"plans\": {\"hits\": 3, \"misses\": 2}"), "{j}");
        assert!(j.contains("\"banks\": {\"hits\": 28, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"int_banks\": {\"hits\": 14, \"misses\": 14}"), "{j}");
        assert!(j.contains("\"packed_banks\": {\"hits\": 9, \"misses\": 5}"), "{j}");
        // Still one well-formed object: the base keys survive and the
        // braces balance.
        assert!(j.contains("\"completed\""));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced braces in {j}"
        );
        assert!(j.ends_with("}}}"), "{j}");
        crate::tune::json::parse(&j).unwrap();
    }

    #[test]
    fn json_has_stable_keys() {
        let r = ServeStats::new().report(1.0);
        let j = r.to_json();
        for key in [
            "\"submitted\"",
            "\"completed\"",
            "\"rejected\"",
            "\"shed\"",
            "\"failed\"",
            "\"deadline_missed\"",
            "\"worker_restarts\"",
            "\"degraded\"",
            "\"batches\"",
            "\"latency_ms\"",
            "\"p99\"",
            "\"p999\"",
            "\"tiles_per_sec\"",
            "\"max_queue_depth\"",
            "\"stage_ns\"",
            "\"stage_ns_per_tile\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    /// Resilience surface: failed requests extend the accounting
    /// identity, restarts and the degraded gauge ride the report and
    /// the metrics registry.
    #[test]
    fn failed_restarts_and_degraded_accounting() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 0, &[1000, 2000]);
        s.record_reject();
        s.record_shed();
        s.record_failed(3);
        s.record_worker_restart();
        s.record_worker_restart();
        s.set_degraded(5);
        s.set_degraded(1); // last write wins
        assert_eq!(s.failed(), 3);
        assert_eq!(s.worker_restarts(), 2);
        let r = s.report(1.0);
        assert_eq!((r.completed, r.rejected, r.shed, r.failed), (2, 1, 1, 3));
        assert_eq!(r.submitted, r.completed + r.rejected + r.shed + r.failed);
        assert_eq!(r.submitted, 7);
        assert_eq!((r.worker_restarts, r.degraded), (2, 1));
        let j = r.to_json();
        assert!(j.contains("\"failed\": 3"), "{j}");
        assert!(j.contains("\"worker_restarts\": 2"), "{j}");
        assert!(j.contains("\"degraded\": 1"), "{j}");
        assert!(r.summary_line().contains("3 failed"), "{}", r.summary_line());
        assert!(r.summary_line().contains("2 restarts"), "{}", r.summary_line());
        let reg = MetricsRegistry::new();
        s.export_metrics(&reg);
        assert_eq!(reg.counter("serve.requests.submitted"), 7);
        assert_eq!(reg.counter("serve.failed"), 3);
        assert_eq!(reg.counter("serve.worker_restarts"), 2);
        assert_eq!(reg.gauge("serve.degraded"), Some(1.0));
    }

    /// Satellite surface: drain-time samples land in rotating windows,
    /// worker bookkeeping folds into a utilization fraction, and both
    /// show up in the JSON report and the metrics registry.
    #[test]
    fn windowed_series_and_worker_utilization() {
        let s = ServeStats::new();
        s.note_workers(2);
        s.record_batch_at(2, 20, 3, &[1000, 2000], 500_000); // window 0
        s.record_batch_at(1, 10, 5, &[3000], 1_500_000); // window 1
        s.record_busy_us(600_000);
        s.record_busy_us(400_000);
        let r = s.report(1.0);
        assert_eq!(r.workers, 2);
        assert_eq!(r.busy_us, 1_000_000);
        // 1.0 busy-second over 2 workers × 1.0 s wall = 50%.
        assert!((r.worker_utilization - 0.5).abs() < 1e-12);
        // Depth samples 3 and 5 over the retained windows (sum is exact
        // in the log histogram, so the mean is too).
        assert!((r.queue_depth_recent_mean - 4.0).abs() < 1e-12);
        let j = r.to_json();
        assert!(j.contains("\"workers\": 2"), "{j}");
        assert!(j.contains("\"busy_us\": 1000000"), "{j}");
        assert!(j.contains("\"worker_utilization\": 0.5000"), "{j}");
        assert!(j.contains("\"queue_depth_recent_mean\": 4.000"), "{j}");
        let reg = MetricsRegistry::new();
        s.export_metrics(&reg);
        assert_eq!(reg.gauge("serve.workers"), Some(2.0));
        assert_eq!(reg.counter("serve.busy_us"), 1_000_000);
        // Two drains crossed a window boundary: two retained windows.
        assert_eq!(reg.gauge("serve.window.queue_depth.windows"), Some(2.0));
        let depth = reg.histogram("serve.window.queue_depth").unwrap();
        assert_eq!((depth.count(), depth.max()), (2, Some(5)));
        let lat = reg.histogram("serve.window.latency_us.recent").unwrap();
        assert_eq!(lat.count(), 3);
        let batch = reg.histogram("serve.window.batch_size").unwrap();
        assert_eq!(batch.sum(), 3, "batch sizes 2 + 1");
    }

    /// `export_metrics` publishes the same aggregates the report folds.
    #[test]
    fn export_metrics_mirrors_the_report() {
        let s = ServeStats::new();
        s.record_batch(2, 20, 4, &[1000, 9000]);
        s.record_reject();
        s.record_shed();
        s.record_stage_ns([7, 8, 9]);
        let reg = MetricsRegistry::new();
        s.export_metrics(&reg);
        assert_eq!(reg.counter("serve.requests.submitted"), 4);
        assert_eq!(reg.counter("serve.requests.completed"), 2);
        assert_eq!(reg.counter("serve.requests.rejected"), 1);
        assert_eq!(reg.counter("serve.requests.shed"), 1);
        assert_eq!(reg.counter("serve.batches"), 1);
        assert_eq!(reg.counter("serve.tiles"), 20);
        assert_eq!(reg.gauge("serve.queue_depth.max"), Some(4.0));
        assert_eq!(reg.counter("engine.stage_ns.hadamard"), 8);
        let h = reg.histogram("serve.latency_us").unwrap();
        assert_eq!((h.count(), h.max()), (2, Some(9000)));
    }
}
