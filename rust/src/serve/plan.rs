//! Transform-plan cache: the precomputed base-changed `Bᵀ/G/A` matrices
//! and transformed (optionally fake-quantized) `G·W` weight banks, shared
//! across every model and layer the server hosts.
//!
//! The exact Toom-Cook construction runs over rationals and the base
//! change conjugates three matrices — cheap once, wasteful when repeated
//! per layer per model per registration. [`PlanCache`] memoizes the
//! lowered [`WinoF`] by [`PlanKey`] `(m, r, base)` and the per-layer
//! transformed weight banks by `(layer id, key)`. The registry consumes
//! both ([`weight_bank`](PlanCache::weight_bank) →
//! [`WinoConv2d::from_transformed`](crate::nn::winolayer::WinoConv2d::from_transformed)),
//! so in the serving path
//! [`WinoEngine::from_transformed_weights`](crate::engine::WinoEngine::from_transformed_weights)
//! is the **only** engine construction route: transforms are computed
//! once, engines are lowered from cached panels.

use crate::engine::int::{IntWeightBank, MAX_CODE_BITS};
use crate::engine::{transform_weight_bank, PackedF64};
use crate::nn::tensor::Tensor;
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cache key for one transform plan: `F(m×m, r×r)` in `base`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub m: usize,
    pub r: usize,
    pub base: Base,
}

impl PlanKey {
    pub fn f(m: usize, r: usize, base: Base) -> PlanKey {
        PlanKey { m, r, base }
    }
}

/// Hit/miss counters for one cache map (telemetry for the stats dump).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
}

/// A transformed `[K][C]` weight bank (each entry an `N×N` tile matrix).
pub type WeightBank = Vec<Vec<Mat>>;

type BankMap = HashMap<(String, PlanKey), Arc<WeightBank>>;

/// Integer code banks are additionally keyed by the weight bit width —
/// `w8` and `w8_h9` variants of one layer share a single 8-bit bank.
type IntBankMap = HashMap<(String, PlanKey, u32), Arc<IntWeightBank>>;

/// Register-tile-packed float weight banks (`engine::gemm` layout),
/// keyed like the float banks they are packed from.
type PackedMap = HashMap<(String, PlanKey), Arc<PackedF64>>;

/// Shared cache of lowered transform plans and transformed weight banks.
///
/// Interior mutability (`Mutex`) so one cache can be shared by reference
/// across the registry and worker threads; both maps are tiny (a handful
/// of plans, one bank per hosted layer) and are only touched at model
/// registration time, never on the request hot path.
#[derive(Default)]
pub struct PlanCache {
    wfs: Mutex<HashMap<PlanKey, Arc<WinoF>>>,
    banks: Mutex<BankMap>,
    int_banks: Mutex<IntBankMap>,
    packed_banks: Mutex<PackedMap>,
    /// Per-shape tile geometry, keyed `(model namespace, h, w)` — the
    /// arbitrary-H×W serving path's cache: walking a whole net's conv
    /// stack for its tile count is cheap but not free, and the scheduler
    /// asks on every admission. Namespacing by model makes cross-shard
    /// collisions structurally impossible (two shards of the same
    /// geometry still get distinct keys).
    shape_tiles: Mutex<HashMap<(String, usize, usize), u64>>,
    wf_counters: Mutex<CacheCounters>,
    bank_counters: Mutex<CacheCounters>,
    int_counters: Mutex<CacheCounters>,
    packed_counters: Mutex<CacheCounters>,
    shape_counters: Mutex<CacheCounters>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The lowered transform plan for `key`, building it on first use.
    pub fn wf(&self, key: PlanKey) -> Arc<WinoF> {
        let mut map = self.wfs.lock().unwrap();
        let mut counters = self.wf_counters.lock().unwrap();
        if let Some(wf) = map.get(&key) {
            counters.hits += 1;
            return wf.clone();
        }
        counters.misses += 1;
        let wf = Arc::new(WinoF::new(&WinogradPlan::new(key.m, key.r), key.base));
        map.insert(key, wf.clone());
        wf
    }

    /// The transformed `[K][C]` weight bank for one layer, computing it on
    /// first use. `layer_id` must be globally unique per weight tensor
    /// (the registry uses `"<model>/<layer prefix>"`); re-registering the
    /// same model — or building several quantized variants of one
    /// checkpoint — reuses the float bank instead of re-transforming.
    pub fn weight_bank(&self, layer_id: &str, key: PlanKey, weights: &Tensor) -> Arc<WeightBank> {
        let map_key = (layer_id.to_string(), key);
        let wf = self.wf(key);
        // The map lock is held across the transform: this runs at model
        // registration, never on the request hot path, and serializing
        // concurrent registrations of the same layer guarantees the heavy
        // transform runs exactly once (and the hit/miss telemetry stays
        // truthful) instead of racing check-then-insert.
        let mut map = self.banks.lock().unwrap();
        let mut counters = self.bank_counters.lock().unwrap();
        if let Some(bank) = map.get(&map_key) {
            counters.hits += 1;
            return bank.clone();
        }
        counters.misses += 1;
        let bank = Arc::new(transform_weight_bank(&wf, weights));
        map.insert(map_key, bank.clone());
        bank
    }

    /// The **i16 transformed-weight code bank** for one quantized layer,
    /// quantizing the (already-fetched) float bank on first use — how the
    /// registry serves quantized models without dequantizing: every
    /// lowered layer's [`IntWinoEngine`](crate::engine::int::IntWinoEngine)
    /// reads codes straight from this shared bank. `float_bank` must be
    /// the [`weight_bank`](Self::weight_bank) entry for the same
    /// `(layer_id, key)` (the registry always holds it already — passing
    /// it in keeps the float-bank telemetry an honest count of transform
    /// lookups). Returns `None` when `weight_bits` exceeds the i16 code
    /// range (such layers fall back to the float engine).
    pub fn int_weight_bank(
        &self,
        layer_id: &str,
        key: PlanKey,
        weight_bits: u32,
        float_bank: &WeightBank,
    ) -> Option<Arc<IntWeightBank>> {
        if weight_bits > MAX_CODE_BITS {
            return None;
        }
        let map_key = (layer_id.to_string(), key, weight_bits);
        let mut map = self.int_banks.lock().unwrap();
        let mut counters = self.int_counters.lock().unwrap();
        if let Some(bank) = map.get(&map_key) {
            counters.hits += 1;
            return Some(bank.clone());
        }
        counters.misses += 1;
        let bank = Arc::new(
            IntWeightBank::from_float_bank(float_bank, weight_bits)
                .expect("weight_bits validated above"),
        );
        map.insert(map_key, bank.clone());
        Some(bank)
    }

    /// The **register-tile-packed** float weight bank for one layer
    /// (`engine::gemm` `[N²][⌈K/MR⌉][C][MR]` layout), packing the
    /// (already-fetched) float bank on first use. Serving lowers
    /// unquantized layers through
    /// [`from_transformed_packed`](crate::nn::winolayer::WinoConv2d::from_transformed_packed)
    /// with this shared bank, so registering several variants of one
    /// checkpoint packs each layer **once** — the `packed_banks`
    /// hit/miss telemetry in the stats JSON counts exactly those packs.
    /// `float_bank` must be the [`weight_bank`](Self::weight_bank) entry
    /// for the same `(layer_id, key)`. (Quantized layers bake fake-quant
    /// into their float panels per config and repack privately; their
    /// *integer* engines share packings through the
    /// [`int_weight_bank`](Self::int_weight_bank) cache instead, which
    /// stores codes pre-packed.)
    pub fn packed_bank(
        &self,
        layer_id: &str,
        key: PlanKey,
        float_bank: &WeightBank,
    ) -> Arc<PackedF64> {
        let map_key = (layer_id.to_string(), key);
        let mut map = self.packed_banks.lock().unwrap();
        let mut counters = self.packed_counters.lock().unwrap();
        if let Some(packed) = map.get(&map_key) {
            counters.hits += 1;
            return packed.clone();
        }
        counters.misses += 1;
        let k = float_bank.len();
        let c = float_bank[0].len();
        let nn = float_bank[0][0].rows() * float_bank[0][0].cols();
        let packed = Arc::new(PackedF64::pack(nn, k, c, 0.0, |f, ki, ci| {
            float_bank[ki][ci].data()[f]
        }));
        map.insert(map_key, packed.clone());
        packed
    }

    /// The Winograd tile count of `model` at input shape `(h, w)`,
    /// computing it via `compute` on first use. Keys are namespaced by
    /// model, so distinct shards can never collide even at identical
    /// shapes.
    pub fn tiles_for_shape(
        &self,
        model: &str,
        h: usize,
        w: usize,
        compute: impl FnOnce() -> u64,
    ) -> u64 {
        let key = (model.to_string(), h, w);
        let mut map = self.shape_tiles.lock().unwrap();
        let mut counters = self.shape_counters.lock().unwrap();
        if let Some(&tiles) = map.get(&key) {
            counters.hits += 1;
            return tiles;
        }
        counters.misses += 1;
        let tiles = compute();
        map.insert(key, tiles);
        tiles
    }

    /// Whether `(model, h, w)` geometry is already cached — a
    /// side-effect-free probe (no counter movement, no insertion) the
    /// tracing layer uses to stamp each request's `plan_cache` event
    /// before [`tiles_for_shape`](Self::tiles_for_shape) resolves it.
    pub fn has_shape(&self, model: &str, h: usize, w: usize) -> bool {
        self.shape_tiles
            .lock()
            .unwrap()
            .contains_key(&(model.to_string(), h, w))
    }

    /// Number of distinct `(model, h, w)` geometry entries cached.
    pub fn shape_count(&self) -> usize {
        self.shape_tiles.lock().unwrap().len()
    }

    /// The cached `(model, h, w)` geometry keys, sorted — lets tests
    /// assert shards never collide in the cache.
    pub fn shape_keys(&self) -> Vec<(String, usize, usize)> {
        let mut keys: Vec<_> = self.shape_tiles.lock().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Shape-geometry hit/miss counters.
    pub fn shape_counters(&self) -> CacheCounters {
        *self.shape_counters.lock().unwrap()
    }

    /// Number of distinct plans currently cached.
    pub fn plan_count(&self) -> usize {
        self.wfs.lock().unwrap().len()
    }

    /// Number of distinct weight banks currently cached.
    pub fn bank_count(&self) -> usize {
        self.banks.lock().unwrap().len()
    }

    /// Number of distinct integer code banks currently cached.
    pub fn int_bank_count(&self) -> usize {
        self.int_banks.lock().unwrap().len()
    }

    /// Number of distinct packed float banks currently cached.
    pub fn packed_bank_count(&self) -> usize {
        self.packed_banks.lock().unwrap().len()
    }

    /// `(plan, bank)` hit/miss counters.
    pub fn counters(&self) -> (CacheCounters, CacheCounters) {
        (
            *self.wf_counters.lock().unwrap(),
            *self.bank_counters.lock().unwrap(),
        )
    }

    /// Integer code-bank hit/miss counters.
    pub fn int_counters(&self) -> CacheCounters {
        *self.int_counters.lock().unwrap()
    }

    /// Packed-float-bank hit/miss counters (misses = packs performed).
    pub fn packed_counters(&self) -> CacheCounters {
        *self.packed_counters.lock().unwrap()
    }

    /// Publish every cache's hit/miss counters and entry counts into a
    /// [`MetricsRegistry`](crate::obs::MetricsRegistry) under the
    /// `plan_cache.*` names — the registry-snapshot view of the same
    /// telemetry `to_json_with_plan_cache` embeds in the stats JSON.
    pub fn export_metrics(&self, reg: &crate::obs::MetricsRegistry) {
        let (plans, banks) = self.counters();
        for (name, c) in [
            ("plans", plans),
            ("banks", banks),
            ("int_banks", self.int_counters()),
            ("packed_banks", self.packed_counters()),
            ("shape_keys", self.shape_counters()),
        ] {
            reg.inc(&format!("plan_cache.{name}.hits"), c.hits);
            reg.inc(&format!("plan_cache.{name}.misses"), c.misses);
        }
        reg.set_gauge("plan_cache.plans.entries", self.plan_count() as f64);
        reg.set_gauge("plan_cache.banks.entries", self.bank_count() as f64);
        reg.set_gauge("plan_cache.int_banks.entries", self.int_bank_count() as f64);
        reg.set_gauge(
            "plan_cache.packed_banks.entries",
            self.packed_bank_count() as f64,
        );
        reg.set_gauge("plan_cache.shape_keys.entries", self.shape_count() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::Conv2dCfg;
    use crate::nn::winolayer::WinoConv2d;
    use crate::testkit::prng_tensor;

    #[test]
    fn plans_are_shared_and_counted() {
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        let a = cache.wf(key);
        let b = cache.wf(key);
        assert!(Arc::ptr_eq(&a, &b), "same key must return the same plan");
        assert_eq!(cache.plan_count(), 1);
        let (wf_c, _) = cache.counters();
        assert_eq!((wf_c.hits, wf_c.misses), (1, 1));
        cache.wf(PlanKey::f(2, 3, Base::Canonical));
        assert_eq!(cache.plan_count(), 2);
    }

    #[test]
    fn shape_geometry_is_cached_per_model_and_shape() {
        let cache = PlanCache::new();
        let mut computes = 0;
        let mut tiles = |t| {
            computes += 1;
            t
        };
        assert_eq!(cache.tiles_for_shape("a", 32, 32, || tiles(383)), 383);
        assert_eq!(cache.tiles_for_shape("a", 32, 32, || tiles(999)), 383, "hit, not recompute");
        assert_eq!(computes, 1);
        // Different shape and different model namespace are distinct keys
        // — identical geometry across shards can never collide.
        assert_eq!(cache.tiles_for_shape("a", 24, 48, || 250), 250);
        assert_eq!(cache.tiles_for_shape("b", 32, 32, || 383), 383);
        assert_eq!(cache.shape_count(), 3);
        assert_eq!(
            cache.shape_keys(),
            vec![
                ("a".to_string(), 24, 48),
                ("a".to_string(), 32, 32),
                ("b".to_string(), 32, 32),
            ]
        );
        let c = cache.shape_counters();
        assert_eq!((c.hits, c.misses), (1, 3));
    }

    #[test]
    fn has_shape_probe_moves_no_counters() {
        let cache = PlanCache::new();
        assert!(!cache.has_shape("a", 32, 32));
        assert_eq!(cache.shape_counters(), CacheCounters::default());
        cache.tiles_for_shape("a", 32, 32, || 383);
        assert!(cache.has_shape("a", 32, 32));
        assert!(!cache.has_shape("b", 32, 32));
        let c = cache.shape_counters();
        assert_eq!((c.hits, c.misses), (0, 1), "probes must not count");
    }

    #[test]
    fn export_metrics_publishes_counters_and_entry_counts() {
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        cache.wf(key);
        cache.wf(key);
        cache.tiles_for_shape("a", 32, 32, || 383);
        let reg = crate::obs::MetricsRegistry::new();
        cache.export_metrics(&reg);
        assert_eq!(reg.counter("plan_cache.plans.hits"), 1);
        assert_eq!(reg.counter("plan_cache.plans.misses"), 1);
        assert_eq!(reg.gauge("plan_cache.plans.entries"), Some(1.0));
        assert_eq!(reg.counter("plan_cache.shape_keys.misses"), 1);
        assert_eq!(reg.gauge("plan_cache.shape_keys.entries"), Some(1.0));
        assert_eq!(reg.gauge("plan_cache.banks.entries"), Some(0.0));
    }

    #[test]
    fn banks_are_reused_per_layer_id() {
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        let w = prng_tensor(5, &[2, 3, 3, 3], 0.5);
        let a = cache.weight_bank("m/conv1", key, &w);
        let b = cache.weight_bank("m/conv1", key, &w);
        assert!(Arc::ptr_eq(&a, &b));
        let c = cache.weight_bank("m/conv2", key, &w);
        assert!(!Arc::ptr_eq(&a, &c), "different layer ids are distinct banks");
        assert_eq!(cache.bank_count(), 2);
    }

    #[test]
    fn int_banks_shared_across_hadamard_variants() {
        // w8 and w8_h9 differ only in hadamard bits: one 8-bit code bank
        // serves both. A different weight width is a distinct bank; a
        // too-wide width yields None.
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        let w = prng_tensor(9, &[2, 3, 3, 3], 0.5);
        let float_bank = cache.weight_bank("m/conv1", key, &w);
        let fb = float_bank.as_ref();
        let a = cache.int_weight_bank("m/conv1", key, 8, fb).unwrap();
        let b = cache.int_weight_bank("m/conv1", key, 8, fb).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same (layer, key, bits) must share the bank");
        let c = cache.int_weight_bank("m/conv1", key, 16, fb).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert!(cache.int_weight_bank("m/conv1", key, 17, fb).is_none());
        assert_eq!(cache.int_bank_count(), 2);
        let counters = cache.int_counters();
        assert_eq!((counters.hits, counters.misses), (1, 2));
        // And int-bank traffic never touches the float-bank telemetry.
        let (_, bank_counters) = cache.counters();
        assert_eq!((bank_counters.hits, bank_counters.misses), (0, 1));
        // Codes agree with quantizing the cached float bank directly.
        let fresh = crate::engine::int::IntWeightBank::from_float_bank(fb, 8).unwrap();
        assert_eq!(a.weights_t, fresh.weights_t);
        assert_eq!(a.codes(), fresh.codes());
    }

    #[test]
    fn packed_banks_shared_and_match_fresh_packing() {
        // Two fetches share one packing (telemetry counts the single
        // pack); lowering through the cached packed bank is bit-identical
        // to a fresh layer.
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        let w = prng_tensor(12, &[3, 2, 3, 3], 0.5);
        let x = prng_tensor(13, &[1, 2, 9, 9], 1.0);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };
        let bank = cache.weight_bank("m/l0", key, &w);
        let a = cache.packed_bank("m/l0", key, bank.as_ref());
        let b = cache.packed_bank("m/l0", key, bank.as_ref());
        assert!(Arc::ptr_eq(&a, &b), "same (layer, key) must share the packing");
        assert_eq!(cache.packed_bank_count(), 1);
        let pc = cache.packed_counters();
        assert_eq!((pc.hits, pc.misses), (1, 1));
        let wf = cache.wf(key);
        let cached = crate::nn::winolayer::WinoConv2d::from_transformed_packed(
            wf.as_ref().clone(),
            bank.as_ref().clone(),
            a.clone(),
        );
        assert!(
            Arc::ptr_eq(cached.engine().packed_weights(), &a),
            "the lowered engine must execute from the cached packing"
        );
        let fresh = WinoConv2d::new(4, &w, Base::Legendre);
        assert_eq!(cached.forward(&x, cfg).data, fresh.forward(&x, cfg).data);
    }

    #[test]
    fn cached_bank_lowering_matches_fresh_layer() {
        // The serving lowering (cached bank → WinoConv2d::from_transformed)
        // must be bit-identical to building the layer from scratch, in
        // float and after quantization.
        use crate::quant::scheme::QuantConfig;
        let cache = PlanCache::new();
        let key = PlanKey::f(4, 3, Base::Legendre);
        let w = prng_tensor(7, &[3, 4, 3, 3], 0.4);
        let x = prng_tensor(8, &[1, 4, 10, 10], 1.0);
        let cfg = Conv2dCfg { stride: 1, padding: 1 };

        let fresh = WinoConv2d::new(4, &w, Base::Legendre);
        let wf = cache.wf(key);
        let bank = cache.weight_bank("m/l0", key, &w);
        let cached = WinoConv2d::from_transformed(wf.as_ref().clone(), bank.as_ref().clone());
        assert_eq!(cached.forward(&x, cfg).data, fresh.forward(&x, cfg).data);

        // Quantizing the bank-lowered layer must match quantizing a fresh
        // one (the cache hands out pristine float banks).
        let mut qfresh = WinoConv2d::new(4, &w, Base::Legendre);
        qfresh.quantize(QuantConfig::w8(), &x, 1);
        let bank2 = cache.weight_bank("m/l0", key, &w);
        let mut qcached =
            WinoConv2d::from_transformed(wf.as_ref().clone(), bank2.as_ref().clone());
        qcached.quantize(QuantConfig::w8(), &x, 1);
        assert_eq!(qcached.forward(&x, cfg).data, qfresh.forward(&x, cfg).data);
    }
}
