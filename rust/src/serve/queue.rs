//! Bounded request queue with micro-batching and admission backpressure.
//!
//! Clients [`submit`](ServeQueue::submit) one item each and get a
//! per-request response channel back; admission fails immediately
//! (`Err(Rejected)`) when the queue is at capacity, so overload turns
//! into fast rejections instead of unbounded memory growth and latency
//! collapse. Workers call [`next_batch`](ServeQueue::next_batch) /
//! [`next_batch_sla`](ServeQueue::next_batch_sla), which block for the
//! first request and then drain according to the shared scheduling
//! policy in [`sched`](super::sched): earliest-deadline-first inside
//! priority lanes, shape-homogeneous batches, and — when a
//! [`TileCostModel`] is supplied — deadline-based batch closing plus
//! load-shedding of requests that can no longer meet their SLO.
//!
//! The queue itself holds only payloads (a `seq → Request` map); every
//! ordering/closing decision is delegated to the embedded pure
//! [`Scheduler`], the same code the deterministic soak harness drives.
//! That is deliberate: it is what lets `tests/serve_deadline.rs` pin the
//! production scheduling path on a virtual clock.
//!
//! Everything is `std::sync` (`Mutex` + `Condvar` + `mpsc`): no async
//! runtime exists in the vendored crate set, and none is needed — the
//! engine pass dwarfs wakeup latency at serving batch sizes.

use super::sched::{Poll, Priority, SchedItem, Scheduler, Shed, SubmitOpts};
use crate::nn::tensor::Tensor;
use crate::obs::{mint_span, TraceKind, Tracer};
use crate::tune::cost::TileCostModel;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Admission rejection. Only [`Full`](Rejected::Full) is transient —
/// closed-loop clients retry it; the other variants are terminal for the
/// request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// Queue at capacity (backpressure) — retry later or shed the load.
    Full,
    /// The server is shutting down.
    Closed,
    /// The input's dims don't match the served model's admission policy.
    /// Validated at admission so a malformed request cannot reach (and
    /// kill) a worker thread.
    Shape {
        /// The dims (exact, or `[c, min_h, min_w]` minimum for a
        /// channels-only policy) the model would accept.
        expected: Vec<usize>,
        /// The offending input dims.
        got: Vec<usize>,
    },
    /// No shard serves a model by the requested name (multi-model
    /// routing, see [`ShardRouter`](super::ShardRouter)).
    UnknownModel {
        /// The name no shard answered to.
        name: String,
    },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Full => write!(f, "request rejected: serve queue at capacity"),
            Rejected::Closed => write!(f, "request rejected: server is shutting down"),
            Rejected::Shape { expected, got } => write!(
                f,
                "request rejected: input dims {got:?} do not match the model's {expected:?}"
            ),
            Rejected::UnknownModel { name } => {
                write!(f, "request rejected: no shard serves model {name:?}")
            }
        }
    }
}

impl std::error::Error for Rejected {}

/// Terminal failure for an *admitted* request: either the scheduler's
/// justified decision to shed it (predicted cost could not meet its
/// deadline), or the supervisor failing it because the worker running
/// its micro-batch panicked (the batch is poisoned; the rest of the
/// queue keeps serving). `Failed` is what replaced the old
/// abort-the-world panic path — the blast radius of a worker panic is
/// exactly the batch it was executing.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Shed by the scheduler, with the predicted-cost justification.
    Shed(Shed),
    /// The micro-batch carrying this request was poisoned by a worker
    /// panic; `reason` is the panic payload (when it was a string).
    Failed {
        /// Human-readable panic payload, e.g. `"worker panic: chaos"`.
        reason: String,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed(shed) => write!(
                f,
                "request shed: predicted {} µs at {} µs misses deadline {} µs",
                shed.predicted_us, shed.decided_us, shed.deadline_us
            ),
            ServeError::Failed { reason } => {
                write!(f, "request failed: batch poisoned by worker panic ({reason})")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a response channel yields: the inference result, or a terminal
/// [`ServeError`] (shed by the scheduler, or failed by the supervisor).
pub type ServeResult = Result<Response, ServeError>;

/// Admission-time shape validation policy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShapePolicy {
    /// Input dims must match exactly (fixed-shape models).
    Exact(Vec<usize>),
    /// Arbitrary-H×W models: rank-3 `[c, h, w]` with the given channel
    /// count and both spatial dims at least `min_hw`.
    Channels {
        /// Required channel count (`dims[0]`).
        c: usize,
        /// Minimum spatial extent for each of `h`, `w`.
        min_hw: usize,
    },
}

impl ShapePolicy {
    /// Validate an input's dims against the policy.
    pub fn validate(&self, dims: &[usize]) -> Result<(), Rejected> {
        let ok = match self {
            ShapePolicy::Exact(expected) => dims == expected.as_slice(),
            ShapePolicy::Channels { c, min_hw } => {
                dims.len() == 3 && dims[0] == *c && dims[1] >= *min_hw && dims[2] >= *min_hw
            }
        };
        if ok {
            return Ok(());
        }
        let expected = match self {
            ShapePolicy::Exact(expected) => expected.clone(),
            ShapePolicy::Channels { c, min_hw } => vec![*c, *min_hw, *min_hw],
        };
        Err(Rejected::Shape { expected, got: dims.to_vec() })
    }
}

/// One queued inference request.
pub struct Request {
    /// Trace span minted at admission ([`crate::obs::mint_span`]) —
    /// stamps every event this request generates downstream (batch,
    /// stage, shed, complete) so `--trace-json` output groups by it.
    pub span: u64,
    /// Per-item input tensor (no batch axis; e.g. `[C, H, W]`).
    pub input: Tensor,
    /// Admission timestamp — latency is measured from here.
    pub enqueued: Instant,
    /// Absolute deadline on the queue clock (µs since queue creation),
    /// `None` for best-effort requests.
    pub deadline_us: Option<u64>,
    /// Priority lane the request was admitted into.
    pub priority: Priority,
    /// Where the worker sends the response (or shed notice).
    pub tx: Sender<ServeResult>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Per-item output tensor (no batch axis; e.g. logits `[num_classes]`).
    pub output: Tensor,
    /// End-to-end latency (admission → response), microseconds.
    pub latency_us: u64,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
}

/// One worker drain: the batch to run plus any requests the scheduler
/// shed on this poll (the worker delivers their shed notices).
pub struct DrainedBatch {
    /// Shape-homogeneous batch in service order. May be empty when the
    /// poll only shed.
    pub batch: Vec<Request>,
    /// Requests shed this poll, each with its predicted-cost
    /// justification.
    pub shed: Vec<(Request, Shed)>,
}

struct QueueState {
    /// Payloads keyed by the scheduler's admission ticket.
    reqs: HashMap<u64, Request>,
    /// The pure scheduling policy (ordering, closing, shedding).
    sched: Scheduler,
    closed: bool,
}

/// The bounded micro-batching queue shared by clients and workers.
pub struct ServeQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    /// Origin of the queue's µs clock (deadlines are absolute µs since
    /// this instant).
    epoch: Instant,
    /// When set, `submit` rejects inputs the policy refuses.
    policy: Option<ShapePolicy>,
    /// Tile weight assigned to plain [`submit`](ServeQueue::submit)
    /// requests (cost-aware callers use
    /// [`submit_with_tiles`](ServeQueue::submit_with_tiles)).
    default_tiles: u64,
    /// Model name stamped on this queue's submit trace events (each
    /// queue serves exactly one model; the router labels its queues).
    model_label: String,
    /// When set, admission records submit/reject trace events here and
    /// workers record the rest of each span's lifecycle.
    tracer: Option<Arc<Tracer>>,
}

/// The `priority` label trace events carry.
pub(crate) fn lane(p: Priority) -> &'static str {
    match p {
        Priority::High => "high",
        Priority::Normal => "normal",
        Priority::Low => "low",
    }
}

/// Pop the payload a dispatched [`SchedItem`] refers to.
fn take_payload(reqs: &mut HashMap<u64, Request>, it: &SchedItem) -> Request {
    reqs.remove(&it.seq).expect("payload exists for every scheduled seq")
}

/// Spatial `(h, w)` of a per-item tensor: its two trailing dims.
fn spatial(dims: &[usize]) -> (usize, usize) {
    match dims {
        [.., h, w] => (*h, *w),
        [n] => (*n, 1),
        [] => (1, 1),
    }
}

impl ServeQueue {
    /// A queue admitting at most `cap` in-flight (queued) requests, with
    /// no input-shape validation (the embedder's responsibility).
    pub fn new(cap: usize) -> ServeQueue {
        Self::build(cap, None)
    }

    /// A queue that additionally validates every submission against the
    /// served model's exact per-item dims.
    pub fn with_dims(cap: usize, expected_dims: Vec<usize>) -> ServeQueue {
        Self::build(cap, Some(ShapePolicy::Exact(expected_dims)))
    }

    /// A queue validating submissions against an arbitrary
    /// [`ShapePolicy`] — what [`with_server`](super::with_server)
    /// constructs from the model's own policy, so a malformed request is
    /// rejected at admission instead of panicking a worker.
    pub fn with_policy(cap: usize, policy: ShapePolicy) -> ServeQueue {
        Self::build(cap, Some(policy))
    }

    fn build(cap: usize, policy: Option<ShapePolicy>) -> ServeQueue {
        assert!(cap > 0, "queue capacity must be positive");
        ServeQueue {
            state: Mutex::new(QueueState {
                reqs: HashMap::new(),
                sched: Scheduler::new(cap),
                closed: false,
            }),
            cv: Condvar::new(),
            epoch: Instant::now(),
            policy,
            default_tiles: 1,
            model_label: "default".to_string(),
            tracer: None,
        }
    }

    /// Set the tile weight plain [`submit`](ServeQueue::submit) requests
    /// carry into the cost model (typically the served model's
    /// nominal-shape tile count).
    pub fn with_default_tiles(mut self, tiles: u64) -> ServeQueue {
        self.default_tiles = tiles.max(1);
        self
    }

    /// Set the model name this queue's trace events carry (the shard
    /// router labels each per-model queue it builds).
    pub fn with_model_label(mut self, name: &str) -> ServeQueue {
        self.model_label = name.to_string();
        self
    }

    /// Attach a [`Tracer`]: admission starts recording submit/reject
    /// events, and workers (which read it back via
    /// [`tracer`](Self::tracer)) record shed/batch/stage/complete, so
    /// every span ends in exactly one terminal event.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> ServeQueue {
        self.tracer = Some(tracer);
        self
    }

    /// The attached tracer, if any — workers stamp batch-side events
    /// through this.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Microseconds elapsed on this queue's clock (the timeline request
    /// deadlines live on).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Submit one best-effort item; returns the response channel, or
    /// [`Rejected`] when the input shape is wrong, the queue is at
    /// capacity, or the server is shutting down.
    pub fn submit(&self, input: Tensor) -> Result<Receiver<ServeResult>, Rejected> {
        self.submit_with(input, SubmitOpts::default())
    }

    /// Submit with explicit priority and (relative) deadline, carrying
    /// the queue's default tile weight.
    pub fn submit_with(
        &self,
        input: Tensor,
        opts: SubmitOpts,
    ) -> Result<Receiver<ServeResult>, Rejected> {
        self.submit_with_tiles(input, opts, self.default_tiles)
    }

    /// Submit with explicit options **and** per-request tile weight (the
    /// model's predicted tile cost at this input's shape) — the routing
    /// layer's entry point.
    pub fn submit_with_tiles(
        &self,
        input: Tensor,
        opts: SubmitOpts,
        tiles: u64,
    ) -> Result<Receiver<ServeResult>, Rejected> {
        self.submit_span(input, opts, tiles, mint_span())
    }

    /// [`submit_with_tiles`](Self::submit_with_tiles) with a
    /// caller-minted span — the router mints early so it can attach
    /// routing-side events (plan-cache probes) to the same span.
    pub(crate) fn submit_span(
        &self,
        input: Tensor,
        opts: SubmitOpts,
        tiles: u64,
        span: u64,
    ) -> Result<Receiver<ServeResult>, Rejected> {
        let shape = spatial(&input.dims);
        if let Some(tr) = &self.tracer {
            // The submit event carries the request's *relative* SLO
            // (microseconds of budget); shed events carry the absolute
            // queue-clock numbers that justified the drop.
            tr.record(
                span,
                self.now_us(),
                TraceKind::Submit {
                    model: self.model_label.clone(),
                    priority: lane(opts.priority).to_string(),
                    deadline_us: opts.deadline_us.unwrap_or(0),
                    tiles: tiles.max(1),
                    h: shape.0 as u64,
                    w: shape.1 as u64,
                },
            );
        }
        let reject = |why: &str, err: Rejected| {
            if let Some(tr) = &self.tracer {
                tr.record(span, self.now_us(), TraceKind::Reject { why: why.to_string() });
            }
            Err(err)
        };
        if let Some(policy) = &self.policy {
            if let Err(e) = policy.validate(&input.dims) {
                return reject("bad_shape", e);
            }
        }
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return reject("closed", Rejected::Closed);
        }
        let now = self.now_us();
        let deadline = opts.deadline_us.map(|d| now.saturating_add(d));
        let Some(seq) = st.sched.submit(now, opts.priority, deadline, tiles.max(1), shape)
        else {
            return reject("queue_full", Rejected::Full);
        };
        let (tx, rx) = channel();
        st.reqs.insert(
            seq,
            Request {
                span,
                input,
                enqueued: Instant::now(),
                deadline_us: deadline,
                priority: opts.priority,
                tx,
            },
        );
        drop(st);
        self.cv.notify_one();
        Ok(rx)
    }

    /// Current queue depth (queued, not yet drained).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().sched.depth()
    }

    /// Close the queue: pending requests still drain, new submissions are
    /// rejected, and workers return `None` once the queue is empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Close the queue **and drop every pending request** — each waiting
    /// client's `recv` errors out immediately instead of blocking on a
    /// batch that will never run. Called when a worker dies so a broken
    /// session fails fast rather than hanging submitters.
    pub fn abort(&self) {
        let pending: Vec<Request> = {
            let mut st = self.state.lock().unwrap();
            st.closed = true;
            let items = st.sched.clear();
            items.iter().filter_map(|it| st.reqs.remove(&it.seq)).collect()
        };
        self.cv.notify_all();
        drop(pending);
    }

    /// Worker side, legacy window-only form: block until at least one
    /// request is queued, then drain per the scheduler's policy with the
    /// global `batch_window` and no cost model (so nothing is ever shed
    /// and deadline-free load is plain FIFO). Returns `None` when the
    /// queue is closed and drained. Never returns an empty batch.
    pub fn next_batch(&self, max_batch: usize, batch_window: Duration) -> Option<Vec<Request>> {
        loop {
            let drained = self.next_batch_sla(max_batch, batch_window, None)?;
            debug_assert!(drained.shed.is_empty(), "no cost model, nothing can shed");
            if !drained.batch.is_empty() {
                return Some(drained.batch);
            }
        }
    }

    /// Worker side, SLO-aware form: block until the scheduler dispatches,
    /// honouring per-request deadlines against `cost` (deadline-based
    /// batch closing, load shedding — see [`sched`](super::sched)).
    /// Returns `None` when the queue is closed and drained; otherwise the
    /// batch and/or the sheds of one scheduler dispatch.
    pub fn next_batch_sla(
        &self,
        max_batch: usize,
        batch_window: Duration,
        cost: Option<&TileCostModel>,
    ) -> Option<DrainedBatch> {
        let window_us = batch_window.as_micros().min(u64::MAX as u128) as u64;
        let mut st = self.state.lock().unwrap();
        loop {
            while st.sched.depth() == 0 {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
            }
            let now = self.now_us();
            let flush = st.closed;
            match st.sched.poll(now, max_batch, window_us, cost, flush) {
                // A racing worker drained everything between our wait and
                // poll; go back to waiting.
                Poll::Idle => continue,
                Poll::WaitUntil(t) => {
                    let wait = Duration::from_micros(t.saturating_sub(now).max(1));
                    let (guard, _timeout) = self.cv.wait_timeout(st, wait).unwrap();
                    st = guard;
                }
                Poll::Dispatch { batch, shed } => {
                    let out_batch: Vec<Request> = batch
                        .iter()
                        .map(|it| take_payload(&mut st.reqs, it))
                        .collect();
                    let out_shed: Vec<(Request, Shed)> = shed
                        .iter()
                        .map(|(it, why)| (take_payload(&mut st.reqs, it), *why))
                        .collect();
                    return Some(DrainedBatch { batch: out_batch, shed: out_shed });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(v: f32) -> Tensor {
        Tensor::from_vec(&[1, 2, 2], vec![v; 4])
    }

    #[test]
    fn admission_rejects_when_full() {
        let q = ServeQueue::new(2);
        let _a = q.submit(item(1.0)).unwrap();
        let _b = q.submit(item(2.0)).unwrap();
        assert_eq!(q.submit(item(3.0)).unwrap_err(), Rejected::Full);
        assert_eq!(q.depth(), 2);
        // Draining frees capacity again.
        let batch = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(q.submit(item(4.0)).is_ok());
    }

    #[test]
    fn admission_rejects_wrong_shape() {
        let q = ServeQueue::with_dims(4, vec![1, 2, 2]);
        assert!(q.submit(item(1.0)).is_ok());
        let bad = Tensor::from_vec(&[2, 2], vec![0.0; 4]);
        match q.submit(bad).unwrap_err() {
            Rejected::Shape { expected, got } => {
                assert_eq!(expected, vec![1, 2, 2]);
                assert_eq!(got, vec![2, 2]);
            }
            other => panic!("expected Shape rejection, got {other:?}"),
        }
        // The well-formed request is still queued and served.
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn channels_policy_admits_any_large_enough_hw() {
        let q = ServeQueue::with_policy(4, ShapePolicy::Channels { c: 3, min_hw: 8 });
        assert!(q.submit(Tensor::from_vec(&[3, 9, 13], vec![0.0; 3 * 9 * 13])).is_ok());
        assert!(q.submit(Tensor::from_vec(&[3, 32, 32], vec![0.0; 3 * 32 * 32])).is_ok());
        // Wrong channel count and too-small spatial extents both bounce.
        assert!(matches!(
            q.submit(Tensor::from_vec(&[2, 9, 9], vec![0.0; 2 * 81])).unwrap_err(),
            Rejected::Shape { .. }
        ));
        assert!(matches!(
            q.submit(Tensor::from_vec(&[3, 4, 9], vec![0.0; 3 * 36])).unwrap_err(),
            Rejected::Shape { .. }
        ));
    }

    #[test]
    fn batch_respects_max_batch_and_fifo() {
        let q = ServeQueue::new(16);
        for i in 0..5 {
            q.submit(item(i as f32)).unwrap();
        }
        let batch = q.next_batch(3, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].input.data[0], 0.0);
        assert_eq!(batch[2].input.data[0], 2.0);
        let rest = q.next_batch(3, Duration::ZERO).unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].input.data[0], 3.0);
    }

    #[test]
    fn drains_earliest_deadline_first_even_when_submitted_later() {
        // The satellite bugfix this PR pins: before the scheduler-backed
        // queue, workers drained strictly in submit order, so a tight
        // deadline submitted behind a lax one was starved. Deadline order
        // (within a lane) must win over submit order.
        let q = ServeQueue::new(16);
        let _lax = q
            .submit_with(item(1.0), SubmitOpts { deadline_us: Some(500_000), ..Default::default() })
            .unwrap();
        let _tight = q
            .submit_with(item(2.0), SubmitOpts { deadline_us: Some(1_000), ..Default::default() })
            .unwrap();
        let _fifo = q.submit(item(3.0)).unwrap();
        let first = q.next_batch(1, Duration::ZERO).unwrap();
        assert_eq!(first[0].input.data[0], 2.0, "earliest deadline must drain first");
        let second = q.next_batch(1, Duration::ZERO).unwrap();
        assert_eq!(second[0].input.data[0], 1.0);
        // Deadline-free requests rank after deadlined ones in the lane.
        let third = q.next_batch(1, Duration::ZERO).unwrap();
        assert_eq!(third[0].input.data[0], 3.0);
    }

    #[test]
    fn high_priority_lane_preempts_normal() {
        let q = ServeQueue::new(16);
        q.submit(item(1.0)).unwrap();
        q.submit_with(
            item(2.0),
            SubmitOpts { priority: Priority::High, ..Default::default() },
        )
        .unwrap();
        let first = q.next_batch(1, Duration::ZERO).unwrap();
        assert_eq!(first[0].input.data[0], 2.0, "High lane drains before Normal");
    }

    #[test]
    fn hopeless_deadline_is_shed_with_justification() {
        let cost = TileCostModel::new(10_000.0, 0.0); // every batch "costs" 10ms
        let q = ServeQueue::new(16);
        let rx = q
            .submit_with(item(1.0), SubmitOpts { deadline_us: Some(10), ..Default::default() })
            .unwrap();
        let drained = q.next_batch_sla(4, Duration::ZERO, Some(&cost)).unwrap();
        assert!(drained.batch.is_empty());
        assert_eq!(drained.shed.len(), 1);
        let (req, why) = &drained.shed[0];
        assert!(why.decided_us + why.predicted_us > why.deadline_us);
        // The worker (here: us) delivers the shed notice to the client.
        req.tx.send(Err(ServeError::Shed(*why))).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::Shed(*why));
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        q.close();
        assert_eq!(q.submit(item(2.0)).unwrap_err(), Rejected::Closed);
        // The already-admitted request still comes out...
        assert_eq!(q.next_batch(8, Duration::from_millis(50)).unwrap().len(), 1);
        // ...and then workers are told to stop.
        assert!(q.next_batch(8, Duration::from_millis(50)).is_none());
    }

    #[test]
    fn window_expires_with_partial_batch() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        let t = Instant::now();
        let batch = q.next_batch(8, Duration::from_millis(20)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15), "window must be honoured");
    }

    #[test]
    fn max_batch_one_skips_the_window() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        let t = Instant::now();
        let batch = q.next_batch(1, Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() < Duration::from_secs(1), "no window wait at max_batch 1");
    }

    #[test]
    fn admission_records_submit_and_reject_spans() {
        use crate::obs::TraceSink;
        let tracer = Arc::new(Tracer::new(1 << 10));
        let q = ServeQueue::with_dims(1, vec![1, 2, 2])
            .with_model_label("resnet")
            .with_tracer(tracer.clone());
        let _ok = q.submit(item(1.0)).unwrap();
        assert_eq!(q.submit(item(2.0)).unwrap_err(), Rejected::Full);
        let bad = Tensor::from_vec(&[2, 2], vec![0.0; 4]);
        assert!(matches!(q.submit(bad).unwrap_err(), Rejected::Shape { .. }));
        let events = tracer.events();
        assert_eq!(events.len(), 5, "3 submits + 2 rejects");
        let submits: Vec<_> = events
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::Submit { .. }))
            .collect();
        assert_eq!(submits.len(), 3);
        for ev in &submits {
            match &ev.kind {
                TraceKind::Submit { model, priority, h, w, .. } => {
                    assert_eq!((model.as_str(), priority.as_str()), ("resnet", "normal"));
                    assert_eq!((*h, *w), (2, 2));
                }
                _ => unreachable!(),
            }
        }
        // Each reject stamps the span its own submit minted.
        let whys: Vec<(u64, String)> = events
            .iter()
            .filter_map(|e| match &e.kind {
                TraceKind::Reject { why } => Some((e.span, why.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(whys.len(), 2);
        assert_eq!(whys[0], (submits[1].span, "queue_full".to_string()));
        assert_eq!(whys[1], (submits[2].span, "bad_shape".to_string()));
        // The admitted request carries its span into the batch.
        let batch = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(batch[0].span, submits[0].span);
    }

    #[test]
    fn cross_thread_batching_assembles() {
        let q = ServeQueue::new(64);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..6 {
                    q.submit(item(i as f32)).unwrap();
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
            let mut total = 0;
            while total < 6 {
                let batch = q.next_batch(8, Duration::from_millis(100)).unwrap();
                assert!(!batch.is_empty());
                total += batch.len();
            }
            assert_eq!(total, 6);
        });
    }
}
