//! Bounded request queue with micro-batching and admission backpressure.
//!
//! Clients [`submit`](ServeQueue::submit) one item each and get a
//! per-request response channel back; admission fails immediately
//! (`Err(Rejected)`) when the queue is at capacity, so overload turns
//! into fast rejections instead of unbounded memory growth and latency
//! collapse. Workers call [`next_batch`](ServeQueue::next_batch), which
//! blocks for the first request and then keeps draining until either
//! `max_batch` requests are assembled or the `batch_window` deadline
//! expires — the standard micro-batching trade: a bounded wait buys a
//! wider `T` panel for the engine pass.
//!
//! Everything is `std::sync` (`Mutex` + `Condvar` + `mpsc`): no async
//! runtime exists in the vendored crate set, and none is needed — the
//! engine pass dwarfs wakeup latency at serving batch sizes.

use crate::nn::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Admission rejection. Only [`Full`](Rejected::Full) is transient —
/// closed-loop clients retry it; the other variants are terminal for the
/// request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// Queue at capacity (backpressure) — retry later or shed the load.
    Full,
    /// The server is shutting down.
    Closed,
    /// The input's dims don't match the served model's per-item dims.
    /// Validated at admission so a malformed request cannot reach (and
    /// kill) a worker thread.
    Shape { expected: Vec<usize>, got: Vec<usize> },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::Full => write!(f, "request rejected: serve queue at capacity"),
            Rejected::Closed => write!(f, "request rejected: server is shutting down"),
            Rejected::Shape { expected, got } => write!(
                f,
                "request rejected: input dims {got:?} do not match the model's {expected:?}"
            ),
        }
    }
}

impl std::error::Error for Rejected {}

/// One queued inference request.
pub struct Request {
    /// Per-item input tensor (no batch axis; e.g. `[C, H, W]`).
    pub input: Tensor,
    /// Admission timestamp — latency is measured from here.
    pub enqueued: Instant,
    /// Where the worker sends the response.
    pub tx: Sender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Per-item output tensor (no batch axis; e.g. logits `[num_classes]`).
    pub output: Tensor,
    /// End-to-end latency (admission → response), microseconds.
    pub latency_us: u64,
    /// Size of the micro-batch this request rode in.
    pub batch_size: usize,
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// The bounded micro-batching queue shared by clients and workers.
pub struct ServeQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    cap: usize,
    /// When set, `submit` rejects inputs whose dims differ.
    expected_dims: Option<Vec<usize>>,
}

impl ServeQueue {
    /// A queue admitting at most `cap` in-flight (queued) requests, with
    /// no input-shape validation (the embedder's responsibility).
    pub fn new(cap: usize) -> ServeQueue {
        Self::build(cap, None)
    }

    /// A queue that additionally validates every submission against the
    /// served model's per-item dims — what [`with_server`](super::with_server)
    /// constructs, so a malformed request is rejected at admission
    /// instead of panicking a worker.
    pub fn with_dims(cap: usize, expected_dims: Vec<usize>) -> ServeQueue {
        Self::build(cap, Some(expected_dims))
    }

    fn build(cap: usize, expected_dims: Option<Vec<usize>>) -> ServeQueue {
        assert!(cap > 0, "queue capacity must be positive");
        ServeQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap,
            expected_dims,
        }
    }

    /// Submit one item; returns the response channel, or [`Rejected`]
    /// when the input shape is wrong, the queue is at capacity, or the
    /// server is shutting down.
    pub fn submit(&self, input: Tensor) -> Result<Receiver<Response>, Rejected> {
        if let Some(expected) = &self.expected_dims {
            if &input.dims != expected {
                return Err(Rejected::Shape {
                    expected: expected.clone(),
                    got: input.dims.clone(),
                });
            }
        }
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(Rejected::Closed);
        }
        if st.items.len() >= self.cap {
            return Err(Rejected::Full);
        }
        let (tx, rx) = channel();
        st.items.push_back(Request { input, enqueued: Instant::now(), tx });
        drop(st);
        self.cv.notify_one();
        Ok(rx)
    }

    /// Current queue depth (queued, not yet drained).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// Close the queue: pending requests still drain, new submissions are
    /// rejected, and workers return `None` once the queue is empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Close the queue **and drop every pending request** — each waiting
    /// client's `recv` errors out immediately instead of blocking on a
    /// batch that will never run. Called when a worker dies so a broken
    /// session fails fast rather than hanging submitters.
    pub fn abort(&self) {
        let pending: Vec<Request> = {
            let mut st = self.state.lock().unwrap();
            st.closed = true;
            st.items.drain(..).collect()
        };
        self.cv.notify_all();
        drop(pending);
    }

    /// Worker side: block until at least one request is queued, then keep
    /// waiting up to `batch_window` (from the moment the first request is
    /// seen) for more, returning as soon as `max_batch` are available.
    /// Returns `None` when the queue is closed and drained. Never returns
    /// an empty batch: if a racing worker drains the queue during this
    /// worker's batch window, it goes back to waiting.
    pub fn next_batch(&self, max_batch: usize, batch_window: Duration) -> Option<Vec<Request>> {
        let max_batch = max_batch.max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            while st.items.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
            }
            let deadline = Instant::now() + batch_window;
            while st.items.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = st.items.len().min(max_batch);
            if take > 0 {
                return Some(st.items.drain(..take).collect());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(v: f32) -> Tensor {
        Tensor::from_vec(&[1, 2, 2], vec![v; 4])
    }

    #[test]
    fn admission_rejects_when_full() {
        let q = ServeQueue::new(2);
        let _a = q.submit(item(1.0)).unwrap();
        let _b = q.submit(item(2.0)).unwrap();
        assert_eq!(q.submit(item(3.0)).unwrap_err(), Rejected::Full);
        assert_eq!(q.depth(), 2);
        // Draining frees capacity again.
        let batch = q.next_batch(8, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(q.submit(item(4.0)).is_ok());
    }

    #[test]
    fn admission_rejects_wrong_shape() {
        let q = ServeQueue::with_dims(4, vec![1, 2, 2]);
        assert!(q.submit(item(1.0)).is_ok());
        let bad = Tensor::from_vec(&[2, 2], vec![0.0; 4]);
        match q.submit(bad).unwrap_err() {
            Rejected::Shape { expected, got } => {
                assert_eq!(expected, vec![1, 2, 2]);
                assert_eq!(got, vec![2, 2]);
            }
            other => panic!("expected Shape rejection, got {other:?}"),
        }
        // The well-formed request is still queued and served.
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn batch_respects_max_batch_and_fifo() {
        let q = ServeQueue::new(16);
        for i in 0..5 {
            q.submit(item(i as f32)).unwrap();
        }
        let batch = q.next_batch(3, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].input.data[0], 0.0);
        assert_eq!(batch[2].input.data[0], 2.0);
        let rest = q.next_batch(3, Duration::ZERO).unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].input.data[0], 3.0);
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        q.close();
        assert_eq!(q.submit(item(2.0)).unwrap_err(), Rejected::Closed);
        // The already-admitted request still comes out...
        assert_eq!(q.next_batch(8, Duration::from_millis(50)).unwrap().len(), 1);
        // ...and then workers are told to stop.
        assert!(q.next_batch(8, Duration::from_millis(50)).is_none());
    }

    #[test]
    fn window_expires_with_partial_batch() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        let t = Instant::now();
        let batch = q.next_batch(8, Duration::from_millis(20)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15), "window must be honoured");
    }

    #[test]
    fn max_batch_one_skips_the_window() {
        let q = ServeQueue::new(4);
        q.submit(item(1.0)).unwrap();
        let t = Instant::now();
        let batch = q.next_batch(1, Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() < Duration::from_secs(1), "no window wait at max_batch 1");
    }

    #[test]
    fn cross_thread_batching_assembles() {
        let q = ServeQueue::new(64);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..6 {
                    q.submit(item(i as f32)).unwrap();
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
            let mut total = 0;
            while total < 6 {
                let batch = q.next_batch(8, Duration::from_millis(100)).unwrap();
                assert!(!batch.is_empty());
                total += batch.len();
            }
            assert_eq!(total, 6);
        });
    }
}
