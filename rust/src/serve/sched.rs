//! The pure, clock-free batch scheduler behind both serving front-ends.
//!
//! [`Scheduler`] owns the admission/ordering/closing *policy* and nothing
//! else: no threads, no channels, no `Instant` — every decision is a
//! function of an explicit `now_us` timestamp. Two drivers share it:
//!
//! * the threaded [`ServeQueue`](super::ServeQueue) feeds it real wall
//!   time and real requests, and
//! * the deterministic soak harness
//!   ([`testkit::soak`](crate::testkit::soak)) feeds it a virtual clock,
//!   so the property suites in `tests/serve_deadline.rs` pin the *same*
//!   scheduling code production workers run.
//!
//! Policy, in order of application on each [`Scheduler::poll`]:
//!
//! 1. **Shed** — with a [`TileCostModel`], any pending request whose
//!    *solo* predicted cost already overruns its deadline is removed and
//!    reported with its justification ([`Shed`]): it cannot be served in
//!    time, so burning engine cycles on it would only hurt its neighbors.
//! 2. **Order** — earliest-deadline-first inside priority lanes
//!    ([`Priority`]); deadline-free requests rank after deadlined ones in
//!    their lane and FIFO among themselves (submit `seq` breaks ties), so
//!    a deadline-free workload degrades to plain FIFO micro-batching.
//! 3. **Close** — the candidate batch is the most urgent run of
//!    shape-identical requests (mixed shapes never share a batch). It
//!    closes when full, on `flush`, or at
//!    `min(oldest_submit + window_us, earliest_deadline − predicted_cost)`
//!    — the deadline term is what turns the global batching window into a
//!    per-request SLO. A closing batch additionally *shrinks* from the
//!    least-urgent tail until its predicted cost fits the earliest member
//!    deadline, which is the invariant the property suite pins: **no
//!    batch ever closes later than `earliest deadline − predicted cost`**.

use crate::tune::cost::TileCostModel;

/// Request priority lane. Lanes are strict: any `High` request batches
/// before any `Normal` one regardless of deadlines (derived `Ord` is the
/// declaration order).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-critical lane, always drained first.
    High,
    /// The default lane.
    #[default]
    Normal,
    /// Background lane, only drained when nothing above is pending.
    Low,
}

/// Per-submit scheduling options, shared by every front-end
/// (`ServeQueue::submit_with`, the shard router, the soak harness).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SubmitOpts {
    /// Deadline **relative to submission**, in µs. `None` = best-effort:
    /// never shed, ranked after deadlined work in its lane.
    pub deadline_us: Option<u64>,
    /// Priority lane.
    pub priority: Priority,
}

/// One scheduled request as the scheduler sees it: pure metadata, no
/// payload (drivers key their payloads by `seq`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedItem {
    /// Admission ticket, unique per scheduler, monotonically increasing
    /// in submit order (the FIFO tie-break).
    pub seq: u64,
    /// Absolute submission time, µs on the driver's clock.
    pub submitted_us: u64,
    /// Absolute deadline, µs on the driver's clock (`None` = best-effort).
    pub deadline_us: Option<u64>,
    /// Priority lane.
    pub priority: Priority,
    /// Predicted-cost weight: Winograd tiles one forward of this request
    /// costs (per-shape, via `BatchModel::tiles_for`).
    pub tiles: u64,
    /// Spatial shape `(h, w)` — batches are shape-homogeneous.
    pub shape: (usize, usize),
}

/// Why a request was shed: the predicted-cost justification the
/// accounting invariants require (`decided_us + predicted_us >
/// deadline_us` always holds — a shed is never speculative).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shed {
    /// Predicted solo service cost at decision time, µs.
    pub predicted_us: u64,
    /// The deadline that could not be met (absolute µs).
    pub deadline_us: u64,
    /// When the scheduler decided (absolute µs).
    pub decided_us: u64,
}

impl Shed {
    /// How far past the deadline the request was predicted to land
    /// (`decided + predicted − deadline`, µs) — strictly positive by
    /// the shed invariant; the number a trace reader sorts sheds by.
    pub fn overshoot_us(&self) -> u64 {
        (self.decided_us + self.predicted_us).saturating_sub(self.deadline_us)
    }

    /// The trace event this justification renders as — stamped on the
    /// span by whichever layer delivers the shed notice.
    pub fn trace_event(&self) -> crate::obs::TraceKind {
        crate::obs::TraceKind::Shed {
            why: "deadline_unreachable".to_string(),
            predicted_us: self.predicted_us,
            deadline_us: self.deadline_us,
            decided_us: self.decided_us,
        }
    }
}

/// Outcome of one [`Scheduler::poll`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Poll {
    /// Nothing pending; wait for a submit.
    Idle,
    /// Work is pending but its batch should not close before the given
    /// absolute µs timestamp (always `> now`); poll again then or on the
    /// next submit.
    WaitUntil(u64),
    /// Work to do now.
    Dispatch {
        /// Shape-homogeneous batch in service order (possibly empty when
        /// the poll only shed).
        batch: Vec<SchedItem>,
        /// Requests shed this poll, each with its justification.
        shed: Vec<(SchedItem, Shed)>,
    },
}

/// Lane → deadline → FIFO ordering key (smaller = more urgent).
fn order_key(it: &SchedItem) -> (Priority, u64, u64) {
    (it.priority, it.deadline_us.unwrap_or(u64::MAX), it.seq)
}

/// Split a shared admission budget across shards proportionally to their
/// weights: shard `i` gets `max(1, ⌈budget · wᵢ / Σw⌉)` queue slots (so
/// every shard can always admit *something*, and rounding never starves
/// a low-weight tenant). Zero total weight degrades to one slot each.
pub fn admission_caps(budget: usize, weights: &[u64]) -> Vec<usize> {
    let total: u64 = weights.iter().sum();
    weights
        .iter()
        .map(|&w| {
            if total == 0 {
                return 1;
            }
            let cap = (budget as u64 * w).div_ceil(total) as usize;
            cap.clamp(1, budget.max(1))
        })
        .collect()
}

/// Deadline-aware admission + batching policy over pending requests.
/// See the [module docs](self) for the decision procedure.
#[derive(Clone, Debug)]
pub struct Scheduler {
    /// Admission cap: `submit` returns `None` at this depth.
    cap: usize,
    /// Next admission ticket.
    next_seq: u64,
    /// Admitted, not-yet-dispatched requests (unordered between polls).
    pending: Vec<SchedItem>,
}

impl Scheduler {
    /// New scheduler admitting at most `cap` pending requests.
    pub fn new(cap: usize) -> Scheduler {
        assert!(cap > 0, "admission cap must be positive");
        Scheduler { cap, next_seq: 0, pending: Vec::new() }
    }

    /// Pending (admitted, undispatched) request count.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    /// Admission cap this scheduler was built with.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Admit a request at `now_us`. `deadline_us` is **absolute** (the
    /// driver resolves relative deadlines against its own clock). Returns
    /// the admission ticket, or `None` when the queue is at capacity.
    pub fn submit(
        &mut self,
        now_us: u64,
        priority: Priority,
        deadline_us: Option<u64>,
        tiles: u64,
        shape: (usize, usize),
    ) -> Option<u64> {
        if self.pending.len() >= self.cap {
            return None;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push(SchedItem {
            seq,
            submitted_us: now_us,
            deadline_us,
            priority,
            tiles,
            shape,
        });
        Some(seq)
    }

    /// Drop every pending request (abort path), returning them so the
    /// driver can fail their response channels.
    pub fn clear(&mut self) -> Vec<SchedItem> {
        std::mem::take(&mut self.pending)
    }

    /// Run the shed → order → close decision at `now_us`. `flush` forces
    /// pending work out (drain-on-close path) regardless of the window;
    /// the deadline-shrink invariant still applies.
    pub fn poll(
        &mut self,
        now_us: u64,
        max_batch: usize,
        window_us: u64,
        cost: Option<&TileCostModel>,
        flush: bool,
    ) -> Poll {
        let max_batch = max_batch.max(1);
        // 1. Shed pass: solo-infeasible requests leave with justification.
        let mut shed = Vec::new();
        if let Some(cost) = cost {
            let mut i = 0;
            while i < self.pending.len() {
                let it = self.pending[i];
                let hopeless = it.deadline_us.is_some_and(|d| {
                    now_us.saturating_add(cost.predict_us(it.tiles)) > d
                });
                if hopeless {
                    let d = it.deadline_us.expect("hopeless implies a deadline");
                    self.pending.swap_remove(i);
                    shed.push((
                        it,
                        Shed {
                            predicted_us: cost.predict_us(it.tiles),
                            deadline_us: d,
                            decided_us: now_us,
                        },
                    ));
                } else {
                    i += 1;
                }
            }
        }
        if self.pending.is_empty() {
            return if shed.is_empty() {
                Poll::Idle
            } else {
                Poll::Dispatch { batch: Vec::new(), shed }
            };
        }
        // 2. Order: EDF within lanes, FIFO tie-break.
        self.pending.sort_by_key(order_key);
        // 3. Candidate batch: the most urgent request plus every other
        // pending request of the same shape, in urgency order.
        let head_shape = self.pending[0].shape;
        let sel: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, it)| it.shape == head_shape)
            .map(|(i, _)| i)
            .take(max_batch)
            .collect();
        let oldest_submit = sel
            .iter()
            .map(|&i| self.pending[i].submitted_us)
            .min()
            .expect("candidate batch is non-empty");
        let mut close_at = oldest_submit.saturating_add(window_us);
        if let Some(cost) = cost {
            let tiles: u64 = sel.iter().map(|&i| self.pending[i].tiles).sum();
            if let Some(min_d) =
                sel.iter().filter_map(|&i| self.pending[i].deadline_us).min()
            {
                close_at = close_at.min(min_d.saturating_sub(cost.predict_us(tiles)));
            }
        }
        let full = sel.len() == max_batch;
        if !(full || flush || now_us >= close_at) {
            return if shed.is_empty() {
                Poll::WaitUntil(close_at.max(now_us + 1))
            } else {
                Poll::Dispatch { batch: Vec::new(), shed }
            };
        }
        // 4. Close: split off the selection, then shrink from the
        // least-urgent tail until predicted cost meets the earliest
        // member deadline (a singleton always fits — the shed pass
        // guaranteed solo feasibility at this `now`).
        let mut batch = Vec::with_capacity(sel.len());
        let mut keep = Vec::with_capacity(self.pending.len() - sel.len());
        for (i, it) in std::mem::take(&mut self.pending).into_iter().enumerate() {
            if batch.len() < max_batch && it.shape == head_shape && sel.contains(&i) {
                batch.push(it);
            } else {
                keep.push(it);
            }
        }
        self.pending = keep;
        if let Some(cost) = cost {
            while batch.len() > 1 {
                let tiles: u64 = batch.iter().map(|it| it.tiles).sum();
                let overruns = batch
                    .iter()
                    .filter_map(|it| it.deadline_us)
                    .min()
                    .is_some_and(|d| now_us.saturating_add(cost.predict_us(tiles)) > d);
                if !overruns {
                    break;
                }
                let popped = batch.pop().expect("len > 1");
                self.pending.push(popped);
            }
        }
        Poll::Dispatch { batch, shed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item_at(s: &mut Scheduler, now: u64, pri: Priority, d: Option<u64>, tiles: u64) -> u64 {
        s.submit(now, pri, d, tiles, (8, 8)).expect("under cap")
    }

    #[test]
    fn shed_justification_renders_overshoot_and_trace_event() {
        let shed = Shed { predicted_us: 900, deadline_us: 800, decided_us: 100 };
        assert_eq!(shed.overshoot_us(), 200);
        match shed.trace_event() {
            crate::obs::TraceKind::Shed { why, predicted_us, deadline_us, decided_us } => {
                assert_eq!(why, "deadline_unreachable");
                assert_eq!((predicted_us, deadline_us, decided_us), (900, 800, 100));
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn submit_respects_cap_and_tickets_are_fifo() {
        let mut s = Scheduler::new(2);
        assert_eq!(item_at(&mut s, 0, Priority::Normal, None, 1), 0);
        assert_eq!(item_at(&mut s, 1, Priority::Normal, None, 1), 1);
        assert_eq!(s.submit(2, Priority::Normal, None, 1, (8, 8)), None);
        assert_eq!(s.depth(), 2);
        assert_eq!(s.clear().len(), 2);
        assert_eq!(s.depth(), 0);
        // Tickets keep increasing across a clear.
        assert_eq!(item_at(&mut s, 3, Priority::Normal, None, 1), 2);
    }

    #[test]
    fn poll_orders_edf_within_priority_lanes() {
        let mut s = Scheduler::new(16);
        let a = item_at(&mut s, 0, Priority::Low, Some(50), 1);
        let b = item_at(&mut s, 0, Priority::Normal, Some(900), 1);
        let c = item_at(&mut s, 0, Priority::Normal, Some(100), 1);
        let d = item_at(&mut s, 0, Priority::High, None, 1);
        let e = item_at(&mut s, 0, Priority::Normal, None, 1);
        match s.poll(0, 16, 0, None, false) {
            Poll::Dispatch { batch, shed } => {
                assert!(shed.is_empty());
                let seqs: Vec<u64> = batch.iter().map(|it| it.seq).collect();
                // High first; Normal lane EDF then FIFO; Low last.
                assert_eq!(seqs, vec![d, c, b, e, a]);
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn deadline_free_load_is_plain_fifo() {
        let mut s = Scheduler::new(8);
        for t in 0..4 {
            item_at(&mut s, t, Priority::Normal, None, 1);
        }
        match s.poll(10, 3, 0, None, false) {
            Poll::Dispatch { batch, .. } => {
                assert_eq!(batch.iter().map(|it| it.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn window_holds_partial_batches_until_close() {
        let mut s = Scheduler::new(8);
        item_at(&mut s, 100, Priority::Normal, None, 1);
        assert_eq!(s.poll(100, 4, 500, None, false), Poll::WaitUntil(600));
        assert_eq!(s.poll(400, 4, 500, None, false), Poll::WaitUntil(600));
        match s.poll(600, 4, 500, None, false) {
            Poll::Dispatch { batch, .. } => assert_eq!(batch.len(), 1),
            other => panic!("expected dispatch at window close, got {other:?}"),
        }
    }

    #[test]
    fn deadline_minus_predicted_cost_beats_the_window() {
        let cost = TileCostModel::new(0.0, 1.0);
        let mut s = Scheduler::new(8);
        // Solo predicted cost 50µs, deadline 100µs: must close by 50.
        item_at(&mut s, 0, Priority::Normal, Some(100), 50);
        assert_eq!(s.poll(10, 4, 100_000, Some(&cost), false), Poll::WaitUntil(50));
        match s.poll(50, 4, 100_000, Some(&cost), false) {
            Poll::Dispatch { batch, shed } => {
                assert_eq!(batch.len(), 1);
                assert!(shed.is_empty());
            }
            other => panic!("expected SLA close, got {other:?}"),
        }
    }

    #[test]
    fn hopeless_request_sheds_with_predicted_cost_justification() {
        let cost = TileCostModel::new(0.0, 1.0);
        let mut s = Scheduler::new(8);
        item_at(&mut s, 0, Priority::Normal, Some(30), 50); // needs 50µs, has 30
        match s.poll(5, 4, 1000, Some(&cost), false) {
            Poll::Dispatch { batch, shed } => {
                assert!(batch.is_empty());
                assert_eq!(shed.len(), 1);
                let (_, why) = shed[0];
                assert_eq!(why, Shed { predicted_us: 50, deadline_us: 30, decided_us: 5 });
                assert!(why.decided_us + why.predicted_us > why.deadline_us);
            }
            other => panic!("expected shed-only dispatch, got {other:?}"),
        }
        assert_eq!(s.depth(), 0);
    }

    #[test]
    fn closing_batch_shrinks_to_meet_earliest_deadline() {
        let cost = TileCostModel::new(0.0, 1.0);
        let mut s = Scheduler::new(8);
        let a = item_at(&mut s, 0, Priority::Normal, Some(12), 10);
        item_at(&mut s, 0, Priority::Normal, None, 10);
        item_at(&mut s, 0, Priority::Normal, None, 10);
        // Full 3-batch predicts 30µs > A's 12µs slack; it must shrink to
        // [A] alone (10µs ≤ 12µs) and keep the rest pending.
        match s.poll(0, 3, 0, Some(&cost), false) {
            Poll::Dispatch { batch, shed } => {
                assert!(shed.is_empty());
                assert_eq!(batch.iter().map(|it| it.seq).collect::<Vec<_>>(), vec![a]);
            }
            other => panic!("expected shrunk dispatch, got {other:?}"),
        }
        assert_eq!(s.depth(), 2);
    }

    #[test]
    fn batches_are_shape_homogeneous_and_led_by_the_most_urgent() {
        let mut s = Scheduler::new(8);
        s.submit(0, Priority::Normal, Some(500), 4, (32, 32)).unwrap();
        s.submit(0, Priority::Normal, Some(100), 4, (16, 16)).unwrap();
        s.submit(0, Priority::Normal, Some(600), 4, (16, 16)).unwrap();
        match s.poll(0, 4, 0, None, true) {
            Poll::Dispatch { batch, .. } => {
                // (16,16) has the most urgent member; both (16,16) items
                // ride together past the interleaved (32,32) one.
                assert_eq!(batch.len(), 2);
                assert!(batch.iter().all(|it| it.shape == (16, 16)));
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
        assert_eq!(s.depth(), 1);
    }

    #[test]
    fn admission_caps_split_the_budget_by_weight() {
        assert_eq!(admission_caps(8, &[3, 1]), vec![6, 2]);
        assert_eq!(admission_caps(64, &[1, 2]), vec![22, 43]);
        // Rounding never starves, never exceeds the budget per shard.
        assert_eq!(admission_caps(4, &[1000, 1]), vec![4, 1]);
        assert_eq!(admission_caps(5, &[0, 0]), vec![1, 1]);
    }
}
