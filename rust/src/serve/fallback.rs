//! Drift-triggered graceful degradation: a per-layer circuit breaker
//! over the engine fallback ladder `int → float → direct`.
//!
//! The paper's accuracy/speed trade is a *runtime* property: a layer
//! tuned to a quantized Winograd operating point is fast while its
//! activations stay inside the calibrated range, and silently wrong the
//! moment traffic drifts out of it. The
//! [`DriftMonitor`](crate::obs::drift::DriftMonitor) detects that
//! (shadow-oracle rel-L2 vs the NetPlan budget); this module *acts* on
//! it. Each sampled observation feeds
//! [`FallbackController::note`] with a per-layer over-budget verdict:
//!
//! * `alerts_to_degrade` consecutive over-budget observations trip the
//!   breaker one rung down the ladder
//!   ([`EngineMode::degraded`](crate::nn::EngineMode::degraded)) and
//!   emit a [`FallbackEngaged`](TraceKind::FallbackEngaged) event;
//!   continued violations walk further, to the direct-conv floor.
//! * `quiet_to_restore` consecutive in-budget observations on a
//!   degraded layer re-arm it all the way back to
//!   [`EngineMode::Int`](crate::nn::EngineMode::Int) (the tuned path)
//!   and emit [`FallbackCleared`](TraceKind::FallbackCleared) — the
//!   half-open probe of a classic circuit breaker: if the restored
//!   quantized path drifts again, the breaker simply re-trips.
//!
//! The controller is pure policy: it decides mode transitions; the
//! caller (the serve worker loop) applies them through
//! [`BatchModel::set_layer_mode`](super::BatchModel::set_layer_mode)
//! and publishes the [`degraded`](FallbackController::degraded) count
//! as the `serve.degraded` gauge. Counting *sampled observations*
//! (not the monitor's deduplicated per-window alert events) keeps the
//! breaker responsive at any window length — a CI-sized run whose whole
//! life fits in one drift window still accumulates a streak.

use crate::nn::EngineMode;
use crate::obs::drift::{rel_err_to_ppb, DriftMonitor, DriftSample};
use crate::obs::TraceKind;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Circuit-breaker thresholds.
#[derive(Clone, Copy, Debug)]
pub struct FallbackConfig {
    /// Consecutive over-budget sampled observations on one layer that
    /// trip the breaker one rung down the ladder.
    pub alerts_to_degrade: u32,
    /// Consecutive in-budget sampled observations on a degraded layer
    /// that restore it to the quantized path.
    pub quiet_to_restore: u32,
}

impl Default for FallbackConfig {
    fn default() -> FallbackConfig {
        FallbackConfig { alerts_to_degrade: 2, quiet_to_restore: 16 }
    }
}

#[derive(Clone, Copy)]
struct LayerBreaker {
    mode: EngineMode,
    /// Consecutive over-budget observations since the last transition.
    streak: u32,
    /// Consecutive in-budget observations since the last violation.
    quiet: u32,
}

impl Default for LayerBreaker {
    fn default() -> LayerBreaker {
        LayerBreaker { mode: EngineMode::Int, streak: 0, quiet: 0 }
    }
}

/// Per-layer breaker state shared by every serve worker (one mutex,
/// touched only on the drift-sampled subset of spans).
#[derive(Default)]
pub struct FallbackController {
    cfg: FallbackConfig,
    layers: Mutex<BTreeMap<String, LayerBreaker>>,
}

impl FallbackController {
    pub fn new(cfg: FallbackConfig) -> FallbackController {
        assert!(cfg.alerts_to_degrade > 0, "alerts_to_degrade must be positive");
        assert!(cfg.quiet_to_restore > 0, "quiet_to_restore must be positive");
        FallbackController { cfg, layers: Mutex::new(BTreeMap::new()) }
    }

    pub fn config(&self) -> &FallbackConfig {
        &self.cfg
    }

    /// Feed one sampled observation for `layer`. Returns the mode the
    /// layer should now run in plus the trace event to record, when
    /// this observation crossed a threshold; `None` when the breaker
    /// state merely advanced. The caller applies the returned mode via
    /// `BatchModel::set_layer_mode` — the controller never touches
    /// engines itself.
    pub fn note(&self, layer: &str, violated: bool) -> Option<(EngineMode, TraceKind)> {
        let mut layers = self.layers.lock().unwrap();
        let st = layers.entry(layer.to_string()).or_default();
        if violated {
            st.quiet = 0;
            st.streak += 1;
            if st.streak < self.cfg.alerts_to_degrade {
                return None;
            }
            st.streak = 0;
            if st.mode == EngineMode::Direct {
                return None; // already at the ladder's floor
            }
            let from = st.mode;
            st.mode = from.degraded();
            return Some((
                st.mode,
                TraceKind::FallbackEngaged {
                    layer: layer.to_string(),
                    from: from.as_str().to_string(),
                    to: st.mode.as_str().to_string(),
                },
            ));
        }
        st.streak = 0;
        if st.mode == EngineMode::Int {
            return None; // healthy layer, nothing to restore
        }
        st.quiet += 1;
        if st.quiet < self.cfg.quiet_to_restore {
            return None;
        }
        st.quiet = 0;
        st.mode = EngineMode::Int;
        Some((
            EngineMode::Int,
            TraceKind::FallbackCleared {
                layer: layer.to_string(),
                to: EngineMode::Int.as_str().to_string(),
            },
        ))
    }

    /// The per-sample over-budget verdict [`note`](Self::note) consumes:
    /// the sample's instantaneous rel-L2, in ppb, against the monitor's
    /// headroom-scaled budget for that layer. Layers without a tuned
    /// anchor never count as violated (report-only, like the monitor).
    pub fn violated(dm: &DriftMonitor, sample: &DriftSample) -> bool {
        dm.budget_ppb(&sample.layer)
            .is_some_and(|budget| rel_err_to_ppb(sample.rel_err) > budget)
    }

    /// Layers currently serving off the quantized path — the
    /// `serve.degraded` gauge.
    pub fn degraded(&self) -> u64 {
        let layers = self.layers.lock().unwrap();
        layers.values().filter(|st| st.mode != EngineMode::Int).count() as u64
    }

    /// Current breaker mode for `layer` (never-observed layers are
    /// healthy).
    pub fn mode(&self, layer: &str) -> EngineMode {
        let layers = self.layers.lock().unwrap();
        layers.get(layer).map_or(EngineMode::Int, |st| st.mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degrades_after_streak_and_walks_the_ladder() {
        let fb = FallbackController::new(FallbackConfig {
            alerts_to_degrade: 2,
            quiet_to_restore: 3,
        });
        assert!(fb.note("stem", true).is_none(), "one violation is not a streak");
        let (mode, ev) = fb.note("stem", true).expect("second violation trips");
        assert_eq!(mode, EngineMode::Float);
        match ev {
            TraceKind::FallbackEngaged { layer, from, to } => {
                assert_eq!((layer.as_str(), from.as_str(), to.as_str()), ("stem", "int", "float"));
            }
            other => panic!("expected FallbackEngaged, got {other:?}"),
        }
        assert_eq!(fb.degraded(), 1);
        // Two more violations walk to the floor...
        assert!(fb.note("stem", true).is_none());
        let (mode, _) = fb.note("stem", true).unwrap();
        assert_eq!(mode, EngineMode::Direct);
        // ...and the floor absorbs further violations silently.
        assert!(fb.note("stem", true).is_none());
        assert!(fb.note("stem", true).is_none());
        assert_eq!(fb.mode("stem"), EngineMode::Direct);
        assert_eq!(fb.degraded(), 1, "one layer, however many rungs down");
    }

    #[test]
    fn quiet_period_restores_and_violations_reset_it() {
        let fb = FallbackController::new(FallbackConfig {
            alerts_to_degrade: 1,
            quiet_to_restore: 3,
        });
        fb.note("s0b0.conv1", true).expect("threshold 1 trips immediately");
        assert_eq!(fb.mode("s0b0.conv1"), EngineMode::Float);
        // Quiet, quiet — then a violation resets the quiet streak (and
        // at threshold 1 immediately degrades another rung).
        assert!(fb.note("s0b0.conv1", false).is_none());
        assert!(fb.note("s0b0.conv1", false).is_none());
        let (mode, _) = fb.note("s0b0.conv1", true).unwrap();
        assert_eq!(mode, EngineMode::Direct);
        // Three consecutive quiet observations restore fully to Int.
        assert!(fb.note("s0b0.conv1", false).is_none());
        assert!(fb.note("s0b0.conv1", false).is_none());
        let (mode, ev) = fb.note("s0b0.conv1", false).expect("third quiet restores");
        assert_eq!(mode, EngineMode::Int);
        match ev {
            TraceKind::FallbackCleared { layer, to } => {
                assert_eq!((layer.as_str(), to.as_str()), ("s0b0.conv1", "int"));
            }
            other => panic!("expected FallbackCleared, got {other:?}"),
        }
        assert_eq!(fb.degraded(), 0);
        // A healthy layer accumulates no quiet state and never "restores".
        for _ in 0..10 {
            assert!(fb.note("healthy", false).is_none());
        }
        assert_eq!(fb.mode("healthy"), EngineMode::Int);
    }

    #[test]
    fn layers_trip_independently() {
        let fb = FallbackController::new(FallbackConfig {
            alerts_to_degrade: 1,
            quiet_to_restore: 8,
        });
        fb.note("a", true).unwrap();
        fb.note("b", true).unwrap();
        assert!(fb.note("c", false).is_none());
        assert_eq!(fb.degraded(), 2);
        assert_eq!(fb.mode("a"), EngineMode::Float);
        assert_eq!(fb.mode("c"), EngineMode::Int);
    }

    #[test]
    fn violated_compares_instantaneous_rel_err_to_the_budget() {
        use crate::obs::drift::DriftConfig;
        let mut dm = DriftMonitor::new(DriftConfig::default());
        dm.set_budget("stem", Some(0.001)); // budget = 0.001 × headroom 4
        let sample = |layer: &str, rel_err: f64| DriftSample {
            layer: layer.to_string(),
            m: 4,
            base: crate::wino::basis::Base::Legendre,
            weight_bits: 8,
            hadamard_bits: 9,
            rel_err,
        };
        assert!(FallbackController::violated(&dm, &sample("stem", 0.5)));
        assert!(!FallbackController::violated(&dm, &sample("stem", 0.002)));
        // No tuned anchor → report-only, never violated.
        assert!(!FallbackController::violated(&dm, &sample("unplanned", 9.0)));
    }
}
