//! Micro-batching inference serving: bounded request [`queue`], named
//! model [`registry`], transform-[`plan`] cache and latency [`stats`].
//!
//! The deployment story the paper (and LANCE, arXiv 2003.08646) tells —
//! quantized Winograd in a conditioned base wins at serving time — only
//! materializes when independent requests are **micro-batched into one
//! engine pass**: the per-frequency `[K,C] × [C,T]` panel multiply reads
//! each weight panel once per pass, so widening `T` from one request's
//! tiles to a whole batch's amortizes weight traffic, thread fork/join
//! and workspace setup across the batch. The flow:
//!
//! ```text
//!  clients ──submit──▶ ServeQueue (bounded, rejects when full)
//!                          │ drain ≤ max_batch within batch_window_us
//!                          ▼
//!                    worker threads (one EngineScratch each)
//!                          │ stack [C,H,W] items → [B,C,H,W]
//!                          ▼
//!            BatchModel::infer_batch (WinoEngine / IntWinoEngine panel
//!              pipeline, lowered once via registry + PlanCache —
//!              quantized layers run integer end-to-end)
//!                          │ split rows, per-request Response
//!                          ▼
//!                  response channels + ServeStats (p50/p95/p99)
//! ```
//!
//! Models are registered either at one uniform operating point or from a
//! tuned [`NetPlan`](crate::tune::netplan::NetPlan) artifact
//! ([`ModelRegistry::register_netplan`], `winoq serve --plan`), in which
//! case every conv layer carries its own `(m, base, bit-width)` engine —
//! the plan cache keys `(m, r, base)`, so heterogeneous models simply
//! populate more entries (watch `plan_cache` in the stats JSON).
//!
//! Batching changes **nothing numerically**: every engine stage is
//! per-tile independent with a fixed channel-accumulation order, so a
//! response is bit-identical to running that request alone
//! (`rust/tests/serve_parity.rs` pins this for both paper quant configs
//! across bases). Workers hand the actual parallelism to the engine's
//! **persistent worker pool** ([`engine::pool`](crate::engine::pool),
//! warmed once at session start so no request pays thread creation; a
//! dispatch is a condvar wake) via
//! [`engine::parallel`](crate::engine::parallel); keep
//! `workers × WINOQ_THREADS` at or below the core count.
//!
//! **Serving at scale** adds three layers on top of that core loop (see
//! `docs/ARCHITECTURE.md`, "Serve at scale"):
//!
//! * [`sched`] — the pure, clock-free scheduling policy every front-end
//!   shares: EDF inside priority lanes, shape-homogeneous batches,
//!   deadline-based batch closing against a
//!   [`TileCostModel`](crate::tune::cost::TileCostModel), and justified
//!   load shedding. The deterministic soak harness
//!   ([`testkit::soak`](crate::testkit::soak)) drives the same struct on
//!   a virtual clock.
//! * [`shard`] — multi-model routing: one queue + worker pool per served
//!   model, a shared admission budget split by per-model weights
//!   ([`admission_caps`]), and a [`ShardRouter`] clients submit to by
//!   model name.
//! * arbitrary image H×W — admission validates a [`ShapePolicy`] rather
//!   than one exact shape, and per-shape tile geometry is cached in the
//!   [`PlanCache`] keyed `(model, h, w)`.

pub mod fallback;
pub mod plan;
pub mod queue;
pub mod registry;
pub mod sched;
pub mod shard;
pub mod stats;

pub use fallback::{FallbackConfig, FallbackController};
pub use plan::{PlanCache, PlanKey};
pub use queue::{
    DrainedBatch, Rejected, Request, Response, ServeError, ServeQueue, ServeResult,
    ShapePolicy,
};
pub use registry::{ModelRegistry, ServedModel};
pub use sched::{admission_caps, Poll, Priority, SchedItem, Scheduler, Shed, SubmitOpts};
pub use shard::{with_shards, with_shards_traced, ShardRouter, ShardSpec};
pub use stats::{ServeStats, StatsReport};

use crate::engine::{EngineScratch, WinoEngine};
use crate::nn::layers::Conv2dCfg;
use crate::nn::tensor::Tensor;
use crate::nn::EngineMode;
use crate::obs::drift::{DriftMonitor, DriftSample};
use crate::obs::{TraceKind, Tracer};
use crate::testkit::chaos::{Fault, FaultPlan};
use crate::tune::cost::TileCostModel;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Anything the serve loop can host: a batched forward pass over stacked
/// per-item inputs. `Sync` because one model instance is shared by every
/// worker thread.
pub trait BatchModel: Sync {
    /// Per-item input dims (no batch axis), e.g. `[3, 32, 32]`.
    fn input_dims(&self) -> &[usize];

    /// Run one micro-batch: `batch` is `[B, ..input_dims]`, the result
    /// must keep the batch axis first (`[B, ..]`) with per-item rows
    /// independent of `B` — the worker splits it back into responses.
    fn infer_batch(&self, batch: &Tensor, scratch: &mut EngineScratch) -> Tensor;

    /// Winograd tiles one item pushes through the engine (the stats
    /// throughput unit; 0 when unknown).
    fn tiles_per_item(&self) -> usize;

    /// Admission shape policy. Defaults to requiring
    /// [`input_dims`](BatchModel::input_dims) exactly; arbitrary-H×W
    /// models override with [`ShapePolicy::Channels`].
    fn shape_policy(&self) -> ShapePolicy {
        ShapePolicy::Exact(self.input_dims().to_vec())
    }

    /// Tile weight of one item at spatial shape `(h, w)` — the scheduler's
    /// cost unit. Defaults to the nominal-shape
    /// [`tiles_per_item`](BatchModel::tiles_per_item) (correct for
    /// exact-shape models, where only one shape is admitted).
    fn tiles_for(&self, _h: usize, _w: usize) -> u64 {
        self.tiles_per_item().max(1) as u64
    }

    /// Side-effect-free probe: would a [`tiles_for`](BatchModel::tiles_for)
    /// call at `(h, w)` hit a plan/geometry cache? `Some(hit)` lets the
    /// tracing layer stamp a `plan_cache` event on the request's span;
    /// `None` (the default) means the model keeps no such cache.
    fn plan_cache_probe(&self, _h: usize, _w: usize) -> Option<bool> {
        None
    }

    /// Shadow-oracle drift probe: re-run `item`'s Winograd-eligible
    /// layers against the f64 direct-conv oracle and return one
    /// [`DriftSample`] per lowered layer. Only called on the
    /// deterministically sampled subset of spans when a
    /// [`DriftMonitor`] is attached. The default (models with no
    /// oracle path, e.g. single-engine test models) reports nothing.
    fn drift_probe(&self, _item: &Tensor) -> Vec<DriftSample> {
        Vec::new()
    }

    /// Flip one named layer onto a fallback-ladder rung (the
    /// [`FallbackController`]'s lever). Must be safe to call while other
    /// workers are serving (the registry model backs it with an atomic
    /// per layer). Returns `false` when the model has no layer by that
    /// name or no alternative engine — the default, for single-engine
    /// test models, which therefore never degrade.
    fn set_layer_mode(&self, _layer: &str, _mode: EngineMode) -> bool {
        false
    }
}

/// Serving loop knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Micro-batch size cap per engine pass.
    pub max_batch: usize,
    /// How long a worker waits (µs) to widen a batch past one request.
    pub batch_window_us: u64,
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Worker threads (each owns one [`EngineScratch`]).
    pub workers: usize,
    /// Batch-cost predictor enabling the SLO machinery: deadline-based
    /// batch closing and load shedding (see [`sched`]). `None` keeps the
    /// legacy window-only micro-batching (nothing is ever shed).
    pub cost: Option<TileCostModel>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 8,
            batch_window_us: 2000,
            queue_cap: 256,
            workers: 1,
            cost: None,
        }
    }
}

/// A single pre-planned [`WinoEngine`] served as a model — one conv layer
/// behind the queue. Used by the parity tests and useful as a
/// minimal-overhead serving target; full networks go through the
/// [`registry`].
pub struct EngineModel<'a> {
    engine: &'a WinoEngine,
    conv: Conv2dCfg,
    input_dims: Vec<usize>,
    tiles_per_item: usize,
}

impl<'a> EngineModel<'a> {
    pub fn new(engine: &'a WinoEngine, conv: Conv2dCfg, input_dims: [usize; 3]) -> EngineModel<'a> {
        let [c, h, w] = input_dims;
        assert_eq!(c, engine.c, "input channels must match the engine");
        let tiles_per_item = engine.tile_count_for(&[1, c, h, w], conv.padding);
        EngineModel { engine, conv, input_dims: input_dims.to_vec(), tiles_per_item }
    }
}

impl BatchModel for EngineModel<'_> {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }

    fn infer_batch(&self, batch: &Tensor, scratch: &mut EngineScratch) -> Tensor {
        self.engine.forward_with(batch, self.conv, scratch)
    }

    fn tiles_per_item(&self) -> usize {
        self.tiles_per_item
    }
}

/// Closes the queue when dropped — including when the client closure
/// unwinds, so worker threads never outlive a panicking session (the
/// scope would otherwise join them against a never-closed queue forever).
struct CloseOnDrop<'a>(&'a ServeQueue);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Supervisor restart budget and backoff schedule for one worker
/// thread. A panicking batch costs one restart; the budget bounds how
/// many a single worker may consume over a session, so a deterministic
/// model bug (every batch panics) degenerates into today's fail-fast
/// abort instead of an infinite crash loop.
#[derive(Clone, Copy, Debug)]
pub struct RestartPolicy {
    /// Restarts a worker may consume before the supervisor gives up,
    /// aborts the queue and re-raises the panic.
    pub max_restarts: u32,
    /// Backoff before the first restart, microseconds.
    pub backoff_base_us: u64,
    /// Backoff ceiling (the doubling stops here).
    pub backoff_cap_us: u64,
}

impl Default for RestartPolicy {
    fn default() -> RestartPolicy {
        RestartPolicy { max_restarts: 5, backoff_base_us: 200, backoff_cap_us: 20_000 }
    }
}

impl RestartPolicy {
    /// Backoff before the `restarts`-th consecutive restart (1-based):
    /// exponential from the base, capped.
    pub fn backoff_us(&self, restarts: u32) -> u64 {
        let base = self.backoff_base_us.max(1);
        (base << (restarts.saturating_sub(1)).min(20)).min(self.backoff_cap_us.max(base))
    }
}

/// The serving stack's resilience wiring: the supervisor's restart
/// policy, an optional seeded fault plan (chaos testing) and an
/// optional drift-fallback controller. `Default` is production posture:
/// bounded restarts, no injected faults, no fallback (attach a
/// controller whenever a [`DriftMonitor`] is attached).
#[derive(Clone, Default)]
pub struct Resilience {
    pub restart: RestartPolicy,
    /// Seeded fault schedule dealt to worker batches (`--chaos-*`).
    pub chaos: Option<Arc<FaultPlan>>,
    /// Per-layer circuit breaker fed by drift samples.
    pub fallback: Option<Arc<FallbackController>>,
}

/// Best-effort panic payload rendering for `Failed{reason}`.
fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One supervised worker: run [`worker_loop`] as a logical worker on
/// this thread, catching panics. Each panic has already failed exactly
/// its poisoned batch (see the failure path in `worker_loop`); the
/// supervisor's job is the *worker lifecycle* — count the restart,
/// stamp a `worker_restart` event on the reserved span 0, replenish any
/// engine-pool threads the unwind may have quenched, back off, and run
/// a fresh logical worker. Budget exhausted ⇒ abort the queue (pending
/// submitters fail fast, new submissions see `Rejected::Closed`) and
/// re-raise the panic so the session's caller still observes it.
pub(crate) fn supervised_worker(
    worker: u64,
    model: &dyn BatchModel,
    queue: &ServeQueue,
    cfg: &ServeConfig,
    stats: &ServeStats,
    drift: Option<&DriftMonitor>,
    res: &Resilience,
) {
    let mut restarts: u32 = 0;
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            worker_loop(
                model,
                queue,
                cfg,
                stats,
                drift,
                res.chaos.as_deref(),
                res.fallback.as_deref(),
            );
        }));
        let payload = match run {
            Ok(()) => return, // queue closed and drained: clean exit
            Err(payload) => payload,
        };
        restarts += 1;
        if restarts > res.restart.max_restarts {
            // Fail-fast backstop: the pre-supervision behavior.
            queue.abort();
            resume_unwind(payload);
        }
        let backoff_us = res.restart.backoff_us(restarts);
        stats.record_worker_restart();
        if let Some(tr) = queue.tracer() {
            // Span 0 is the reserved "untraced" span: worker lifecycle
            // events are process-level, not request-level, and span 0 is
            // never submitted so accounting stays exact.
            tr.record(
                0,
                queue.now_us(),
                TraceKind::WorkerRestart { worker, restarts: restarts as u64, backoff_us },
            );
        }
        crate::engine::pool::replenish();
        std::thread::sleep(Duration::from_micros(backoff_us));
    }
}

/// Run a serving session: spawn `cfg.workers` scoped worker threads over
/// a fresh bounded queue, hand the queue to `client`, and shut the
/// workers down (draining admitted requests) when `client` returns.
///
/// The client closure runs on the calling thread, so non-`Send` state and
/// return values flow through untouched. Panic-safe in both directions: a
/// panicking client still closes the queue (workers exit, the panic
/// propagates), and a panicking worker aborts the queue (pending and
/// future submissions fail with [`Rejected::Closed`] instead of hanging).
pub fn with_server<R>(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    client: impl FnOnce(&ServeQueue) -> R,
) -> R {
    with_server_traced(model, cfg, stats, None, client)
}

/// [`with_server`] with an optional [`Tracer`]: admission records
/// submit/reject events and the worker loop records
/// shed/batch/stage/complete, so draining the tracer afterwards yields
/// every request's full lifecycle (`winoq serve --trace-json`).
pub fn with_server_traced<R>(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    tracer: Option<Arc<Tracer>>,
    client: impl FnOnce(&ServeQueue) -> R,
) -> R {
    with_server_observed(model, cfg, stats, tracer, None, client)
}

/// [`with_server_traced`] plus an optional [`DriftMonitor`]: workers
/// shadow-sample every `stride`-th completed span through the model's
/// [`drift_probe`](BatchModel::drift_probe) and stamp any resulting
/// `drift_alert` events onto the span's trace (`winoq serve
/// --drift-json`). Kept separate so `ServeConfig` stays `Copy`.
pub fn with_server_observed<R>(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    tracer: Option<Arc<Tracer>>,
    drift: Option<&DriftMonitor>,
    client: impl FnOnce(&ServeQueue) -> R,
) -> R {
    with_server_resilient(model, cfg, stats, tracer, drift, &Resilience::default(), client)
}

/// The full-fat session entry: [`with_server_observed`] plus an explicit
/// [`Resilience`] (restart policy, chaos plan, fallback controller).
/// Every other `with_server*` variant delegates here with
/// `Resilience::default()`, so **all** serving sessions run supervised:
/// a worker panic fails only its poisoned batch
/// ([`ServeError::Failed`]), the worker restarts with exponential
/// backoff, and only an exhausted restart budget aborts the queue and
/// re-raises the panic.
pub fn with_server_resilient<R>(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    tracer: Option<Arc<Tracer>>,
    drift: Option<&DriftMonitor>,
    res: &Resilience,
    client: impl FnOnce(&ServeQueue) -> R,
) -> R {
    // Shape-validating queue: malformed submissions are rejected at
    // admission instead of reaching (and panicking) a worker. Plain
    // `submit` calls carry the model's nominal tile weight into the
    // scheduler's cost model.
    let mut queue = ServeQueue::with_policy(cfg.queue_cap, model.shape_policy())
        .with_default_tiles(model.tiles_per_item().max(1) as u64);
    if let Some(tr) = tracer {
        queue = queue.with_tracer(tr);
    }
    stats.note_workers(cfg.workers.max(1));
    // Pay the engine pool's thread-creation cost here, before the first
    // request is admitted, so no batch ever eats it as latency.
    crate::engine::pool::warm();
    std::thread::scope(|scope| {
        let q = &queue;
        for worker in 0..cfg.workers.max(1) as u64 {
            scope.spawn(move || supervised_worker(worker, model, q, cfg, stats, drift, res));
        }
        let _close = CloseOnDrop(q);
        client(q)
    })
}

/// One logical worker: drain micro-batches per the scheduler's policy,
/// deliver shed notices, stack the batch, run the engine pass, split
/// and answer. Owns its [`EngineScratch`] for its lifetime (a restart
/// gets a fresh one). A panic inside the engine pass — injected by the
/// chaos plan or genuine — fails exactly the poisoned batch's requests
/// ([`ServeError::Failed`], `failed` trace terminals, `serve.failed`)
/// and then re-raises for the supervisor to handle the worker
/// lifecycle.
pub(crate) fn worker_loop(
    model: &dyn BatchModel,
    queue: &ServeQueue,
    cfg: &ServeConfig,
    stats: &ServeStats,
    drift: Option<&DriftMonitor>,
    chaos: Option<&FaultPlan>,
    fallback: Option<&FallbackController>,
) {
    let mut scratch = EngineScratch::new();
    let window = Duration::from_micros(cfg.batch_window_us);
    while let Some(drained) = queue.next_batch_sla(cfg.max_batch, window, cfg.cost.as_ref()) {
        // Shed requests get their predicted-cost justification instead of
        // burning an engine pass they could never ride in time.
        for (req, why) in drained.shed {
            stats.record_shed();
            if let Some(tr) = queue.tracer() {
                tr.record(req.span, queue.now_us(), why.trace_event());
            }
            let _ = req.tx.send(Err(ServeError::Shed(why)));
        }
        let mut batch = drained.batch;
        if batch.is_empty() {
            continue;
        }
        // Chaos: only real batches consume schedule indices, so the
        // dealt fault sequence is the schedule's prefix regardless of
        // how polls interleave. Corruption mutates the stacked inputs
        // *and* what the drift probe later sees — the resulting alerts
        // are genuine out-of-distribution measurements.
        let fault = chaos.and_then(|c| c.next_fault());
        match fault {
            Some(Fault::Latency { us }) => std::thread::sleep(Duration::from_micros(us)),
            Some(Fault::Corrupt { scale }) => {
                for req in &mut batch {
                    crate::testkit::chaos::corrupt_tensor(&mut req.input, scale);
                }
            }
            _ => {}
        }
        let busy_started = Instant::now();
        let depth_after_drain = queue.depth();
        let bsz = batch.len();
        // Admission validated each shape against the model's policy, and
        // the scheduler only assembles shape-homogeneous batches, so the
        // head request defines the batch geometry.
        let item_dims = batch[0].input.dims.clone();
        let item_len: usize = item_dims.iter().product();
        let mut data = Vec::with_capacity(bsz * item_len);
        for req in &batch {
            debug_assert_eq!(
                req.input.dims, item_dims,
                "scheduler batches must be shape-homogeneous"
            );
            data.extend_from_slice(&req.input.data);
        }
        let mut dims = Vec::with_capacity(item_dims.len() + 1);
        dims.push(bsz);
        dims.extend_from_slice(&item_dims);
        let (h, w) = match item_dims.as_slice() {
            [.., h, w] => (*h, *w),
            _ => (1, 1),
        };
        let batch_tiles = model.tiles_for(h, w) * bsz as u64;
        if let Some(tr) = queue.tracer() {
            let predicted_us =
                cfg.cost.as_ref().map_or(0, |c| c.predict_us(batch_tiles));
            let at = queue.now_us();
            for req in &batch {
                tr.record(
                    req.span,
                    at,
                    TraceKind::Batch { size: bsz as u64, predicted_us },
                );
            }
        }
        // The poisoned-batch boundary: a panic below this line (chaos
        // or genuine) must not strand the batch's clients. Fail exactly
        // these requests with a typed terminal, then re-raise so the
        // supervisor restarts the worker.
        let stacked = Tensor::from_vec(&dims, data);
        let run = catch_unwind(AssertUnwindSafe(|| {
            if fault == Some(Fault::Panic) {
                panic!("chaos: injected worker panic");
            }
            model.infer_batch(&stacked, &mut scratch)
        }));
        let y = match run {
            Ok(y) => y,
            Err(payload) => {
                let reason = format!("worker panic: {}", panic_reason(payload.as_ref()));
                stats.record_failed(bsz as u64);
                let at = queue.now_us();
                for req in batch {
                    if let Some(tr) = queue.tracer() {
                        tr.record(req.span, at, TraceKind::Failed { reason: reason.clone() });
                    }
                    let _ = req.tx.send(Err(ServeError::Failed { reason: reason.clone() }));
                }
                resume_unwind(payload);
            }
        };
        assert_eq!(y.dims[0], bsz, "model must preserve the batch axis");
        // Per-stage engine breakdown for this batch (accumulated in the
        // worker's scratch across every layer of the pass) — the stats
        // JSON's `stage_ns` view of *where* serving time goes, and each
        // member span's `stage` trace event.
        let stage_ns = scratch.take_stage_ns();
        if let Some(tr) = queue.tracer() {
            let at = queue.now_us();
            for req in &batch {
                tr.record(
                    req.span,
                    at,
                    TraceKind::Stage {
                        input_transform_ns: stage_ns[0],
                        hadamard_ns: stage_ns[1],
                        inverse_ns: stage_ns[2],
                        tiles: batch_tiles,
                    },
                );
            }
        }
        let row = y.data.len() / bsz;
        let out_dims: Vec<usize> = y.dims[1..].to_vec();
        let mut lat_us = Vec::with_capacity(bsz);
        let mut missed = 0u64;
        for (i, req) in batch.into_iter().enumerate() {
            let output = Tensor::from_vec(&out_dims, y.data[i * row..(i + 1) * row].to_vec());
            let latency_us = req.enqueued.elapsed().as_micros() as u64;
            lat_us.push(latency_us);
            if req.deadline_us.is_some_and(|d| queue.now_us() > d) {
                missed += 1;
            }
            // Shadow-oracle drift check on the sampled subset: a pure
            // span-stride rule (zero PRNG draws), stamped before the
            // span's terminal event so alerts sit inside the lifecycle.
            // The same samples feed the fallback circuit breaker, which
            // may flip a layer's engine mode right here (taking effect
            // from the next batch onward).
            if let Some(dm) = drift {
                if dm.should_sample(req.span) {
                    let samples = model.drift_probe(&req.input);
                    let at = queue.now_us();
                    let alerts = dm.observe(req.span, at, &samples);
                    if let Some(tr) = queue.tracer() {
                        for kind in alerts {
                            tr.record(req.span, at, kind);
                        }
                    }
                    if let Some(fb) = fallback {
                        for s in &samples {
                            let violated = FallbackController::violated(dm, s);
                            let Some((mode, event)) = fb.note(&s.layer, violated) else {
                                continue;
                            };
                            model.set_layer_mode(&s.layer, mode);
                            stats.set_degraded(fb.degraded());
                            if let Some(tr) = queue.tracer() {
                                tr.record(req.span, at, event);
                            }
                        }
                    }
                }
            }
            if let Some(tr) = queue.tracer() {
                tr.record(
                    req.span,
                    queue.now_us(),
                    TraceKind::Complete { latency_us, batch_size: bsz as u64 },
                );
            }
            // A gone client (dropped receiver) is not a server error.
            let _ = req.tx.send(Ok(Response { output, latency_us, batch_size: bsz }));
        }
        stats.record_batch_at(bsz, batch_tiles, depth_after_drain, &lat_us, queue.now_us());
        if missed > 0 {
            stats.record_deadline_miss(missed);
        }
        stats.record_stage_ns(stage_ns);
        stats.record_busy_us(busy_started.elapsed().as_micros() as u64);
    }
}

/// The built-in synthetic closed-loop client: `concurrency` threads each
/// submit one request from `inputs` (round-robin), wait for its response,
/// and repeat until `total_requests` have completed. Admission rejections
/// are counted and retried after a short backoff, so the loop always
/// finishes. Returns the folded stats report (wall clock measured around
/// the whole session, server startup included).
pub fn run_closed_loop(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    inputs: &[Tensor],
    total_requests: usize,
    concurrency: usize,
) -> StatsReport {
    run_closed_loop_with(model, cfg, &ServeStats::new(), inputs, total_requests, concurrency, None)
}

/// [`run_closed_loop`] with a [`Tracer`] attached to the session's
/// queue: every request's lifecycle lands in `tracer` (admission
/// rejections that the closed loop retries each mint their own span
/// and terminate it with a `reject`, so accounting stays exact).
pub fn run_closed_loop_traced(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    inputs: &[Tensor],
    total_requests: usize,
    concurrency: usize,
    tracer: &Arc<Tracer>,
) -> StatsReport {
    run_closed_loop_with(
        model,
        cfg,
        &ServeStats::new(),
        inputs,
        total_requests,
        concurrency,
        Some(tracer.clone()),
    )
}

/// The shared closed-loop body: caller-supplied [`ServeStats`] (so the
/// CLI can [`export_metrics`](ServeStats::export_metrics) from the same
/// sink afterwards) and an optional tracer.
pub fn run_closed_loop_with(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    inputs: &[Tensor],
    total_requests: usize,
    concurrency: usize,
    tracer: Option<Arc<Tracer>>,
) -> StatsReport {
    run_closed_loop_observed(model, cfg, stats, inputs, total_requests, concurrency, tracer, None)
}

/// [`run_closed_loop_with`] plus an optional [`DriftMonitor`] — what
/// pre-resilience callers (the drift suite) drive. Default
/// [`Resilience`]: supervised, no chaos, no fallback.
#[allow(clippy::too_many_arguments)]
pub fn run_closed_loop_observed(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    inputs: &[Tensor],
    total_requests: usize,
    concurrency: usize,
    tracer: Option<Arc<Tracer>>,
    drift: Option<&DriftMonitor>,
) -> StatsReport {
    run_closed_loop_resilient(
        model,
        cfg,
        stats,
        inputs,
        total_requests,
        concurrency,
        tracer,
        drift,
        &Resilience::default(),
    )
}

/// The full closed-loop entry — [`run_closed_loop_observed`] with an
/// explicit [`Resilience`]; what `winoq serve --chaos-*` drives. A
/// request answered with [`ServeError::Failed`] counts as consumed by
/// the closed loop (it reached a terminal), so the loop always
/// finishes and `submitted == completed + rejected + shed + failed`
/// holds in the report.
#[allow(clippy::too_many_arguments)]
pub fn run_closed_loop_resilient(
    model: &dyn BatchModel,
    cfg: &ServeConfig,
    stats: &ServeStats,
    inputs: &[Tensor],
    total_requests: usize,
    concurrency: usize,
    tracer: Option<Arc<Tracer>>,
    drift: Option<&DriftMonitor>,
    res: &Resilience,
) -> StatsReport {
    assert!(!inputs.is_empty(), "need at least one input to serve");
    let started = Instant::now();
    let next = AtomicUsize::new(0);
    with_server_resilient(model, cfg, stats, tracer, drift, res, |queue| {
        std::thread::scope(|s| {
            for _ in 0..concurrency.max(1) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total_requests {
                        break;
                    }
                    let input = &inputs[i % inputs.len()];
                    loop {
                        match queue.submit(input.clone()) {
                            Ok(rx) => {
                                let _ = rx.recv();
                                break;
                            }
                            Err(Rejected::Full) => {
                                stats.record_reject();
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(e) => panic!("closed-loop submit failed: {e}"),
                        }
                    }
                });
            }
        });
    });
    stats.report(started.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prng_tensor;
    use crate::wino::basis::Base;

    fn engine_and_inputs() -> (WinoEngine, Vec<Tensor>) {
        let w = prng_tensor(81, &[3, 2, 3, 3], 0.4);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let inputs = (0..5)
            .map(|i| prng_tensor(100 + i, &[2, 8, 8], 1.0))
            .collect();
        (engine, inputs)
    }

    #[test]
    fn served_responses_match_single_request_forward() {
        let (engine, inputs) = engine_and_inputs();
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model = EngineModel::new(&engine, conv, [2, 8, 8]);
        let stats = ServeStats::new();
        let cfg = ServeConfig { max_batch: 4, batch_window_us: 3000, ..Default::default() };
        let responses = with_server(&model, &cfg, &stats, |queue| {
            // Submit everything up front, then collect: forces real
            // micro-batches to assemble.
            let rxs: Vec<_> = inputs
                .iter()
                .map(|x| queue.submit(x.clone()).unwrap())
                .collect();
            rxs.into_iter()
                .map(|rx| rx.recv().expect("worker died").expect("nothing sheds"))
                .collect::<Vec<Response>>()
        });
        assert_eq!(responses.len(), inputs.len());
        for (x, resp) in inputs.iter().zip(&responses) {
            let mut single = x.clone();
            single.dims.insert(0, 1);
            let want = engine.forward(&single, conv);
            assert_eq!(resp.output.dims, want.dims[1..].to_vec());
            for (a, b) in resp.output.data.iter().zip(&want.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "served ≠ single-request");
            }
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
        }
        let report = stats.report(0.1);
        assert_eq!(report.completed, 5);
        assert!(report.batches <= 5);
        assert!(report.tiles_per_sec > 0.0);
    }

    #[test]
    fn closed_loop_completes_every_request() {
        let (engine, inputs) = engine_and_inputs();
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model = EngineModel::new(&engine, conv, [2, 8, 8]);
        let cfg = ServeConfig {
            max_batch: 4,
            batch_window_us: 200,
            queue_cap: 8,
            workers: 2,
            cost: None,
        };
        let report = run_closed_loop(&model, &cfg, &inputs, 23, 6);
        assert_eq!(report.completed, 23);
        assert!(report.wall_seconds > 0.0);
        assert!(report.requests_per_sec > 0.0);
    }

    struct PanickingModel;

    impl BatchModel for PanickingModel {
        fn input_dims(&self) -> &[usize] {
            &[1, 2, 2]
        }

        fn infer_batch(&self, _batch: &Tensor, _scratch: &mut EngineScratch) -> Tensor {
            panic!("model exploded");
        }

        fn tiles_per_item(&self) -> usize {
            0
        }
    }

    #[test]
    fn dead_worker_fails_its_batch_then_restart_budget_aborts() {
        let stats = ServeStats::new();
        let cfg =
            ServeConfig { max_batch: 2, batch_window_us: 100, queue_cap: 4, ..Default::default() };
        let item = || Tensor::from_vec(&[1, 2, 2], vec![0.0; 4]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_server(&PanickingModel, &cfg, &stats, |queue| {
                let rx = queue.submit(item()).unwrap();
                // Supervision fails only the poisoned batch: the channel
                // delivers a typed error instead of hanging up.
                match rx.recv().expect("failed batches still answer") {
                    Err(ServeError::Failed { reason }) => {
                        assert!(reason.contains("model exploded"), "reason: {reason}");
                    }
                    other => panic!("expected ServeError::Failed, got {other:?}"),
                }
                // Every restarted incarnation dies too; once the restart
                // budget exhausts, the queue transitions to Closed (the
                // fail-fast backstop), never stranding later submitters.
                loop {
                    match queue.submit(item()) {
                        Err(Rejected::Closed) => break,
                        Ok(_) | Err(Rejected::Full) => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => panic!("unexpected rejection: {e}"),
                    }
                }
            });
        }));
        assert!(result.is_err(), "the worker's final panic must propagate, not vanish");
        assert_eq!(
            stats.worker_restarts(),
            RestartPolicy::default().max_restarts as u64,
            "the supervisor must spend its whole restart budget before aborting"
        );
        assert!(stats.failed() >= 1, "the poisoned batch's requests count as failed");
    }

    /// A model that panics exactly once, then serves identity responses —
    /// the supervisor must restart the worker and later requests must
    /// complete normally.
    struct FlakyModel {
        blown: std::sync::atomic::AtomicBool,
    }

    impl BatchModel for FlakyModel {
        fn input_dims(&self) -> &[usize] {
            &[1, 2, 2]
        }

        fn infer_batch(&self, batch: &Tensor, _scratch: &mut EngineScratch) -> Tensor {
            if !self.blown.swap(true, std::sync::atomic::Ordering::SeqCst) {
                panic!("transient fault");
            }
            batch.clone()
        }

        fn tiles_per_item(&self) -> usize {
            0
        }
    }

    #[test]
    fn supervisor_restarts_worker_and_serving_recovers() {
        let stats = ServeStats::new();
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window_us: 100,
            queue_cap: 4,
            ..Default::default()
        };
        let model = FlakyModel { blown: std::sync::atomic::AtomicBool::new(false) };
        let item = || Tensor::from_vec(&[1, 2, 2], vec![1.0; 4]);
        with_server(&model, &cfg, &stats, |queue| {
            // First request poisons its batch...
            let rx = queue.submit(item()).unwrap();
            assert!(matches!(
                rx.recv().expect("failed batches still answer"),
                Err(ServeError::Failed { .. })
            ));
            // ...and after the supervised restart the next requests serve.
            for _ in 0..3 {
                let rx = queue.submit(item()).unwrap();
                let resp = rx.recv().expect("restarted worker serves").expect("no shed");
                assert_eq!(resp.output.dims, vec![1, 2, 2]);
            }
        });
        assert_eq!(stats.worker_restarts(), 1, "exactly one restart for one transient fault");
        assert_eq!(stats.failed(), 1);
        let report = stats.report(0.01);
        assert_eq!(report.completed, 3);
        assert_eq!(
            report.submitted,
            report.completed + report.rejected + report.shed + report.failed,
            "accounting stays exact across the restart"
        );
    }

    #[test]
    fn traced_session_reconstructs_every_span_exactly() {
        use crate::obs::TraceSink;
        let (engine, inputs) = engine_and_inputs();
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model = EngineModel::new(&engine, conv, [2, 8, 8]);
        let cfg = ServeConfig {
            max_batch: 4,
            batch_window_us: 200,
            queue_cap: 8,
            workers: 2,
            cost: None,
        };
        let tracer = Arc::new(Tracer::default());
        let report = run_closed_loop_traced(&model, &cfg, &inputs, 17, 4, &tracer);
        assert_eq!(report.completed, 17);
        let acc = tracer.accounting();
        assert!(acc.exact, "every span must end in exactly one terminal: {acc:?}");
        assert_eq!(acc.completed, report.completed);
        assert_eq!(acc.rejected, report.rejected);
        assert_eq!(acc.shed, report.shed);
        // Completed spans carry the full lifecycle: batch + stage
        // between submit and complete.
        let events = tracer.events();
        let done: Vec<u64> = events
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::Complete { .. }))
            .map(|e| e.span)
            .collect();
        for span in done {
            let kinds: Vec<&str> = events
                .iter()
                .filter(|e| e.span == span)
                .map(|e| match &e.kind {
                    TraceKind::Submit { .. } => "submit",
                    TraceKind::Batch { .. } => "batch",
                    TraceKind::Stage { .. } => "stage",
                    TraceKind::Complete { .. } => "complete",
                    _ => "other",
                })
                .collect();
            assert_eq!(
                kinds,
                ["submit", "batch", "stage", "complete"],
                "span {span} lifecycle out of order"
            );
        }
        // Every line renders as parseable JSON.
        for line in tracer.to_json_lines().lines() {
            crate::tune::json::parse(line).unwrap();
        }
    }

    #[test]
    fn backpressure_is_observable() {
        // One slow-ish model, capacity 1, many eager clients: some
        // submissions must bounce and be retried.
        let (engine, inputs) = engine_and_inputs();
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model = EngineModel::new(&engine, conv, [2, 8, 8]);
        let cfg = ServeConfig {
            max_batch: 1,
            batch_window_us: 0,
            queue_cap: 1,
            workers: 1,
            cost: None,
        };
        let report = run_closed_loop(&model, &cfg, &inputs, 12, 4);
        assert_eq!(report.completed, 12, "retries must finish the closed loop");
        assert!(report.rejected > 0, "cap-1 queue with 4 clients must reject");
    }
}
