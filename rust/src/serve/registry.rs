//! Named model registry for the serving subsystem.
//!
//! A [`ServedModel`] is a ResNet18 wrapped with the per-item input
//! geometry and tile accounting the queue workers need — either pinned
//! to one [`ConvMode`]/[`QuantConfig`](crate::quant::QuantConfig)
//! operating point, or **heterogeneous** (one operating point per layer,
//! from a tuned NetPlan). Models come from three sources:
//!
//! * **checkpoints** — the `runtime::client` interchange format: a
//!   `<tag>.manifest.txt` naming parameters in canonical sorted order
//!   plus a flat f32-LE blob (`<tag>.init.bin` or a trained checkpoint
//!   file), loaded without touching the (stubbed) PJRT client;
//! * **synthetic** — He-initialised and calibration-quantized in
//!   process, so the whole serve path is exercisable offline;
//! * **NetPlans** — `winoq tune` artifacts rebuilding a synthetic model
//!   with per-layer `(m, base, bit-width)` engines
//!   ([`ModelRegistry::register_netplan`]).
//!
//! All transform lowering goes through the shared
//! [`PlanCache`](super::plan::PlanCache): one registry hosting several
//! variants of a model (w8 vs w8_h9, Legendre vs Chebyshev) builds each
//! `F(m, r)` plan exactly once and transforms each weight bank once;
//! float-mode layers additionally share one panel-GEMM register-tile
//! **packing** per bank
//! ([`PlanCache::packed_bank`](super::plan::PlanCache::packed_bank) —
//! quantized layers skip it, since calibration repacks their fake-quant
//! panels privately). Quantized layers instead receive
//! their **i16 weight-code bank** from the cache
//! ([`PlanCache::int_weight_bank`](super::plan::PlanCache::int_weight_bank)),
//! so their integer engines serve from shared codes and a quantized
//! model never dequantizes its weights on the request path.

use super::plan::{PlanCache, PlanKey};
use super::{BatchModel, ShapePolicy};
use crate::data::synthcifar;
use crate::engine::EngineScratch;
use crate::nn::layers::Conv2dCfg;
use crate::nn::tensor::Tensor;
use crate::nn::winolayer::WinoConv2d;
use crate::nn::{ConvMode, EngineMode, Params, ResNet18, ResNetCfg};
use crate::obs::drift::DriftSample;
use crate::runtime::manifest::Manifest;
use crate::tune::cost::{direct_conv_f64, rel_l2};
use crate::tune::netplan::NetPlan;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// Registered models admit any spatial size of at least this many pixels
/// per side: smaller inputs would shrink below the stage-4 stride chain
/// (three stride-2 downsamples plus the 3×3 receptive field) and the
/// forward pass could not produce a well-formed feature map.
pub const MIN_SERVE_HW: usize = 8;

/// A registered model: the network plus serving metadata.
pub struct ServedModel {
    pub name: String,
    pub net: ResNet18,
    /// Per-item input dims (no batch axis), `[C, H, W]` — the *nominal*
    /// (calibration) geometry. Serving admits any H×W ≥
    /// [`MIN_SERVE_HW`] with the same channel count.
    input_dims: Vec<usize>,
    /// Winograd tiles one item of the nominal shape pushes through the
    /// engine (stats unit; per-shape weights come from
    /// [`BatchModel::tiles_for`]).
    tiles_per_item: usize,
    /// The registry's shared plan cache — also hosts the per-model
    /// shape→tile-count geometry cache
    /// ([`PlanCache::tiles_for_shape`]), keyed by this model's name so
    /// two shards can never collide on a shape entry.
    plans: Arc<PlanCache>,
}

impl BatchModel for ServedModel {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }

    fn infer_batch(&self, batch: &Tensor, scratch: &mut EngineScratch) -> Tensor {
        self.net.forward_with_scratch(batch, scratch)
    }

    fn tiles_per_item(&self) -> usize {
        self.tiles_per_item
    }

    fn shape_policy(&self) -> ShapePolicy {
        ShapePolicy::Channels { c: self.input_dims[0], min_hw: MIN_SERVE_HW }
    }

    fn tiles_for(&self, h: usize, w: usize) -> u64 {
        self.plans
            .tiles_for_shape(&self.name, h, w, || {
                (self.net.wino_tiles_per_shape(h, w) as u64).max(1)
            })
            .max(1)
    }

    fn plan_cache_probe(&self, h: usize, w: usize) -> Option<bool> {
        Some(self.plans.has_shape(&self.name, h, w))
    }

    /// Drift-fallback hook: flip one lowered layer's engine mode
    /// (int → float → direct) in place. Atomic per layer, so the
    /// fallback controller can degrade a drifting layer while other
    /// workers are mid-batch. Layers the plan never lowered (already
    /// direct) report `false` — there is nothing to degrade.
    fn set_layer_mode(&self, layer: &str, mode: EngineMode) -> bool {
        match self.net.wino_layer(layer) {
            Some(l) => {
                l.set_mode(mode);
                true
            }
            None => false,
        }
    }

    /// Shadow-oracle probe: replay this item through the network,
    /// capturing every Winograd-eligible layer's *actual* input
    /// activations (the same stem-to-tail capture calibration uses), then
    /// score each lowered layer's served output against the f64 direct
    /// oracle from `tune::cost`. Layers are visited in network order, so
    /// the sample list — and everything downstream of it — is
    /// deterministic.
    fn drift_probe(&self, item: &Tensor) -> Vec<DriftSample> {
        let mut x = item.clone();
        x.dims.insert(0, 1);
        let captured = self.net.capture_wino_inputs(&x);
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let mut scratch = EngineScratch::new();
        let mut out = Vec::new();
        for (prefix, _cin, _cout) in ResNet18::wino_eligible_units(&self.net.cfg) {
            let Some(layer) = self.net.wino_layer(&prefix) else { continue };
            let Some(input) = captured.get(&prefix) else { continue };
            let weights = &self.net.params[&format!("{prefix}.w")];
            let got = layer.forward_with_scratch(input, conv, &mut scratch);
            let oracle = direct_conv_f64(input, weights, conv.padding);
            let rel_err = rel_l2(&got.data, &oracle);
            let (weight_bits, hadamard_bits) = layer
                .quant
                .as_ref()
                .map_or((32, 32), |(q, _)| (q.weight_bits, q.hadamard_bits));
            out.push(DriftSample {
                layer: prefix.clone(),
                m: layer.wf.m,
                base: layer.wf.base,
                weight_bits,
                hadamard_bits,
                rel_err,
            });
        }
        out
    }
}

/// Named model registry sharing one [`PlanCache`].
pub struct ModelRegistry {
    plans: Arc<PlanCache>,
    models: HashMap<String, Arc<ServedModel>>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        Self::with_plans(Arc::new(PlanCache::new()))
    }

    /// Share an existing plan cache (e.g. across registries in tests).
    pub fn with_plans(plans: Arc<PlanCache>) -> ModelRegistry {
        ModelRegistry { plans, models: HashMap::new() }
    }

    /// The shared transform-plan cache.
    pub fn plans(&self) -> &PlanCache {
        &self.plans
    }

    /// Look up a model by name.
    pub fn get(&self, name: &str) -> Option<Arc<ServedModel>> {
        self.models.get(name).cloned()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.models.keys().cloned().collect();
        names.sort();
        names
    }

    /// Register a He-initialised synthetic model (calibrated on a
    /// synthetic batch when its mode is quantized). `image_hw` is the
    /// square input size; 32 uses the synthetic-CIFAR generator.
    pub fn register_synthetic(
        &mut self,
        name: &str,
        cfg: ResNetCfg,
        image_hw: usize,
        seed: u64,
        calib_batch: usize,
    ) -> Result<Arc<ServedModel>> {
        self.ensure_unregistered(name)?;
        let params = ResNet18::init_params(&cfg, seed);
        // Bank namespace keyed by content (seed + width), not registry
        // name: two registered variants of one synthetic model share the
        // float weight banks.
        let ns = format!("synth:{seed}:w{}", cfg.width_mult);
        let mut net = self.build_net(cfg, params, &ns);
        calibrate_uniform(&mut net, [3, image_hw, image_hw], seed, calib_batch);
        self.finish(name, net, [3, image_hw, image_hw])
    }

    /// Register a tuned, **heterogeneous** model from a
    /// [`NetPlan`](crate::tune::netplan::NetPlan) artifact (the output of
    /// `winoq tune`, loaded by `winoq serve --plan`): synthetic
    /// parameters come from the plan's recorded seed, every planned layer
    /// is lowered through the shared [`PlanCache`] under its **own**
    /// `(m, base)` key, and each is calibrated to its own bit widths with
    /// the plan's calibration recipe (batch + activation percentile), so
    /// the served network is bit-identical to what the tuner measured.
    /// Layers absent from the plan run direct convolution.
    pub fn register_netplan(&mut self, name: &str, plan: &NetPlan) -> Result<Arc<ServedModel>> {
        self.ensure_unregistered(name)?;
        if !plan.model.starts_with("resnet18") {
            bail!(
                "NetPlan model {:?} is not a resnet18 variant this registry can build",
                plan.model
            );
        }
        // Any geometry the stride chain supports is servable — the
        // calibration batch generator handles 32×32 (synthetic-CIFAR)
        // and arbitrary sizes alike. Only degenerate sizes that cannot
        // survive three stride-2 downsamples are rejected.
        if plan.image_hw < MIN_SERVE_HW {
            bail!(
                "NetPlan image_hw {} is below the minimum servable size {}",
                plan.image_hw,
                MIN_SERVE_HW
            );
        }
        let (nm, nb, nq) = plan
            .nominal()
            .context("NetPlan has no layers — nothing to serve")?;
        let cfg = ResNetCfg {
            width_mult: plan.width_mult,
            num_classes: plan.num_classes,
            mode: ConvMode::Winograd { m: nm, base: nb, quant: Some(nq) },
        };
        // Validate plan layer names against this architecture before any
        // transform/calibration cost is paid (same eligibility rule the
        // builder and tuner use).
        let eligible = ResNet18::wino_eligible_units(&cfg);
        for l in &plan.layers {
            if !eligible.iter().any(|(p, _, _)| p == &l.layer) {
                bail!(
                    "NetPlan names layer {:?}, which is not a Winograd-eligible unit \
                     of resnet18 at width {}",
                    l.layer,
                    plan.width_mult
                );
            }
        }
        let params = ResNet18::init_params(&cfg, plan.seed);
        // Same namespace scheme as register_synthetic: banks are shared
        // with uniform variants of the same seed/width wherever the
        // per-layer (m, base) keys coincide.
        let ns = format!("synth:{}:w{}", plan.seed, cfg.width_mult);
        let plans = &self.plans;
        let mut net = ResNet18::from_params_per_layer(cfg, params, &|prefix: &str, w: &Tensor| {
            plan.layer(prefix).map(|l| {
                let key = PlanKey::f(l.m, 3, l.base);
                let wf = plans.wf(key);
                let layer_id = format!("{ns}/{prefix}");
                let bank = plans.weight_bank(&layer_id, key, w);
                // NetPlan layers are always quantized: calibration will
                // replace this construction-time float engine with a
                // fake-quant repack, so no shared packed bank is cached
                // for it (the *integer* engine shares through the i16
                // code-bank cache instead).
                let mut conv =
                    WinoConv2d::from_transformed(wf.as_ref().clone(), bank.as_ref().clone());
                // Per-layer quantized operating point → per-layer shared
                // i16 code bank (None only for exotic >16-bit widths).
                if let Some(ib) = plans.int_weight_bank(
                    &layer_id,
                    key,
                    l.quant.weight_bits,
                    bank.as_ref(),
                ) {
                    conv.set_int_codes(ib);
                }
                conv
            })
        });
        let hw = plan.image_hw;
        let calib = calibration_batch(&[3, hw, hw], plan.seed, plan.calib_batch.max(1));
        net.calibrate_quant_with(&calib, &|prefix| {
            plan.layer(prefix).map(|l| (l.quant, plan.calib_pct))
        });
        self.finish(name, net, [3, hw, hw])
    }

    /// Register a model from the `runtime::client` checkpoint format:
    /// `<dir>/<tag>.manifest.txt` plus a flat f32-LE parameter blob
    /// (`checkpoint` path, or `<dir>/<tag>.init.bin` when `None`). The
    /// width multiplier is inferred from the stem's output channels; the
    /// serving `mode` pins base and quantization.
    pub fn register_checkpoint(
        &mut self,
        name: &str,
        dir: &Path,
        tag: &str,
        checkpoint: Option<&Path>,
        mode: ConvMode,
        calib_batch: usize,
    ) -> Result<Arc<ServedModel>> {
        self.ensure_unregistered(name)?;
        let manifest = Manifest::load(&dir.join(format!("{tag}.manifest.txt")))?;
        let blob_path = match checkpoint {
            Some(p) => p.to_path_buf(),
            None => dir.join(format!("{tag}.init.bin")),
        };
        let bytes = std::fs::read(&blob_path)
            .with_context(|| format!("reading checkpoint blob {blob_path:?}"))?;
        let want = manifest.total_param_len() * 4;
        if bytes.len() != want {
            bail!(
                "checkpoint blob {blob_path:?} is {} bytes, manifest wants {want}",
                bytes.len()
            );
        }
        let mut params: Params = HashMap::new();
        let mut off = 0usize;
        for spec in &manifest.params {
            let n = spec.len();
            let mut vals = vec![0f32; n];
            for (i, v) in vals.iter_mut().enumerate() {
                let b = off + i * 4;
                *v = f32::from_le_bytes([bytes[b], bytes[b + 1], bytes[b + 2], bytes[b + 3]]);
                // Bit-rotted or truncated-write checkpoints surface as
                // NaN/Inf weights; reject at load time rather than serve
                // garbage logits (or poison a shared weight-bank cache
                // entry keyed by these bytes).
                if !v.is_finite() {
                    bail!(
                        "checkpoint blob {blob_path:?} has non-finite weight {v} at \
                         {}[{i}] — corrupt checkpoint refused",
                        spec.name
                    );
                }
            }
            off += n * 4;
            params.insert(spec.name.clone(), Tensor::from_vec(&spec.dims, vals));
        }
        let (c, h, w) = manifest.image;
        if c != 3 || h != w {
            bail!("expected a 3xHxH image, manifest says {c}x{h}x{w}");
        }
        let stem = params
            .get("stem.w")
            .context("checkpoint has no stem.w — not a ResNet18 parameter blob")?;
        let width_mult = stem.dims[0] as f32 / 64.0;
        if manifest.num_classes == 0 {
            bail!("manifest is missing num_classes");
        }
        let cfg = ResNetCfg { width_mult, num_classes: manifest.num_classes, mode };
        // Validate shapes, not just names: an inferred width that does not
        // round-trip through the stage-channel arithmetic must fail here,
        // not panic mid-serving inside a worker.
        for (prefix, _stride, cin, cout) in ResNet18::conv_units(&cfg) {
            let ksize = if prefix.ends_with("down") { 1 } else { 3 };
            let want = vec![cout, cin, ksize, ksize];
            match params.get(&format!("{prefix}.w")) {
                None => bail!("checkpoint is missing {prefix}.w for inferred width {width_mult}"),
                Some(t) if t.dims != want => bail!(
                    "checkpoint {prefix}.w has dims {:?}, inferred width {width_mult} wants {want:?}",
                    t.dims
                ),
                Some(_) => {}
            }
            for bn in ["bn.gamma", "bn.beta", "bn.mean", "bn.var"] {
                match params.get(&format!("{prefix}.{bn}")) {
                    Some(t) if t.dims == vec![cout] => {}
                    other => bail!(
                        "checkpoint {prefix}.{bn} is {:?}, want [{cout}]",
                        other.map(|t| t.dims.clone())
                    ),
                }
            }
        }
        let w3 = cfg.widths()[3];
        match params.get("fc.w") {
            Some(t) if t.dims == vec![w3, manifest.num_classes] => {}
            other => bail!(
                "checkpoint fc.w is {:?}, want [{w3}, {}]",
                other.map(|t| t.dims.clone()),
                manifest.num_classes
            ),
        }
        // Bank namespace keyed by the blob's *content* (not its path, and
        // not the registry name): the same bytes registered under several
        // quant/base-pinned entries reuse the transformed float banks,
        // while an overwritten checkpoint file can never serve stale
        // banks.
        let ns = format!("ckpt:{tag}:{:016x}", fnv1a64(&bytes));
        let mut net = self.build_net(cfg, params, &ns);
        calibrate_uniform(&mut net, [3, h, w], 0x5EED, calib_batch);
        self.finish(name, net, [3, h, w])
    }

    /// Lower the network through the shared plan cache (Winograd modes) or
    /// directly (Direct mode). Every Winograd layer's transformed weight
    /// bank is fetched from (or inserted into) the cache under
    /// `<bank_ns>/<layer prefix>` and the layer is constructed via
    /// [`WinoConv2d::from_transformed`](crate::nn::winolayer::WinoConv2d::from_transformed)
    /// — `WinoEngine::from_transformed_weights` is the only engine
    /// construction path in serving.
    fn build_net(&self, cfg: ResNetCfg, params: Params, bank_ns: &str) -> ResNet18 {
        use crate::nn::winolayer::WinoConv2d;
        // Lowering and the calibration passes that follow run engine
        // dispatches; warm the persistent pool so registration doesn't
        // pay thread creation mid-calibration (serving sessions warm it
        // again — idempotent).
        crate::engine::pool::warm();
        match cfg.mode {
            ConvMode::Winograd { m, base, quant } => {
                let key = PlanKey::f(m, 3, base);
                let wf = self.plans.wf(key);
                let plans = &self.plans;
                ResNet18::from_params_lowered(
                    cfg,
                    params,
                    &wf,
                    &|prefix: &str, w: &Tensor| {
                        let layer_id = format!("{bank_ns}/{prefix}");
                        let bank = plans.weight_bank(&layer_id, key, w);
                        // Float-mode layers serve from the shared packed
                        // bank. Quantized layers skip it: calibration
                        // replaces their float engine with a private
                        // fake-quant repack anyway, and caching a pack
                        // nothing will ever execute from would just pin
                        // dead f64 panels for the registry's lifetime.
                        let mut conv = if quant.is_none() {
                            let packed =
                                plans.packed_bank(&layer_id, key, bank.as_ref());
                            WinoConv2d::from_transformed_packed(
                                wf.as_ref().clone(),
                                bank.as_ref().clone(),
                                packed,
                            )
                        } else {
                            WinoConv2d::from_transformed(
                                wf.as_ref().clone(),
                                bank.as_ref().clone(),
                            )
                        };
                        // Quantized serving: hand the layer the shared i16
                        // code bank so calibration lowers its integer
                        // engine from cached codes instead of requantizing
                        // per registered variant.
                        if let Some(q) = quant {
                            if let Some(ib) = plans.int_weight_bank(
                                &layer_id,
                                key,
                                q.weight_bits,
                                bank.as_ref(),
                            ) {
                                conv.set_int_codes(ib);
                            }
                        }
                        conv
                    },
                )
            }
            ConvMode::Direct => ResNet18::from_params(cfg, params),
        }
    }

    /// Duplicate names fail before any parse/transform/calibration cost
    /// is paid (and before the shared bank cache is touched).
    fn ensure_unregistered(&self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            bail!("model {name:?} is already registered");
        }
        Ok(())
    }

    /// Wrap and insert an already-calibrated model. Tile accounting walks
    /// the network's own lowered layers
    /// ([`ResNet18::wino_tiles_per_shape`]), so heterogeneous NetPlan
    /// models are counted per their actual per-layer grids.
    fn finish(
        &mut self,
        name: &str,
        net: ResNet18,
        input_dims: [usize; 3],
    ) -> Result<Arc<ServedModel>> {
        if self.models.contains_key(name) {
            bail!("model {name:?} is already registered");
        }
        let tiles_per_item = net.wino_tiles_per_shape(input_dims[1], input_dims[2]);
        let model = Arc::new(ServedModel {
            name: name.to_string(),
            net,
            input_dims: input_dims.to_vec(),
            tiles_per_item,
            plans: self.plans.clone(),
        });
        self.models.insert(name.to_string(), model.clone());
        Ok(model)
    }
}

/// The uniform calibration step `register_synthetic`/`register_checkpoint`
/// share: quantized Winograd modes calibrate on a representative batch,
/// everything else is a no-op.
fn calibrate_uniform(net: &mut ResNet18, input_dims: [usize; 3], seed: u64, calib_batch: usize) {
    if let ConvMode::Winograd { quant: Some(_), .. } = net.cfg.mode {
        let calib = calibration_batch(&input_dims, seed, calib_batch.max(1));
        net.calibrate_quant(&calib);
    }
}

/// FNV-1a over a byte slice — fingerprints checkpoint blobs for the
/// weight-bank cache namespace, so two registrations share banks only
/// when their bytes are identical. Not cryptographic; 64 bits across a
/// handful of hosted models is ample separation.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A representative calibration batch: the synthetic-CIFAR generator for
/// 32×32 inputs, a seeded uniform tensor otherwise.
fn calibration_batch(input_dims: &[usize; 3], seed: u64, batch: usize) -> Tensor {
    if input_dims[1] == 32 && input_dims[2] == 32 {
        return synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, batch).0;
    }
    let dims = [batch, input_dims[0], input_dims[1], input_dims[2]];
    crate::testkit::prng_tensor(seed, &dims, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantConfig;
    use crate::wino::basis::Base;

    fn wino_cfg(quant: Option<QuantConfig>) -> ResNetCfg {
        ResNetCfg {
            width_mult: 0.25,
            num_classes: 10,
            mode: ConvMode::Winograd { m: 4, base: Base::Legendre, quant },
        }
    }

    #[test]
    fn synthetic_registration_and_lookup() {
        let mut reg = ModelRegistry::new();
        let m = reg
            .register_synthetic("rn", wino_cfg(Some(QuantConfig::w8())), 32, 7, 4)
            .unwrap();
        assert_eq!(m.input_dims(), &[3, 32, 32]);
        assert!(m.tiles_per_item() > 0);
        assert!(reg.get("rn").is_some());
        assert!(reg.get("absent").is_none());
        assert_eq!(reg.names(), vec!["rn".to_string()]);
        // Duplicate names are an error.
        assert!(reg.register_synthetic("rn", wino_cfg(None), 32, 7, 4).is_err());
        // The F(4,3)/Legendre plan was built exactly once.
        assert_eq!(reg.plans().plan_count(), 1);
    }

    #[test]
    fn registry_variants_share_one_plan_and_banks() {
        let mut reg = ModelRegistry::new();
        reg.register_synthetic("a", wino_cfg(Some(QuantConfig::w8())), 32, 7, 2)
            .unwrap();
        reg.register_synthetic("b", wino_cfg(Some(QuantConfig::w8_h9())), 32, 7, 2)
            .unwrap();
        let (wf_counters, bank_counters) = reg.plans().counters();
        assert_eq!(reg.plans().plan_count(), 1, "both variants share F(4,3)/Legendre");
        assert!(wf_counters.hits >= 1, "second registration must hit the plan cache");
        // ResNet18 has 14 stride-1 3×3 layers: the first registration
        // transforms each once, the second reuses every bank.
        assert_eq!(reg.plans().bank_count(), 14);
        assert_eq!(bank_counters.misses, 14);
        assert_eq!(bank_counters.hits, 14);
        // Quantized registrations never touch the packed-float-bank
        // cache: calibration replaces their float engines with private
        // fake-quant repacks, so caching a shared pack would only pin
        // dead panels (sharing happens at the i16 code-bank level).
        assert_eq!(reg.plans().packed_bank_count(), 0);
        let pc = reg.plans().packed_counters();
        assert_eq!((pc.hits, pc.misses), (0, 0));
    }

    #[test]
    fn float_variants_share_packed_engine_banks() {
        // Two *unquantized* registrations of one synthetic model: their
        // float engines must execute from the very same packed weight
        // bank (quantized layers re-bake and repack privately — their
        // sharing happens at the i16 code-bank level instead).
        let mut reg = ModelRegistry::new();
        let a = reg.register_synthetic("a", wino_cfg(None), 32, 7, 1).unwrap();
        let b = reg.register_synthetic("b", wino_cfg(None), 32, 7, 1).unwrap();
        let la = a.net.wino_layer("s0b0.conv1").unwrap();
        let lb = b.net.wino_layer("s0b0.conv1").unwrap();
        assert!(
            Arc::ptr_eq(la.engine().packed_weights(), lb.engine().packed_weights()),
            "float variants must share one packed bank"
        );
        let pc = reg.plans().packed_counters();
        assert_eq!((pc.hits, pc.misses), (14, 14));
    }

    #[test]
    fn quantized_registration_attaches_shared_int_banks() {
        // Two quantized variants (w8, w8_h9) of one checkpoint: every
        // lowered layer serves through an integer engine whose weight
        // codes are one shared plan-cache bank (8-bit codes are common to
        // both Hadamard widths).
        let mut reg = ModelRegistry::new();
        let a = reg
            .register_synthetic("a", wino_cfg(Some(QuantConfig::w8())), 32, 7, 2)
            .unwrap();
        let b = reg
            .register_synthetic("b", wino_cfg(Some(QuantConfig::w8_h9())), 32, 7, 2)
            .unwrap();
        let la = a.net.wino_layer("s0b0.conv1").unwrap();
        let lb = b.net.wino_layer("s0b0.conv1").unwrap();
        let ia = la.int_engine().expect("quantized layer must lower an int engine");
        let ib = lb.int_engine().expect("quantized layer must lower an int engine");
        assert!(
            Arc::ptr_eq(ia.bank(), ib.bank()),
            "variants must share one i16 code bank"
        );
        assert_eq!(ia.cfg.hadamard_bits, 8);
        assert_eq!(ib.cfg.hadamard_bits, 9);
        // 14 layers: first registration computes each bank, second hits.
        assert_eq!(reg.plans().int_bank_count(), 14);
        let ic = reg.plans().int_counters();
        assert_eq!((ic.hits, ic.misses), (14, 14));
        // And the served nets produce finite logits through the int path.
        let x = calibration_batch(&[3, 32, 32], 5, 2);
        let mut scratch = EngineScratch::new();
        assert!(a.infer_batch(&x, &mut scratch).data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn tiles_per_item_counts_stage_grids() {
        // Width 0.25, 32×32: stem + s0 (5 layers at 8×8 tiles = 64),
        // s1: 3 wino layers at 16×16 → 16 tiles, s2: 3 at 8×8 → 4,
        // s3: 3 at 4×4 → 1. Total 5·64 + 3·16 + 3·4 + 3·1 = 383.
        let mut reg = ModelRegistry::new();
        let served = reg.register_synthetic("t", wino_cfg(None), 32, 7, 1).unwrap();
        assert_eq!(served.tiles_per_item(), 383);
        let direct = ResNet18::init(
            ResNetCfg { width_mult: 0.25, num_classes: 10, mode: ConvMode::Direct },
            7,
        );
        assert_eq!(direct.wino_tiles_per_item(32), 0);
    }

    #[test]
    fn netplan_registration_builds_heterogeneous_engines() {
        use crate::quant::scheme::QuantConfig;
        use crate::tune::netplan::{LayerPlan, NetPlan, NETPLAN_VERSION};
        let plan = NetPlan {
            version: NETPLAN_VERSION,
            model: "resnet18-synthetic".into(),
            width_mult: 0.25,
            num_classes: 10,
            image_hw: 32,
            seed: 7,
            calib_batch: 2,
            calib_pct: 100.0,
            layers: vec![
                LayerPlan {
                    layer: "stem".into(),
                    m: 4,
                    base: Base::Legendre,
                    quant: QuantConfig::w8_h9(),
                    tuned_err: Some(0.05),
                    tuned_tiles_per_sec: Some(100000.0),
                },
                LayerPlan {
                    layer: "s0b0.conv1".into(),
                    m: 2,
                    base: Base::Canonical,
                    quant: QuantConfig::w8(),
                    tuned_err: None,
                    tuned_tiles_per_sec: None,
                },
            ],
        };
        let mut reg = ModelRegistry::new();
        let served = reg.register_netplan("tuned", &plan).unwrap();
        // Two distinct (m, base) keys were lowered.
        assert_eq!(reg.plans().plan_count(), 2);
        // Per-layer engines carry their own operating points.
        assert_eq!(served.net.wino_layer("stem").unwrap().wf.m, 4);
        assert_eq!(served.net.wino_layer("stem").unwrap().quant.unwrap().0.hadamard_bits, 9);
        assert_eq!(served.net.wino_layer("s0b0.conv1").unwrap().wf.m, 2);
        assert!(served.net.wino_layer("s0b0.conv2").is_none(), "unplanned layer stays direct");
        // Tiles: stem m=4 on 32×32 → 64, s0b0.conv1 m=2 → 256.
        assert_eq!(served.tiles_per_item(), 64 + 256);
        // And it serves finite logits.
        let x = calibration_batch(&[3, 32, 32], 3, 2);
        let mut scratch = EngineScratch::new();
        let y = served.infer_batch(&x, &mut scratch);
        assert_eq!(y.dims, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Bad layer names are rejected before any lowering.
        let mut bad = plan.clone();
        bad.layers[0].layer = "s0b0.down".into();
        let err = reg.register_netplan("bad", &bad).unwrap_err();
        assert!(err.to_string().contains("s0b0.down"), "{err}");
        // A geometry too small to survive the stride chain is rejected,
        // not served.
        let mut bad_hw = plan.clone();
        bad_hw.image_hw = 4;
        let err = reg.register_netplan("bad-hw", &bad_hw).unwrap_err();
        assert!(err.to_string().contains("image_hw"), "{err}");
        // Any servable geometry registers: tiles follow the actual grid.
        // 40×40: stem m=4 → ⌈40/4⌉² = 100, s0b0.conv1 m=2 → ⌈40/2⌉² = 400.
        let mut wide = plan.clone();
        wide.image_hw = 40;
        let served40 = reg.register_netplan("tuned-40", &wide).unwrap();
        assert_eq!(served40.input_dims(), &[3, 40, 40]);
        assert_eq!(served40.tiles_per_item(), 100 + 400);
    }

    #[test]
    fn served_models_admit_any_large_enough_hw() {
        // The registry policy is Channels { c: 3, min_hw: 8 }: a model
        // calibrated at 32×32 still admits a 24×48 image, and its
        // per-shape tile weight comes from the real grid through the
        // shared geometry cache (keyed by model name).
        let mut reg = ModelRegistry::new();
        let served = reg.register_synthetic("rn", wino_cfg(None), 32, 7, 1).unwrap();
        match served.shape_policy() {
            ShapePolicy::Channels { c, min_hw } => {
                assert_eq!((c, min_hw), (3, MIN_SERVE_HW));
            }
            other => panic!("expected Channels policy, got {other:?}"),
        }
        // Nominal shape matches the per-item accounting.
        assert_eq!(served.tiles_for(32, 32), 383);
        // A non-square shape hits the real per-layer grids.
        let want = served.net.wino_tiles_per_shape(24, 48) as u64;
        assert_eq!(served.tiles_for(24, 48), want);
        // Both shapes are now cached under this model's namespace.
        let mut keys = reg.plans().shape_keys();
        keys.sort();
        assert_eq!(
            keys,
            vec![("rn".to_string(), 24, 48), ("rn".to_string(), 32, 32)]
        );
        // A second lookup is a cache hit, not a recount.
        let before = reg.plans().shape_counters().hits;
        assert_eq!(served.tiles_for(24, 48), want);
        assert_eq!(reg.plans().shape_counters().hits, before + 1);
    }

    #[test]
    fn checkpoint_round_trip() {
        // Serialize init params in manifest (sorted-name) order, then load
        // through the registry and check the model serves the same logits
        // as a directly-constructed network.
        let cfg = wino_cfg(None);
        let params = ResNet18::init_params(&cfg, 11);
        let mut names: Vec<&String> = params.keys().collect();
        names.sort();
        let mut manifest = String::from(
            "winoq-manifest v1\nvariant test-ckpt\ntrain_batch 8\neval_batch 8\n\
             image 3x32x32\nnum_classes 10\n",
        );
        let mut blob: Vec<u8> = Vec::new();
        for name in &names {
            let t = &params[name.as_str()];
            let dims: Vec<String> = t.dims.iter().map(|d| d.to_string()).collect();
            manifest.push_str(&format!("param {name} {}\n", dims.join("x")));
            for v in &t.data {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        let dir = std::env::temp_dir().join(format!("winoq-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("test-ckpt.manifest.txt"), &manifest).unwrap();
        std::fs::write(dir.join("test-ckpt.init.bin"), &blob).unwrap();

        let mut reg = ModelRegistry::new();
        let served = reg
            .register_checkpoint("ckpt", &dir, "test-ckpt", None, cfg.mode, 2)
            .unwrap();
        assert_eq!(served.net.cfg.width_mult, 0.25);
        let x = calibration_batch(&[3, 32, 32], 3, 2);
        let direct = ResNet18::from_params(cfg, params).forward(&x);
        let mut scratch = EngineScratch::new();
        let got = served.infer_batch(&x, &mut scratch);
        assert_eq!(got.data, direct.data, "checkpoint model must serve identical logits");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_mismatched_dims() {
        // Same byte count, wrong shape: fc.w written transposed. Name
        // validation alone would admit it; the dims check must not.
        let cfg = wino_cfg(None);
        let params = ResNet18::init_params(&cfg, 13);
        let mut names: Vec<&String> = params.keys().collect();
        names.sort();
        let mut manifest = String::from(
            "winoq-manifest v1\nvariant flip\ntrain_batch 8\neval_batch 8\n\
             image 3x32x32\nnum_classes 10\n",
        );
        let mut blob: Vec<u8> = Vec::new();
        for name in &names {
            let t = &params[name.as_str()];
            let dims: Vec<String> = if name.as_str() == "fc.w" {
                t.dims.iter().rev().map(|d| d.to_string()).collect()
            } else {
                t.dims.iter().map(|d| d.to_string()).collect()
            };
            manifest.push_str(&format!("param {name} {}\n", dims.join("x")));
            for v in &t.data {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        let dir = std::env::temp_dir().join(format!("winoq-reg-flip-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("flip.manifest.txt"), &manifest).unwrap();
        std::fs::write(dir.join("flip.init.bin"), &blob).unwrap();
        let mut reg = ModelRegistry::new();
        let err = reg
            .register_checkpoint("flip", &dir, "flip", None, cfg.mode, 1)
            .unwrap_err();
        assert!(err.to_string().contains("fc.w"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_non_finite_weights() {
        // Chaos bit-rot on a valid checkpoint blob: NaN weights must be
        // refused at load time with a typed complaint naming the rotted
        // parameter, never served (and never poison the shared
        // content-keyed bank cache).
        let cfg = wino_cfg(None);
        let params = ResNet18::init_params(&cfg, 17);
        let mut names: Vec<&String> = params.keys().collect();
        names.sort();
        let mut manifest = String::from(
            "winoq-manifest v1\nvariant rot\ntrain_batch 8\neval_batch 8\n\
             image 3x32x32\nnum_classes 10\n",
        );
        let mut blob: Vec<u8> = Vec::new();
        for name in &names {
            let t = &params[name.as_str()];
            let dims: Vec<String> = t.dims.iter().map(|d| d.to_string()).collect();
            manifest.push_str(&format!("param {name} {}\n", dims.join("x")));
            for v in &t.data {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        crate::testkit::chaos::poison_floats(&mut blob, 42, 4);
        let dir = std::env::temp_dir().join(format!("winoq-reg-rot-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("rot.manifest.txt"), &manifest).unwrap();
        std::fs::write(dir.join("rot.init.bin"), &blob).unwrap();
        let mut reg = ModelRegistry::new();
        let err = reg
            .register_checkpoint("rot", &dir, "rot", None, cfg.mode, 1)
            .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(reg.get("rot").is_none(), "a refused checkpoint must not register");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn layer_mode_hook_flips_lowered_layers_only() {
        let mut reg = ModelRegistry::new();
        let served = reg
            .register_synthetic("rn", wino_cfg(Some(QuantConfig::w8())), 32, 7, 2)
            .unwrap();
        // A lowered layer flips; its engine mode is observable on the net.
        assert!(served.set_layer_mode("s0b0.conv1", EngineMode::Float));
        assert_eq!(
            served.net.wino_layer("s0b0.conv1").unwrap().mode(),
            EngineMode::Float
        );
        assert!(served.set_layer_mode("s0b0.conv1", EngineMode::Int));
        // Unknown / never-lowered layers have nothing to degrade.
        assert!(!served.set_layer_mode("no.such.layer", EngineMode::Direct));
    }

    #[test]
    fn checkpoint_rejects_bad_blob() {
        let dir = std::env::temp_dir().join(format!("winoq-reg-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("bad.manifest.txt"),
            "winoq-manifest v1\nvariant bad\nimage 3x32x32\nnum_classes 10\nparam stem.w 16x3x3x3\n",
        )
        .unwrap();
        std::fs::write(dir.join("bad.init.bin"), vec![0u8; 7]).unwrap();
        let mut reg = ModelRegistry::new();
        let err = reg
            .register_checkpoint("bad", &dir, "bad", None, ConvMode::Direct, 1)
            .unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
