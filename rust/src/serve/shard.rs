//! Multi-model serving: per-model worker shards behind one router.
//!
//! Each served model gets its own **shard** — a bounded [`ServeQueue`]
//! plus a private worker pool running the shared
//! [`worker_loop`](super::with_server) machinery — so one model's load
//! (or one model's panic) never blocks another's batches, and batches
//! are trivially model-homogeneous. The shards share one **admission
//! budget**: [`with_shards`] splits `budget` queue slots across shards
//! proportionally to their weights via
//! [`admission_caps`](super::admission_caps), so a heavy tenant buys
//! deeper queues without starving light ones (every shard keeps ≥ 1
//! slot).
//!
//! Clients see only the [`ShardRouter`]: submit by model name with
//! per-request [`SubmitOpts`] (priority lane + deadline), get back the
//! same per-request [`ServeResult`] channel single-model serving uses.
//! Routing failures are typed ([`Rejected::UnknownModel`]); everything
//! downstream — shape validation, weighted admission, deadline-based
//! closing, shedding — is the per-shard queue's ordinary behaviour.

use super::queue::{lane, Rejected, ServeQueue, ServeResult};
use super::sched::{admission_caps, SubmitOpts};
use super::stats::ServeStats;
use super::{supervised_worker, BatchModel, CloseOnDrop, Resilience, ServeConfig};
use crate::nn::tensor::Tensor;
use crate::obs::{mint_span, TraceKind, Tracer};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// One shard's static description: the model it serves, its share of the
/// admission budget, and its serving knobs.
pub struct ShardSpec<'a> {
    /// Routing name clients submit against (unique across the fleet).
    pub name: &'a str,
    /// The model this shard's workers run.
    pub model: &'a dyn BatchModel,
    /// Admission weight: this shard's queue capacity is
    /// `max(1, ⌈budget · weight / Σweights⌉)`.
    pub weight: u64,
    /// Per-shard serving knobs (batch size, window, workers, cost model).
    /// `queue_cap` is ignored — the shared budget decides capacity.
    pub cfg: ServeConfig,
}

/// One live shard: name + model + its bounded queue.
struct Shard<'a> {
    name: &'a str,
    model: &'a dyn BatchModel,
    queue: ServeQueue,
}

/// The client-facing handle of a multi-shard session: routes submissions
/// to the named model's shard.
pub struct ShardRouter<'a> {
    shards: Vec<Shard<'a>>,
    /// Router-level tracer: each shard's queue stamps admission events
    /// itself; the router only needs this for routing failures, which
    /// never reach a queue.
    tracer: Option<Arc<Tracer>>,
}

impl ShardRouter<'_> {
    /// Submit one item to the named model with explicit scheduling
    /// options. The request's tile weight is computed from its own
    /// spatial shape via [`BatchModel::tiles_for`], so the cost model
    /// prices mixed-shape traffic correctly.
    pub fn submit(
        &self,
        model: &str,
        input: Tensor,
        opts: SubmitOpts,
    ) -> Result<Receiver<ServeResult>, Rejected> {
        let Some(shard) = self.shards.iter().find(|s| s.name == model) else {
            // Routing failure: no queue ever saw this request, so the
            // router itself opens and terminates the span.
            if let Some(tr) = &self.tracer {
                let span = mint_span();
                let (h, w) = match input.dims.as_slice() {
                    [.., h, w] => (*h as u64, *w as u64),
                    _ => (1, 1),
                };
                tr.record(
                    span,
                    0,
                    TraceKind::Submit {
                        model: model.to_string(),
                        priority: lane(opts.priority).to_string(),
                        deadline_us: opts.deadline_us.unwrap_or(0),
                        tiles: 0,
                        h,
                        w,
                    },
                );
                tr.record(span, 0, TraceKind::Reject { why: "unknown_model".to_string() });
            }
            return Err(Rejected::UnknownModel { name: model.to_string() });
        };
        let (h, w) = match input.dims.as_slice() {
            [.., h, w] => (*h, *w),
            _ => (1, 1),
        };
        // Probe before `tiles_for` resolves (and inserts) the geometry —
        // the event must report what the cache knew at admission.
        let plan_hit = shard.model.plan_cache_probe(h, w);
        let tiles = shard.model.tiles_for(h, w);
        let span = mint_span();
        let rx = shard.queue.submit_span(input, opts, tiles, span)?;
        if let (Some(tr), Some(hit)) = (&self.tracer, plan_hit) {
            tr.record(
                span,
                shard.queue.now_us(),
                TraceKind::PlanCache { model: shard.name.to_string(), hit },
            );
        }
        Ok(rx)
    }

    /// Registered shard names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.shards.iter().map(|s| s.name).collect()
    }

    /// The named shard's queue (observability: depth, manual close).
    pub fn queue(&self, model: &str) -> Option<&ServeQueue> {
        self.shards.iter().find(|s| s.name == model).map(|s| &s.queue)
    }
}

/// Run a multi-model serving session: one queue + worker pool per shard,
/// admission capacity split across shards by weight from the shared
/// `budget`, per-shard stats in `stats` (one entry per shard, same
/// order). The client closure runs on the calling thread with the
/// router; when it returns, every queue closes and drains. Panic-safe
/// exactly like [`with_server`](super::with_server), per shard: a dying
/// worker aborts only its own shard's queue.
pub fn with_shards<'a, R>(
    shards: &[ShardSpec<'a>],
    budget: usize,
    stats: &[ServeStats],
    client: impl FnOnce(&ShardRouter<'a>) -> R,
) -> R {
    with_shards_traced(shards, budget, stats, None, client)
}

/// [`with_shards`] with an optional [`Tracer`] shared by every shard:
/// each per-model queue stamps its admission events under its own model
/// label, workers stamp batch-side events, and the router terminates
/// unknown-model spans — so one drain reconstructs the whole fleet's
/// traffic with exact accounting.
pub fn with_shards_traced<'a, R>(
    shards: &[ShardSpec<'a>],
    budget: usize,
    stats: &[ServeStats],
    tracer: Option<Arc<Tracer>>,
    client: impl FnOnce(&ShardRouter<'a>) -> R,
) -> R {
    assert!(!shards.is_empty(), "need at least one shard");
    assert_eq!(shards.len(), stats.len(), "one ServeStats per shard");
    let weights: Vec<u64> = shards.iter().map(|s| s.weight).collect();
    let caps = admission_caps(budget, &weights);
    let router = ShardRouter {
        shards: shards
            .iter()
            .zip(&caps)
            .map(|(spec, &cap)| {
                let mut queue = ServeQueue::with_policy(cap, spec.model.shape_policy())
                    .with_default_tiles(spec.model.tiles_per_item().max(1) as u64)
                    .with_model_label(spec.name);
                if let Some(tr) = &tracer {
                    queue = queue.with_tracer(tr.clone());
                }
                Shard { name: spec.name, model: spec.model, queue }
            })
            .collect(),
        tracer,
    };
    // One engine pool serves every shard's workers; warm it before the
    // first admission so no shard's first batch pays thread creation.
    crate::engine::pool::warm();
    // Shards run under default supervision: a panicking worker fails
    // only its own batch and restarts within the default budget; other
    // shards never notice.
    let res = Resilience::default();
    std::thread::scope(|scope| {
        let res = &res;
        for (i, spec) in shards.iter().enumerate() {
            let queue = &router.shards[i].queue;
            let model = router.shards[i].model;
            let shard_stats = &stats[i];
            shard_stats.note_workers(spec.cfg.workers.max(1));
            for worker in 0..spec.cfg.workers.max(1) as u64 {
                let cfg = &spec.cfg;
                scope.spawn(move || {
                    supervised_worker(worker, model, queue, cfg, shard_stats, None, res);
                });
            }
        }
        // Dropped when the client returns (or unwinds): closes every
        // shard queue so the scoped workers drain and join.
        let _close: Vec<CloseOnDrop<'_>> =
            router.shards.iter().map(|s| CloseOnDrop(&s.queue)).collect();
        client(&router)
    })
}

#[cfg(test)]
mod tests {
    use super::super::{EngineModel, Priority};
    use super::*;
    use crate::engine::WinoEngine;
    use crate::nn::layers::Conv2dCfg;
    use crate::testkit::prng_tensor;
    use crate::wino::basis::Base;

    #[test]
    fn routes_by_name_and_rejects_unknown_models() {
        let w = prng_tensor(91, &[3, 2, 3, 3], 0.4);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model_a = EngineModel::new(&engine, conv, [2, 8, 8]);
        let model_b = EngineModel::new(&engine, conv, [2, 8, 8]);
        let specs = [
            ShardSpec { name: "a", model: &model_a, weight: 3, cfg: ServeConfig::default() },
            ShardSpec { name: "b", model: &model_b, weight: 1, cfg: ServeConfig::default() },
        ];
        let stats = [ServeStats::new(), ServeStats::new()];
        with_shards(&specs, 8, &stats, |router| {
            assert_eq!(router.names(), vec!["a", "b"]);
            let x = prng_tensor(17, &[2, 8, 8], 1.0);
            let opts = SubmitOpts { priority: Priority::High, ..Default::default() };
            let rx = router.submit("a", x.clone(), opts).expect("shard a admits");
            let resp = rx.recv().expect("worker alive").expect("not shed");
            assert_eq!(resp.batch_size, 1);
            match router.submit("nope", x, SubmitOpts::default()).unwrap_err() {
                Rejected::UnknownModel { name } => assert_eq!(name, "nope"),
                other => panic!("expected UnknownModel, got {other}"),
            }
        });
        // Per-shard stats separation: only shard a served anything.
        assert_eq!(stats[0].completed(), 1);
        assert_eq!(stats[1].completed(), 0);
    }

    #[test]
    fn traced_fleet_labels_models_and_terminates_unknown_routes() {
        use crate::obs::TraceSink;
        let w = prng_tensor(93, &[3, 2, 3, 3], 0.4);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model_a = EngineModel::new(&engine, conv, [2, 8, 8]);
        let model_b = EngineModel::new(&engine, conv, [2, 8, 8]);
        let specs = [
            ShardSpec { name: "a", model: &model_a, weight: 1, cfg: ServeConfig::default() },
            ShardSpec { name: "b", model: &model_b, weight: 1, cfg: ServeConfig::default() },
        ];
        let stats = [ServeStats::new(), ServeStats::new()];
        let tracer = Arc::new(Tracer::default());
        with_shards_traced(&specs, 8, &stats, Some(tracer.clone()), |router| {
            let x = prng_tensor(19, &[2, 8, 8], 1.0);
            let rx = router.submit("b", x.clone(), SubmitOpts::default()).unwrap();
            rx.recv().unwrap().unwrap();
            assert!(matches!(
                router.submit("ghost", x, SubmitOpts::default()),
                Err(Rejected::UnknownModel { .. })
            ));
        });
        let acc = tracer.accounting();
        assert!(acc.exact, "{acc:?}");
        assert_eq!((acc.completed, acc.rejected), (1, 1));
        // The completed span is labeled with its shard's model name, the
        // rejected one with the name no shard answered to.
        let events = tracer.events();
        let labels: Vec<&str> = events
            .iter()
            .filter_map(|e| match &e.kind {
                TraceKind::Submit { model, .. } => Some(model.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(labels, ["b", "ghost"]);
        let why = events
            .iter()
            .find_map(|e| match &e.kind {
                TraceKind::Reject { why } => Some(why.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(why, "unknown_model");
    }

    #[test]
    fn admission_budget_splits_by_weight() {
        let w = prng_tensor(92, &[3, 2, 3, 3], 0.4);
        let engine = WinoEngine::from_weights(4, &w, Base::Legendre);
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        let model = EngineModel::new(&engine, conv, [2, 8, 8]);
        // Zero workers is not possible (max(1)), so park the workers with
        // an enormous window and max_batch to keep requests queued while
        // we probe the admission caps.
        let slow = ServeConfig { batch_window_us: 60_000_000, max_batch: 64, ..Default::default() };
        let specs = [
            ShardSpec { name: "heavy", model: &model, weight: 3, cfg: slow },
            ShardSpec { name: "light", model: &model, weight: 1, cfg: slow },
        ];
        let stats = [ServeStats::new(), ServeStats::new()];
        with_shards(&specs, 8, &stats, |router| {
            // Caps from admission_caps(8, [3,1]) = [6, 2].
            let x = || prng_tensor(18, &[2, 8, 8], 1.0);
            let mut heavy = Vec::new();
            for _ in 0..6 {
                heavy.push(router.submit("heavy", x(), SubmitOpts::default()).expect("cap 6"));
            }
            assert_eq!(
                router.submit("heavy", x(), SubmitOpts::default()).unwrap_err(),
                Rejected::Full
            );
            let _l0 = router.submit("light", x(), SubmitOpts::default()).expect("cap 2");
            let _l1 = router.submit("light", x(), SubmitOpts::default()).expect("cap 2");
            assert_eq!(
                router.submit("light", x(), SubmitOpts::default()).unwrap_err(),
                Rejected::Full
            );
            // Release the parked workers so the session can drain: closing
            // the queues flushes pending batches immediately.
            router.queue("heavy").unwrap().close();
            router.queue("light").unwrap().close();
            for rx in heavy {
                rx.recv().expect("drained on close").expect("not shed");
            }
        });
        assert_eq!(stats[0].completed(), 6);
        assert_eq!(stats[1].completed(), 2);
    }
}
