//! Minimal TOML-subset config parser + typed run configuration.
//!
//! serde isn't in the vendored crate set, so this implements the subset we
//! need: `[section]` headers, `key = value` with string / number / bool
//! values, `#` comments. Good enough for experiment configs like
//! `examples/train.toml`.

use crate::coordinator::schedule::Schedule;
use crate::coordinator::trainer::TrainCfg;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// A parsed config: section -> key -> raw value string.
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: HashMap<String, HashMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = value.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_u64(&self, section: &str, key: &str, default: u64) -> Result<u64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{section}.{key} = {v:?}")),
        }
    }

    pub fn get_f32(&self, section: &str, key: &str, default: f32) -> Result<f32> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{section}.{key} = {v:?}")),
        }
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(section, key, default as u64)? as usize)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

/// A full training-run configuration file.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub tag: String,
    pub artifacts_dir: PathBuf,
    pub train: TrainCfg,
    pub metrics_csv: Option<PathBuf>,
}

impl RunConfig {
    /// Read the `[run]`, `[train]`, `[schedule]` sections.
    pub fn from_config(cfg: &Config) -> Result<RunConfig> {
        let tag = cfg
            .get("run", "artifact")
            .context("[run] artifact = <tag> is required")?
            .to_string();
        let artifacts_dir = PathBuf::from(cfg.get_or("run", "artifacts_dir", "artifacts"));
        let steps = cfg.get_u64("train", "steps", 200)?;
        let kind = cfg.get_or("schedule", "kind", "warmup_cosine");
        let lr = cfg.get_f32("schedule", "lr", 0.05)?;
        let schedule = match kind {
            "constant" => Schedule::Constant { lr },
            "warmup_cosine" => Schedule::WarmupCosine {
                lr,
                warmup: cfg.get_u64("schedule", "warmup", steps / 10)?,
                total: cfg.get_u64("schedule", "total", steps)?,
                final_frac: cfg.get_f32("schedule", "final_frac", 0.05)?,
            },
            "step_decay" => Schedule::StepDecay {
                lr,
                gamma: cfg.get_f32("schedule", "gamma", 0.1)?,
                milestones: [
                    cfg.get_u64("schedule", "m1", steps / 2)?,
                    cfg.get_u64("schedule", "m2", 3 * steps / 4)?,
                    cfg.get_u64("schedule", "m3", 7 * steps / 8)?,
                ],
            },
            other => bail!("unknown schedule kind {other:?}"),
        };
        let train = TrainCfg {
            steps,
            schedule,
            eval_every: cfg.get_u64("train", "eval_every", 0)?,
            eval_batches: cfg.get_usize("train", "eval_batches", 5)?,
            log_every: cfg.get_u64("train", "log_every", 20)?,
            checkpoint: cfg.get("train", "checkpoint").map(PathBuf::from),
            dataset_size: cfg.get_u64("train", "dataset_size", 4096)?,
        };
        let metrics_csv = cfg.get("run", "metrics_csv").map(PathBuf::from);
        Ok(RunConfig { tag, artifacts_dir, train, metrics_csv })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
[run]
artifact = "t2-direct-8b-w0.25"
metrics_csv = out/metrics.csv

[train]
steps = 50
eval_every = 25   # inline comment
checkpoint = out/ckpt.bin

[schedule]
kind = warmup_cosine
lr = 0.1
"#;

    #[test]
    fn parses_sections_and_values() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.get("run", "artifact"), Some("t2-direct-8b-w0.25"));
        assert_eq!(cfg.get_u64("train", "steps", 0).unwrap(), 50);
        assert_eq!(cfg.get_u64("train", "eval_every", 0).unwrap(), 25);
        assert_eq!(cfg.get("missing", "x"), None);
        assert_eq!(cfg.get_or("missing", "x", "d"), "d");
    }

    #[test]
    fn run_config_roundtrip() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let run = RunConfig::from_config(&cfg).unwrap();
        assert_eq!(run.tag, "t2-direct-8b-w0.25");
        assert_eq!(run.train.steps, 50);
        assert_eq!(run.train.eval_every, 25);
        assert!(run.train.checkpoint.is_some());
        match run.train.schedule {
            Schedule::WarmupCosine { lr, warmup, .. } => {
                assert!((lr - 0.1).abs() < 1e-7);
                assert_eq!(warmup, 5);
            }
            _ => panic!("wrong schedule"),
        }
    }

    #[test]
    fn missing_artifact_errors() {
        let cfg = Config::parse("[train]\nsteps = 1\n").unwrap();
        assert!(RunConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let cfg = Config::parse("[train]\nsteps = abc\n").unwrap();
        assert!(cfg.get_u64("train", "steps", 0).is_err());
    }

    #[test]
    fn step_decay_schedule() {
        let cfg = Config::parse(
            "[run]\nartifact = x\n[train]\nsteps = 100\n[schedule]\nkind = step_decay\nlr = 1.0\n",
        )
        .unwrap();
        let run = RunConfig::from_config(&cfg).unwrap();
        match run.train.schedule {
            Schedule::StepDecay { milestones, .. } => {
                assert_eq!(milestones, [50, 75, 87]);
            }
            _ => panic!("wrong schedule"),
        }
    }
}
