//! winoq: quantized Winograd/Toom-Cook convolution for DNNs beyond the
//! canonical polynomial base — a three-layer reproduction of Barabasz 2020.
//!
//! * [`wino`] — exact Toom-Cook/Winograd construction, polynomial bases,
//!   floating-point pipelines, error analysis (the math substrate).
//! * [`quant`] — symmetric quantization and the staged quantized-Winograd
//!   pipeline of the paper's Fig. 2 (fake-quant + true-integer paths).
//! * [`nn`] — pure-rust NCHW inference: layers, Winograd conv layer,
//!   ResNet18 (the serving path).
//! * [`engine`] — the batched Winograd execution engines: flat tile
//!   buffers, per-frequency GEMM panels run through the register-tiled,
//!   cache-blocked micro-kernels of [`engine::gemm`] (packed weight
//!   panels, fused requantize epilogue), scoped-thread parallelism and
//!   reusable scratch (the serving hot loop; see `docs/ARCHITECTURE.md`).
//!   [`engine::int`] is the fully integer-domain variant (i16 code
//!   panels, i64-widened channel reduction) quantized layers serve on.
//! * [`serve`] — micro-batching inference serving: bounded request
//!   queue, model registry, transform-plan cache, latency stats (the
//!   `winoq serve` subsystem).
//! * [`obs`] — the unified observability layer: process-wide metrics
//!   registry (counters/gauges/log-bucketed histograms), request-span
//!   tracing with exact accounting, numeric-health surfacing, and the
//!   one shared JSON writer every `BENCH_*.json` emitter goes through,
//!   plus windowed time-series and shadow-oracle accuracy-drift
//!   monitoring (the training-step CSV logger lives at
//!   [`obs::trainlog`]).
//! * [`tune`] — the per-layer autotuner: sweeps base × tile size ×
//!   Hadamard bit width per conv layer, selects winners under an
//!   accuracy budget, and emits deployable [`tune::NetPlan`] JSON
//!   artifacts that `winoq serve --plan` loads (the `winoq tune`
//!   subsystem).
//! * [`data`] — synthetic CIFAR substitute + prefetching loader.
//! * [`runtime`] — PJRT client running the AOT'd JAX/Pallas artifacts
//!   (stubbed bindings in this vendored build; see `runtime::pjrt_stub`).
//! * [`coordinator`] — the training loop, schedules and experiments.
//! * [`config`], [`cli`], [`testkit`], [`benchkit`] — infrastructure
//!   (no serde/clap/criterion in the vendored set).
//!
//! Start with the repo-level `README.md` for the quickstart and
//! `docs/ARCHITECTURE.md` for the module graph and buffer layouts.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod tune;
pub mod wino;
