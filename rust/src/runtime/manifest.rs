//! Artifact manifest parser — the contract between `python/compile/aot.py`
//! and the rust runtime: parameter names/shapes in canonical order plus
//! batch geometry.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// One parameter's metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed `<tag>.manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub variant: String,
    pub train_batch: usize,
    pub eval_batch: usize,
    /// (C, H, W).
    pub image: (usize, usize, usize),
    pub num_classes: usize,
    /// Parameters in canonical (sorted-name) order.
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        if header.trim() != "winoq-manifest v1" {
            bail!("bad manifest header: {header:?}");
        }
        let mut variant = String::new();
        let mut train_batch = 0;
        let mut eval_batch = 0;
        let mut image = (0, 0, 0);
        let mut num_classes = 0;
        let mut params = Vec::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().context("empty line")?;
            match key {
                "variant" => variant = it.next().context("variant value")?.to_string(),
                "train_batch" => {
                    train_batch = it.next().context("train_batch")?.parse()?
                }
                "eval_batch" => eval_batch = it.next().context("eval_batch")?.parse()?,
                "image" => {
                    let v = it.next().context("image value")?;
                    let dims: Vec<usize> = v
                        .split('x')
                        .map(|d| d.parse())
                        .collect::<Result<_, _>>()?;
                    if dims.len() != 3 {
                        bail!("image must be CxHxW, got {v:?}");
                    }
                    image = (dims[0], dims[1], dims[2]);
                }
                "num_classes" => num_classes = it.next().context("num_classes")?.parse()?,
                "param" => {
                    let name = it.next().context("param name")?.to_string();
                    let shape = it.next().context("param shape")?;
                    let dims = shape
                        .split('x')
                        .map(|d| d.parse())
                        .collect::<Result<Vec<usize>, _>>()?;
                    params.push(ParamSpec { name, dims });
                }
                other => bail!("unknown manifest key {other:?}"),
            }
        }
        if variant.is_empty() || params.is_empty() {
            bail!("manifest missing variant or params");
        }
        // Canonical order is sorted-name: verify so a drifted aot.py fails
        // loudly here instead of silently permuting parameters.
        for w in params.windows(2) {
            if w[0].name >= w[1].name {
                bail!(
                    "manifest params not in canonical sorted order: {} >= {}",
                    w[0].name,
                    w[1].name
                );
            }
        }
        Ok(Manifest { variant, train_batch, eval_batch, image, num_classes, params })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        Self::parse(&text)
    }

    /// Total f32 count across all params (the size of `<tag>.init.bin` / 4).
    pub fn total_param_len(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "winoq-manifest v1\n\
        variant t2-direct-8b-w0.25\n\
        train_batch 32\n\
        eval_batch 100\n\
        image 3x32x32\n\
        num_classes 10\n\
        param a.w 4x3x3x3\n\
        param b.bn.gamma 4\n\
        param fc.w 128x10\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.variant, "t2-direct-8b-w0.25");
        assert_eq!(m.train_batch, 32);
        assert_eq!(m.eval_batch, 100);
        assert_eq!(m.image, (3, 32, 32));
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].dims, vec![4, 3, 3, 3]);
        assert_eq!(m.params[0].len(), 108);
        assert_eq!(m.total_param_len(), 108 + 4 + 1280);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("nope v9\nvariant x\nparam a 1\n").is_err());
    }

    #[test]
    fn rejects_unsorted_params() {
        let bad = "winoq-manifest v1\nvariant v\nparam z.w 1\nparam a.w 1\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_bad_image() {
        let bad = "winoq-manifest v1\nvariant v\nimage 3x32\nparam a.w 1\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn scalar_param_shape() {
        let m = Manifest::parse(
            "winoq-manifest v1\nvariant v\nparam s 1\n",
        )
        .unwrap();
        assert_eq!(m.params[0].len(), 1);
    }
}
