//! Runtime: PJRT client wrapper loading the AOT'd HLO-text artifacts and
//! exposing typed train/eval steps to the coordinator.

pub mod client;
pub mod manifest;

pub use client::{artifacts_dir, list_artifacts, Artifact, StepStats, TrainState};
pub use manifest::{Manifest, ParamSpec};
