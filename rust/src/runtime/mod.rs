//! Runtime: PJRT client wrapper loading the AOT'd HLO-text artifacts
//! (written by `python/compile/aot.py`) and exposing typed train/eval
//! steps to the coordinator.
//!
//! In this vendored build the XLA bindings are provided by
//! [`pjrt_stub`]; see that module for how to swap in a real backend.

pub mod client;
pub mod manifest;
pub mod pjrt_stub;

pub use client::{artifacts_dir, list_artifacts, Artifact, StepStats, TrainState};
pub use manifest::{Manifest, ParamSpec};
