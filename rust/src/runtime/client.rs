//! PJRT runtime: load AOT'd HLO-text artifacts, compile them once on the
//! CPU PJRT client, and expose typed train/eval step calls.
//!
//! Interchange is HLO text (see `python/compile/aot.py`). The executable
//! outputs arrive as a single tuple buffer; we sync it to a literal and
//! decompose — on a CPU client this is a memcpy, well under 10% of step
//! time in past perf passes.
//!
//! The `xla` alias below binds to [`pjrt_stub`](super::pjrt_stub) in this
//! vendored build; point it at the real `xla-rs` crate to enable PJRT.

use super::manifest::Manifest;
use super::pjrt_stub as xla;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A compiled model variant: manifest + train/eval executables.
pub struct Artifact {
    pub tag: String,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    train_exe: Option<xla::PjRtLoadedExecutable>,
    eval_exe: Option<xla::PjRtLoadedExecutable>,
}

/// Training state: parameter + momentum literals in canonical order.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub momentum: Vec<xla::Literal>,
    pub step: u64,
}

/// One train-step result.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 path")?,
    )
    .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))
}

fn f32_literal(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal size mismatch: dims {dims:?} vs {} values", data.len());
    }
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

fn i32_literal(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

impl Artifact {
    /// Load `<dir>/<tag>.{manifest.txt, train.hlo.txt, eval.hlo.txt}` and
    /// compile the step functions. Missing step files are tolerated (e.g.
    /// eval-only use); calling the corresponding step then errors.
    pub fn load(dir: &Path, tag: &str) -> Result<Artifact> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Self::load_with_client(dir, tag, client)
    }

    pub fn load_with_client(
        dir: &Path,
        tag: &str,
        client: xla::PjRtClient,
    ) -> Result<Artifact> {
        let manifest = Manifest::load(&dir.join(format!("{tag}.manifest.txt")))?;
        let train_path = dir.join(format!("{tag}.train.hlo.txt"));
        let eval_path = dir.join(format!("{tag}.eval.hlo.txt"));
        let train_exe = if train_path.exists() {
            Some(compile(&client, &train_path)?)
        } else {
            None
        };
        let eval_exe = if eval_path.exists() {
            Some(compile(&client, &eval_path)?)
        } else {
            None
        };
        if train_exe.is_none() && eval_exe.is_none() {
            bail!("artifact {tag}: no train or eval HLO found in {dir:?}");
        }
        Ok(Artifact { tag: tag.to_string(), manifest, client, train_exe, eval_exe })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Initial training state from `<dir>/<tag>.init.bin` (flat f32 LE in
    /// canonical order; momentum zero-filled).
    pub fn init_state(&self, dir: &Path) -> Result<TrainState> {
        let path = dir.join(format!("{}.init.bin", self.tag));
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        self.state_from_bytes(&bytes)
    }

    /// Build a state from a flat f32-LE parameter blob (init or checkpoint).
    pub fn state_from_bytes(&self, bytes: &[u8]) -> Result<TrainState> {
        let want = self.manifest.total_param_len() * 4;
        if bytes.len() != want {
            bail!(
                "param blob is {} bytes, manifest wants {want} ({} f32)",
                bytes.len(),
                self.manifest.total_param_len()
            );
        }
        let mut params = Vec::with_capacity(self.manifest.params.len());
        let mut momentum = Vec::with_capacity(self.manifest.params.len());
        let mut off = 0usize;
        for spec in &self.manifest.params {
            let n = spec.len();
            let mut vals = vec![0f32; n];
            for (i, v) in vals.iter_mut().enumerate() {
                let b = off + i * 4;
                *v = f32::from_le_bytes([bytes[b], bytes[b + 1], bytes[b + 2], bytes[b + 3]]);
            }
            off += n * 4;
            params.push(f32_literal(&spec.dims, &vals)?);
            momentum.push(f32_literal(&spec.dims, &vec![0f32; n])?);
        }
        Ok(TrainState { params, momentum, step: 0 })
    }

    /// Serialize the current parameters back to the flat blob format
    /// (checkpointing; momentum is not persisted, matching init semantics).
    pub fn state_to_bytes(&self, state: &TrainState) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.manifest.total_param_len() * 4);
        for (lit, spec) in state.params.iter().zip(&self.manifest.params) {
            let vals: Vec<f32> = lit
                .to_vec()
                .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))?;
            if vals.len() != spec.len() {
                bail!("param {} has {} values, want {}", spec.name, vals.len(), spec.len());
            }
            for v in vals {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// One SGD step. `images` is NCHW f32 (train_batch), `labels` i32.
    /// Advances `state` in place and returns (loss, acc).
    pub fn train_step(
        &self,
        state: &mut TrainState,
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<StepStats> {
        let exe = self.train_exe.as_ref().context("artifact has no train step")?;
        let m = &self.manifest;
        let (c, h, w) = m.image;
        if images.len() != m.train_batch * c * h * w {
            bail!("train images: got {} values", images.len());
        }
        if labels.len() != m.train_batch {
            bail!("train labels: got {}", labels.len());
        }
        let np = m.params.len();
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(2 * np + 3);
        inputs.extend(state.params.iter());
        inputs.extend(state.momentum.iter());
        let img_lit = f32_literal(&[m.train_batch, c, h, w], images)?;
        let lab_lit = i32_literal(&[m.train_batch], labels)?;
        let lr_lit = xla::Literal::scalar(lr);
        inputs.push(&img_lit);
        inputs.push(&lab_lit);
        inputs.push(&lr_lit);
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("train execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != 2 * np + 2 {
            bail!("train step returned {} outputs, want {}", parts.len(), 2 * np + 2);
        }
        let acc_lit = parts.pop().unwrap();
        let loss_lit = parts.pop().unwrap();
        let momentum = parts.split_off(np);
        state.params = parts;
        state.momentum = momentum;
        state.step += 1;
        Ok(StepStats {
            loss: loss_lit
                .get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?,
            acc: acc_lit
                .get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("acc: {e:?}"))?,
        })
    }

    /// Evaluate one batch: returns (mean nll, #correct).
    pub fn eval_step(
        &self,
        state: &TrainState,
        images: &[f32],
        labels: &[i32],
    ) -> Result<(f32, i32)> {
        let exe = self.eval_exe.as_ref().context("artifact has no eval step")?;
        let m = &self.manifest;
        let (c, h, w) = m.image;
        if images.len() != m.eval_batch * c * h * w {
            bail!("eval images: got {} values", images.len());
        }
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(m.params.len() + 2);
        inputs.extend(state.params.iter());
        let img_lit = f32_literal(&[m.eval_batch, c, h, w], images)?;
        let lab_lit = i32_literal(&[m.eval_batch], labels)?;
        inputs.push(&img_lit);
        inputs.push(&lab_lit);
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("eval execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        let (loss_lit, correct_lit) = tuple
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("to_tuple2: {e:?}"))?;
        Ok((
            loss_lit
                .get_first_element::<f32>()
                .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?,
            correct_lit
                .get_first_element::<i32>()
                .map_err(|e| anyhow::anyhow!("correct: {e:?}"))?,
        ))
    }
}

/// List artifact tags present in a directory (any `<tag>.manifest.txt`).
pub fn list_artifacts(dir: &Path) -> Result<Vec<String>> {
    let mut tags = Vec::new();
    if !dir.exists() {
        return Ok(tags);
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(tag) = name.strip_suffix(".manifest.txt") {
            tags.push(tag.to_string());
        }
    }
    tags.sort();
    Ok(tags)
}

/// Default artifacts directory: `$WINOQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("WINOQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
