//! Build-time stub for the `xla` (PJRT) bindings.
//!
//! The vendored build environment has no crates.io/network access, so the
//! real `xla-rs` crate cannot be declared in `Cargo.toml`. This module
//! mirrors exactly the slice of its API that [`runtime::client`] uses:
//!
//! * [`Literal`] is **fully functional** — an in-memory typed buffer with
//!   reshape/tuple/scalar accessors, so checkpoint (de)serialisation and
//!   manifest plumbing stay testable without a PJRT backend;
//! * [`PjRtClient`], [`HloModuleProto`] and the compile/execute surface
//!   return a descriptive [`XlaError`], so anything that would actually
//!   need XLA fails fast with a clear message instead of at link time.
//!
//! To wire a real backend, change the `use crate::runtime::pjrt_stub as
//! xla;` alias in `runtime/client.rs` to `use xla;` and add the binding
//! crate to `Cargo.toml`; no other code changes are needed.
//!
//! [`runtime::client`]: super::client

/// Error type standing in for `xla::Error`. Only ever formatted with
/// `{:?}`, matching how `runtime::client` reports backend failures.
pub struct XlaError(pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT backend unavailable (built with runtime::pjrt_stub; \
         the xla-rs bindings are not vendored in this environment)"
    ))
}

/// Element types a [`Literal`] can hold.
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Marker trait for native element types (`f32`, `i32`).
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// In-memory typed buffer mirroring `xla::Literal`.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    pub dims: Vec<i64>,
    pub data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 (scalar) f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: LiteralData::F32(vec![v]) }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Reshape without moving data; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape to {dims:?} ({want} elements) from {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy the contents out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| XlaError("to_vec: element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self.data {
            LiteralData::Tuple(parts) => Ok(parts),
            _ => Err(XlaError("to_tuple: literal is not a tuple".into())),
        }
    }

    /// Decompose a 2-tuple literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal), XlaError> {
        let mut parts = self.to_tuple()?;
        if parts.len() != 2 {
            return Err(XlaError(format!("to_tuple2: arity {}", parts.len())));
        }
        let b = parts.pop().unwrap();
        let a = parts.pop().unwrap();
        Ok((a, b))
    }

    /// First element of a non-empty typed literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        T::unwrap(&self.data)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| XlaError("get_first_element: empty or mistyped".into()))
    }
}

/// Parsed HLO module handle (stub: construction always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

/// Computation handle wrapping a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer (stub — never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub — never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: `cpu()` always fails with a clear message).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let shaped = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(shaped.dims, vec![2, 2]);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(shaped.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn literal_type_mismatch() {
        let lit = Literal::vec1(&[1i32, 2]);
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn reshape_size_checked() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal {
            dims: vec![],
            data: LiteralData::Tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]),
        };
        let (a, b) = t.to_tuple2().unwrap();
        assert_eq!(a.get_first_element::<f32>().unwrap(), 1.0);
        assert_eq!(b.get_first_element::<f32>().unwrap(), 2.0);
    }

    #[test]
    fn backend_calls_fail_with_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("pjrt_stub"));
    }
}
