//! NetPlan — the versioned, deployable artifact the autotuner emits: one
//! Winograd operating point `(m, base, bit widths)` per conv layer, plus
//! everything a server needs to rebuild the exact same network
//! (parameter seed, width, calibration recipe).
//!
//! `winoq tune` writes one (`NetPlan::save`), `winoq serve --plan` loads
//! it (`NetPlan::load`) and the registry builds a **heterogeneous**
//! per-layer-engine network from it
//! (`serve::registry::ModelRegistry::register_netplan`). The format is
//! plain JSON with an explicit `netplan_version` so older servers reject
//! newer plans loudly instead of misreading them; layers absent from the
//! plan run direct convolution.
//!
//! **v2** records the tuner's measured acceptance point per layer —
//! `tuned_err` (rel-L2 vs the f64 direct oracle) and
//! `tuned_tiles_per_sec` — so the drift monitor
//! ([`obs::drift`](crate::obs::drift)) checks live traffic against the
//! budget the tuner actually accepted, and `winoq benchdiff` has a
//! committed perf anchor. v1 artifacts (no tuned fields) still load;
//! drift checks on them degrade to report-only.

use super::json::{self, escape, Json};
use crate::obs::json::JsonObj;
use crate::quant::scheme::QuantConfig;
use crate::wino::basis::Base;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The NetPlan schema version this build writes. Versions `1..=2` are
/// accepted on load; anything newer is rejected loudly.
pub const NETPLAN_VERSION: u64 = 2;

/// Tile sizes the tuner grid sweeps (and a loaded plan may use).
pub const SUPPORTED_M: [usize; 3] = [2, 4, 6];

/// One conv layer's chosen operating point.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    /// Conv-unit prefix, e.g. `"stem"` or `"s2b1.conv2"`.
    pub layer: String,
    /// Winograd output tile size `m` (kernel is always 3×3 here).
    pub m: usize,
    pub base: Base,
    /// Full per-stage bit widths (the tuner varies `hadamard_bits`; the
    /// rest are recorded explicitly so future grids can widen the sweep
    /// without a schema change).
    pub quant: QuantConfig,
    /// v2: rel-L2 error (vs the f64 direct oracle) the tuner measured
    /// when it accepted this operating point — the drift monitor's
    /// per-layer budget anchor. `None` on v1 artifacts (report-only).
    pub tuned_err: Option<f64>,
    /// v2: Winograd tiles/sec the tuner measured for this operating
    /// point — `winoq benchdiff`'s committed perf anchor. `None` on v1.
    pub tuned_tiles_per_sec: Option<f64>,
}

/// A tuned network: per-layer operating points + reconstruction recipe.
#[derive(Clone, Debug, PartialEq)]
pub struct NetPlan {
    pub version: u64,
    /// Model family tag; `"resnet18-synthetic"` is the only source today.
    pub model: String,
    pub width_mult: f32,
    pub num_classes: usize,
    /// Square input size (synthetic CIFAR = 32).
    pub image_hw: usize,
    /// Parameter seed (synthetic source) — pins the exact weights.
    pub seed: u64,
    /// Calibration recipe: batch size and activation percentile, so a
    /// server reproduces the tuner's quantizer scales bit-for-bit.
    pub calib_batch: usize,
    pub calib_pct: f64,
    pub layers: Vec<LayerPlan>,
}

impl NetPlan {
    /// The plan entry for a conv-unit prefix, if tuned.
    pub fn layer(&self, prefix: &str) -> Option<&LayerPlan> {
        self.layers.iter().find(|l| l.layer == prefix)
    }

    /// The modal `(m, base, quant)` across layers — the nominal label a
    /// heterogeneous network carries in its `ConvMode` (reporting only).
    pub fn nominal(&self) -> Option<(usize, Base, QuantConfig)> {
        let mut best: Option<(usize, Base, QuantConfig)> = None;
        let mut best_count = 0;
        for l in &self.layers {
            let key = (l.m, l.base, l.quant);
            let count = self
                .layers
                .iter()
                .filter(|o| (o.m, o.base, o.quant) == key)
                .count();
            if count > best_count {
                best_count = count;
                best = Some(key);
            }
        }
        best
    }

    /// Serialize to the versioned JSON artifact (stable key order).
    pub fn to_json(&self) -> String {
        let mut out = format!(
            concat!(
                "{{\n  \"netplan_version\": {},\n  \"model\": \"{}\",\n",
                "  \"width_mult\": {},\n  \"num_classes\": {},\n",
                "  \"image_hw\": {},\n  \"seed\": {},\n",
                "  \"calib\": {{\"batch\": {}, \"pct\": {}}},\n  \"layers\": [\n"
            ),
            self.version,
            escape(&self.model),
            self.width_mult,
            self.num_classes,
            self.image_hw,
            self.seed,
            self.calib_batch,
            self.calib_pct,
        );
        for (i, l) in self.layers.iter().enumerate() {
            let mut obj = JsonObj::new()
                .str("layer", &l.layer)
                .u64("m", l.m as u64)
                .str("base", l.base.name())
                .u64("act_bits", u64::from(l.quant.act_bits))
                .u64("weight_bits", u64::from(l.quant.weight_bits))
                .u64("hadamard_bits", u64::from(l.quant.hadamard_bits))
                .u64("out_bits", u64::from(l.quant.out_bits));
            // v2 tuned anchors: emitted via `{}` (shortest exact f64
            // representation) so save→load is lossless.
            if let Some(e) = l.tuned_err {
                obj = obj.raw("tuned_err", &e.to_string());
            }
            if let Some(t) = l.tuned_tiles_per_sec {
                obj = obj.raw("tuned_tiles_per_sec", &t.to_string());
            }
            let line = obj.finish();
            let sep = if i + 1 == self.layers.len() { "" } else { "," };
            out.push_str(&format!("    {line}{sep}\n"));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse and validate a NetPlan JSON document.
    pub fn from_json(text: &str) -> Result<NetPlan> {
        let doc = json::parse(text).context("parsing NetPlan JSON")?;
        let version = doc
            .get("netplan_version")
            .and_then(Json::as_u64)
            .context("NetPlan is missing netplan_version")?;
        if !(1..=NETPLAN_VERSION).contains(&version) {
            bail!(
                "NetPlan version {version} is not supported (this build reads v1..=v{NETPLAN_VERSION})"
            );
        }
        let calib = member(&doc, "calib", "NetPlan")?;
        let calib_batch = calib
            .get("batch")
            .and_then(Json::as_u64)
            .context("NetPlan calib.batch must be a non-negative integer")?
            as usize;
        let calib_pct = calib
            .get("pct")
            .and_then(Json::as_f64)
            .context("NetPlan calib.pct must be a number")?;
        if !(calib_pct > 0.0 && calib_pct <= 100.0) {
            bail!("NetPlan calib.pct {calib_pct} out of (0, 100]");
        }
        let mut layers: Vec<LayerPlan> = Vec::new();
        for (i, l) in member(&doc, "layers", "NetPlan")?
            .as_arr()
            .context("NetPlan layers must be an array")?
            .iter()
            .enumerate()
        {
            let what = format!("NetPlan layer {i}");
            let m = member(l, "m", &what)?
                .as_u64()
                .with_context(|| format!("{what} m must be an integer"))?
                as usize;
            if !SUPPORTED_M.contains(&m) {
                bail!("{what} m = {m} not in the supported set {SUPPORTED_M:?}");
            }
            let base_name = member(l, "base", &what)?
                .as_str()
                .with_context(|| format!("{what} base must be a string"))?;
            let base = Base::from_name(base_name).with_context(|| {
                format!(
                    "{what} has unknown base {base_name:?} (valid: {})",
                    Base::names()
                )
            })?;
            let layer = member(l, "layer", &what)?
                .as_str()
                .with_context(|| format!("{what} layer must be a string"))?
                .to_string();
            if layers.iter().any(|p| p.layer == layer) {
                bail!("NetPlan names layer {layer:?} twice");
            }
            layers.push(LayerPlan {
                layer,
                m,
                base,
                quant: QuantConfig {
                    act_bits: bits(l, "act_bits", &what)?,
                    weight_bits: bits(l, "weight_bits", &what)?,
                    hadamard_bits: bits(l, "hadamard_bits", &what)?,
                    out_bits: bits(l, "out_bits", &what)?,
                },
                tuned_err: tuned(l, "tuned_err", &what, 0.0)?,
                tuned_tiles_per_sec: tuned(l, "tuned_tiles_per_sec", &what, f64::MIN_POSITIVE)?,
            });
        }
        let width_mult = member(&doc, "width_mult", "NetPlan")?
            .as_f64()
            .context("NetPlan width_mult must be a number")?;
        if !(width_mult > 0.0 && width_mult.is_finite()) {
            bail!("NetPlan width_mult {width_mult} must be a positive finite number");
        }
        Ok(NetPlan {
            version,
            model: member(&doc, "model", "NetPlan")?
                .as_str()
                .context("NetPlan model must be a string")?
                .to_string(),
            width_mult: width_mult as f32,
            num_classes: uint(&doc, "num_classes")? as usize,
            image_hw: uint(&doc, "image_hw")? as usize,
            seed: uint(&doc, "seed")?,
            calib_batch,
            calib_pct,
            layers,
        })
    }

    /// Write the artifact to disk. Refuses a seed at or above 2⁵³ — the
    /// JSON reader's exact-integer limit — so a plan can never emit an
    /// artifact it (or a server) cannot reload.
    pub fn save(&self, path: &Path) -> Result<()> {
        if self.seed >= (1u64 << 53) {
            bail!(
                "NetPlan seed {} exceeds the JSON exact-integer limit (2^53) and \
                 could not be reloaded; pick a smaller seed",
                self.seed
            );
        }
        for l in &self.layers {
            for (key, v) in [
                ("tuned_err", l.tuned_err),
                ("tuned_tiles_per_sec", l.tuned_tiles_per_sec),
            ] {
                if let Some(v) = v {
                    if !v.is_finite() {
                        bail!(
                            "NetPlan layer {:?} {key} = {v} is not finite and could \
                             not be reloaded",
                            l.layer
                        );
                    }
                }
            }
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing NetPlan {path:?}"))
    }

    /// Load and validate an artifact from disk.
    pub fn load(path: &Path) -> Result<NetPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading NetPlan {path:?}"))?;
        Self::from_json(&text).with_context(|| format!("in NetPlan {path:?}"))
    }
}

/// Required-member lookup with a contextual error.
fn member<'a>(doc: &'a Json, key: &str, what: &str) -> Result<&'a Json> {
    doc.get(key)
        .with_context(|| format!("{what} is missing {key:?}"))
}

/// Required non-negative integer member of the top-level document.
fn uint(doc: &Json, key: &str) -> Result<u64> {
    member(doc, key, "NetPlan")?
        .as_u64()
        .with_context(|| format!("NetPlan {key:?} must be a non-negative integer"))
}

/// Optional v2 tuned-anchor member: absent is `None`; present must be a
/// finite number `>= floor` or the whole plan is rejected.
fn tuned(l: &Json, key: &str, what: &str, floor: f64) -> Result<Option<f64>> {
    let Some(j) = l.get(key) else { return Ok(None) };
    let v = j
        .as_f64()
        .with_context(|| format!("{what} {key} must be a number"))?;
    if !(v.is_finite() && v >= floor) {
        bail!("{what} {key} = {v} must be a finite number >= {floor:e}");
    }
    Ok(Some(v))
}

/// Required bit-width member, range-checked to the quantizer's 2..=24.
fn bits(l: &Json, key: &str, what: &str) -> Result<u32> {
    let b = member(l, key, what)?
        .as_u64()
        .with_context(|| format!("{what} {key:?} must be an integer"))?;
    if !(2..=24).contains(&b) {
        bail!("{what} {key} = {b} out of the supported 2..=24");
    }
    Ok(b as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> NetPlan {
        NetPlan {
            version: NETPLAN_VERSION,
            model: "resnet18-synthetic".into(),
            width_mult: 0.25,
            num_classes: 10,
            image_hw: 32,
            seed: 7,
            calib_batch: 4,
            calib_pct: 99.5,
            layers: vec![
                LayerPlan {
                    layer: "stem".into(),
                    m: 4,
                    base: Base::Legendre,
                    quant: QuantConfig::w8_h9(),
                    tuned_err: Some(0.0025),
                    tuned_tiles_per_sec: Some(1250000.0),
                },
                LayerPlan {
                    layer: "s0b0.conv1".into(),
                    m: 6,
                    base: Base::Canonical,
                    quant: QuantConfig::w8(),
                    tuned_err: Some(0.004),
                    tuned_tiles_per_sec: Some(987654.5),
                },
                // One untuned layer: the optional fields stay optional
                // even inside a v2 artifact.
                LayerPlan {
                    layer: "s0b0.conv2".into(),
                    m: 4,
                    base: Base::Legendre,
                    quant: QuantConfig::w8_h9(),
                    tuned_err: None,
                    tuned_tiles_per_sec: None,
                },
            ],
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let plan = sample();
        let reloaded = NetPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, reloaded);
    }

    #[test]
    fn lookup_and_nominal() {
        let plan = sample();
        assert_eq!(plan.layer("s0b0.conv1").unwrap().m, 6);
        assert!(plan.layer("absent").is_none());
        // Two of three layers run (4, Legendre, w8_h9) — the modal label.
        assert_eq!(
            plan.nominal(),
            Some((4, Base::Legendre, QuantConfig::w8_h9()))
        );
    }

    #[test]
    fn v1_artifacts_without_tuned_fields_still_load() {
        let mut v1 = sample();
        v1.version = 1;
        for l in &mut v1.layers {
            l.tuned_err = None;
            l.tuned_tiles_per_sec = None;
        }
        let text = v1.to_json();
        assert!(text.contains("\"netplan_version\": 1"));
        assert!(!text.contains("tuned_err"));
        let loaded = NetPlan::from_json(&text).unwrap();
        assert_eq!(loaded, v1);
        assert!(loaded.layers.iter().all(|l| l.tuned_err.is_none()));
        // And the reloaded v1 plan re-serialises byte-identically.
        assert_eq!(loaded.to_json(), text);
    }

    #[test]
    fn rejects_future_versions_and_bad_fields() {
        let plan = sample();
        let bumped = plan.to_json().replace(
            "\"netplan_version\": 2",
            "\"netplan_version\": 99",
        );
        let err = NetPlan::from_json(&bumped).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");

        // v2 tuned-field domain violations are always errors.
        for (from, to) in [
            ("\"tuned_err\": 0.0025", "\"tuned_err\": -0.5"),
            ("\"tuned_err\": 0.0025", "\"tuned_err\": \"small\""),
            ("\"tuned_tiles_per_sec\": 1250000", "\"tuned_tiles_per_sec\": 0"),
            ("\"tuned_tiles_per_sec\": 1250000", "\"tuned_tiles_per_sec\": -3"),
        ] {
            let bad = plan.to_json().replace(from, to);
            assert_ne!(bad, plan.to_json(), "replace {from:?} matched nothing");
            assert!(NetPlan::from_json(&bad).is_err(), "{to} must be rejected");
        }

        let bad_m = plan.to_json().replace("\"m\": 6", "\"m\": 5");
        assert!(NetPlan::from_json(&bad_m).is_err(), "m=5 must be rejected");

        let bad_base = plan.to_json().replace("\"canonical\"", "\"hermite\"");
        let err = NetPlan::from_json(&bad_base).unwrap_err();
        assert!(format!("{err:#}").contains("hermite"), "{err:#}");

        let dup = plan
            .to_json()
            .replace("\"layer\": \"s0b0.conv2\"", "\"layer\": \"stem\"");
        assert!(NetPlan::from_json(&dup).is_err(), "duplicate layer must be rejected");
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("winoq-netplan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = sample();
        plan.save(&path).unwrap();
        assert_eq!(NetPlan::load(&path).unwrap(), plan);
        // A seed the JSON reader could not reload must be refused at
        // write time, not discovered at serve time.
        let mut unrepresentable = sample();
        unrepresentable.seed = 1u64 << 53;
        let err = unrepresentable.save(&path).unwrap_err();
        assert!(err.to_string().contains("2^53"), "{err}");
        // Same contract for the v2 tuned anchors: a NaN budget would
        // reload as an error, so it must be refused at write time.
        let mut nan_budget = sample();
        nan_budget.layers[0].tuned_err = Some(f64::NAN);
        let err = nan_budget.save(&path).unwrap_err();
        assert!(err.to_string().contains("not finite"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
