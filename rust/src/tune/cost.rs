//! The tuner's cost model: what one `(layer, candidate)` cell costs in
//! quantized numerical error and what it buys in throughput.
//!
//! * **Error** — the candidate layer is quantized exactly the way serving
//!   quantizes it ([`WinoConv2d::quantize_pct`] on the layer's real
//!   captured activations, Fig. 2 cast sites) and its forward output is
//!   compared against an **f64 direct-convolution oracle** (same
//!   convolution, no Winograd, no quantization — the reference
//!   `wino::error` measures tiles against, lifted to NCHW layers). The
//!   statistic is relative L2 over the whole output tensor.
//! * **Throughput** — short timed [`benchkit`] runs of the lowered
//!   engine's forward pass. Two units are reported: `tiles_per_sec` in
//!   the candidate's **own** tile grid (the serving-stats unit) and
//!   `outputs_per_sec` (output pixels × filters per second), which is
//!   invariant to `m` and therefore the unit candidate selection
//!   compares across tile sizes.
//!
//! Both measurements run `WinoConv2d::forward*`, i.e. the **serving
//! dispatch**: every grid candidate (8-bit codes, so all of them) scores
//! the real integer-domain engine
//! ([`IntWinoEngine`](crate::engine::int::IntWinoEngine)) — the path a
//! NetPlan deploys — not the fake-quant float pipeline
//! (`int_path_is_what_gets_scored` pins this). That dispatch executes
//! the register-tiled panel GEMM ([`engine::gemm`](crate::engine::gemm))
//! over pre-packed weight codes, so the throughput the tuner trades off
//! against error is the micro-kernel path serving actually runs — a
//! candidate's tile-size cost reflects the tiled kernel's behaviour at
//! that layer's `(C, K, T, N²)`, not a naive loop's.

use super::grid::Candidate;
use crate::benchkit;
use crate::engine::EngineScratch;
use crate::nn::layers::Conv2dCfg;
use crate::nn::tensor::Tensor;
use crate::nn::winolayer::WinoConv2d;
use crate::wino::matrix::Mat;
use crate::wino::transform::WinoF;

/// Affine batch-cost predictor the SLO scheduler consults: a dispatched
/// batch of `t` tiles is predicted to take `fixed_us + per_tile_us · t`
/// microseconds. `fixed_us` absorbs per-batch overhead (stacking,
/// dispatch, response fan-out); `per_tile_us` is the marginal tile cost,
/// the inverse of the `tiles_per_sec` the tuner measures per candidate.
///
/// The serving layer treats this as a *deadline oracle*: a batch may
/// only close at time `t` if `t + predict_us(batch tiles)` is at or
/// before every member's deadline, and a request whose **solo** predicted
/// cost already overruns its deadline is shed instead of admitted to a
/// batch (see [`serve::sched`](crate::serve::sched)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileCostModel {
    /// Per-batch fixed overhead in microseconds.
    pub fixed_us: f64,
    /// Marginal cost per Winograd tile in microseconds.
    pub per_tile_us: f64,
}

impl TileCostModel {
    /// Build a predictor from its two coefficients (clamped to ≥ 0).
    pub fn new(fixed_us: f64, per_tile_us: f64) -> TileCostModel {
        TileCostModel {
            fixed_us: fixed_us.max(0.0),
            per_tile_us: per_tile_us.max(0.0),
        }
    }

    /// Predicted wall-µs for a batch totalling `tiles` Winograd tiles,
    /// rounded up so a nonzero prediction is never flattened to 0 by
    /// integer truncation (the scheduler compares µs timestamps).
    pub fn predict_us(&self, tiles: u64) -> u64 {
        (self.fixed_us + self.per_tile_us * tiles as f64).ceil() as u64
    }

    /// Least-squares fit of `(tiles, measured_us)` samples — how a
    /// deployment turns tuner bench output into a serving cost model.
    /// Coefficients are clamped to ≥ 0 (a negative marginal tile cost is
    /// measurement noise, not physics). Needs ≥ 2 distinct tile counts;
    /// degenerate inputs fall back to a flat mean-cost model.
    pub fn fit(samples: &[(u64, f64)]) -> TileCostModel {
        let n = samples.len() as f64;
        if samples.is_empty() {
            return TileCostModel::new(0.0, 0.0);
        }
        let mean_x = samples.iter().map(|&(t, _)| t as f64).sum::<f64>() / n;
        let mean_y = samples.iter().map(|&(_, us)| us).sum::<f64>() / n;
        let mut sxx = 0.0;
        let mut sxy = 0.0;
        for &(t, us) in samples {
            let dx = t as f64 - mean_x;
            sxx += dx * dx;
            sxy += dx * (us - mean_y);
        }
        if sxx <= 0.0 {
            return TileCostModel::new(mean_y, 0.0);
        }
        let slope = (sxy / sxx).max(0.0);
        TileCostModel::new(mean_y - slope * mean_x, slope)
    }
}

/// Measurement knobs (small by default — tuning is offline but should
/// not take minutes per layer).
#[derive(Clone, Copy, Debug)]
pub struct CostOpts {
    /// Images (from the captured activation batch) the error statistic
    /// averages over.
    pub err_images: usize,
    /// Images per timed forward pass.
    pub bench_images: usize,
    /// Untimed warmup passes.
    pub bench_warmup: usize,
    /// Timed samples (median is reported).
    pub bench_samples: usize,
    /// Activation calibration percentile (see `Quantizer::calibrate_percentile`).
    pub calib_pct: f64,
}

impl Default for CostOpts {
    fn default() -> CostOpts {
        CostOpts {
            err_images: 2,
            bench_images: 2,
            bench_warmup: 1,
            bench_samples: 3,
            calib_pct: 100.0,
        }
    }
}

/// One measured `(layer, candidate)` cell.
#[derive(Clone, Copy, Debug)]
pub struct Measure {
    /// Relative L2 error of the quantized candidate vs the f64 direct
    /// oracle over the error subset.
    pub err: f64,
    /// Median seconds per timed forward pass (`bench_images` images).
    pub seconds: f64,
    /// Winograd tiles per second in the candidate's own grid.
    pub tiles_per_sec: f64,
    /// Output elements (pixels × filters × images) per second —
    /// comparable across tile sizes.
    pub outputs_per_sec: f64,
}

/// First `images` items of an NCHW batch (or the whole batch if smaller).
fn head_images(x: &Tensor, images: usize) -> Tensor {
    let b = x.dims[0].min(images.max(1));
    let item: usize = x.dims[1..].iter().product();
    let mut dims = x.dims.clone();
    dims[0] = b;
    Tensor::from_vec(&dims, x.data[..b * item].to_vec())
}

/// Plain f64 direct correlation of `x` `[N,C,H,W]` (padded by `padding`)
/// against `w` `[K,C,3,3]` — the oracle quantized candidates are scored
/// against. Everything accumulates in f64; the (f32) inputs are lifted
/// exactly.
pub fn direct_conv_f64(x: &Tensor, w: &Tensor, padding: usize) -> Vec<f64> {
    let (bn, c, h, wid) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (k, wc, r, s) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    assert_eq!(c, wc, "channel mismatch");
    assert_eq!(r, s, "square kernels only");
    let (ph, pw) = (h + 2 * padding, wid + 2 * padding);
    let (oh, ow) = (ph - r + 1, pw - r + 1);
    let mut out = vec![0.0f64; bn * k * oh * ow];
    let at = |ni: usize, ci: usize, i: isize, j: isize| -> f64 {
        let (i, j) = (i - padding as isize, j - padding as isize);
        if i < 0 || j < 0 || i as usize >= h || j as usize >= wid {
            0.0
        } else {
            x.at4(ni, ci, i as usize, j as usize) as f64
        }
    };
    for ni in 0..bn {
        for ki in 0..k {
            let plane = &mut out[(ni * k + ki) * oh * ow..][..oh * ow];
            for ci in 0..c {
                for a in 0..r {
                    for b in 0..r {
                        let wv = w.at4(ki, ci, a, b) as f64;
                        if wv == 0.0 {
                            continue;
                        }
                        for oi in 0..oh {
                            for oj in 0..ow {
                                plane[oi * ow + oj] +=
                                    wv * at(ni, ci, (oi + a) as isize, (oj + b) as isize);
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Relative L2 distance between an f32 output and the f64 oracle.
pub fn rel_l2(got: &[f32], oracle: &[f64]) -> f64 {
    assert_eq!(got.len(), oracle.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (&g, &o) in got.iter().zip(oracle) {
        let d = g as f64 - o;
        num += d * d;
        den += o * o;
    }
    (num / den.max(1e-300)).sqrt()
}

/// Measure one candidate on one layer: lower the layer from the shared
/// `wf` and pre-transformed float weight `bank` (both must match the
/// candidate's `(m, base)` — candidates differing only in bit width
/// share them, halving the sweep's transform cost), quantize it on the
/// full captured activation batch with the candidate's bit config, score
/// error on the first `err_images`, and time the engine forward. `weights`
/// is the raw `[K,C,3,3]` tensor, needed for the direct oracle.
pub fn measure_candidate(
    wf: &WinoF,
    bank: &[Vec<Mat>],
    cand: Candidate,
    weights: &Tensor,
    acts: &Tensor,
    opts: &CostOpts,
) -> Measure {
    assert_eq!(wf.m, cand.m, "plan/candidate tile mismatch");
    assert_eq!(wf.base, cand.base, "plan/candidate base mismatch");
    let conv = Conv2dCfg { stride: 1, padding: 1 };
    // quantize_pct bakes the weight cast into the stored bank, so each
    // candidate gets its own copy of the shared float bank.
    let mut layer = WinoConv2d::from_transformed(wf.clone(), bank.to_vec());
    layer.quantize_pct(cand.quant(), acts, 1, opts.calib_pct);

    // Error vs the f64 direct oracle.
    let err_x = head_images(acts, opts.err_images);
    let got = layer.forward(&err_x, conv);
    let oracle = direct_conv_f64(&err_x, weights, 1);
    let err = rel_l2(&got.data, &oracle);

    // Throughput: short engine runs through benchkit.
    let bench_x = head_images(acts, opts.bench_images);
    let mut scratch = EngineScratch::new();
    let summary = benchkit::bench(opts.bench_warmup, opts.bench_samples.max(1), || {
        layer.forward_with_scratch(&bench_x, conv, &mut scratch)
    });
    let tiles = layer.engine().tile_count_for(&bench_x.dims, 1);
    let k = weights.dims[0];
    let outputs = bench_x.dims[0] * k * bench_x.dims[2] * bench_x.dims[3];
    Measure {
        err,
        seconds: summary.median,
        tiles_per_sec: tiles as f64 / summary.median.max(1e-12),
        outputs_per_sec: outputs as f64 / summary.median.max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::conv2d;
    use crate::testkit::prng_tensor;
    use crate::wino::basis::Base;
    use crate::wino::toomcook::WinogradPlan;

    #[test]
    fn f64_oracle_matches_f32_direct_conv() {
        let x = prng_tensor(51, &[2, 3, 9, 9], 1.0);
        let w = prng_tensor(52, &[4, 3, 3, 3], 0.5);
        for padding in [0usize, 1] {
            let oracle = direct_conv_f64(&x, &w, padding);
            let direct = conv2d(&x, &w, None, Conv2dCfg { stride: 1, padding });
            assert_eq!(oracle.len(), direct.data.len());
            for (o, d) in oracle.iter().zip(&direct.data) {
                assert!((o - *d as f64).abs() < 1e-4, "{o} vs {d}");
            }
        }
    }

    #[test]
    fn head_images_slices_the_batch() {
        let x = prng_tensor(53, &[3, 2, 4, 4], 1.0);
        let h = head_images(&x, 2);
        assert_eq!(h.dims, vec![2, 2, 4, 4]);
        assert_eq!(h.data[..], x.data[..2 * 2 * 16]);
        assert_eq!(head_images(&x, 10).dims[0], 3);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let e = rel_l2(&[1.1, 2.0], &[1.0, 2.0]);
        assert!((e - (0.01f64 / 5.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn int_path_is_what_gets_scored() {
        // A quantized candidate layer must carry a lowered integer engine
        // and forward through it — the tuner's numbers describe the path
        // the NetPlan will actually serve.
        use crate::engine::transform_weight_bank;
        let acts = prng_tensor(61, &[1, 3, 10, 10], 1.0);
        let w = prng_tensor(62, &[3, 3, 3, 3], 0.4);
        let wf = WinoF::new(&WinogradPlan::new(4, 3), Base::Canonical);
        let bank = transform_weight_bank(&wf, &w);
        let cand = Candidate { m: 4, base: Base::Canonical, hadamard_bits: 9 };
        let mut layer = WinoConv2d::from_transformed(wf.clone(), bank.clone());
        layer.quantize_pct(cand.quant(), &acts, 1, 100.0);
        let ie = layer.int_engine().expect("8-bit candidates fit the int engine");
        let conv = Conv2dCfg { stride: 1, padding: 1 };
        assert_eq!(layer.forward(&acts, conv).data, ie.forward(&acts, conv).data);
    }

    #[test]
    fn tile_cost_model_predicts_and_fits() {
        let m = TileCostModel::new(40.0, 0.5);
        assert_eq!(m.predict_us(0), 40);
        assert_eq!(m.predict_us(100), 90);
        // ceil: 40 + 0.5·3 = 41.5 → 42.
        assert_eq!(m.predict_us(3), 42);
        // Exact affine samples recover the coefficients.
        let samples: Vec<(u64, f64)> =
            [10u64, 50, 200, 800].iter().map(|&t| (t, 40.0 + 0.5 * t as f64)).collect();
        let fit = TileCostModel::fit(&samples);
        assert!((fit.fixed_us - 40.0).abs() < 1e-9, "fixed {}", fit.fixed_us);
        assert!((fit.per_tile_us - 0.5).abs() < 1e-12, "slope {}", fit.per_tile_us);
        // Degenerate: one distinct tile count falls back to the mean.
        let flat = TileCostModel::fit(&[(64, 100.0), (64, 120.0)]);
        assert_eq!(flat.per_tile_us, 0.0);
        assert!((flat.fixed_us - 110.0).abs() < 1e-9);
        // Negative measured slope clamps to 0, never predicts negative.
        let noisy = TileCostModel::fit(&[(10, 200.0), (1000, 50.0)]);
        assert_eq!(noisy.per_tile_us, 0.0);
        assert!(noisy.fixed_us >= 0.0);
        assert_eq!(TileCostModel::fit(&[]).predict_us(999), 0);
    }

    #[test]
    fn candidate_measurement_is_sane_and_h9_beats_h8() {
        use crate::engine::transform_weight_bank;
        let acts = prng_tensor(54, &[2, 4, 12, 12], 1.0);
        let w = prng_tensor(55, &[4, 4, 3, 3], 0.3);
        let wf = WinoF::new(&WinogradPlan::new(4, 3), Base::Legendre);
        let bank = transform_weight_bank(&wf, &w);
        let opts = CostOpts { bench_samples: 1, bench_warmup: 0, ..Default::default() };
        let m8 = measure_candidate(
            &wf,
            &bank,
            Candidate { m: 4, base: Base::Legendre, hadamard_bits: 8 },
            &w,
            &acts,
            &opts,
        );
        let m9 = measure_candidate(
            &wf,
            &bank,
            Candidate { m: 4, base: Base::Legendre, hadamard_bits: 9 },
            &w,
            &acts,
            &opts,
        );
        assert!(m8.err > 1e-5 && m8.err < 0.5, "8-bit err out of range: {}", m8.err);
        assert!(m9.err < m8.err, "9-bit hadamard {} !< 8-bit {}", m9.err, m8.err);
        assert!(m8.seconds > 0.0 && m8.tiles_per_sec > 0.0 && m8.outputs_per_sec > 0.0);
    }
}
