//! Per-layer autotuning — searching the paper's operating space so each
//! conv layer gets its *own* `(polynomial base, tile size m, Hadamard bit
//! width)` instead of one globally hard-coded choice.
//!
//! The paper's whole point is that this operating point decides whether
//! quantized accuracy survives; Fernandez-Marques et al. 2020 (ref [5])
//! show the space should be searched per layer. This subsystem wires the
//! repo's pieces into that search:
//!
//! ```text
//!   nn/resnet (layer shapes, captured activations from data/synthcifar)
//!        │
//!        ▼
//!   grid::default_grid  ──▶  cost::measure_candidate  per (layer, cand):
//!   {base}×{m}×{h-bits}       err  = quantized layer vs f64 direct oracle
//!        │                    perf = short engine runs through benchkit
//!        ▼
//!   select_winner (Pareto front + --max-err / --objective)
//!        │
//!        ▼
//!   netplan::NetPlan  ──(JSON artifact)──▶  winoq serve --plan
//!                                           (heterogeneous per-layer engines)
//! ```
//!
//! Selection is budgeted: every candidate whose error exceeds the
//! accuracy budget (`--max-err`, defaulting to the uniform
//! canonical-`F(4,3)`-8-bit baseline's own measured error on that layer)
//! is infeasible; among feasible candidates the [`Objective`] picks the
//! winner ([`Objective::Balanced`], the default, minimizes error while
//! refusing to give up more than ~10% of the baseline's throughput). The
//! emitted [`NetPlan`](netplan::NetPlan) is versioned JSON that
//! `serve::registry::ModelRegistry::register_netplan` rebuilds
//! bit-identically (pinned by `rust/tests/tune_roundtrip.rs`).

pub mod cost;
pub mod grid;
pub mod json;
pub mod netplan;

pub use grid::{default_grid, tiny_grid, Candidate};
pub use netplan::{LayerPlan, NetPlan, NETPLAN_VERSION};

use crate::benchkit;
use crate::data::synthcifar;
use crate::engine::{transform_weight_bank, EngineScratch};
use crate::nn::tensor::Tensor;
use crate::nn::winolayer::WinoConv2d;
use crate::nn::{ConvMode, Params, ResNet18, ResNetCfg};
use crate::wino::basis::Base;
use crate::wino::matrix::Mat;
use crate::wino::toomcook::WinogradPlan;
use crate::wino::transform::WinoF;
use anyhow::{ensure, Context, Result};
use cost::{CostOpts, Measure};
use std::collections::HashMap;

/// What the tuner optimizes once the accuracy budget is satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize quantized error; throughput unconstrained.
    Error,
    /// Maximize throughput among candidates within the error budget.
    Throughput,
    /// Minimize error among candidates within the error budget that also
    /// keep ≥ 90% of the baseline's throughput (the default).
    Balanced,
}

impl Objective {
    /// Table behind [`from_name`](Self::from_name)/[`names`](Self::names)
    /// — the same single-registry pattern as [`Base::ALL`].
    pub const ALL: [Objective; 3] =
        [Objective::Error, Objective::Throughput, Objective::Balanced];

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Error => "error",
            Objective::Throughput => "throughput",
            Objective::Balanced => "balanced",
        }
    }

    pub fn from_name(s: &str) -> Option<Objective> {
        Objective::ALL.into_iter().find(|o| o.name() == s)
    }

    /// Valid objective names rendered `a|b|c` for CLI errors.
    pub fn names() -> String {
        Objective::ALL.map(|o| o.name()).join("|")
    }
}

/// Search configuration (CLI flags map onto this).
#[derive(Clone, Debug)]
pub struct TuneConfig {
    pub width_mult: f32,
    pub num_classes: usize,
    /// Synthetic parameter seed (recorded in the NetPlan).
    pub seed: u64,
    /// Calibration batch size (synthetic-CIFAR train split).
    pub calib_batch: usize,
    /// Activation calibration percentile (`--calib-pct`, 100 = max).
    pub calib_pct: f64,
    /// Absolute per-layer error budget; `None` = each layer's uniform
    /// baseline error.
    pub max_err: Option<f64>,
    pub objective: Objective,
    pub grid: Vec<Candidate>,
    /// Tune only the first N eligible layers (0 = all) — the CI smoke
    /// knob; untuned layers run direct convolution in the emitted plan.
    pub max_layers: usize,
    /// Cost-model knobs (see [`CostOpts`]).
    pub err_images: usize,
    pub bench_images: usize,
    pub bench_warmup: usize,
    pub bench_samples: usize,
    /// End-to-end comparison batch (synthetic-CIFAR test split).
    pub eval_batch: usize,
    /// Throughput slack for [`Objective::Balanced`] (0.10 = may give up
    /// 10% of baseline throughput).
    pub throughput_slack: f64,
    /// Per-layer progress on stderr.
    pub verbose: bool,
}

impl Default for TuneConfig {
    fn default() -> TuneConfig {
        TuneConfig {
            width_mult: 0.25,
            num_classes: 10,
            seed: 7,
            calib_batch: 4,
            calib_pct: 100.0,
            max_err: None,
            objective: Objective::Balanced,
            grid: default_grid(),
            max_layers: 0,
            err_images: 2,
            bench_images: 2,
            bench_warmup: 1,
            bench_samples: 3,
            eval_batch: 8,
            throughput_slack: 0.10,
            verbose: false,
        }
    }
}

/// One measured candidate on one layer, with its selection flags.
#[derive(Clone, Copy, Debug)]
pub struct CandidateResult {
    pub cand: Candidate,
    pub measure: Measure,
    /// Within the error budget (and, for `Balanced`, the throughput bar).
    pub feasible: bool,
    /// On the (error ↓, outputs/sec ↑) Pareto front of this layer.
    pub pareto: bool,
}

/// One layer's full sweep.
#[derive(Clone, Debug)]
pub struct LayerResult {
    pub prefix: String,
    pub c: usize,
    pub k: usize,
    /// Input spatial size (square).
    pub hw: usize,
    /// Per-layer error budget the selection used.
    pub budget: f64,
    /// Index (into `candidates`) of the winner / the uniform baseline.
    pub winner: usize,
    pub baseline: usize,
    pub candidates: Vec<CandidateResult>,
}

impl LayerResult {
    pub fn winner_result(&self) -> &CandidateResult {
        &self.candidates[self.winner]
    }

    pub fn baseline_result(&self) -> &CandidateResult {
        &self.candidates[self.baseline]
    }
}

/// End-to-end measurement of one whole network.
#[derive(Clone, Debug)]
pub struct EndToEnd {
    /// Relative L2 of the quantized net's logits vs the float direct net.
    pub logit_rel_l2: f64,
    /// Median seconds per eval-batch forward.
    pub seconds: f64,
    pub images_per_sec: f64,
    /// Winograd tiles per image in this net's own per-layer grids.
    pub tiles_per_item: usize,
    pub tiles_per_sec: f64,
    /// Tiles/sec counted in the *uniform* net's grid for both sides —
    /// work-normalized, so the tuned:uniform ratio equals the images/sec
    /// ratio even when tile sizes differ per layer.
    pub eq_tiles_per_sec: f64,
}

/// Everything one `winoq tune` run produces.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub plan: NetPlan,
    pub layers: Vec<LayerResult>,
    pub uniform: EndToEnd,
    pub tuned: EndToEnd,
    /// Layers whose winner differs from the uniform default.
    pub changed_layers: usize,
}

/// Mark the (error ↓, outputs/sec ↑) Pareto front.
fn pareto_flags(measures: &[Measure]) -> Vec<bool> {
    measures
        .iter()
        .map(|a| {
            !measures.iter().any(|b| {
                b.err <= a.err
                    && b.outputs_per_sec >= a.outputs_per_sec
                    && (b.err < a.err || b.outputs_per_sec > a.outputs_per_sec)
            })
        })
        .collect()
}

/// Feasibility + winner selection for one layer. Returns the feasibility
/// flags and the winning index; if nothing is feasible (a user-set
/// `--max-err` below every candidate) the minimum-error candidate wins.
fn select_winner(
    objective: Objective,
    measures: &[Measure],
    baseline: usize,
    budget: f64,
    slack: f64,
) -> (Vec<bool>, usize) {
    let tps_bar = match objective {
        Objective::Balanced => measures[baseline].outputs_per_sec * (1.0 - slack),
        _ => 0.0,
    };
    let feasible: Vec<bool> = measures
        .iter()
        .map(|m| m.err <= budget && m.outputs_per_sec >= tps_bar)
        .collect();
    let better = |a: &Measure, b: &Measure| -> bool {
        match objective {
            Objective::Error | Objective::Balanced => {
                a.err < b.err || (a.err == b.err && a.outputs_per_sec > b.outputs_per_sec)
            }
            Objective::Throughput => {
                a.outputs_per_sec > b.outputs_per_sec
                    || (a.outputs_per_sec == b.outputs_per_sec && a.err < b.err)
            }
        }
    };
    let mut winner: Option<usize> = None;
    for (i, m) in measures.iter().enumerate() {
        if !feasible[i] {
            continue;
        }
        let improves = match winner {
            None => true,
            Some(w) => better(m, &measures[w]),
        };
        if improves {
            winner = Some(i);
        }
    }
    let winner = winner.unwrap_or_else(|| {
        // Budget unreachable: degrade gracefully to the most accurate
        // candidate instead of failing the whole tune.
        (0..measures.len())
            .min_by(|&a, &b| measures[a].err.partial_cmp(&measures[b].err).unwrap())
            .unwrap()
    });
    (feasible, winner)
}

/// The Winograd-eligible conv units the tuner sweeps — delegates to the
/// single eligibility definition in [`ResNet18::wino_eligible_units`].
pub fn eligible_layers(cfg: &ResNetCfg) -> Vec<(String, usize, usize)> {
    ResNet18::wino_eligible_units(cfg)
}

/// Build the (float, then per-layer-calibrated) network a NetPlan
/// describes, straight from its parameter set — the same lowering the
/// serve registry performs through its plan cache, without the cache.
/// `rust/tests/tune_roundtrip.rs` pins the two bit-identical.
pub fn build_plan_net(plan: &NetPlan, params: &Params) -> Result<ResNet18> {
    let (nm, nb, nq) = plan
        .nominal()
        .context("NetPlan has no layers — nothing to build")?;
    let cfg = ResNetCfg {
        width_mult: plan.width_mult,
        num_classes: plan.num_classes,
        mode: ConvMode::Winograd { m: nm, base: nb, quant: Some(nq) },
    };
    let mut wfs: HashMap<(usize, Base), WinoF> = HashMap::new();
    for l in &plan.layers {
        wfs.entry((l.m, l.base))
            .or_insert_with(|| WinoF::new(&WinogradPlan::new(l.m, 3), l.base));
    }
    let eligible = eligible_layers(&cfg);
    for l in &plan.layers {
        ensure!(
            eligible.iter().any(|(p, _, _)| p == &l.layer),
            "NetPlan names layer {:?}, which is not a Winograd-eligible unit of this net",
            l.layer
        );
    }
    let mut net = ResNet18::from_params_per_layer(cfg, params.clone(), &|prefix, w| {
        plan.layer(prefix)
            .map(|l| WinoConv2d::with_plan(wfs[&(l.m, l.base)].clone(), w))
    });
    let (calib, _) =
        synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, plan.calib_batch.max(1));
    net.calibrate_quant_with(&calib, &|prefix| {
        plan.layer(prefix).map(|l| (l.quant, plan.calib_pct))
    });
    Ok(net)
}

fn end_to_end(
    net: &ResNet18,
    eval_x: &Tensor,
    ref_logits: &[f64],
    eq_tiles_per_item: usize,
    warmup: usize,
    samples: usize,
) -> EndToEnd {
    let logits = net.forward(eval_x);
    let logit_rel_l2 = cost::rel_l2(&logits.data, ref_logits);
    let mut scratch = EngineScratch::new();
    let s = benchkit::bench(warmup, samples.max(1), || {
        net.forward_with_scratch(eval_x, &mut scratch)
    });
    let images = eval_x.dims[0];
    let tiles_per_item = net.wino_tiles_per_item(eval_x.dims[2]);
    let sec = s.median.max(1e-12);
    EndToEnd {
        logit_rel_l2,
        seconds: s.median,
        images_per_sec: images as f64 / sec,
        tiles_per_item,
        tiles_per_sec: (tiles_per_item * images) as f64 / sec,
        eq_tiles_per_sec: (eq_tiles_per_item * images) as f64 / sec,
    }
}

/// Run the whole search on a synthetic (He-initialised, calibrated)
/// ResNet18: sweep the grid per layer, select winners, assemble the
/// NetPlan, and measure the tuned network against the uniform
/// canonical-`F(4,3)`-8-bit baseline end to end.
pub fn tune_synthetic(cfg: &TuneConfig) -> Result<TuneOutcome> {
    ensure!(!cfg.grid.is_empty(), "empty candidate grid");
    ensure!(
        cfg.calib_pct > 0.0 && cfg.calib_pct <= 100.0,
        "--calib-pct must be in (0, 100], got {}",
        cfg.calib_pct
    );
    let direct_cfg = ResNetCfg {
        width_mult: cfg.width_mult,
        num_classes: cfg.num_classes,
        mode: ConvMode::Direct,
    };
    let params = ResNet18::init_params(&direct_cfg, cfg.seed);
    let direct = ResNet18::from_params(direct_cfg, params.clone());
    let (calib, _) =
        synthcifar::generate_batch(synthcifar::TRAIN_SEED, 0, cfg.calib_batch.max(1));
    let captured = direct.capture_wino_inputs(&calib);

    let mut layers = eligible_layers(&direct_cfg);
    if cfg.max_layers > 0 {
        layers.truncate(cfg.max_layers);
    }

    let mut grid = cfg.grid.clone();
    let baseline_cand = Candidate::uniform_default();
    if !grid.contains(&baseline_cand) {
        grid.push(baseline_cand);
    }
    let baseline = grid.iter().position(|c| *c == baseline_cand).unwrap();

    let mut wfs: HashMap<(usize, Base), WinoF> = HashMap::new();
    let opts = CostOpts {
        err_images: cfg.err_images,
        bench_images: cfg.bench_images,
        bench_warmup: cfg.bench_warmup,
        bench_samples: cfg.bench_samples,
        calib_pct: cfg.calib_pct,
    };

    let mut layer_results = Vec::with_capacity(layers.len());
    let mut plan_layers = Vec::with_capacity(layers.len());
    for (li, (prefix, c, k)) in layers.iter().enumerate() {
        let weights = params
            .get(&format!("{prefix}.w"))
            .with_context(|| format!("missing weights for {prefix}"))?;
        let acts = captured
            .get(prefix)
            .with_context(|| format!("no captured activations for {prefix}"))?;
        if cfg.verbose {
            eprintln!(
                "tune: layer {}/{} {prefix} (C={c}, K={k}, {}x{}) over {} candidates…",
                li + 1,
                layers.len(),
                acts.dims[2],
                acts.dims[3],
                grid.len()
            );
        }
        // One float weight transform per distinct (m, base) — candidates
        // differing only in bit width reuse the bank.
        let mut banks: HashMap<(usize, Base), Vec<Vec<Mat>>> = HashMap::new();
        let measures: Vec<Measure> = grid
            .iter()
            .map(|cand| {
                let key = (cand.m, cand.base);
                let wf = wfs
                    .entry(key)
                    .or_insert_with(|| WinoF::new(&WinogradPlan::new(cand.m, 3), cand.base))
                    .clone();
                let bank = banks
                    .entry(key)
                    .or_insert_with(|| transform_weight_bank(&wf, weights));
                cost::measure_candidate(&wf, bank, *cand, weights, acts, &opts)
            })
            .collect();
        let budget = cfg.max_err.unwrap_or(measures[baseline].err);
        let (feasible, winner) =
            select_winner(cfg.objective, &measures, baseline, budget, cfg.throughput_slack);
        let pareto = pareto_flags(&measures);
        plan_layers.push(LayerPlan {
            layer: prefix.clone(),
            m: grid[winner].m,
            base: grid[winner].base,
            quant: grid[winner].quant(),
            // v2: record the measured acceptance point so serve-side
            // drift checks budget against what the tuner actually saw.
            tuned_err: Some(measures[winner].err),
            tuned_tiles_per_sec: Some(measures[winner].tiles_per_sec),
        });
        layer_results.push(LayerResult {
            prefix: prefix.clone(),
            c: *c,
            k: *k,
            hw: acts.dims[2],
            budget,
            winner,
            baseline,
            candidates: grid
                .iter()
                .zip(&measures)
                .zip(feasible.iter().zip(&pareto))
                .map(|((cand, measure), (f, p))| CandidateResult {
                    cand: *cand,
                    measure: *measure,
                    feasible: *f,
                    pareto: *p,
                })
                .collect(),
        });
    }

    let plan = NetPlan {
        version: NETPLAN_VERSION,
        model: "resnet18-synthetic".to_string(),
        width_mult: cfg.width_mult,
        num_classes: cfg.num_classes,
        image_hw: synthcifar::IMAGE_HW,
        seed: cfg.seed,
        calib_batch: cfg.calib_batch.max(1),
        calib_pct: cfg.calib_pct,
        layers: plan_layers,
    };
    let changed_layers = layer_results
        .iter()
        .filter(|lr| lr.candidates[lr.winner].cand != baseline_cand)
        .count();

    // End-to-end: tuned vs a uniform-baseline net over the *same* layer
    // set and the *same* calibration percentile (so a truncated smoke run
    // — or a --calib-pct run — compares like with like: the per-layer
    // budget measurements also calibrate every candidate, the baseline
    // included, at cfg.calib_pct), both against the float direct net's
    // logits.
    let uniform_plan = NetPlan {
        layers: plan
            .layers
            .iter()
            .map(|l| LayerPlan {
                layer: l.layer.clone(),
                m: baseline_cand.m,
                base: baseline_cand.base,
                quant: baseline_cand.quant(),
                tuned_err: None,
                tuned_tiles_per_sec: None,
            })
            .collect(),
        ..plan.clone()
    };
    let tuned_net = build_plan_net(&plan, &params)?;
    let uniform_net = build_plan_net(&uniform_plan, &params)?;
    let (eval_x, _) =
        synthcifar::generate_batch(synthcifar::TEST_SEED, 0, cfg.eval_batch.max(1));
    let ref_logits: Vec<f64> = direct.forward(&eval_x).data.iter().map(|&v| v as f64).collect();
    let eq_tiles = uniform_net.wino_tiles_per_item(eval_x.dims[2]);
    let uniform = end_to_end(
        &uniform_net,
        &eval_x,
        &ref_logits,
        eq_tiles,
        cfg.bench_warmup,
        cfg.bench_samples,
    );
    let tuned = end_to_end(
        &tuned_net,
        &eval_x,
        &ref_logits,
        eq_tiles,
        cfg.bench_warmup,
        cfg.bench_samples,
    );
    Ok(TuneOutcome { plan, layers: layer_results, uniform, tuned, changed_layers })
}

fn candidate_json(cand: &Candidate, m: &Measure) -> String {
    format!(
        concat!(
            "\"m\": {}, \"base\": \"{}\", \"hadamard_bits\": {}, ",
            "\"err\": {:e}, \"seconds\": {:e}, \"tiles_per_sec\": {:.1}, ",
            "\"outputs_per_sec\": {:.1}"
        ),
        cand.m, cand.base.name(), cand.hadamard_bits,
        m.err, m.seconds, m.tiles_per_sec, m.outputs_per_sec,
    )
}

fn end_to_end_json(e: &EndToEnd) -> String {
    format!(
        concat!(
            "{{\"logit_rel_l2\": {:e}, \"seconds\": {:e}, ",
            "\"images_per_sec\": {:.2}, \"tiles_per_item\": {}, ",
            "\"tiles_per_sec\": {:.1}, \"uniform_equiv_tiles_per_sec\": {:.1}}}"
        ),
        e.logit_rel_l2, e.seconds, e.images_per_sec, e.tiles_per_item,
        e.tiles_per_sec, e.eq_tiles_per_sec,
    )
}

/// Render the `BENCH_tune.json` report: per-layer winner table, every
/// candidate's error/throughput, and the end-to-end tuned-vs-uniform
/// comparison. The throughput ratio is work-normalized (both sides
/// counted in the uniform net's tiles), so `≥ 0.9` means the tuned net
/// kept at least 90% of the baseline's speed.
pub fn bench_json(cfg: &TuneConfig, out: &TuneOutcome) -> String {
    let mut s = format!(
        concat!(
            "{{\n\"bench\": \"tune\", \"netplan_version\": {}, \"model\": \"{}\", ",
            "\"width_mult\": {}, \"objective\": \"{}\", \"max_err\": {}, ",
            "\"calib_pct\": {}, \"calib_batch\": {}, \"grid_size\": {}, ",
            "\"layers_tuned\": {}, \"layers_changed_vs_uniform\": {},\n",
            "\"layers\": [\n"
        ),
        out.plan.version,
        json::escape(&out.plan.model),
        out.plan.width_mult,
        cfg.objective.name(),
        cfg.max_err.map_or("null".to_string(), |e| format!("{e:e}")),
        out.plan.calib_pct,
        out.plan.calib_batch,
        out.layers.first().map_or(0, |l| l.candidates.len()),
        out.layers.len(),
        out.changed_layers,
    );
    for (i, lr) in out.layers.iter().enumerate() {
        let w = lr.winner_result();
        let b = lr.baseline_result();
        s.push_str(&format!(
            concat!(
                "  {{\"layer\": \"{}\", \"c\": {}, \"k\": {}, \"hw\": {}, ",
                "\"budget\": {:e},\n   \"winner\": {{{}}},\n   \"baseline\": {{{}}},\n",
                "   \"candidates\": [\n"
            ),
            json::escape(&lr.prefix),
            lr.c,
            lr.k,
            lr.hw,
            lr.budget,
            candidate_json(&w.cand, &w.measure),
            candidate_json(&b.cand, &b.measure),
        ));
        for (ci, cr) in lr.candidates.iter().enumerate() {
            s.push_str(&format!(
                "    {{{}, \"feasible\": {}, \"pareto\": {}}}{}\n",
                candidate_json(&cr.cand, &cr.measure),
                cr.feasible,
                cr.pareto,
                if ci + 1 == lr.candidates.len() { "" } else { "," },
            ));
        }
        s.push_str(&format!(
            "   ]}}{}\n",
            if i + 1 == out.layers.len() { "" } else { "," }
        ));
    }
    let ratio = if out.uniform.eq_tiles_per_sec > 0.0 {
        out.tuned.eq_tiles_per_sec / out.uniform.eq_tiles_per_sec
    } else {
        0.0
    };
    let err_ratio = if out.uniform.logit_rel_l2 > 0.0 {
        out.tuned.logit_rel_l2 / out.uniform.logit_rel_l2
    } else {
        0.0
    };
    s.push_str(&format!(
        concat!(
            "],\n\"endtoend\": {{\"eval_batch\": {}, \"uniform\": {}, \"tuned\": {}, ",
            "\"err_ratio_tuned_vs_uniform\": {:.4}, ",
            "\"tiles_per_sec_ratio_tuned_vs_uniform\": {:.4}}}\n}}\n"
        ),
        cfg.eval_batch.max(1),
        end_to_end_json(&out.uniform),
        end_to_end_json(&out.tuned),
        err_ratio,
        ratio,
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scheme::QuantConfig;

    fn m(err: f64, ops: f64) -> Measure {
        Measure { err, seconds: 1.0, tiles_per_sec: ops, outputs_per_sec: ops }
    }

    #[test]
    fn pareto_front_flags() {
        // (err, ops): b dominates c; a and b are on the front; d ties a on
        // err but is slower — dominated.
        let ms = [m(1.0, 10.0), m(2.0, 20.0), m(3.0, 15.0), m(1.0, 5.0)];
        assert_eq!(pareto_flags(&ms), vec![true, true, false, false]);
    }

    #[test]
    fn balanced_minimizes_error_within_throughput_bar() {
        // Baseline idx 0. Candidate 1: lower err, same speed — wins.
        // Candidate 2: even lower err but 50% slower — infeasible.
        let ms = [m(1.0, 100.0), m(0.5, 99.0), m(0.1, 50.0)];
        let (feasible, w) = select_winner(Objective::Balanced, &ms, 0, 1.0, 0.10);
        assert_eq!(feasible, vec![true, true, false]);
        assert_eq!(w, 1);
    }

    #[test]
    fn throughput_maximizes_speed_within_budget() {
        let ms = [m(1.0, 100.0), m(0.9, 300.0), m(2.0, 900.0)];
        let (feasible, w) = select_winner(Objective::Throughput, &ms, 0, 1.0, 0.10);
        assert_eq!(feasible, vec![true, true, false]);
        assert_eq!(w, 1);
    }

    #[test]
    fn error_objective_ignores_throughput() {
        let ms = [m(1.0, 100.0), m(0.2, 1.0)];
        let (_, w) = select_winner(Objective::Error, &ms, 0, 1.0, 0.10);
        assert_eq!(w, 1);
    }

    #[test]
    fn unreachable_budget_falls_back_to_min_error() {
        let ms = [m(1.0, 100.0), m(0.5, 10.0)];
        let (feasible, w) = select_winner(Objective::Balanced, &ms, 0, 1e-9, 0.10);
        assert_eq!(feasible, vec![false, false]);
        assert_eq!(w, 1, "fallback must be the most accurate candidate");
    }

    #[test]
    fn objective_names_round_trip() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("speed"), None);
        assert_eq!(Objective::names(), "error|throughput|balanced");
    }

    #[test]
    fn eligible_layer_listing() {
        let cfg = ResNetCfg {
            width_mult: 0.25,
            num_classes: 10,
            mode: ConvMode::Direct,
        };
        let layers = eligible_layers(&cfg);
        assert_eq!(layers.len(), 14);
        assert_eq!(layers[0].0, "stem");
        assert!(layers.iter().all(|(p, _, _)| !p.ends_with("down")));
    }

    #[test]
    fn tiny_tune_emits_a_consistent_plan() {
        // The CI-smoke shape of the search: 2 layers × 2 candidates.
        let cfg = TuneConfig {
            grid: tiny_grid(),
            max_layers: 2,
            calib_batch: 2,
            err_images: 1,
            bench_images: 1,
            bench_warmup: 0,
            bench_samples: 1,
            eval_batch: 2,
            objective: Objective::Error,
            ..Default::default()
        };
        let out = tune_synthetic(&cfg).unwrap();
        assert_eq!(out.plan.layers.len(), 2);
        assert_eq!(out.plan.layers[0].layer, "stem");
        for lr in &out.layers {
            assert_eq!(lr.candidates.len(), 2);
            let w = lr.winner_result();
            let b = lr.baseline_result();
            assert!(
                w.measure.err <= b.measure.err,
                "{}: winner err {} > baseline {}",
                lr.prefix,
                w.measure.err,
                b.measure.err
            );
            assert!(lr.baseline_result().cand == Candidate::uniform_default());
        }
        // The 9-bit-Hadamard alternative strictly tightens layer error, so
        // under the error objective the plan must leave the uniform default.
        assert!(out.changed_layers >= 1, "no layer left the uniform default");
        // Tuned end-to-end error cannot exceed the uniform baseline's
        // (same layers, each at most as erroneous).
        assert!(
            out.tuned.logit_rel_l2 <= out.uniform.logit_rel_l2 * 1.01,
            "tuned {} vs uniform {}",
            out.tuned.logit_rel_l2,
            out.uniform.logit_rel_l2
        );
        // NetPlan artifact round-trips.
        let reloaded = NetPlan::from_json(&out.plan.to_json()).unwrap();
        assert_eq!(reloaded, out.plan);
        // Report JSON carries the stable keys CI greps.
        let report = bench_json(&cfg, &out);
        for key in [
            "\"bench\": \"tune\"",
            "\"layers_changed_vs_uniform\"",
            "\"winner\"",
            "\"endtoend\"",
            "\"tiles_per_sec_ratio_tuned_vs_uniform\"",
        ] {
            assert!(report.contains(key), "missing {key}");
        }
        // And parses back as JSON (the writer emits what the reader reads).
        let doc = json::parse(&report).unwrap();
        assert_eq!(
            doc.get("layers").unwrap().as_arr().unwrap().len(),
            2
        );
        assert!(doc.get("endtoend").unwrap().get("tuned").is_some());
    }

    #[test]
    fn build_plan_net_rejects_unknown_layers() {
        let plan = NetPlan {
            version: NETPLAN_VERSION,
            model: "resnet18-synthetic".into(),
            width_mult: 0.25,
            num_classes: 10,
            image_hw: 32,
            seed: 3,
            calib_batch: 1,
            calib_pct: 100.0,
            layers: vec![LayerPlan {
                layer: "s9b9.conv9".into(),
                m: 4,
                base: Base::Legendre,
                quant: QuantConfig::w8(),
                tuned_err: None,
                tuned_tiles_per_sec: None,
            }],
        };
        let cfg = ResNetCfg {
            width_mult: 0.25,
            num_classes: 10,
            mode: ConvMode::Direct,
        };
        let params = ResNet18::init_params(&cfg, 3);
        let err = build_plan_net(&plan, &params).unwrap_err();
        assert!(err.to_string().contains("s9b9.conv9"), "{err}");
    }
}
