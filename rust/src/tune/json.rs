//! Minimal JSON reader/escaper — just enough for the NetPlan artifact
//! (serde is not in the vendored crate set, and the crate's other JSON is
//! write-only). Objects preserve key order; numbers are f64 (every value
//! a NetPlan carries fits exactly).

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Non-negative integer view of a number. Rejects fractions and
    /// anything at or above 2⁵³ — from there an f64 cannot represent
    /// every integer, and a larger document value (e.g. a NetPlan seed of
    /// 2⁵³ + 1) would already have rounded *to* 2⁵³ during the parse, so
    /// the bound must be strict to refuse the rounded impostor too.
    pub fn as_u64(&self) -> Option<u64> {
        const F64_EXACT_LIMIT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v < F64_EXACT_LIMIT => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON string literal (quotes,
/// backslashes, control characters).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse one JSON document (trailing whitespace allowed, nothing else).
pub fn parse(s: &str) -> Result<Json> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {} of JSON document", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        match self.bytes.get(self.pos) {
            Some(&b) => Ok(b),
            None => bail!("unexpected end of JSON document"),
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {} of JSON document",
                b as char,
                self.pos
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {} of JSON document", self.pos);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            let key = match self.peek()? {
                b'"' => self.string()?,
                _ => bail!("expected object key at byte {}", self.pos),
            };
            self.expect(b':')?;
            members.push((key, self.value()?));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("unterminated JSON string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        bail!("unterminated escape in JSON string");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // BMP only — ample for NetPlan content (our own
                            // writer never emits astral escapes).
                            match char::from_u32(cp) {
                                Some(c) => out.push(c),
                                None => bail!("unsupported \\u escape {cp:#x}"),
                            }
                        }
                        _ => bail!("invalid escape '\\{}'", esc as char),
                    }
                }
                _ => {
                    // Take the whole unescaped run in one slice. The input
                    // arrived as &str (valid UTF-8) and both delimiters are
                    // ASCII, so these boundaries are always char-safe.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len()
                        && self.bytes[end] != b'"'
                        && self.bytes[end] != b'\\'
                    {
                        end += 1;
                    }
                    let run = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in JSON string"))?;
                    out.push_str(run);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("non-ASCII \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("invalid \\u escape {hex:?}"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if start == self.pos {
            bail!("expected a JSON value at byte {start}");
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => bail!("invalid JSON number {text:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#" {"a": 1, "b": [true, null, -2.5e2], "c": {"d": "x\ny"}} "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_f64(), Some(-250.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x\ny"));
        assert!(v.get("absent").is_none());
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let nasty = "a\"b\\c\nd\te\u{1}f µ∂";
        let doc = format!("{{\"k\": \"{}\"}}", escape(nasty));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(nasty));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "{\"a\": 1} x", "\"unterminated",
            "{\"a\": nul}", "1e999",
        ] {
            assert!(parse(bad).is_err(), "accepted malformed JSON {bad:?}");
        }
    }

    #[test]
    fn as_u64_rejects_fractions_negatives_and_inexact_range() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("3").unwrap().as_u64(), Some(3));
        // From 2^53 up, integers are no longer uniquely representable —
        // 2^53 + 1 parses to the same f64 as 2^53, so both are refused.
        assert_eq!(
            parse("9007199254740991").unwrap().as_u64(),
            Some(9_007_199_254_740_991)
        );
        assert_eq!(parse("9007199254740992").unwrap().as_u64(), None);
        assert_eq!(parse("9007199254740993").unwrap().as_u64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
