//! The tuner's candidate space: every `(polynomial base, tile size m,
//! Hadamard bit width)` operating point a layer may run. The paper's two
//! bit configurations (8-bit, 8-bit + 9-bit Hadamard) crossed with the
//! three implemented bases and the `F(2,3)/F(4,3)/F(6,3)` tile sizes give
//! 18 candidates; the uniform deployment default — canonical `F(4,3)`
//! all-8-bit — is one of them and doubles as the per-layer accuracy
//! budget when `--max-err` is not given.

use super::netplan::SUPPORTED_M;
use crate::quant::scheme::QuantConfig;
use crate::wino::basis::Base;

/// Hadamard-stage widths the grid sweeps (paper Table 1's two rows).
pub const HADAMARD_BITS: [u32; 2] = [8, 9];

/// One point of the search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Candidate {
    /// Output tile size `m` of `F(m×m, 3×3)`.
    pub m: usize,
    pub base: Base,
    /// Bit width of the Hadamard-product stage (8 or 9 in the paper).
    pub hadamard_bits: u32,
}

impl Candidate {
    /// The staged bit-width configuration this candidate quantizes with
    /// (everything 8-bit except the swept Hadamard stage).
    pub fn quant(&self) -> QuantConfig {
        QuantConfig {
            act_bits: 8,
            weight_bits: 8,
            hadamard_bits: self.hadamard_bits,
            out_bits: 8,
        }
    }

    /// Transform size `n = m + r − 1` (r = 3 throughout the grid).
    pub fn n(&self) -> usize {
        self.m + 2
    }

    /// Human label, e.g. `F(4,3)/legendre/h9`.
    pub fn label(&self) -> String {
        format!("F({},3)/{}/h{}", self.m, self.base.name(), self.hadamard_bits)
    }

    /// The uniform deployment default: canonical `F(4,3)`, all-8-bit —
    /// today's one-globally-hard-coded operating point and the tuner's
    /// built-in baseline.
    pub fn uniform_default() -> Candidate {
        Candidate { m: 4, base: Base::Canonical, hadamard_bits: 8 }
    }
}

/// The full sweep: every base × m × Hadamard width (18 candidates, the
/// uniform default included).
pub fn default_grid() -> Vec<Candidate> {
    let mut grid = Vec::new();
    for base in Base::ALL {
        for m in SUPPORTED_M {
            for hadamard_bits in HADAMARD_BITS {
                grid.push(Candidate { m, base, hadamard_bits });
            }
        }
    }
    grid
}

/// The CI smoke grid: the uniform default plus the paper's headline
/// alternative (Legendre with a 9-bit Hadamard) — two candidates, enough
/// to exercise selection, NetPlan emission and serve loading cheaply.
pub fn tiny_grid() -> Vec<Candidate> {
    vec![
        Candidate::uniform_default(),
        Candidate { m: 4, base: Base::Legendre, hadamard_bits: 9 },
    ]
}

/// Resolve a grid name (`full` | `tiny`).
pub fn grid_from_name(name: &str) -> Option<Vec<Candidate>> {
    match name {
        "full" => Some(default_grid()),
        "tiny" => Some(tiny_grid()),
        _ => None,
    }
}

/// Valid grid names rendered `a|b` for CLI errors.
pub fn grid_names() -> String {
    "full|tiny".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_covers_the_space() {
        let grid = default_grid();
        assert_eq!(grid.len(), Base::ALL.len() * SUPPORTED_M.len() * HADAMARD_BITS.len());
        assert!(grid.contains(&Candidate::uniform_default()));
        // No duplicates.
        for (i, a) in grid.iter().enumerate() {
            assert!(!grid[i + 1..].contains(a), "duplicate candidate {a:?}");
        }
    }

    #[test]
    fn tiny_grid_contains_baseline_and_an_alternative() {
        let grid = tiny_grid();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0], Candidate::uniform_default());
        assert_ne!(grid[1], grid[0]);
    }

    #[test]
    fn candidate_quant_and_label() {
        let c = Candidate { m: 6, base: Base::Chebyshev, hadamard_bits: 9 };
        assert_eq!(c.quant().hadamard_bits, 9);
        assert_eq!(c.quant().act_bits, 8);
        assert_eq!(c.n(), 8);
        assert_eq!(c.label(), "F(6,3)/chebyshev/h9");
        assert_eq!(Candidate::uniform_default().quant(), QuantConfig::w8());
    }

    #[test]
    fn grid_names_resolve() {
        assert_eq!(grid_from_name("full").unwrap().len(), 18);
        assert_eq!(grid_from_name("tiny").unwrap().len(), 2);
        assert!(grid_from_name("huge").is_none());
        assert_eq!(grid_names(), "full|tiny");
    }
}
