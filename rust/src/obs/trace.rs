//! Request tracing: span IDs, structured trace events, and the two
//! recorders (deterministic log, lock-cheap threaded ring).
//!
//! A **span** is one request's identity from admission to terminal
//! state. IDs are minted process-wide by [`mint_span`] so every layer —
//! [`ShardRouter`](crate::serve::ShardRouter) routing,
//! [`ServeQueue`](crate::serve::ServeQueue) admission, scheduler
//! shed/batch decisions, engine stages — can stamp events for the same
//! request without threading a generator through the call graph.
//!
//! # Span lifecycle
//!
//! ```text
//! submit ──→ (plan_cache hit/miss) ──→ batch ──→ stage ──→ complete
//!    │                                   │
//!    │                                   └──→ failed  (worker panic
//!    │                                         poisoned the batch; the
//!    │                                         supervisor failed its
//!    │                                         members and restarted)
//!    ├──→ reject   (admission refuses: queue_full, unknown_model, …)
//!    └──→ shed     (scheduler drops a hopeless deadline, with the
//!                   predicted/deadline/decided numbers that justify it)
//! ```
//!
//! Every submitted span ends in **exactly one** of
//! `complete`/`reject`/`shed`/`failed` — the accounting invariant
//! ([`TraceSink::accounting`]) that `scripts/ci.sh` gates on and the
//! property suite in `testkit::soak` pins against the soak report.
//! Supervision and drift-fallback also emit process-level advisory
//! events (`worker_restart` on span 0, `fallback_engaged`/
//! `fallback_cleared` on the sampled span) — all non-terminal, so they
//! never perturb accounting.
//!
//! Events serialize as JSON lines via [`obs::json`](crate::obs::json):
//! `{"span": 3, "at_us": 120, "event": "submit", ...}` — one object per
//! line, reconstructable per span by grouping on `span`.
//!
//! Two recorders share the [`TraceSink`] event store:
//! [`TraceLog`] is single-threaded and unbounded with insertion order
//! preserved (the soak harness needs byte-identical output per seed);
//! [`Tracer`] is the serving-path recorder — sharded mutex rings with a
//! bounded capacity and a global sequence number so a drain yields one
//! deterministic total order, dropping (and counting) events past the
//! cap instead of growing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::json::JsonObj;

/// Process-wide span ID source. First minted span is 1; 0 is reserved
/// as "untraced".
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);

/// Mint a fresh, process-unique span ID.
pub fn mint_span() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// What happened to a span at one point in its life.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    /// Request admitted into a queue (terminal events must follow).
    Submit {
        model: String,
        priority: String,
        deadline_us: u64,
        tiles: u64,
        h: u64,
        w: u64,
    },
    /// Admission refused the request outright. Terminal.
    Reject { why: String },
    /// Scheduler dropped a hopeless request, with the numbers that
    /// justify it: it would have finished at `predicted_us` past
    /// `deadline_us`, decided at `decided_us`. Terminal.
    Shed {
        why: String,
        predicted_us: u64,
        deadline_us: u64,
        decided_us: u64,
    },
    /// Span was placed into a closed batch of `size` requests.
    Batch { size: u64, predicted_us: u64 },
    /// Plan-cache interaction while routing/lowering for `model`.
    PlanCache { model: String, hit: bool },
    /// Per-stage engine nanoseconds attributed to this span's batch.
    Stage {
        input_transform_ns: u64,
        hadamard_ns: u64,
        inverse_ns: u64,
        tiles: u64,
    },
    /// Response delivered. Terminal.
    Complete { latency_us: u64, batch_size: u64 },
    /// The span's batch was poisoned by a worker panic: the supervisor
    /// failed every member request with `reason` instead of aborting
    /// the whole queue. Terminal.
    Failed { reason: String },
    /// A supervised serve worker came back after a panic: its
    /// `restarts`-th restart, after `backoff_us` of exponential
    /// backoff. Process-level (recorded on span 0), non-terminal.
    WorkerRestart { worker: u64, restarts: u64, backoff_us: u64 },
    /// The drift circuit breaker stepped `layer` down one engine rung
    /// (`int` → `float` → `direct`) after persistent drift alerts.
    /// Non-terminal advisory on the sampled span.
    FallbackEngaged { layer: String, from: String, to: String },
    /// The quiet period elapsed: `layer` re-armed back to `to`
    /// (the fast quantized path). Non-terminal advisory.
    FallbackCleared { layer: String, to: String },
    /// Shadow-oracle drift check on a sampled span found one layer's
    /// windowed rel-L2 error above its tuned budget. Non-terminal (the
    /// span still completes normally); errors are carried in parts per
    /// billion so the payload stays integer-exact and the trace stream
    /// byte-identical across reruns.
    DriftAlert {
        layer: String,
        m: u64,
        base: String,
        weight_bits: u64,
        hadamard_bits: u64,
        rel_err_ppb: u64,
        budget_ppb: u64,
    },
}

/// One timestamped event on one span.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub span: u64,
    pub at_us: u64,
    pub kind: TraceKind,
}

impl TraceEvent {
    /// House-style JSON line (no trailing newline): common fields
    /// first, then the kind's payload.
    pub fn to_json_line(&self) -> String {
        let head = JsonObj::new().u64("span", self.span).u64("at_us", self.at_us);
        match &self.kind {
            TraceKind::Submit { model, priority, deadline_us, tiles, h, w } => head
                .str("event", "submit")
                .str("model", model)
                .str("priority", priority)
                .u64("deadline_us", *deadline_us)
                .u64("tiles", *tiles)
                .u64("h", *h)
                .u64("w", *w)
                .finish(),
            TraceKind::Reject { why } => {
                head.str("event", "reject").str("why", why).finish()
            }
            TraceKind::Shed { why, predicted_us, deadline_us, decided_us } => head
                .str("event", "shed")
                .str("why", why)
                .u64("predicted_us", *predicted_us)
                .u64("deadline_us", *deadline_us)
                .u64("decided_us", *decided_us)
                .finish(),
            TraceKind::Batch { size, predicted_us } => head
                .str("event", "batch")
                .u64("size", *size)
                .u64("predicted_us", *predicted_us)
                .finish(),
            TraceKind::PlanCache { model, hit } => head
                .str("event", "plan_cache")
                .str("model", model)
                .bool("hit", *hit)
                .finish(),
            TraceKind::Stage { input_transform_ns, hadamard_ns, inverse_ns, tiles } => {
                head.str("event", "stage")
                    .u64("input_transform_ns", *input_transform_ns)
                    .u64("hadamard_ns", *hadamard_ns)
                    .u64("inverse_ns", *inverse_ns)
                    .u64("tiles", *tiles)
                    .finish()
            }
            TraceKind::Complete { latency_us, batch_size } => head
                .str("event", "complete")
                .u64("latency_us", *latency_us)
                .u64("batch_size", *batch_size)
                .finish(),
            TraceKind::Failed { reason } => {
                head.str("event", "failed").str("reason", reason).finish()
            }
            TraceKind::WorkerRestart { worker, restarts, backoff_us } => head
                .str("event", "worker_restart")
                .u64("worker", *worker)
                .u64("restarts", *restarts)
                .u64("backoff_us", *backoff_us)
                .finish(),
            TraceKind::FallbackEngaged { layer, from, to } => head
                .str("event", "fallback_engaged")
                .str("layer", layer)
                .str("from", from)
                .str("to", to)
                .finish(),
            TraceKind::FallbackCleared { layer, to } => head
                .str("event", "fallback_cleared")
                .str("layer", layer)
                .str("to", to)
                .finish(),
            TraceKind::DriftAlert {
                layer,
                m,
                base,
                weight_bits,
                hadamard_bits,
                rel_err_ppb,
                budget_ppb,
            } => head
                .str("event", "drift_alert")
                .str("layer", layer)
                .u64("m", *m)
                .str("base", base)
                .u64("weight_bits", *weight_bits)
                .u64("hadamard_bits", *hadamard_bits)
                .u64("rel_err_ppb", *rel_err_ppb)
                .u64("budget_ppb", *budget_ppb)
                .finish(),
        }
    }

    /// True for the four lifecycle-ending kinds.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self.kind,
            TraceKind::Reject { .. }
                | TraceKind::Shed { .. }
                | TraceKind::Complete { .. }
                | TraceKind::Failed { .. }
        )
    }
}

/// Span-accounting summary over a set of events.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanAccounting {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub shed: u64,
    /// Spans whose batch was poisoned by a worker panic and failed by
    /// the supervisor.
    pub failed: u64,
    /// Every submitted span has exactly one terminal event, and no
    /// terminal event names an unsubmitted span.
    pub exact: bool,
}

/// Common read-side over an ordered slice of trace events.
pub trait TraceSink {
    /// The recorded events in their deterministic order.
    fn events(&self) -> Vec<TraceEvent>;

    /// JSON-lines rendering (one event per line, trailing newline when
    /// nonempty).
    fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            out.push_str(&ev.to_json_line());
            out.push('\n');
        }
        out
    }

    /// Check the span-accounting invariant over all recorded events.
    fn accounting(&self) -> SpanAccounting {
        use std::collections::BTreeMap;
        let mut submitted: BTreeMap<u64, u64> = BTreeMap::new();
        let mut terminals: BTreeMap<u64, Vec<&'static str>> = BTreeMap::new();
        for ev in self.events() {
            match ev.kind {
                TraceKind::Submit { .. } => {
                    *submitted.entry(ev.span).or_insert(0) += 1
                }
                TraceKind::Reject { .. } => {
                    terminals.entry(ev.span).or_default().push("reject")
                }
                TraceKind::Shed { .. } => {
                    terminals.entry(ev.span).or_default().push("shed")
                }
                TraceKind::Complete { .. } => {
                    terminals.entry(ev.span).or_default().push("complete")
                }
                TraceKind::Failed { .. } => {
                    terminals.entry(ev.span).or_default().push("failed")
                }
                _ => {}
            }
        }
        let mut acc = SpanAccounting {
            submitted: submitted.len() as u64,
            exact: true,
            ..Default::default()
        };
        // Single submit per span, and every terminal span was submitted.
        acc.exact &= submitted.values().all(|&n| n == 1);
        acc.exact &= terminals.keys().all(|s| submitted.contains_key(s));
        for span in submitted.keys() {
            match terminals.get(span).map(Vec::as_slice) {
                Some(["reject"]) => acc.rejected += 1,
                Some(["shed"]) => acc.shed += 1,
                Some(["complete"]) => acc.completed += 1,
                Some(["failed"]) => acc.failed += 1,
                _ => acc.exact = false,
            }
        }
        acc.exact &= acc.submitted
            == acc.completed + acc.rejected + acc.shed + acc.failed;
        acc
    }
}

/// Deterministic, unbounded, single-threaded recorder — insertion order
/// is the output order (the soak harness depends on byte-identical
/// output per seed).
#[derive(Default, Debug)]
pub struct TraceLog {
    events: Vec<TraceEvent>,
}

impl TraceLog {
    pub fn new() -> TraceLog {
        TraceLog::default()
    }

    pub fn record(&mut self, span: u64, at_us: u64, kind: TraceKind) {
        self.events.push(TraceEvent { span, at_us, kind });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl TraceSink for TraceLog {
    fn events(&self) -> Vec<TraceEvent> {
        self.events.clone()
    }
}

const TRACER_SHARDS: usize = 8;

/// Serving-path recorder: events land in one of [`TRACER_SHARDS`]
/// mutex-guarded rings keyed by span (same span → same shard → one
/// short lock among `1/TRACER_SHARDS` of the traffic). A global
/// sequence number gives drains a deterministic total order; past
/// `capacity` events per shard, new events are counted as dropped
/// instead of growing memory.
#[derive(Debug)]
pub struct Tracer {
    shards: [Mutex<Vec<(u64, TraceEvent)>>; TRACER_SHARDS],
    seq: AtomicU64,
    capacity: usize,
    dropped: AtomicU64,
    /// Subset of `dropped` that were *terminal* events (reject / shed /
    /// complete). A dropped terminal leaves its span dangling in the
    /// recorded stream, so [`Tracer::accounting`] reconciles dangling
    /// spans against this counter instead of reporting a healthy run as
    /// a leak.
    dropped_terminal: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(1 << 16)
    }
}

impl Tracer {
    /// `capacity` bounds each shard's event count.
    pub fn new(capacity: usize) -> Tracer {
        Tracer {
            shards: std::array::from_fn(|_| Mutex::new(Vec::new())),
            seq: AtomicU64::new(0),
            capacity,
            dropped: AtomicU64::new(0),
            dropped_terminal: AtomicU64::new(0),
        }
    }

    pub fn record(&self, span: u64, at_us: u64, kind: TraceKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = TraceEvent { span, at_us, kind };
        let mut shard =
            self.shards[(span as usize) % TRACER_SHARDS].lock().unwrap();
        if shard.len() >= self.capacity {
            if ev.is_terminal() {
                self.dropped_terminal.fetch_add(1, Ordering::Relaxed);
            }
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        shard.push((seq, ev));
    }

    /// Events dropped because a shard hit capacity.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Subset of [`dropped`](Self::dropped) that were terminal
    /// (reject / shed / complete) — the spans accounting must forgive.
    pub fn dropped_terminal(&self) -> u64 {
        self.dropped_terminal.load(Ordering::Relaxed)
    }

    /// Remove and return all recorded events in global sequence order.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut all: Vec<(u64, TraceEvent)> = Vec::new();
        for shard in &self.shards {
            all.append(&mut shard.lock().unwrap());
        }
        all.sort_by_key(|&(seq, _)| seq);
        all.into_iter().map(|(_, ev)| ev).collect()
    }
}

impl TraceSink for Tracer {
    /// Non-destructive snapshot in global sequence order.
    fn events(&self) -> Vec<TraceEvent> {
        let mut all: Vec<(u64, TraceEvent)> = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|&(seq, _)| seq);
        all.into_iter().map(|(_, ev)| ev).collect()
    }

    /// Span accounting reconciled against the drop counters.
    ///
    /// The bounded recorder may have dropped a *terminal* event at
    /// capacity, leaving its span dangling in the recorded stream even
    /// though the request really did end — the default trait accounting
    /// would flag that as a leak and fail a healthy run. Here a span
    /// with a submit but no terminal is forgiven as long as the total
    /// number of dangling spans does not exceed
    /// [`dropped_terminal`](Tracer::dropped_terminal); genuine
    /// violations (double terminals, orphan terminals, more dangling
    /// spans than dropped terminals) still report `exact = false`.
    fn accounting(&self) -> SpanAccounting {
        use std::collections::BTreeMap;
        let mut submitted: BTreeMap<u64, u64> = BTreeMap::new();
        let mut terminals: BTreeMap<u64, Vec<&'static str>> = BTreeMap::new();
        for ev in self.events() {
            match ev.kind {
                TraceKind::Submit { .. } => {
                    *submitted.entry(ev.span).or_insert(0) += 1
                }
                TraceKind::Reject { .. } => {
                    terminals.entry(ev.span).or_default().push("reject")
                }
                TraceKind::Shed { .. } => {
                    terminals.entry(ev.span).or_default().push("shed")
                }
                TraceKind::Complete { .. } => {
                    terminals.entry(ev.span).or_default().push("complete")
                }
                TraceKind::Failed { .. } => {
                    terminals.entry(ev.span).or_default().push("failed")
                }
                _ => {}
            }
        }
        let mut acc = SpanAccounting {
            submitted: submitted.len() as u64,
            exact: true,
            ..Default::default()
        };
        acc.exact &= submitted.values().all(|&n| n == 1);
        acc.exact &= terminals.keys().all(|s| submitted.contains_key(s));
        let mut dangling = 0u64;
        for span in submitted.keys() {
            match terminals.get(span).map(Vec::as_slice) {
                Some(["reject"]) => acc.rejected += 1,
                Some(["shed"]) => acc.shed += 1,
                Some(["complete"]) => acc.completed += 1,
                Some(["failed"]) => acc.failed += 1,
                None => dangling += 1,
                _ => acc.exact = false,
            }
        }
        // Each dropped terminal explains at most one dangling span.
        acc.exact &= dangling <= self.dropped_terminal();
        acc.exact &= acc.submitted
            == acc.completed + acc.rejected + acc.shed + acc.failed + dangling;
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn submit() -> TraceKind {
        TraceKind::Submit {
            model: "m".into(),
            priority: "normal".into(),
            deadline_us: 1000,
            tiles: 4,
            h: 8,
            w: 8,
        }
    }

    #[test]
    fn span_ids_are_unique_and_nonzero() {
        let a = mint_span();
        let b = mint_span();
        assert!(a > 0 && b > 0 && a != b);
    }

    #[test]
    fn event_lines_are_house_style_and_parseable() {
        let ev = TraceEvent {
            span: 3,
            at_us: 120,
            kind: TraceKind::Shed {
                why: "predicted past deadline".into(),
                predicted_us: 900,
                deadline_us: 800,
                decided_us: 100,
            },
        };
        let line = ev.to_json_line();
        assert!(line.starts_with("{\"span\": 3, \"at_us\": 120, \"event\": \"shed\""));
        let doc = crate::tune::json::parse(&line).unwrap();
        assert_eq!(doc.get("predicted_us").and_then(|j| j.as_u64()), Some(900));
        assert_eq!(
            doc.get("why").and_then(crate::tune::json::Json::as_str),
            Some("predicted past deadline")
        );
    }

    #[test]
    fn drift_alert_is_non_terminal_and_renders_house_style() {
        let ev = TraceEvent {
            span: 11,
            at_us: 500,
            kind: TraceKind::DriftAlert {
                layer: "s0b0.conv1".into(),
                m: 4,
                base: "legendre".into(),
                weight_bits: 8,
                hadamard_bits: 9,
                rel_err_ppb: 7_500_000,
                budget_ppb: 2_500_000,
            },
        };
        assert!(!ev.is_terminal(), "a drift alert must not close the span");
        let line = ev.to_json_line();
        assert!(line.starts_with("{\"span\": 11, \"at_us\": 500, \"event\": \"drift_alert\""));
        let doc = crate::tune::json::parse(&line).unwrap();
        assert_eq!(doc.get("rel_err_ppb").and_then(|j| j.as_u64()), Some(7_500_000));
        assert_eq!(doc.get("budget_ppb").and_then(|j| j.as_u64()), Some(2_500_000));
        // Accounting stays exact with alerts interleaved.
        let mut log = TraceLog::new();
        log.record(11, 0, submit());
        log.record(11, 500, ev.kind.clone());
        log.record(11, 900, TraceKind::Complete { latency_us: 900, batch_size: 1 });
        assert!(log.accounting().exact);
    }

    #[test]
    fn accounting_is_exact_for_a_clean_lifecycle() {
        let mut log = TraceLog::new();
        log.record(1, 0, submit());
        log.record(2, 1, submit());
        log.record(3, 2, submit());
        log.record(1, 5, TraceKind::Batch { size: 1, predicted_us: 40 });
        log.record(1, 9, TraceKind::Complete { latency_us: 9, batch_size: 1 });
        log.record(2, 3, TraceKind::Reject { why: "queue_full".into() });
        log.record(
            3,
            4,
            TraceKind::Shed {
                why: "hopeless".into(),
                predicted_us: 99,
                deadline_us: 50,
                decided_us: 4,
            },
        );
        let acc = log.accounting();
        assert_eq!(
            acc,
            SpanAccounting {
                submitted: 3,
                completed: 1,
                rejected: 1,
                shed: 1,
                failed: 0,
                exact: true
            }
        );
    }

    #[test]
    fn failed_is_terminal_and_accounted() {
        let ev = TraceEvent {
            span: 4,
            at_us: 70,
            kind: TraceKind::Failed { reason: "worker panic: chaos".into() },
        };
        assert!(ev.is_terminal(), "failed must close the span");
        let line = ev.to_json_line();
        assert!(line.starts_with("{\"span\": 4, \"at_us\": 70, \"event\": \"failed\""));
        let doc = crate::tune::json::parse(&line).unwrap();
        assert_eq!(
            doc.get("reason").and_then(crate::tune::json::Json::as_str),
            Some("worker panic: chaos")
        );
        let mut log = TraceLog::new();
        log.record(4, 0, submit());
        log.record(4, 50, TraceKind::Batch { size: 2, predicted_us: 40 });
        log.record(4, 70, ev.kind.clone());
        let acc = log.accounting();
        assert!(acc.exact, "{acc:?}");
        assert_eq!((acc.submitted, acc.failed, acc.completed), (1, 1, 0));
        // failed + complete on one span is a double terminal.
        log.record(4, 80, TraceKind::Complete { latency_us: 80, batch_size: 1 });
        assert!(!log.accounting().exact, "double terminal must not be exact");
    }

    #[test]
    fn supervision_events_are_non_terminal_and_render_house_style() {
        let restart = TraceEvent {
            span: 0,
            at_us: 900,
            kind: TraceKind::WorkerRestart { worker: 2, restarts: 1, backoff_us: 200 },
        };
        assert!(!restart.is_terminal());
        assert!(restart
            .to_json_line()
            .starts_with("{\"span\": 0, \"at_us\": 900, \"event\": \"worker_restart\""));
        let engaged = TraceEvent {
            span: 16,
            at_us: 1000,
            kind: TraceKind::FallbackEngaged {
                layer: "stem".into(),
                from: "int".into(),
                to: "float".into(),
            },
        };
        assert!(!engaged.is_terminal());
        let line = engaged.to_json_line();
        assert!(line.contains("\"event\": \"fallback_engaged\""), "{line}");
        let doc = crate::tune::json::parse(&line).unwrap();
        assert_eq!(doc.get("to").and_then(crate::tune::json::Json::as_str), Some("float"));
        let cleared = TraceEvent {
            span: 16,
            at_us: 5000,
            kind: TraceKind::FallbackCleared { layer: "stem".into(), to: "int".into() },
        };
        assert!(!cleared.is_terminal());
        assert!(cleared.to_json_line().contains("\"event\": \"fallback_cleared\""));
        // Interleaved with a normal lifecycle the accounting stays exact
        // (worker_restart rides the reserved span 0, which is never
        // submitted and never terminal, so it cannot dangle).
        let mut log = TraceLog::new();
        log.record(0, 900, restart.kind.clone());
        log.record(16, 0, submit());
        log.record(16, 1000, engaged.kind.clone());
        log.record(16, 5000, cleared.kind.clone());
        log.record(16, 6000, TraceKind::Complete { latency_us: 6000, batch_size: 1 });
        assert!(log.accounting().exact);
    }

    #[test]
    fn accounting_flags_double_terminal_and_orphans() {
        let mut log = TraceLog::new();
        log.record(1, 0, submit());
        log.record(1, 1, TraceKind::Complete { latency_us: 1, batch_size: 1 });
        log.record(1, 2, TraceKind::Reject { why: "again".into() });
        assert!(!log.accounting().exact, "double terminal must not be exact");

        let mut log = TraceLog::new();
        log.record(7, 0, TraceKind::Complete { latency_us: 1, batch_size: 1 });
        assert!(!log.accounting().exact, "orphan terminal must not be exact");

        let mut log = TraceLog::new();
        log.record(1, 0, submit());
        assert!(!log.accounting().exact, "dangling span must not be exact");
    }

    #[test]
    fn tracer_drains_in_sequence_order_across_threads() {
        let tracer = std::sync::Arc::new(Tracer::new(1 << 10));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let tr = tracer.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    tr.record(
                        t * 100 + i,
                        i,
                        TraceKind::Batch { size: 1, predicted_us: i },
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let events = tracer.drain();
        assert_eq!(events.len(), 200);
        assert_eq!(tracer.dropped(), 0);
        // Second drain is empty: drain is destructive.
        assert!(tracer.drain().is_empty());
    }

    #[test]
    fn tracer_bounds_memory_and_counts_drops() {
        let tracer = Tracer::new(2);
        // All events on one span → one shard → cap bites at 2.
        for i in 0..5u64 {
            tracer.record(8, i, TraceKind::Batch { size: 1, predicted_us: 0 });
        }
        assert_eq!(tracer.drain().len(), 2);
        assert_eq!(tracer.dropped(), 3);
        assert_eq!(tracer.dropped_terminal(), 0, "batch events are non-terminal");
    }

    #[test]
    fn dropped_terminal_reconciles_accounting_on_a_healthy_run() {
        // Regression: a capacity-1 tracer keeps the submit but drops the
        // span's complete. The request really finished — accounting must
        // forgive exactly as many dangling spans as terminals dropped,
        // not report a leak and make the CLI bail on a healthy run.
        let tracer = Tracer::new(1);
        tracer.record(8, 0, submit());
        tracer.record(8, 9, TraceKind::Complete { latency_us: 9, batch_size: 1 });
        assert_eq!(tracer.dropped(), 1);
        assert_eq!(tracer.dropped_terminal(), 1);
        let acc = tracer.accounting();
        assert!(
            acc.exact,
            "dangling span explained by a dropped terminal must stay exact: {acc:?}"
        );
        assert_eq!(acc.submitted, 1);
        assert_eq!(acc.completed + acc.rejected + acc.shed, 0);
    }

    #[test]
    fn dropped_terminal_does_not_excuse_real_leaks() {
        // Two dangling spans but only one dropped terminal: one span
        // genuinely leaked, and reconciliation must not paper over it.
        let tracer = Tracer::new(2);
        tracer.record(8, 0, submit()); // shard 0 slot 1
        tracer.record(16, 1, submit()); // shard 0 slot 2 — shard full
        tracer.record(8, 9, TraceKind::Complete { latency_us: 9, batch_size: 1 });
        assert_eq!(tracer.dropped_terminal(), 1);
        // Span 16 never got a terminal at all (nothing was dropped for
        // it beyond the one explained drop already consumed by span 8).
        tracer.record(24, 2, submit()); // dropped — but non-terminal
        assert!(
            !tracer.accounting().exact,
            "two dangling spans vs one dropped terminal must not be exact"
        );
    }

    #[test]
    fn tracer_accounting_still_flags_double_terminals() {
        let tracer = Tracer::new(1 << 10);
        tracer.record(1, 0, submit());
        tracer.record(1, 1, TraceKind::Complete { latency_us: 1, batch_size: 1 });
        tracer.record(1, 2, TraceKind::Reject { why: "again".into() });
        assert!(!tracer.accounting().exact, "double terminal must not be exact");
    }
}
