//! Process-wide metrics registry: named counters, gauges, and
//! log-bucketed histograms behind one mutex, snapshotted as JSON lines.
//!
//! Every subsystem that used to keep private aggregation state —
//! [`ServeStats`](crate::serve::ServeStats)' counters,
//! [`PlanCache`](crate::serve::PlanCache)'s
//! `packed_banks/int_banks/shape_keys` plumbing, the engine's
//! `stage_ns` drain — can export into a [`MetricsRegistry`] and the
//! single [`snapshot_json_lines`](MetricsRegistry::snapshot_json_lines)
//! emitter renders all of it. Memory is fixed per metric name: counters
//! and gauges are one word, histograms are the 128-bucket
//! [`LogHistogram`] — nothing grows with request count.
//!
//! # Naming scheme
//!
//! Dotted lowercase paths, most-general component first:
//!
//! - `serve.requests.{submitted,completed,rejected,shed}`
//! - `serve.latency_us` (histogram), `serve.batches`, `serve.queue_depth.max`
//! - `plan_cache.{packed_banks,int_banks,shape_keys,hits,misses}`
//! - `engine.stage_ns.{input_transform,hadamard,inverse}`
//! - `health.<layer>.{input_sat,hadamard_sat,output_sat}`
//!
//! Names are registered implicitly on first touch; the snapshot is
//! sorted by name (`BTreeMap`), so output order is deterministic.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::hist::LogHistogram;
use super::json::JsonObj;

/// One named metric's current value.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Hist(LogHistogram),
}

/// Thread-safe registry of named metrics. Cheap to share behind an
/// `Arc`; all mutation is through `&self`.
#[derive(Default, Debug)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, MetricValue>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Add `delta` to the named counter (created at 0 on first touch).
    ///
    /// # Panics
    /// If the name is already registered as a different metric kind.
    pub fn inc(&self, name: &str, delta: u64) {
        let mut m = self.inner.lock().unwrap();
        match m.entry(name.to_string()).or_insert(MetricValue::Counter(0)) {
            MetricValue::Counter(c) => *c += delta,
            other => panic!("metric {name:?} is not a counter: {other:?}"),
        }
    }

    /// Set the named gauge to `v` (last write wins).
    ///
    /// # Panics
    /// If the name is already registered as a different metric kind.
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut m = self.inner.lock().unwrap();
        match m.entry(name.to_string()).or_insert(MetricValue::Gauge(0.0)) {
            MetricValue::Gauge(g) => *g = v,
            other => panic!("metric {name:?} is not a gauge: {other:?}"),
        }
    }

    /// Record one sample into the named histogram.
    ///
    /// # Panics
    /// If the name is already registered as a different metric kind.
    pub fn observe(&self, name: &str, v: u64) {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| MetricValue::Hist(LogHistogram::new()))
        {
            MetricValue::Hist(h) => h.record(v),
            other => panic!("metric {name:?} is not a histogram: {other:?}"),
        }
    }

    /// Merge a pre-aggregated histogram into the named histogram —
    /// the bulk path for per-worker [`LogHistogram`]s.
    ///
    /// # Panics
    /// If the name is already registered as a different metric kind.
    pub fn merge_hist(&self, name: &str, other: &LogHistogram) {
        let mut m = self.inner.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| MetricValue::Hist(LogHistogram::new()))
        {
            MetricValue::Hist(h) => h.merge(other),
            v => panic!("metric {name:?} is not a histogram: {v:?}"),
        }
    }

    /// Current value of a counter (0 if absent or not a counter).
    pub fn counter(&self, name: &str) -> u64 {
        match self.inner.lock().unwrap().get(name) {
            Some(MetricValue::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// Current value of a gauge (`None` if absent or not a gauge).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.inner.lock().unwrap().get(name) {
            Some(MetricValue::Gauge(g)) => Some(*g),
            _ => None,
        }
    }

    /// Clone of a histogram (`None` if absent or not a histogram).
    pub fn histogram(&self, name: &str) -> Option<LogHistogram> {
        match self.inner.lock().unwrap().get(name) {
            Some(MetricValue::Hist(h)) => Some(h.clone()),
            _ => None,
        }
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when no metric has been touched yet.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Snapshot every metric as one JSON object per line (trailing
    /// newline included), sorted by metric name. Counters/gauges emit
    /// `value`; histograms emit `count/min/max/mean`, a
    /// `sum_overflowed` honesty flag (when true the mean is a floor —
    /// the underlying sum saturated), and the standard percentile
    /// ladder.
    pub fn snapshot_json_lines(&self) -> String {
        let m = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, v) in m.iter() {
            let line = match v {
                MetricValue::Counter(c) => JsonObj::new()
                    .str("metric", name)
                    .str("type", "counter")
                    .u64("value", *c)
                    .finish(),
                MetricValue::Gauge(g) => JsonObj::new()
                    .str("metric", name)
                    .str("type", "gauge")
                    .f64("value", *g, 6)
                    .finish(),
                MetricValue::Hist(h) => JsonObj::new()
                    .str("metric", name)
                    .str("type", "hist")
                    .u64("count", h.count())
                    .u64("min", h.min().unwrap_or(0))
                    .u64("max", h.max().unwrap_or(0))
                    .f64("mean", h.mean(), 3)
                    .bool("sum_overflowed", h.sum_overflowed())
                    .u64("p50", h.value_at_quantile(0.50))
                    .u64("p95", h.value_at_quantile(0.95))
                    .u64("p99", h.value_at_quantile(0.99))
                    .u64("p999", h.value_at_quantile(0.999))
                    .finish(),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_round_trip() {
        let reg = MetricsRegistry::new();
        reg.inc("serve.requests.submitted", 3);
        reg.inc("serve.requests.submitted", 2);
        reg.set_gauge("serve.queue_depth.max", 7.0);
        for v in [1000u64, 9000] {
            reg.observe("serve.latency_us", v);
        }
        assert_eq!(reg.counter("serve.requests.submitted"), 5);
        assert_eq!(reg.gauge("serve.queue_depth.max"), Some(7.0));
        let h = reg.histogram("serve.latency_us").unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), Some(9000));
        assert_eq!(reg.len(), 3);
    }

    #[test]
    fn snapshot_is_sorted_json_lines_and_parseable() {
        let reg = MetricsRegistry::new();
        reg.inc("b.counter", 1);
        reg.set_gauge("a.gauge", 0.5);
        reg.observe("c.hist", 1000);
        reg.observe("c.hist", 9000);
        let snap = reg.snapshot_json_lines();
        let lines: Vec<&str> = snap.lines().collect();
        assert_eq!(lines.len(), 3);
        // BTreeMap order: a.gauge, b.counter, c.hist.
        assert!(lines[0].contains("\"a.gauge\""));
        assert!(lines[1].contains("\"b.counter\""));
        assert!(lines[2].contains("\"c.hist\""));
        for line in &lines {
            let doc = crate::tune::json::parse(line).unwrap();
            assert!(doc.get("metric").is_some(), "line missing metric: {line}");
        }
        let hist = crate::tune::json::parse(lines[2]).unwrap();
        assert_eq!(hist.get("count").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(hist.get("max").and_then(|j| j.as_u64()), Some(9000));
        // Nearest-rank over 2 samples: p50 = min-clamped first bucket.
        assert_eq!(hist.get("p50").and_then(|j| j.as_u64()), Some(1000));
        assert_eq!(hist.get("p999").and_then(|j| j.as_u64()), Some(8192));
        assert!(snap.ends_with('\n'));
    }

    #[test]
    fn snapshot_surfaces_hist_sum_overflow() {
        let reg = MetricsRegistry::new();
        reg.observe("ok.hist", 5);
        reg.observe("bad.hist", u64::MAX);
        reg.observe("bad.hist", u64::MAX);
        let snap = reg.snapshot_json_lines();
        let lines: Vec<&str> = snap.lines().collect();
        assert!(lines[0].contains("\"sum_overflowed\": true"), "{}", lines[0]);
        assert!(lines[1].contains("\"sum_overflowed\": false"), "{}", lines[1]);
    }

    #[test]
    fn merge_hist_folds_worker_local_aggregates() {
        let reg = MetricsRegistry::new();
        let mut local = LogHistogram::new();
        local.record(10);
        local.record(20);
        reg.merge_hist("w.lat", &local);
        reg.merge_hist("w.lat", &local);
        assert_eq!(reg.histogram("w.lat").unwrap().count(), 4);
    }

    #[test]
    #[should_panic(expected = "not a counter")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        reg.set_gauge("x", 1.0);
        reg.inc("x", 1);
    }
}
