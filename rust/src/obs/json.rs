//! The one JSON writer every emitter goes through.
//!
//! Before this module each `BENCH_*.json` producer (`serve/stats.rs`,
//! `testkit/soak.rs`, `tune/netplan.rs`, the bench emitters) hand-rolled
//! its own `format!` string — same house style, zero shared escaping,
//! and a key-order typo away from breaking the `scripts/ci.sh` `sed`
//! gates. [`JsonObj`]/[`JsonArr`] are push-based builders that preserve
//! insertion order and produce exactly the repo's compact one-line
//! style: `{"key": value, "key2": value2}` — byte-compatible with what
//! the `format!` emitters produced, so migrating an emitter changes no
//! output bytes.
//!
//! String values are escaped through the one tested escaper
//! ([`escape`], re-exported from [`tune::json`](crate::tune::json) so
//! the writer and the reader agree on the dialect). Numeric formatting
//! is explicit at the call site — [`JsonObj::f64`] takes the precision
//! (`{:.3}` etc. in the old emitters) and [`JsonObj::raw`] accepts any
//! pre-serialized value (scientific notation, nested objects, arrays) —
//! because the byte-exact output *is* the contract: CI parses these
//! files with `sed`, and the soak emitter is pinned byte-identical per
//! seed.

pub use crate::tune::json::escape;

/// Order-preserving JSON object builder (consuming, chainable).
#[derive(Debug)]
pub struct JsonObj {
    buf: String,
    empty: bool,
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonObj {
    pub fn new() -> JsonObj {
        JsonObj { buf: String::from("{"), empty: true }
    }

    fn key(&mut self, k: &str) {
        if !self.empty {
            self.buf.push_str(", ");
        }
        self.empty = false;
        self.buf.push('"');
        self.buf.push_str(&escape(k));
        self.buf.push_str("\": ");
    }

    /// Unsigned integer field.
    pub fn u64(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Signed integer field.
    pub fn i64(mut self, k: &str, v: i64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Float field with an explicit decimal precision — `f64("p", v, 3)`
    /// emits exactly what `format!("{:.3}", v)` did.
    pub fn f64(mut self, k: &str, v: f64, prec: usize) -> Self {
        self.key(k);
        self.buf.push_str(&format!("{v:.prec$}"));
        self
    }

    /// Escaped string field.
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Boolean field.
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Pre-serialized value (nested object/array, scientific-notation
    /// float, …) — spliced verbatim, caller owns its validity.
    pub fn raw(mut self, k: &str, raw: &str) -> Self {
        self.key(k);
        self.buf.push_str(raw);
        self
    }

    /// Close the object and return the JSON string.
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// Order-preserving JSON array builder over pre-serialized items.
#[derive(Debug)]
pub struct JsonArr {
    buf: String,
    empty: bool,
}

impl Default for JsonArr {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonArr {
    pub fn new() -> JsonArr {
        JsonArr { buf: String::from("["), empty: true }
    }

    /// Append one pre-serialized element (e.g. a [`JsonObj::finish`]
    /// result).
    pub fn item(mut self, raw: &str) -> Self {
        if !self.empty {
            self.buf.push_str(", ");
        }
        self.empty = false;
        self.buf.push_str(raw);
        self
    }

    /// Close the array and return the JSON string.
    pub fn finish(mut self) -> String {
        self.buf.push(']');
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_matches_the_house_format_byte_for_byte() {
        let got = JsonObj::new()
            .u64("submitted", 5)
            .f64("mean_batch", 2.5, 3)
            .str("model", "model-a")
            .bool("hit", true)
            .i64("delta", -3)
            .finish();
        let want = format!(
            "{{\"submitted\": {}, \"mean_batch\": {:.3}, \"model\": \"{}\", \
             \"hit\": {}, \"delta\": {}}}",
            5, 2.5, "model-a", true, -3
        );
        assert_eq!(got, want);
    }

    #[test]
    fn nesting_and_arrays_compose() {
        let inner = JsonObj::new().f64("p50", 1.5, 3).finish();
        let arr = JsonArr::new()
            .item(&JsonObj::new().u64("a", 1).finish())
            .item(&JsonObj::new().u64("a", 2).finish())
            .finish();
        let got = JsonObj::new()
            .raw("latency_ms", &inner)
            .raw("per_model", &arr)
            .finish();
        assert_eq!(
            got,
            "{\"latency_ms\": {\"p50\": 1.500}, \
             \"per_model\": [{\"a\": 1}, {\"a\": 2}]}"
        );
    }

    #[test]
    fn strings_are_escaped_and_parseable() {
        let got = JsonObj::new().str("why", "a \"quoted\"\nline\\").finish();
        let doc = crate::tune::json::parse(&got).unwrap();
        assert_eq!(
            doc.get("why").and_then(crate::tune::json::Json::as_str),
            Some("a \"quoted\"\nline\\")
        );
    }

    #[test]
    fn empty_containers_are_valid() {
        assert_eq!(JsonObj::new().finish(), "{}");
        assert_eq!(JsonArr::new().finish(), "[]");
        let doc = crate::tune::json::parse(&JsonObj::new().finish()).unwrap();
        assert!(doc.get("anything").is_none());
    }
}
