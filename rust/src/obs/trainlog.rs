//! Training-step log: step records, moving averages, CSV export for the
//! training coordinator — the one non-serving metrics surface, kept
//! under `obs` so there is exactly one observability layer.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

/// One recorded training step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    /// Wall-clock seconds for this step (compute + coordinator overhead).
    pub seconds: f64,
}

/// Accumulates step records; computes summaries; writes CSV.
#[derive(Default)]
pub struct MetricLog {
    pub records: Vec<StepRecord>,
    pub evals: Vec<(u64, f32, f64)>, // (step, eval loss, eval accuracy)
}

impl MetricLog {
    pub fn new() -> MetricLog {
        MetricLog::default()
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.records.push(rec);
    }

    pub fn push_eval(&mut self, step: u64, loss: f32, accuracy: f64) {
        self.evals.push((step, loss, accuracy));
    }

    /// Mean training loss over the last `n` steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32
    }

    /// Mean training accuracy over the last `n` steps.
    pub fn recent_acc(&self, n: usize) -> f32 {
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|r| r.acc).sum::<f32>() / tail.len() as f32
    }

    /// Mean seconds/step over the last `n` steps.
    pub fn recent_step_time(&self, n: usize) -> f64 {
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|r| r.seconds).sum::<f64>() / tail.len() as f64
    }

    /// Best (max) eval accuracy seen.
    pub fn best_eval_acc(&self) -> Option<f64> {
        self.evals
            .iter()
            .map(|&(_, _, a)| a)
            .max_by(|x, y| x.partial_cmp(y).unwrap())
    }

    /// Final eval accuracy.
    pub fn last_eval_acc(&self) -> Option<f64> {
        self.evals.last().map(|&(_, _, a)| a)
    }

    /// CSV: step,loss,acc,lr,seconds plus eval rows.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("kind,step,loss,acc,lr,seconds\n");
        for r in &self.records {
            let _ = writeln!(
                s,
                "train,{},{:.6},{:.4},{:.6},{:.4}",
                r.step, r.loss, r.acc, r.lr, r.seconds
            );
        }
        for &(step, loss, acc) in &self.evals {
            let _ = writeln!(s, "eval,{step},{loss:.6},{acc:.4},,");
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32) -> StepRecord {
        StepRecord { step, loss, acc: 0.5, lr: 0.1, seconds: 0.01 }
    }

    #[test]
    fn recent_loss_windows() {
        let mut log = MetricLog::new();
        for i in 0..10 {
            log.push(rec(i, i as f32));
        }
        assert!((log.recent_loss(2) - 8.5).abs() < 1e-6);
        assert!((log.recent_loss(100) - 4.5).abs() < 1e-6);
    }

    #[test]
    fn empty_log_is_nan() {
        let log = MetricLog::new();
        assert!(log.recent_loss(5).is_nan());
        assert!(log.best_eval_acc().is_none());
    }

    #[test]
    fn eval_tracking() {
        let mut log = MetricLog::new();
        log.push_eval(10, 1.0, 0.4);
        log.push_eval(20, 0.8, 0.7);
        log.push_eval(30, 0.9, 0.6);
        assert_eq!(log.best_eval_acc(), Some(0.7));
        assert_eq!(log.last_eval_acc(), Some(0.6));
    }

    #[test]
    fn csv_shape() {
        let mut log = MetricLog::new();
        log.push(rec(1, 2.0));
        log.push_eval(1, 1.5, 0.3);
        let csv = log.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("kind,step"));
        assert!(lines[1].starts_with("train,1,"));
        assert!(lines[2].starts_with("eval,1,"));
    }

    #[test]
    fn timer_runs() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.seconds() >= 0.004);
    }
}
