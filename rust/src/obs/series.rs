//! Windowed time-series: a ring of `LogHistogram` windows rotated on a
//! virtual-clock boundary.
//!
//! The [`MetricsRegistry`](super::MetricsRegistry) aggregates over a
//! process lifetime — good for totals, useless for "what happened in the
//! last minute". A [`TimeSeries`] keeps the newest `capacity` fixed-width
//! windows of samples (each a 128-bucket [`LogHistogram`], so memory is
//! bounded regardless of sample rate) plus one lifetime histogram, and
//! rotates purely on the caller-supplied timestamp. Under the soak
//! harness's virtual microsecond clock the rotation points are therefore
//! exact and replayable: the same `(at_us, value)` stream always produces
//! the same windows, which is what lets drift monitoring ride inside the
//! byte-identical soak pin.
//!
//! Conservation invariant (property-tested below with
//! [`testkit::forall`](crate::testkit::forall)): every recorded sample
//! lands in exactly one retained window or the `evicted` count, so
//! `total.count() == evicted + Σ window counts` at all times.
//!
//! Consumers: queue-depth and inflight-batch gauges in
//! [`serve::stats`](crate::serve), per-window latency, and the
//! per-layer rel-L2 drift series in [`obs::drift`](super::drift).

use super::hist::LogHistogram;
use super::metrics::MetricsRegistry;

/// One rotation window: all samples whose `at_us / window_us == index`.
#[derive(Clone, Debug)]
pub struct SeriesWindow {
    /// Window ordinal: `at_us / window_us` of every sample inside.
    pub index: u64,
    /// The window's samples.
    pub hist: LogHistogram,
}

/// Ring of the newest `capacity` windows plus a lifetime aggregate.
///
/// `record` is O(1); rotation evicts the oldest window by folding its
/// count into `evicted` (its samples stay represented in `total`).
/// Samples older than the oldest retained window are clamped into it so
/// no sample is ever silently dropped — under the deterministic soak
/// clock timestamps are monotone and the clamp never fires.
#[derive(Clone, Debug)]
pub struct TimeSeries {
    name: String,
    window_us: u64,
    capacity: usize,
    windows: Vec<SeriesWindow>,
    total: LogHistogram,
    evicted: u64,
}

impl TimeSeries {
    /// New series named `name`, rotating every `window_us` virtual
    /// microseconds, retaining the newest `capacity` windows.
    ///
    /// # Panics
    /// If `window_us == 0` or `capacity == 0`.
    pub fn new(name: &str, window_us: u64, capacity: usize) -> TimeSeries {
        assert!(window_us > 0, "window_us must be positive");
        assert!(capacity > 0, "capacity must be positive");
        TimeSeries {
            name: name.to_string(),
            window_us,
            capacity,
            windows: Vec::new(),
            total: LogHistogram::new(),
            evicted: 0,
        }
    }

    /// Series name (used as the metric-name prefix on export).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Window width in virtual microseconds.
    pub fn window_us(&self) -> u64 {
        self.window_us
    }

    /// Record `value` at virtual time `at_us`.
    pub fn record(&mut self, at_us: u64, value: u64) {
        self.total.record(value);
        let idx = at_us / self.window_us;
        // Common path: the sample belongs to the newest window.
        if let Some(last) = self.windows.last_mut() {
            if last.index == idx {
                last.hist.record(value);
                return;
            }
        }
        match self.windows.last().map(|w| w.index) {
            Some(newest) if idx < newest => {
                // Late sample: clamp into the nearest retained window
                // (exact window if still retained, else the oldest).
                let w = self
                    .windows
                    .iter_mut()
                    .find(|w| w.index >= idx)
                    .expect("newest window exists");
                w.hist.record(value);
            }
            _ => {
                // New boundary crossed: open a window, evict from the
                // front once over capacity.
                self.windows.push(SeriesWindow { index: idx, hist: LogHistogram::new() });
                self.windows.last_mut().unwrap().hist.record(value);
                while self.windows.len() > self.capacity {
                    let old = self.windows.remove(0);
                    self.evicted += old.hist.count();
                }
            }
        }
    }

    /// Retained windows, oldest first. Indices are strictly increasing
    /// (boundaries are monotone) but not necessarily contiguous — empty
    /// windows are never materialised.
    pub fn windows(&self) -> &[SeriesWindow] {
        &self.windows
    }

    /// The newest retained window, if any sample has been recorded.
    pub fn current(&self) -> Option<&SeriesWindow> {
        self.windows.last()
    }

    /// Lifetime histogram over every sample ever recorded.
    pub fn total(&self) -> &LogHistogram {
        &self.total
    }

    /// Samples rotated out of the ring (still counted in `total`).
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Merge of all retained windows — the "recent" view. Equals the
    /// histogram of the concatenated retained samples (LogHistogram
    /// merge is associative; property-tested).
    pub fn merged(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for w in &self.windows {
            h.merge(&w.hist);
        }
        h
    }

    /// Export into a [`MetricsRegistry`] snapshot:
    ///
    /// - `<name>` — lifetime histogram,
    /// - `<name>.recent` — merge of retained windows,
    /// - `<name>.windows` — gauge, retained window count,
    /// - `<name>.evicted` — counter, samples rotated out.
    pub fn export_metrics(&self, reg: &MetricsRegistry) {
        reg.merge_hist(&self.name, &self.total);
        reg.merge_hist(&format!("{}.recent", self.name), &self.merged());
        reg.set_gauge(&format!("{}.windows", self.name), self.windows.len() as f64);
        if self.evicted > 0 {
            reg.inc(&format!("{}.evicted", self.name), self.evicted);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_land_in_their_clock_window() {
        let mut s = TimeSeries::new("q", 1000, 4);
        s.record(0, 5);
        s.record(999, 6);
        s.record(1000, 7);
        assert_eq!(s.windows().len(), 2);
        assert_eq!(s.windows()[0].index, 0);
        assert_eq!(s.windows()[0].hist.count(), 2);
        assert_eq!(s.windows()[1].index, 1);
        assert_eq!(s.windows()[1].hist.count(), 1);
        assert_eq!(s.total().count(), 3);
        assert_eq!(s.evicted(), 0);
    }

    #[test]
    fn rotation_evicts_front_and_conserves_counts() {
        let mut s = TimeSeries::new("q", 100, 3);
        for i in 0..6u64 {
            // One sample per window: windows 0..6.
            s.record(i * 100, i + 1);
        }
        assert_eq!(s.windows().len(), 3);
        let retained: Vec<u64> = s.windows().iter().map(|w| w.index).collect();
        assert_eq!(retained, vec![3, 4, 5]);
        assert_eq!(s.evicted(), 3);
        let win_count: u64 = s.windows().iter().map(|w| w.hist.count()).sum();
        assert_eq!(s.total().count(), s.evicted() + win_count);
    }

    #[test]
    fn sparse_clocks_skip_empty_windows() {
        let mut s = TimeSeries::new("q", 10, 8);
        s.record(5, 1);
        s.record(95, 2);
        let idx: Vec<u64> = s.windows().iter().map(|w| w.index).collect();
        assert_eq!(idx, vec![0, 9]);
    }

    #[test]
    fn late_samples_clamp_into_nearest_retained_window() {
        let mut s = TimeSeries::new("q", 10, 2);
        s.record(0, 1); // window 0 — will be evicted
        s.record(10, 2); // window 1
        s.record(20, 3); // window 2; evicts window 0
        s.record(1, 99); // late: window 0 gone, clamps into window 1
        assert_eq!(s.windows()[0].index, 1);
        assert_eq!(s.windows()[0].hist.count(), 2);
        assert_eq!(s.total().count(), 4);
        assert_eq!(s.evicted() + s.merged().count(), s.total().count());
    }

    #[test]
    fn merged_equals_concatenation_of_retained() {
        let mut s = TimeSeries::new("q", 50, 4);
        let samples = [(0u64, 3u64), (10, 9), (60, 27), (120, 81), (130, 5)];
        let mut direct = LogHistogram::new();
        for &(t, v) in &samples {
            s.record(t, v);
            direct.record(v);
        }
        let m = s.merged();
        assert_eq!(m.count(), direct.count());
        assert_eq!(m.min(), direct.min());
        assert_eq!(m.max(), direct.max());
        assert_eq!(m.value_at_quantile(0.5), direct.value_at_quantile(0.5));
    }

    #[test]
    fn export_metrics_publishes_the_series_family() {
        let mut s = TimeSeries::new("serve.queue_depth", 100, 2);
        for i in 0..4u64 {
            s.record(i * 100, i);
        }
        let reg = MetricsRegistry::new();
        s.export_metrics(&reg);
        assert_eq!(reg.histogram("serve.queue_depth").unwrap().count(), 4);
        assert_eq!(reg.histogram("serve.queue_depth.recent").unwrap().count(), 2);
        assert_eq!(reg.gauge("serve.queue_depth.windows"), Some(2.0));
        assert_eq!(reg.counter("serve.queue_depth.evicted"), 2);
    }

    #[test]
    #[should_panic(expected = "window_us must be positive")]
    fn zero_window_width_is_refused() {
        TimeSeries::new("q", 0, 1);
    }

    /// Property suite over random sample streams (mostly-monotone clocks
    /// with occasional late samples): window boundaries stay strictly
    /// monotone, no sample is lost or double-counted, the ring respects
    /// its capacity, and the merged view equals the concatenation of the
    /// retained windows.
    #[test]
    fn rotation_properties_hold_for_random_streams() {
        use crate::wino::error::Prng;
        crate::testkit::forall(
            0x5E21E5,
            24,
            |rng: &mut Prng| {
                let window = 1 + rng.next_u64() % 1000;
                let cap = 1 + (rng.next_u64() % 6) as usize;
                let n = 1 + (rng.next_u64() % 200) as usize;
                let mut t = 0u64;
                let samples: Vec<(u64, u64)> = (0..n)
                    .map(|_| {
                        t += rng.next_u64() % (window / 2 + 2);
                        let at = if rng.next_u64() % 8 == 0 {
                            // Late sample: may fall behind the oldest
                            // retained window and exercise the clamp.
                            t.saturating_sub(rng.next_u64() % (window * 3))
                        } else {
                            t
                        };
                        (at, rng.next_u64() % 10_000)
                    })
                    .collect();
                (window, cap, samples)
            },
            |(window, cap, samples)| {
                let mut s = TimeSeries::new("p", *window, *cap);
                for &(at, v) in samples {
                    s.record(at, v);
                }
                let retained: u64 = s.windows().iter().map(|w| w.hist.count()).sum();
                let conserved = s.total().count() == samples.len() as u64
                    && s.total().count() == s.evicted() + retained;
                let monotone = s.windows().windows(2).all(|p| p[0].index < p[1].index);
                let bounded = s.windows().len() <= *cap && !s.windows().is_empty();
                let merged = s.merged();
                let retained_sum: u64 = s.windows().iter().map(|w| w.hist.sum()).sum();
                let merge_is_concat =
                    merged.count() == retained && merged.sum() == retained_sum;
                conserved && monotone && bounded && merge_is_concat
            },
        );
    }
}
