//! Unified observability layer: metrics registry, request tracing, and
//! the shared JSON writer every `BENCH_*.json` emitter goes through.
//!
//! Three pillars, all zero-dependency and bounded-memory:
//!
//! 1. **Metrics** ([`metrics`]) — a process-wide [`MetricsRegistry`] of
//!    named counters, gauges, and log-bucketed [`LogHistogram`]s with a
//!    deterministic JSON-lines snapshot. Serving stats
//!    ([`ServeStats`](crate::serve::ServeStats)), plan-cache counters
//!    ([`PlanCache`](crate::serve::PlanCache)), and engine stage
//!    timings all export here.
//! 2. **Tracing** ([`trace`]) — span IDs minted at admission and
//!    stamped through scheduling, shard routing, plan-cache lookups,
//!    the three engine stages, and completion; drained as JSON lines
//!    (`winoq serve --trace-json`, `--soak --trace-json`) with an exact
//!    accounting invariant: submitted = completed + rejected + shed.
//! 3. **Numeric health** — saturation counters inside
//!    [`engine::int`](crate::engine) (input-quantize clips, 9-bit
//!    Hadamard clamp hits, requant epilogue clips), surfaced per layer
//!    through the registry and `winoq bench --health-json`.
//! 4. **Time series & drift** ([`series`], [`drift`]) — windowed
//!    [`TimeSeries`] (ring of `LogHistogram` windows rotated on the
//!    virtual clock) feeding queue-depth/latency windows and the
//!    shadow-oracle accuracy-drift monitor: every Nth span's
//!    Winograd-eligible layers are re-run against the f64 direct-conv
//!    oracle, per-layer rel-L2 is compared to the NetPlan v2 tuned
//!    budget, and violations emit [`TraceKind::DriftAlert`] events
//!    plus the `winoq serve --drift-json` report.
//!
//! [`trainlog`] is the training coordinator's step/CSV log — separate
//! from serving metrics but kept under the same roof.
//!
//! See the "Observability" and "Accuracy drift & regression gating"
//! sections of `docs/ARCHITECTURE.md` for the naming scheme, span
//! lifecycle, sampling rule, and metric catalog.

pub mod drift;
pub mod hist;
pub mod json;
pub mod metrics;
pub mod series;
pub mod trace;
pub mod trainlog;

pub use drift::{DriftConfig, DriftMonitor, DriftSample};
pub use hist::LogHistogram;
pub use metrics::{MetricValue, MetricsRegistry};
pub use series::TimeSeries;
pub use trace::{
    mint_span, SpanAccounting, TraceEvent, TraceKind, TraceLog, TraceSink, Tracer,
};
pub use trainlog::{MetricLog, StepRecord, Timer};
