//! Unified observability layer: metrics registry, request tracing, and
//! the shared JSON writer every `BENCH_*.json` emitter goes through.
//!
//! Three pillars, all zero-dependency and bounded-memory:
//!
//! 1. **Metrics** ([`metrics`]) — a process-wide [`MetricsRegistry`] of
//!    named counters, gauges, and log-bucketed [`LogHistogram`]s with a
//!    deterministic JSON-lines snapshot. Serving stats
//!    ([`ServeStats`](crate::serve::ServeStats)), plan-cache counters
//!    ([`PlanCache`](crate::serve::PlanCache)), and engine stage
//!    timings all export here.
//! 2. **Tracing** ([`trace`]) — span IDs minted at admission and
//!    stamped through scheduling, shard routing, plan-cache lookups,
//!    the three engine stages, and completion; drained as JSON lines
//!    (`winoq serve --trace-json`, `--soak --trace-json`) with an exact
//!    accounting invariant: submitted = completed + rejected + shed.
//! 3. **Numeric health** — saturation counters inside
//!    [`engine::int`](crate::engine) (input-quantize clips, 9-bit
//!    Hadamard clamp hits, requant epilogue clips), surfaced per layer
//!    through the registry and `winoq bench --health-json`.
//!
//! See the "Observability" section of `docs/ARCHITECTURE.md` for the
//! naming scheme, span lifecycle, and metric catalog.

pub mod hist;
pub mod json;
pub mod metrics;
pub mod trace;

pub use hist::LogHistogram;
pub use metrics::{MetricValue, MetricsRegistry};
pub use trace::{
    mint_span, SpanAccounting, TraceEvent, TraceKind, TraceLog, TraceSink, Tracer,
};
