//! Log-bucketed histogram — the bounded-memory distribution primitive
//! under every latency/size metric in the observability layer.
//!
//! [`ServeStats`](crate::serve::ServeStats) used to push every request's
//! latency into a raw `Vec<u64>` for the lifetime of the run — unbounded
//! growth under a soak. [`LogHistogram`] replaces that with a **fixed**
//! 128-bucket layout at ~2 buckets per octave: bucket `0` holds the
//! value `0`, bucket `1` holds `1`, and from there every octave
//! `[2^e, 2^{e+1})` splits into two half-octave buckets at `3·2^{e-1}`.
//! The indexing is *compact* — every bucket index in `0..=127` is
//! reachable and the lower bounds are strictly monotone with no gaps —
//! so `u64::MAX` lands safely in bucket 127 (`e = 63`, upper half).
//!
//! Percentiles come out nearest-rank over the cumulative bucket counts
//! (the same rank rule as
//! [`benchkit::percentile_sorted`](crate::benchkit::percentile_sorted)),
//! reporting the selected bucket's lower bound clamped into the exact
//! `[min, max]` the histogram tracked — so the max percentile is exact,
//! and small sample counts whose values sit on bucket boundaries agree
//! with the sorted nearest-rank answer exactly (pinned in
//! `serve::stats` and the property suite below). Resolution inside a
//! bucket is a half octave (≤ 50% relative), the classic
//! latency-histogram trade: fixed memory, mergeable, O(1) record.

/// Number of buckets: indices `0` and `1` for the exact values 0 and 1,
/// then two half-octave buckets per exponent `e ∈ 1..=63`.
pub const BUCKETS: usize = 128;

/// Fixed-memory log-bucketed histogram over `u64` samples with exact
/// min/max tracking. `Default`-constructible, mergeable, clonable.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    /// Saturating sum of all recorded values (mean reporting).
    sum: u64,
    /// True once `sum` has saturated at `u64::MAX` — from then on the
    /// exported mean is a floor, not the truth, and snapshots must say
    /// so instead of silently reporting a corrupted average.
    sum_overflowed: bool,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0,
            sum_overflowed: false,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of a value: `0 → 0`, `1 → 1`, else with
    /// `e = floor(log2 v)` the index is `2e` for the lower half-octave
    /// (`v < 3·2^{e-1}`) and `2e + 1` for the upper. Compact: every
    /// index in `0..BUCKETS` is hit by some value, and
    /// `idx(u64::MAX) = 127`.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        match v {
            0 => 0,
            1 => 1,
            _ => {
                let e = 63 - v.leading_zeros() as usize;
                2 * e + usize::from(v >= 3u64 << (e - 1))
            }
        }
    }

    /// Inclusive lower bound of bucket `i` — the value a percentile
    /// query reports for that bucket (before min/max clamping). Strictly
    /// monotone in `i`; `bucket_lo(bucket_index(v)) <= v` always holds.
    #[inline]
    pub fn bucket_lo(i: usize) -> u64 {
        assert!(i < BUCKETS, "bucket index out of range");
        match i {
            0 => 0,
            1 => 1,
            _ => {
                let e = i / 2;
                (1u64 << e) + (i as u64 % 2) * (1u64 << (e - 1))
            }
        }
    }

    /// Record one sample. O(1); the sum saturates rather than wraps,
    /// and saturation latches [`sum_overflowed`](Self::sum_overflowed).
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum = match self.sum.checked_add(v) {
            Some(s) => s,
            None => {
                self.sum_overflowed = true;
                u64::MAX
            }
        };
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram in (bucket-wise add; min/max widen; sum
    /// saturates and the overflow latch propagates). Associative and
    /// commutative — the property suite pins both — so per-worker
    /// histograms can merge in any order.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum = match self.sum.checked_add(other.sum) {
            Some(s) => s,
            None => {
                self.sum_overflowed = true;
                u64::MAX
            }
        };
        self.sum_overflowed |= other.sum_overflowed;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Saturating sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// True if `sum` ever saturated (directly or via a merged
    /// histogram that had) — when set, [`mean`](Self::mean) is a lower
    /// bound, not an average, and exporters surface the flag.
    pub fn sum_overflowed(&self) -> bool {
        self.sum_overflowed
    }

    /// Exact smallest recorded value (`None` when empty).
    pub fn min(&self) -> Option<u64> {
        (self.count > 0).then_some(self.min)
    }

    /// Exact largest recorded value (`None` when empty).
    pub fn max(&self) -> Option<u64> {
        (self.count > 0).then_some(self.max)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile `q ∈ [0, 1]`: rank `⌈q·n⌉` clamped to
    /// `[1, n]` over the cumulative bucket counts, reporting the
    /// selected bucket's lower bound clamped into the exact tracked
    /// `[min, max]` (so `q = 1.0` returns the exact max). Returns 0 on
    /// an empty histogram.
    pub fn value_at_quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q >= 1.0 {
            // Rank alone cannot distinguish q = 1.0 from e.g. q = 0.999
            // at small counts (both select the last sample), but only
            // the true max quantile is promised exact.
            return self.max;
        }
        let rank = ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_lo(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn bucket_lo_is_strictly_monotone_and_compact() {
        for i in 1..BUCKETS {
            assert!(
                LogHistogram::bucket_lo(i) > LogHistogram::bucket_lo(i - 1),
                "lo({i}) must exceed lo({})",
                i - 1
            );
            // Compactness: every bucket's lower bound maps back to it —
            // no index is unreachable.
            assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_lo(i)), i);
        }
        assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_lo(0)), 0);
    }

    #[test]
    fn forall_bucket_boundaries_bracket_every_value() {
        // Monotone, no gaps: lo(idx(v)) <= v < lo(idx(v) + 1), over the
        // full u64 range including u64::MAX (safe, bucket 127).
        forall(
            0x0B5,
            4000,
            |rng: &mut crate::wino::error::Prng| {
                // Mix small values and full-range values so every octave
                // band gets traffic.
                let raw = rng.next_u64();
                match raw % 4 {
                    0 => raw % 64,
                    1 => raw % 65_536,
                    2 => raw >> (raw % 40),
                    _ => raw,
                }
            },
            |&v| {
                let i = LogHistogram::bucket_index(v);
                i < BUCKETS
                    && LogHistogram::bucket_lo(i) <= v
                    && (i + 1 >= BUCKETS || v < LogHistogram::bucket_lo(i + 1))
            },
        );
        assert_eq!(LogHistogram::bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(LogHistogram::bucket_index(0), 0);
        assert_eq!(LogHistogram::bucket_index(1), 1);
        assert_eq!(LogHistogram::bucket_index(2), 2);
        assert_eq!(LogHistogram::bucket_index(3), 3);
    }

    #[test]
    fn forall_merge_is_associative_and_commutative() {
        forall(
            0x0B6,
            200,
            |rng: &mut crate::wino::error::Prng| {
                let gen_set =
                    |rng: &mut crate::wino::error::Prng| -> Vec<u64> {
                        (0..(rng.next_u64() % 20)).map(|_| rng.next_u64() >> (rng.next_u64() % 50)).collect()
                    };
                (gen_set(rng), gen_set(rng), gen_set(rng))
            },
            |(a, b, c)| {
                let h = |vs: &[u64]| {
                    let mut h = LogHistogram::new();
                    for &v in vs {
                        h.record(v);
                    }
                    h
                };
                let (ha, hb, hc) = (h(a), h(b), h(c));
                // (A ∪ B) ∪ C == A ∪ (B ∪ C) and A ∪ B == B ∪ A.
                let mut ab_c = ha.clone();
                ab_c.merge(&hb);
                ab_c.merge(&hc);
                let mut bc = hb.clone();
                bc.merge(&hc);
                let mut a_bc = ha.clone();
                a_bc.merge(&bc);
                let mut ba = hb.clone();
                ba.merge(&ha);
                let mut ab = ha.clone();
                ab.merge(&hb);
                ab_c.counts == a_bc.counts
                    && ab_c.count == a_bc.count
                    && ab_c.min == a_bc.min
                    && ab_c.max == a_bc.max
                    && ab.counts == ba.counts
            },
        );
    }

    #[test]
    fn quantiles_agree_with_nearest_rank_on_boundary_samples() {
        // Samples sitting exactly on bucket lower bounds: the histogram
        // quantile must equal benchkit's sorted nearest-rank answer for
        // every q — small-sample agreement, pinned.
        let samples: Vec<u64> = vec![1, 2, 4, 8, 16, 24, 32, 64, 96];
        let mut h = LogHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        let sorted: Vec<f64> = samples.iter().map(|&v| v as f64).collect();
        for q in [0.01, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let want = crate::benchkit::percentile_sorted(&sorted, q) as u64;
            assert_eq!(h.value_at_quantile(q), want, "q = {q}");
        }
    }

    #[test]
    fn min_max_are_exact_and_clamp_quantiles() {
        let mut h = LogHistogram::new();
        h.record(1000);
        h.record(9000);
        assert_eq!(h.min(), Some(1000));
        assert_eq!(h.max(), Some(9000));
        // Rank 1 selects the bucket holding 1000 (lo = 768) but the
        // exact min clamps it back up; rank 2 reports 9000's bucket lo.
        assert_eq!(h.value_at_quantile(0.5), 1000);
        assert_eq!(h.value_at_quantile(0.999), 8192);
        assert_eq!(h.value_at_quantile(1.0), 9000, "max quantile is exact");
    }

    #[test]
    fn empty_and_extreme_histograms_are_safe() {
        let h = LogHistogram::new();
        assert_eq!(h.value_at_quantile(0.5), 0);
        assert_eq!(h.min(), None);
        assert_eq!(h.max(), None);
        assert_eq!(h.mean(), 0.0);
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), u64::MAX, "sum saturates, never wraps");
        assert!(h.sum_overflowed(), "saturation must latch the flag");
        assert_eq!(h.value_at_quantile(1.0), u64::MAX);
    }

    #[test]
    fn sum_overflow_latches_and_propagates_through_merge() {
        // Below saturation the flag stays clear — an exact u64::MAX sum
        // is fine, only a wrap-around sets it.
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.sum(), u64::MAX);
        assert!(!h.sum_overflowed(), "exact MAX sum is not an overflow");
        h.record(0);
        assert!(!h.sum_overflowed(), "adding zero cannot overflow");
        h.record(1);
        assert!(h.sum_overflowed());
        assert_eq!(h.sum(), u64::MAX, "sum stays saturated after overflow");

        // Merge: the latch propagates from either side, and a merge
        // whose combined sum overflows sets it even when neither input
        // had overflowed on its own.
        let mut clean = LogHistogram::new();
        clean.record(7);
        let mut acc = clean.clone();
        acc.merge(&h);
        assert!(acc.sum_overflowed(), "merge must carry the source latch");

        let mut a = LogHistogram::new();
        a.record(u64::MAX - 1);
        let mut b = LogHistogram::new();
        b.record(2);
        assert!(!a.sum_overflowed() && !b.sum_overflowed());
        a.merge(&b);
        assert!(a.sum_overflowed(), "merge-time overflow must be detected");
        assert_eq!(a.sum(), u64::MAX);
    }
}
