//! Shadow-oracle accuracy-drift monitoring.
//!
//! The paper's claim is an *accuracy* statement — 8-bit quantized
//! Winograd within 0.5% of direct convolution — but quantized Winograd
//! error is input-distribution-dependent (arXiv 1803.10986): a NetPlan
//! calibrated on one activation distribution can silently go stale
//! under real traffic. This module closes that loop: serve workers
//! re-run a deterministic subset of live requests' Winograd-eligible
//! layers through the f64 direct-conv oracle already used by
//! [`tune::cost`](crate::tune::cost), record the per-layer rel-L2 error
//! into windowed [`TimeSeries`], and compare each window against the
//! budget the tuner accepted (NetPlan v2 `tuned_err`). Violations emit
//! [`TraceKind::DriftAlert`] events into the trace stream and surface
//! in the `winoq serve --drift-json` report.
//!
//! # Sampling rule
//!
//! A span is shadow-sampled iff `span % stride == seed % stride`
//! (stride 0 disables sampling). The rule is a pure function of the
//! span ID — it consumes **zero** PRNG draws — so enabling drift
//! monitoring cannot perturb a deterministic soak run, and rerunning
//! the same seed samples the same spans: the trace stream stays
//! byte-identical.
//!
//! # Budgets
//!
//! The per-layer budget is `tuned_err × headroom` — the tuner's
//! measured acceptance error with slack for ordinary input variation.
//! Layers without a tuned anchor (v1 plans, self-calibrated synthetic
//! serving before the first probe) are **report-only**: their series
//! still record, but no alert can fire. Errors are carried as integer
//! parts-per-billion (`rel_err × 1e9`) so histograms, trace payloads,
//! and reports stay integer-exact and replay-stable.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use super::json::{JsonArr, JsonObj};
use super::metrics::MetricsRegistry;
use super::series::TimeSeries;
use super::trace::TraceKind;
use crate::wino::basis::Base;

/// One sampled layer's shadow-oracle measurement.
#[derive(Clone, Debug)]
pub struct DriftSample {
    /// Conv-unit prefix, e.g. `"stem"`.
    pub layer: String,
    /// Winograd tile size the layer executed with.
    pub m: usize,
    pub base: Base,
    pub weight_bits: u32,
    pub hadamard_bits: u32,
    /// Rel-L2 of the served output vs the f64 direct oracle.
    pub rel_err: f64,
}

/// Drift-monitor knobs.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// Sample every `stride`-th span (`span % stride == seed % stride`);
    /// 0 disables sampling entirely.
    pub stride: u64,
    /// Seed folded into the sampling offset so different deployments
    /// don't all sample the same residue class.
    pub seed: u64,
    /// Width of one error window in (virtual) microseconds.
    pub window_us: u64,
    /// Retained windows per layer series.
    pub windows: usize,
    /// Budget slack: alert when a window's mean rel-L2 exceeds
    /// `tuned_err × headroom`.
    pub headroom: f64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            stride: 16,
            seed: 0,
            window_us: 1_000_000,
            windows: 8,
            headroom: 4.0,
        }
    }
}

/// Fixed-point ppb conversion, saturating (a wildly divergent output
/// must clamp, not wrap).
pub fn rel_err_to_ppb(rel_err: f64) -> u64 {
    let ppb = rel_err.max(0.0) * 1e9;
    if ppb >= 1e18 {
        1_000_000_000_000_000_000
    } else {
        ppb.round() as u64
    }
}

/// Per-layer identity captured from the first sample (reporting only).
#[derive(Clone, Debug)]
struct LayerMeta {
    m: usize,
    base: Base,
    weight_bits: u32,
    hadamard_bits: u32,
}

#[derive(Default, Debug)]
struct DriftState {
    /// Per-layer ppb error series, keyed by layer prefix.
    series: BTreeMap<String, TimeSeries>,
    meta: BTreeMap<String, LayerMeta>,
    /// `(layer, window index)` pairs that already alerted — one alert
    /// per violated window, not one per sample.
    alerted: BTreeSet<(String, u64)>,
    /// Per-layer alert counts.
    alerts_by_layer: BTreeMap<String, u64>,
    sampled: u64,
    alerts: u64,
}

/// Thread-safe drift monitor: budgets are immutable after
/// construction, all per-sample state sits behind one mutex (the
/// sampled path is `1/stride` of traffic, so contention is negligible).
#[derive(Debug)]
pub struct DriftMonitor {
    cfg: DriftConfig,
    /// Per-layer tuned rel-L2 anchor; `None` = report-only layer.
    budgets: BTreeMap<String, Option<f64>>,
    state: Mutex<DriftState>,
}

impl DriftMonitor {
    pub fn new(cfg: DriftConfig) -> DriftMonitor {
        assert!(cfg.headroom > 0.0, "headroom must be positive");
        DriftMonitor { cfg, budgets: BTreeMap::new(), state: Mutex::new(DriftState::default()) }
    }

    /// Budgets from a NetPlan's layers: v2 plans carry `tuned_err`,
    /// v1 layers map to `None` (report-only).
    pub fn from_netplan(cfg: DriftConfig, plan: &crate::tune::NetPlan) -> DriftMonitor {
        let mut dm = DriftMonitor::new(cfg);
        for l in &plan.layers {
            dm.set_budget(&l.layer, l.tuned_err);
        }
        dm
    }

    /// Set (or clear) one layer's tuned rel-L2 anchor.
    pub fn set_budget(&mut self, layer: &str, tuned_err: Option<f64>) {
        if let Some(e) = tuned_err {
            assert!(e.is_finite() && e >= 0.0, "tuned_err {e} out of domain");
        }
        self.budgets.insert(layer.to_string(), tuned_err);
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    /// True when no layer has a tuned anchor — series still record,
    /// but no alert can fire.
    pub fn report_only(&self) -> bool {
        self.budgets.values().all(Option::is_none)
    }

    /// The deterministic sampling rule: a pure function of the span ID,
    /// zero PRNG draws.
    pub fn should_sample(&self, span: u64) -> bool {
        self.cfg.stride > 0 && span % self.cfg.stride == self.cfg.seed % self.cfg.stride
    }

    /// One layer's alert ceiling in ppb, if it has a tuned anchor.
    pub fn budget_ppb(&self, layer: &str) -> Option<u64> {
        let tuned = (*self.budgets.get(layer)?)?;
        Some(rel_err_to_ppb(tuned * self.cfg.headroom))
    }

    /// Ingest one sampled span's shadow measurements at virtual time
    /// `at_us`. Returns the `DriftAlert` events the caller should stamp
    /// onto the span's trace (empty when every layer is within budget
    /// or report-only).
    pub fn observe(&self, _span: u64, at_us: u64, samples: &[DriftSample]) -> Vec<TraceKind> {
        let mut st = self.state.lock().unwrap();
        st.sampled += 1;
        let mut out = Vec::new();
        for s in samples {
            let ppb = rel_err_to_ppb(s.rel_err);
            let series = st.series.entry(s.layer.clone()).or_insert_with(|| {
                TimeSeries::new(
                    &format!("drift.{}.rel_err_ppb", s.layer),
                    self.cfg.window_us,
                    self.cfg.windows,
                )
            });
            series.record(at_us, ppb);
            let (win_index, win_mean) = {
                let w = series.current().expect("just recorded");
                (w.index, w.hist.mean())
            };
            st.meta.entry(s.layer.clone()).or_insert(LayerMeta {
                m: s.m,
                base: s.base,
                weight_bits: s.weight_bits,
                hadamard_bits: s.hadamard_bits,
            });
            let Some(budget_ppb) = self.budget_ppb(&s.layer) else { continue };
            let key = (s.layer.clone(), win_index);
            if win_mean > budget_ppb as f64 && !st.alerted.contains(&key) {
                st.alerted.insert(key);
                st.alerts += 1;
                *st.alerts_by_layer.entry(s.layer.clone()).or_insert(0) += 1;
                out.push(TraceKind::DriftAlert {
                    layer: s.layer.clone(),
                    m: s.m as u64,
                    base: s.base.name().to_string(),
                    weight_bits: u64::from(s.weight_bits),
                    hadamard_bits: u64::from(s.hadamard_bits),
                    rel_err_ppb: win_mean.round() as u64,
                    budget_ppb,
                });
            }
        }
        out
    }

    /// Spans sampled so far.
    pub fn sampled(&self) -> u64 {
        self.state.lock().unwrap().sampled
    }

    /// Budget-violation alerts emitted so far (one per violated
    /// `(layer, window)`).
    pub fn alerts(&self) -> u64 {
        self.state.lock().unwrap().alerts
    }

    /// Export into a [`MetricsRegistry`]: `drift.sampled` /
    /// `drift.alerts` counters plus every per-layer series family.
    pub fn export_metrics(&self, reg: &MetricsRegistry) {
        let st = self.state.lock().unwrap();
        reg.inc("drift.sampled", st.sampled);
        reg.inc("drift.alerts", st.alerts);
        for series in st.series.values() {
            series.export_metrics(reg);
        }
    }

    /// The `--drift-json` report: sampling rule, totals, and one entry
    /// per observed layer with its error statistics and budget.
    pub fn to_json(&self) -> String {
        let st = self.state.lock().unwrap();
        let offset = if self.cfg.stride > 0 { self.cfg.seed % self.cfg.stride } else { 0 };
        let mut layers = JsonArr::new();
        for (layer, series) in &st.series {
            let meta = &st.meta[layer];
            let total = series.total();
            let recent = series.merged();
            let mut obj = JsonObj::new()
                .str("layer", layer)
                .u64("m", meta.m as u64)
                .str("base", meta.base.name())
                .u64("weight_bits", u64::from(meta.weight_bits))
                .u64("hadamard_bits", u64::from(meta.hadamard_bits))
                .u64("samples", total.count())
                .f64("mean_rel_err", total.mean() / 1e9, 9)
                .f64("max_rel_err", total.max().unwrap_or(0) as f64 / 1e9, 9)
                .f64("recent_mean_rel_err", recent.mean() / 1e9, 9)
                .u64("windows", series.windows().len() as u64);
            if let Some(tuned) = self.budgets.get(layer).copied().flatten() {
                obj = obj
                    .f64("tuned_err", tuned, 9)
                    .f64("budget", tuned * self.cfg.headroom, 9);
            }
            obj = obj.u64(
                "alerts",
                st.alerts_by_layer.get(layer).copied().unwrap_or(0),
            );
            layers = layers.item(&obj.finish());
        }
        JsonObj::new()
            .u64("stride", self.cfg.stride)
            .u64("offset", offset)
            .u64("window_us", self.cfg.window_us)
            .f64("headroom", self.cfg.headroom, 3)
            .bool("report_only", self.report_only())
            .u64("sampled", st.sampled)
            .u64("alerts", st.alerts)
            .raw("layers", &layers.finish())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(layer: &str, rel_err: f64) -> DriftSample {
        DriftSample {
            layer: layer.into(),
            m: 4,
            base: Base::Legendre,
            weight_bits: 8,
            hadamard_bits: 9,
            rel_err,
        }
    }

    #[test]
    fn sampling_rule_is_a_pure_stride_over_spans() {
        let dm = DriftMonitor::new(DriftConfig { stride: 8, seed: 3, ..DriftConfig::default() });
        let picked: Vec<u64> = (1..=32).filter(|&s| dm.should_sample(s)).collect();
        assert_eq!(picked, vec![3, 11, 19, 27]);
        let off = DriftMonitor::new(DriftConfig { stride: 0, ..DriftConfig::default() });
        assert!((1..=32).all(|s| !off.should_sample(s)));
    }

    #[test]
    fn within_budget_traffic_never_alerts() {
        let mut dm = DriftMonitor::new(DriftConfig::default());
        dm.set_budget("stem", Some(0.005));
        for span in 0..20u64 {
            let evs = dm.observe(span, span * 1000, &[sample("stem", 0.004)]);
            assert!(evs.is_empty(), "0.004 < 0.005*4 must not alert");
        }
        assert_eq!(dm.alerts(), 0);
        assert_eq!(dm.sampled(), 20);
    }

    #[test]
    fn budget_violation_alerts_once_per_window() {
        let cfg = DriftConfig { window_us: 1000, windows: 4, headroom: 2.0, ..DriftConfig::default() };
        let mut dm = DriftMonitor::new(cfg);
        dm.set_budget("stem", Some(0.001));
        // Window 0: three violating samples → exactly one alert.
        let mut alerts = 0;
        for i in 0..3u64 {
            alerts += dm.observe(i, i * 10, &[sample("stem", 0.01)]).len();
        }
        assert_eq!(alerts, 1);
        // Next window violates again → a second alert.
        let evs = dm.observe(9, 1500, &[sample("stem", 0.01)]);
        assert_eq!(evs.len(), 1);
        match &evs[0] {
            TraceKind::DriftAlert { layer, budget_ppb, rel_err_ppb, .. } => {
                assert_eq!(layer, "stem");
                assert_eq!(*budget_ppb, 2_000_000);
                assert!(*rel_err_ppb > *budget_ppb);
            }
            other => panic!("expected DriftAlert, got {other:?}"),
        }
        assert_eq!(dm.alerts(), 2);
    }

    #[test]
    fn unbudgeted_layers_are_report_only() {
        let dm = DriftMonitor::new(DriftConfig::default());
        assert!(dm.report_only());
        let evs = dm.observe(1, 0, &[sample("stem", 123.0)]);
        assert!(evs.is_empty(), "report-only layers must never alert");
        assert_eq!(dm.alerts(), 0);
        assert_eq!(dm.sampled(), 1);
        // …but the series still records for the report.
        let report = dm.to_json();
        assert!(report.contains("\"report_only\": true"), "{report}");
        assert!(report.contains("\"layer\": \"stem\""), "{report}");
    }

    #[test]
    fn ppb_conversion_saturates_and_rounds() {
        assert_eq!(rel_err_to_ppb(0.0025), 2_500_000);
        assert_eq!(rel_err_to_ppb(0.0), 0);
        assert_eq!(rel_err_to_ppb(-1.0), 0);
        assert_eq!(rel_err_to_ppb(1e30), 1_000_000_000_000_000_000);
    }

    #[test]
    fn report_is_parseable_and_carries_budgets() {
        let mut dm = DriftMonitor::new(DriftConfig::default());
        dm.set_budget("stem", Some(0.002));
        dm.observe(16, 0, &[sample("stem", 0.001)]);
        let doc = crate::tune::json::parse(&dm.to_json()).unwrap();
        assert_eq!(doc.get("sampled").and_then(|j| j.as_u64()), Some(1));
        assert_eq!(doc.get("alerts").and_then(|j| j.as_u64()), Some(0));
        let layers = doc.get("layers").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(layers.len(), 1);
        let stem = &layers[0];
        assert_eq!(stem.get("samples").and_then(|j| j.as_u64()), Some(1));
        let budget = stem.get("budget").and_then(|j| j.as_f64()).unwrap();
        assert!((budget - 0.008).abs() < 1e-9, "budget {budget}");
    }

    #[test]
    fn export_metrics_publishes_counters_and_series() {
        let mut dm = DriftMonitor::new(DriftConfig::default());
        dm.set_budget("stem", Some(0.0001));
        dm.observe(0, 0, &[sample("stem", 0.01)]);
        let reg = MetricsRegistry::new();
        dm.export_metrics(&reg);
        assert_eq!(reg.counter("drift.sampled"), 1);
        assert_eq!(reg.counter("drift.alerts"), 1);
        assert_eq!(reg.histogram("drift.stem.rel_err_ppb").unwrap().count(), 1);
    }
}
